"""Bullion quickstart: write a wide ML table, query it through the lazy
``Dataset`` API, scale the same plan to a sharded directory (pipelining its
I/O with ``io_depth=``), delete a user GDPR-style, audit the physical
erasure, compact + recluster the file into a fresh sharded dataset with
``Dataset.write_to``, profile a scan with the observability layer
(``explain(analyze=True)``, ``Dataset.profile``, the metrics registry),
read the same shards back from an (in-process) object store via
``bullion://`` URIs, then stand them up behind the multi-tenant dataset
service
(``repro.serve.DatasetServer``: prepared plans, admission control, and
bloom-sketch point lookups on unclustered columns), and finally survive
injected bit rot on the self-healing read path (decode-time checksum
verification, page quarantine + skip degradation, in-process repair
pickup).

    PYTHONPATH=src python examples/quickstart.py

I/O knobs (all optional; ``Dataset`` terminals default to the serial
per-group read path):

* ``io_depth=`` on every terminal (``to_table``/``to_batches``/
  ``scan_batches``/``row_ids``/``count_rows``/``write_to``) — how many
  tasks' byte ranges the I/O scheduler may stage ahead of decode.
  ``io_depth=2`` double-buffers (group k+1's preads overlap group k's
  decode); higher depths also let one pread span that many row groups.
* ``BullionLoader(prefetch=)`` — batches-ahead for training iteration; any
  value > 1 also drives the same scheduler so the next groups' reads
  overlap the current decode (the loader has always overlapped I/O with
  consumption, so its default ``prefetch=2`` pipelines out of the box;
  ``prefetch=1`` falls back to serial per-group reads).
* ``BULLION_COALESCE_GAP`` env / ``dataset(coalesce_gap=)`` — the hole
  budget (bytes) for merging nearby preads; holes actually read are
  accounted in ``IOStats.wasted_bytes``.
* repeated ``dataset()`` opens of unchanged shards are served by the
  process-wide footer cache (``IOStats.footer_cache_hits``) — no footer
  pread, no re-parse.

Observability (all off by default; disabled tracing allocates nothing on
the hot path):

* ``Dataset.explain(analyze=True)`` — executes the plan under a scoped
  tracer and appends per-stage wall time / rows / pages / bytes plus the
  exact ``IOStats`` delta the run charged.
* ``Dataset.profile("trace.json")`` — same execution, exported as Chrome
  ``trace_event`` JSON; open it in Perfetto (ui.perfetto.dev) or
  chrome://tracing to see preads overlap decode on the timeline.
* ``BULLION_TRACE=trace.json`` env — trace a whole process (any workload,
  no code changes) and export at exit; ``benchmarks/run.py --trace`` does
  the same for the benchmark suites.
* ``repro.obs.metrics.snapshot()`` — the always-on process-wide counters
  (retired ``IOStats`` fields) and histograms (coalesced-run sizes,
  scheduler read-ahead depth; pread/decode latency while tracing).
"""

import os
import sys
import tempfile

import numpy as np

from repro.core import (BullionWriter, ColumnSpec, Compliance, QuantMode,
                        QuantSpec, delete_rows, verify_deleted)
from repro.core.sparse_delta import SyntheticClickSeq
from repro.dataset import dataset
from repro.scan import C


def write_shard(path, n, seed=0):
    """Sparse click sequences (§2.2), BF16-quantized dense features (§2.4),
    strings, all cascade-encoded (§2.6), with write-time zone maps.

    Each 1024-row group is split into 8 pages of ``page_rows=128`` (the
    derived default is rows_per_group/8 floored at 1024 rows, so these tiny
    demo groups would stay single-page without the explicit override;
    ``BULLION_PAGE_ROWS`` overrides fleet-wide). Every page carries its own
    zone map and is encoded from its own statistics, so scans can skip
    *pages inside* a surviving group and homogeneous spans get tighter
    encodings."""
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("user_id", "int64"),
        ColumnSpec("clk_seq_cids", "list<int64>", sparse_delta=True),
        ColumnSpec("ctr_7d", "float32", quant=QuantSpec(QuantMode.BF16)),
        ColumnSpec("device", "string"),
    ]
    table = {
        "user_id": np.sort(rng.integers(seed * 1000, (seed + 1) * 1000, n)),
        "clk_seq_cids": SyntheticClickSeq(seq_len=128).generate(n),
        "ctr_7d": rng.random(n).astype(np.float32),
        "device": [b"ios" if i % 3 else b"android" for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=1024, page_rows=128)
    w.write_table(table)
    stats = w.close()
    raw = sum(np.asarray(v).nbytes if isinstance(v, np.ndarray)
              else sum(len(x) if isinstance(x, bytes) else x.nbytes for x in v)
              for v in table.values())
    return stats, raw


def main(out_dir=None):
    """``out_dir`` keeps the written datasets around (CI runs fsck over
    them afterwards); default is a throwaway temp directory."""
    td = out_dir if out_dir is not None else tempfile.mkdtemp()
    os.makedirs(td, exist_ok=True)
    path = os.path.join(td, "ads.bln")
    n = 10_000
    stats, raw = write_shard(path, n)
    print(f"wrote {stats['rows']} rows, {stats['groups']} groups -> "
          f"{os.path.getsize(path):,} bytes ({raw / os.path.getsize(path):.1f}x "
          "smaller than raw)")

    # --- lazy plans (§2.3 projection + zone-map pushdown): chaining is free,
    # I/O happens at the terminal, and the optimizer prunes row groups the
    # predicate provably cannot match ---------------------------------------
    with dataset(path) as ds:
        hot = (ds.where(C("ctr_7d") >= 0.99)
                 .select(["user_id", "ctr_7d"]))
        print(hot.explain())
        tbl = hot.to_table()
        st = ds.stats
        print(f"hot-CTR plan: {len(tbl['user_id'])} rows, "
              f"io={st.bytes_read:,}B in {st.preads} preads, "
              f"{st.bytes_pruned:,}B proven prunable, "
              f"metadata parse {st.metadata_seconds * 1e3:.2f} ms")
        # head() pushes the limit into planning: trailing groups never read
        first = ds.select(["device"]).head(5).to_table()
        print(f"first 5 devices: {first['device']}")
        # user_id is write-time sorted, so a point lookup prunes to the one
        # group whose zone map admits it — and, inside that group, to the
        # one page per column whose *page* zone map admits it. Page-granular
        # pruning only bites on clustered columns like this one: on an
        # unclustered column every page's [min, max] spans the whole domain
        # and nothing inside the group can be skipped (recluster with
        # write_to(sort_by=...) first).
        uid = int(ds.select(["user_id"]).head(1).to_table()["user_id"][0])
        point = ds.where(C("user_id") == uid).select(["ctr_7d"])
        phys = point.physical_plan()
        print(f"point lookup user {uid}: {len(phys.tasks)}/{phys.groups_total} "
              f"groups read, {phys.pages_total - phys.pages_pruned}/"
              f"{phys.pages_total} pages read, "
              f"{phys.bytes_pruned:,}B pruned by zone maps")

    # --- the same plan runs unchanged over a sharded directory --------------
    shard_dir = os.path.join(td, "shards")
    os.makedirs(shard_dir)
    for s in range(4):
        write_shard(os.path.join(shard_dir, f"part-{s:04d}.bln"),
                    n // 4, seed=s)
    with dataset(shard_dir) as ds:
        q = ds.where(C("ctr_7d") >= 0.99).select(["user_id", "ctr_7d"])
        tbl = q.to_table()
        print(f"sharded dataset: {ds.n_shards} shards, {ds.num_rows} rows, "
              f"same plan -> {len(tbl['user_id'])} hot rows, "
              f"{ds.stats.bytes_pruned:,}B pruned")
    # pipelined I/O: the same wide projection, serial vs io_depth=4 — the
    # scheduler batches every surviving page range across group boundaries
    # and overlaps the next groups' preads with decode. Identical results;
    # repeated opens also hit the process-wide footer cache (no re-parse).
    wide_cols = ["user_id", "clk_seq_cids", "ctr_7d", "device"]
    with dataset(shard_dir) as ds:
        ds.select(wide_cols).to_table()
        serial_preads = ds.stats.preads
    with dataset(shard_dir) as ds:
        ds.select(wide_cols).to_table(io_depth=4)
        st = ds.stats
        print(f"pipelined wide read (io_depth=4): {serial_preads} serial "
              f"preads -> {st.preads}, "
              f"{st.coalesced_preads} page reads coalesced, "
              f"{st.wasted_bytes}B hole bytes, "
              f"{st.footer_cache_hits}/{ds.n_shards} footers from cache")

    # --- object storage: the same plan over bullion:// URIs -----------------
    # shards need never touch local disk: point the process at an object
    # store (``configure_object_store()`` or ``BULLION_OBJECT_STORE``) and
    # pass ``bullion://bucket/key`` URIs. The storage backend turns each
    # coalesced run into an S3-style ranged GET with retry + capped
    # exponential backoff, ``io_depth=`` bounds concurrent in-flight ranges
    # (batched on a shared event loop), and footers are cached process-wide
    # with ETag/length validation. Here the in-process fake object store the
    # test suite uses fronts the temp dir over real HTTP.
    from repro.core.backend import configure_object_store
    from repro.testing import FakeObjectStore
    with FakeObjectStore(td) as objstore:
        configure_object_store(objstore.endpoint)
        try:
            uris = [f"bullion://shards/part-{s:04d}.bln" for s in range(4)]
            with dataset(uris) as ds:
                tbl = ds.where(C("ctr_7d") >= 0.99) \
                    .select(["user_id", "ctr_7d"]).to_table(io_depth=4)
                st = ds.stats
            print(f"object-store read: {len(tbl['user_id'])} hot rows over "
                  f"{st.backend_fetches} ranged GETs "
                  f"({st.backend_retries} retried, "
                  f"{st.backend_wasted_bytes}B hole bytes), "
                  f"{st.preads} local preads")
        finally:
            configure_object_store(None)

    # --- GDPR delete (§2.1): locate via a raw-row-space plan, physically
    # erase in place, audit -------------------------------------------------
    with dataset(path) as ds:
        victim = int(ds.select(["user_id"]).to_table()["user_id"][n // 2])
        rows = ds.where(C("user_id") == victim).drop_deleted(False).row_ids()
    d = delete_rows(path, rows, Compliance.LEVEL2)
    audit = verify_deleted(path, "user_id", [victim])
    print(f"deleted user {victim} ({d.rows_deleted} rows): "
          f"data rewrite {d.bytes_rewritten_data:,}B vs full rewrite "
          f"{d.bytes_full_rewrite:,}B ({d.bytes_full_rewrite / max(d.bytes_rewritten_data, 1):.0f}x less), "
          f"audit visible={audit['visible_rows']} raw={audit['raw_occurrences']}")

    with dataset(path) as ds:
        assert ds.where(C("user_id") == victim).count_rows() == 0
    print("post-delete read OK — the file is still fully queryable")

    # --- compact + recluster (the write half of the loop): write_to executes
    # the plan, purges deleted rows physically, re-sorts so the CTR zone maps
    # prune, re-encodes each chunk (stats-advised cascade), reshards --------
    compact_dir = os.path.join(td, "ads_compacted")
    with dataset(path) as ds:
        pre = ds.where(C("ctr_7d") >= 0.99).select(["user_id"]) \
            .physical_plan()
        # page_rows= carries through the sink too (default: the input's
        # budget); after the sort_by recluster the CTR pages are monotone,
        # so threshold reads prune to a page-level prefix
        res = ds.write_to(compact_dir, shard_rows=4096, sort_by="ctr_7d",
                          parallelism=2, page_rows=128)
    print(f"compacted -> {res.shards} shard(s), {res.rows} rows, "
          f"{res.bytes_written:,}B (reclustering trades click-seq "
          "compression locality for CTR pruning — sort order is the "
          "dominant lever for both)")
    for p in res.paths:
        a = verify_deleted(p, "user_id", [victim])
        assert a["visible_rows"] == 0 and a["raw_occurrences"] == 0
    print("compacted shards audit clean: deleted user is physically absent")
    with dataset(compact_dir) as ds:
        post = ds.where(C("ctr_7d") >= 0.99).select(["user_id"]) \
            .physical_plan()
        n_hot = ds.where(C("ctr_7d") >= 0.99).count_rows()
    print(f"hot-CTR probe after recluster: {n_hot} rows, "
          f"{post.bytes_pruned:,}B pruned (was {pre.bytes_pruned:,}B "
          "on the unclustered input)")

    # --- observability: what did that scan actually do? ---------------------
    # explain(analyze=True) executes the plan under a scoped tracer: the
    # static plan tree plus per-stage calls/time/attributes and the exact
    # IOStats delta the run charged. profile() exports the same spans as
    # Chrome trace JSON for Perfetto; BULLION_TRACE=path does it process-
    # wide with zero code changes.
    with dataset(compact_dir) as ds:
        print(ds.where(C("ctr_7d") >= 0.99).select(["user_id", "ctr_7d"])
                .explain(analyze=True, io_depth=2))
    trace_path = os.path.join(td, "scan-trace.json")
    with dataset(compact_dir) as ds:
        prof = ds.select(wide_cols).profile(trace_path, io_depth=4)
    print(f"profile: {len(prof.spans)} spans -> {trace_path} "
          "(open in ui.perfetto.dev or chrome://tracing)")
    from repro.obs import metrics
    snap = metrics.snapshot()
    io_counters = {k: v for k, v in snap.items()
                   if k.startswith("bullion.io.") and isinstance(v, (int, float))}
    print(f"process-wide metrics (retired IOStats): {io_counters}")

    # --- serve: the feature-serving read pattern ----------------------------
    # DatasetServer fronts the shards for many concurrent point probes:
    # prepared plans are cached by (dataset, canonical fingerprint), all
    # sessions share one parsed footer + one fd per shard, and per-tenant
    # io_depth budgets bound a noisy tenant's concurrent preads.
    from repro.serve import DatasetServer
    with dataset(shard_dir) as ds:
        uids = ds.select(["user_id"]).to_table()["user_id"]
        probe_uid = int(uids[0])
        # an id inside the stored [min, max] but absent from the table —
        # zone maps admit every group holding its range, so only the
        # write-time per-chunk bloom sketches (format v3) can refute them.
        # This is the everyday serving miss: a churned / unknown user.
        present = set(int(u) for u in uids)
        missing_uid = next(v for v in range(int(uids.min()), int(uids.max()))
                           if v not in present)
    with DatasetServer({"ads": shard_dir}, max_workers=4) as srv:
        res = srv.query("ads", where=C("user_id") == probe_uid,
                        columns=["user_id", "ctr_7d"], tenant="ranker")
        hit = srv.query("ads", where=C("user_id") == probe_uid,
                        columns=["user_id", "ctr_7d"], tenant="ranker")
        miss = srv.query("ads", where=C("user_id") == missing_uid,
                         columns=["user_id", "ctr_7d"], tenant="ranker")
        st = srv.stats()
        io = st["datasets"]["ads"]["io"]
        print(f"served point probe user {probe_uid}: {res.rows} row(s) in "
              f"{res.wall_seconds * 1e3:.2f} ms (repeat: cache_hit="
              f"{hit.cache_hit}, {hit.wall_seconds * 1e3:.2f} ms), plan "
              f"cache {st['plan_cache']['hits']} hit(s) / "
              f"{st['plan_cache']['misses']} miss(es)")
        print(f"absent user {missing_uid}: {miss.rows} rows, "
              f"{io['groups_pruned_sketch']} group(s) refuted by bloom "
              "sketch without touching a data page")
        print(srv.explain("ads", where=C("user_id") == missing_uid,
                          columns=["user_id", "ctr_7d"]))
        # the same server speaks AF_UNIX for out-of-process clients:
        # srv.serve() -> socket path; repro.serve.ServeClient(path).query(...)

    # --- production telemetry: query log, wire traces, metrics, fsck --------
    # Every served query leaves one structured QueryRecord (tenant, plan
    # fingerprint, cache hit, stage timings, the exact IOStats delta).
    # BULLION_QUERY_LOG=path mirrors records to a JSONL sink; BULLION_SLOW_MS
    # promotes any query over the threshold to carry its full span tree
    # (threshold 0 here so the demo always shows one). A traced ServeClient
    # stamps its id into each request frame; the server's spans ride back on
    # the response and profile() merges both sides into one Chrome trace.
    from repro.obs.querylog import QueryLog
    from repro.serve import ServeClient
    with DatasetServer({"ads": shard_dir},
                       query_log=QueryLog(slow_seconds=0.0)) as srv:
        sock = srv.serve()
        serve_trace = os.path.join(td, "serve-trace.json")
        with ServeClient(sock, trace=True) as cli:
            cli.query("ads", where=C("user_id") == probe_uid,
                      columns=["user_id", "ctr_7d"])
            cli.query("ads", columns=["device"], head=3)
            prof = cli.profile(serve_trace)
        rec = srv.query_log.records()[-1]
        print(f"query log: {srv.query_log.summary()['total']} record(s); "
              f"last: {rec!r}")
        print(f"slow-query promotion: {len(rec.spans or [])} span(s) "
              f"attached to the record (stages: {sorted(rec.stages)})")
        print(f"merged client+server profile: {len(prof.spans)} span(s) "
              f"under trace id {prof.trace_id} -> {serve_trace}")
        queries_line = next(
            ln for ln in srv.metrics_text().splitlines()
            if ln.startswith("bullion_serve_queries"))
        print(f"prometheus exposition ready to scrape: {queries_line!r} "
              "(full text via srv.metrics_text() or the `metrics` wire op)")

    # the bullion CLI reads it all back: `inspect` dumps a shard's anatomy,
    # `fsck` re-verifies page checksums, Merkle bounds, deletion vectors,
    # zone maps and sketches (exit 0 = clean, 1 = corruption, 2 = unusable
    # torn file; --json emits per-category counts for machines)
    from repro import cli as bullion_cli
    rc = bullion_cli.main(["fsck", "-v", path, shard_dir, compact_dir])
    assert rc == 0, "fsck found corruption in freshly written datasets"
    print("bullion fsck: every page checksum, Merkle bound, deletion "
          "vector, zone map and sketch verified (exit 0)")

    # --- durability: the self-healing read path ------------------------------
    # fsck is the offline story; the live reader defends itself too.
    # Decode-time verification (BULLION_VERIFY=off|sample|full, default
    # sample: each page hashed once per cached footer) checks page bytes
    # against the footer checksums *before* decode; a mismatch gets one
    # re-read, and only a persistent mismatch quarantines the page.
    # BULLION_ON_CORRUPT picks the failure mode: raise (default, names
    # shard/group/page), skip (drop the page's rows, exact degraded-row
    # accounting), or mask (shape-stable zero fill). Writes are crash-safe:
    # shards materialize under path+".tmp" and os.replace() in after fsync,
    # so kill -9 mid-write leaves nothing dataset() can see. This demo
    # corrupts a copy in durability/ — deliberately outside the
    # directories fsck'd above.
    from repro.core import integrity
    from repro.core.footer import read_footer
    dur_dir = os.path.join(td, "durability")
    os.makedirs(dur_dir, exist_ok=True)
    dur = os.path.join(dur_dir, "flaky.bln")
    write_shard(dur, n // 10)
    fv, _ = read_footer(dur)
    off_b, size_b = fv.page_extent(0)
    with open(dur, "r+b") as f:                      # simulated bit rot
        f.seek(off_b + size_b // 2)
        bit = f.read(1)
        f.seek(off_b + size_b // 2)
        f.write(bytes([bit[0] ^ 0xFF]))
    integrity.set_verify_policy("full")
    integrity.set_corruption_policy("skip")
    try:
        with dataset(dur) as ds:
            tbl = ds.select(["user_id"]).to_table()
            st = ds.stats
        q = integrity.QUARANTINE.summary()["quarantined_pages"]
        print(f"bit rot survived: served {len(tbl['user_id'])} rows, "
              f"dropped {st.degraded_rows} from {q} quarantined page(s)")
        write_shard(dur, n // 10)                    # out-of-band repair
        with dataset(dur) as ds:
            healed = len(ds.select(["user_id"]).to_table()["user_id"])
        assert healed == n // 10
        print(f"repair picked up without restart: {healed} rows clean "
              "(footer cache revalidated, quarantine self-invalidated)")
    finally:
        integrity.set_verify_policy(None)
        integrity.set_corruption_policy(None)
        integrity.QUARANTINE.clear()


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else None)
