"""Bullion quickstart: write a wide ML table, project it, quantize it,
delete a user GDPR-style, and audit the physical erasure.

    PYTHONPATH=src python examples/quickstart.py
"""

import os
import tempfile

import numpy as np

from repro.core import (BullionReader, BullionWriter, ColumnSpec, Compliance,
                        QuantMode, QuantSpec, delete_rows, verify_deleted)
from repro.core.sparse_delta import SyntheticClickSeq


def main():
    td = tempfile.mkdtemp()
    path = os.path.join(td, "ads.bln")
    rng = np.random.default_rng(0)
    n = 10_000

    # --- write: sparse click sequences (§2.2), BF16-quantized dense features
    # (§2.4), strings, all cascade-encoded (§2.6) -----------------------------
    schema = [
        ColumnSpec("user_id", "int64"),
        ColumnSpec("clk_seq_cids", "list<int64>", sparse_delta=True),
        ColumnSpec("ctr_7d", "float32", quant=QuantSpec(QuantMode.BF16)),
        ColumnSpec("device", "string"),
    ]
    table = {
        "user_id": np.sort(rng.integers(0, 1000, n)),
        "clk_seq_cids": SyntheticClickSeq(seq_len=128).generate(n),
        "ctr_7d": rng.random(n).astype(np.float32),
        "device": [b"ios" if i % 3 else b"android" for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=1024)
    w.write_table(table)
    stats = w.close()
    raw = sum(np.asarray(v).nbytes if isinstance(v, np.ndarray)
              else sum(len(x) if isinstance(x, bytes) else x.nbytes for x in v)
              for v in table.values())
    print(f"wrote {stats['rows']} rows, {stats['groups']} groups -> "
          f"{os.path.getsize(path):,} bytes ({raw / os.path.getsize(path):.1f}x "
          "smaller than raw)")

    # --- wide-table projection (§2.3): read 2 of 4 columns -------------------
    with BullionReader(path) as r:
        for tbl in r.project(["user_id", "ctr_7d"], groups=[0]):
            print(f"projected group 0: {len(tbl['user_id'])} rows, "
                  f"io={r.stats.bytes_read:,}B in {r.stats.preads} preads, "
                  f"metadata parse {r.stats.metadata_seconds * 1e3:.2f} ms")
            break

    # --- GDPR delete (§2.1): physically erase one user's rows in place -------
    with BullionReader(path) as r:
        victim = int(r.read_column("user_id")[n // 2])
        rows = r.find_rows("user_id", [victim])
    d = delete_rows(path, rows, Compliance.LEVEL2)
    audit = verify_deleted(path, "user_id", [victim])
    print(f"deleted user {victim} ({d.rows_deleted} rows): "
          f"data rewrite {d.bytes_rewritten_data:,}B vs full rewrite "
          f"{d.bytes_full_rewrite:,}B ({d.bytes_full_rewrite / max(d.bytes_rewritten_data, 1):.0f}x less), "
          f"audit visible={audit['visible_rows']} raw={audit['raw_occurrences']}")

    with BullionReader(path) as r:
        assert not (r.read_column("user_id") == victim).any()
    print("post-delete read OK — the file is still fully queryable")


if __name__ == "__main__":
    main()
