"""§2.5 multimodal pipeline: dual meta/media tables, quality-aware presorted
layout, and a quality-filtered sequential read feeding a training loop.

  PYTHONPATH=src python examples/multimodal_pipeline.py
"""

import os
import tempfile

import numpy as np

from repro.core import (MediaStore, MultimodalSample, quality_filtered_read,
                        write_multimodal_dataset)


def main():
    td = tempfile.mkdtemp()
    meta, media = os.path.join(td, "meta.bln"), os.path.join(td, "media.bin")
    rng = np.random.default_rng(0)

    samples = [MultimodalSample(
        text=b"a video about topic %d" % (i % 50),
        quality=float(rng.beta(2, 5)),                 # skewed quality scores
        embedding=rng.normal(size=128).astype(np.float32),
        frames=rng.integers(0, 256, 512, dtype=np.uint8).tobytes(),  # inlined
        media_key=i) for i in range(5000)]
    stats = write_multimodal_dataset(meta, media, samples, rows_per_group=256)
    print(f"meta table: {stats['rows']} rows / {stats['groups']} groups "
          f"({os.path.getsize(meta):,}B), media table {os.path.getsize(media):,}B")

    # training reads only the top-10% quality samples — a sequential prefix
    tables, io = quality_filtered_read(meta, ["text", "quality", "embedding",
                                              "frames"], top_fraction=0.10)
    n = sum(len(t["quality"]) for t in tables)
    print(f"top-10% read: {n} rows in {io.preads} preads / {io.bytes_read:,}B "
          "(sequential prefix — no scattered I/O)")

    # full-size media is an explicit lookup via the media_ref index
    blobs = MediaStore(media).read([17, 42])
    print(f"full-size media fetch: {len(blobs)} objects, "
          f"{sum(len(b) for b in blobs.values()):,}B")


if __name__ == "__main__":
    main()
