"""End-to-end driver: train a ~100M-param LM from a Bullion-backed corpus,
with checkpointing/auto-resume. This is the deliverable-(b) training example;
the same code path lowers the full-size configs on the production mesh via
repro.launch.dryrun.

  # quick CPU demo (reduced width, a few hundred steps):
  PYTHONPATH=src python examples/train_lm.py --steps 300

  # the ~100M configuration (slow on CPU; sized for a single accelerator):
  PYTHONPATH=src python examples/train_lm.py --full --steps 300
"""

import argparse
import sys

from repro.launch.train import main as train_main


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--full", action="store_true",
                    help="~100M params (d_model=768) instead of the CPU demo")
    ap.add_argument("--data", default="/tmp/bullion_lm_example")
    ap.add_argument("--ckpt", default="/tmp/bullion_ckpt_example")
    args = ap.parse_args()

    argv = ["--arch", "llama3.2-1b", "--smoke", "--steps", str(args.steps),
            "--batch", "8", "--seq", "128",
            "--data", args.data, "--ckpt", args.ckpt,
            "--ckpt-every", "100", "--log-every", "25"]
    if args.full:
        # reduced llama family at d_model=768/12L ~= 100M params incl. embeds
        argv += ["--d-model", "768"]
    else:
        argv += ["--d-model", "128"]
    train_main(argv)


if __name__ == "__main__":
    main()
