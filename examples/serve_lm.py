"""Batched serving: load prompts from a Bullion table, prefill + greedy
decode with jitted steps, report throughput.

  PYTHONPATH=src python examples/serve_lm.py
"""

import os
import tempfile

import jax
import numpy as np

import repro.configs as configs
from repro.core import BullionReader, BullionWriter, ColumnSpec
from repro.models import zoo
from repro.serve import ServeEngine


def main():
    cfg = configs.get_smoke("llama3.2-1b").scaled(compute_dtype="float32")
    model = zoo.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # prompts live in a Bullion table (the §2.3 projection path feeds serving
    # just like training)
    td = tempfile.mkdtemp()
    path = os.path.join(td, "prompts.bln")
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 24).astype(np.int32)
               for _ in range(8)]
    w = BullionWriter(path, [ColumnSpec("prompt", "list<int32>")],
                      rows_per_group=8)
    w.write_table({"prompt": prompts})
    w.close()

    with BullionReader(path) as r:
        batch = np.stack(r.read_column("prompt")).astype(np.int32)

    eng = ServeEngine(model, params, max_seq=96)
    out = eng.generate(batch, max_new_tokens=32)
    print(f"batch={batch.shape[0]} prompt_len={batch.shape[1]}")
    print(f"prefill {out['prefill_s'] * 1e3:.1f} ms, "
          f"decode {out['decode_tok_per_s']:,.0f} tok/s")
    print("first continuation:", out["tokens"][0][:16].tolist())


if __name__ == "__main__":
    main()
