"""§2.6 / Table 2 reproduction: cascading encoding vs every single static
encoding across representative ML column distributions. The cascade should
match or beat the best single encoding on each distribution (that is its
entire job)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import CostWeights, EncodeContext, choose_encoding, decode_blob
from repro.core.encodings import BY_NAME, encode_array


def _distributions(rng):
    return {
        "ids_small_range": rng.integers(0, 1000, 65536).astype(np.int64),
        "timestamps": (np.arange(65536) * 1000 +
                       rng.integers(0, 50, 65536)).astype(np.int64),
        "categorical_runs": np.repeat(
            rng.integers(0, 30, 2048), 32).astype(np.int64),
        "mostly_null_ids": np.where(rng.random(65536) < 0.03,
                                    rng.integers(1, 1 << 40, 65536),
                                    0).astype(np.int64),
        "decimal_prices": np.round(
            rng.gamma(2.0, 10.0, 65536), 2).astype(np.float64),
        "embeddings": np.tanh(rng.normal(size=65536)).astype(np.float32),
        "click_labels": (rng.random(65536) < 0.02),
    }


def run(report):
    rng = np.random.default_rng(0)
    singles = ("trivial", "fixed_bit_width", "varint", "rle", "dictionary",
               "for", "mainly_constant", "bitshuffle", "chunked", "xor_float",
               "alp_decimal", "sparse_bool")
    for name, arr in _distributions(rng).items():
        ctx = EncodeContext()
        t0 = time.perf_counter()
        blob = encode_array(arr, ctx)
        t_enc = time.perf_counter() - t0
        t0 = time.perf_counter()
        out = decode_blob(blob)
        t_dec = time.perf_counter() - t0
        assert np.array_equal(out, arr), name
        cascade_ratio = arr.nbytes / len(blob)

        best_single, best_ratio = "trivial", 0.0
        for enc_name in singles:
            enc = BY_NAME[enc_name]
            if not enc.applicable(arr, ctx):
                continue
            try:
                b = enc.encode(arr, EncodeContext(candidates=(enc_name,)))
            except Exception:
                b = None
            if b is not None and arr.nbytes / len(b) > best_ratio:
                best_single, best_ratio = enc_name, arr.nbytes / len(b)

        chosen = choose_encoding(arr, EncodeContext())
        report(f"cascade/ratio/{name}", cascade_ratio,
               f"{cascade_ratio:.1f}x via {chosen} "
               f"(best single: {best_single} {best_ratio:.1f}x) "
               f"enc {arr.nbytes / t_enc / 1e6:.0f}MB/s "
               f"dec {arr.nbytes / t_dec / 1e6:.0f}MB/s")

    # Nimble-style objective: decode-time-weighted selection may pick a
    # faster (less compact) encoding
    arr = _distributions(rng)["categorical_runs"]
    fast_ctx = EncodeContext(weights=CostWeights(size=0.1, decode_time=100.0))
    report("cascade/objective_sensitivity", 1.0,
           f"size-weighted -> {choose_encoding(arr, EncodeContext())}, "
           f"decode-weighted -> {choose_encoding(arr, fast_ctx)}")
