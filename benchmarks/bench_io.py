"""Pipelined I/O benchmark: plan-wide byte-range scheduling vs serial reads.

A matrix of (wide projection, selective point probe) x (serial ``io_depth=1``,
pipelined ``io_depth=4``) over a multi-shard dataset. The wide projection is
the acceptance probe: the scheduler coalesces page ranges across row-group
boundaries and overlaps group k+1's preads with group k's decode, so it must
issue >= 2x fewer data preads than the serial per-group path with
*byte-identical* results, and the wall-clock delta is reported. Only the
pread ratio is gated: on smoke-sized, page-cache-warm tmp files the saved
syscalls are nearly free, so wall clock hovers around parity there (the
scheduler's win is batched submission on cold/real storage) — the CSV
records the time trajectory either way. Also probes
the process-wide footer cache: a repeated ``dataset()`` open of unchanged
shards parses nothing and issues zero footer preads
(``IOStats.footer_cache_hits``). A final backend-matrix probe serves the
same shards from an in-process fake object store under injected latency and
gates on the async batched backend overlapping >= 2 in-flight ranges and
beating serialized single-range fetches by >= 2x.

``BULLION_BENCH_SMOKE=1`` shrinks the dataset for CI smoke runs (same code
path and CSV schema, smaller constants)."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionWriter, ColumnSpec
from repro.dataset import clear_footer_cache, dataset
from repro.scan import C

IO_DEPTH = 4


def _write_shards(d: str, n_shards: int, rows_per_shard: int,
                  rows_per_group: int, n_payload: int) -> None:
    """Clustered ids + a wide block of float payload columns per shard."""
    os.makedirs(d)
    schema = [ColumnSpec("id", "int64")] + \
        [ColumnSpec(f"f{i:02d}", "float32") for i in range(n_payload)]
    for s in range(n_shards):
        rng = np.random.default_rng(s)
        w = BullionWriter(os.path.join(d, f"part-{s:04d}.bln"), schema,
                          rows_per_group=rows_per_group,
                          page_rows=max(1, rows_per_group // 4))
        w.write_table({
            "id": np.arange(s * rows_per_shard, (s + 1) * rows_per_shard,
                            dtype=np.int64),
            **{f"f{i:02d}": rng.random(rows_per_shard).astype(np.float32)
               for i in range(n_payload)},
        })
        w.close()


def run(report):
    smoke = bool(os.environ.get("BULLION_BENCH_SMOKE"))
    n_shards = 4 if smoke else 8
    rows_per_group = 512 if smoke else 2048
    groups_per_shard = 8
    rows_per_shard = rows_per_group * groups_per_shard
    n_payload = 6 if smoke else 12
    cols = ["id"] + [f"f{i:02d}" for i in range(n_payload)]

    with tempfile.TemporaryDirectory() as td:
        d = os.path.join(td, "shards")
        _write_shards(d, n_shards, rows_per_shard, rows_per_group, n_payload)

        # footer preads are 2 per shard on a cold cache; clear it before
        # each measured run so serial and pipelined pay the same metadata
        # cost and the pread ratio isolates the data path
        def measure(build, io_depth):
            """Cold-cache run: footer preads (2 per shard a plan opens) are
            identical between serial and pipelined, so raw pread deltas and
            the post-hoc ``- 2 * n_shards`` correction (full scans open
            every shard) both isolate the data path."""
            clear_footer_cache()
            t0 = time.perf_counter()
            with dataset(d) as ds:
                tbl = build(ds).to_table(io_depth=io_depth)
                st = ds.stats
            dt = time.perf_counter() - t0
            return tbl, st, st.preads - 2 * n_shards, dt

        # --- wide projection (every column, every row) ----------------------
        def wide(ds):
            return ds.select(cols)

        s_tbl, s_st, s_preads, s_dt = measure(wide, 1)
        p_tbl, p_st, p_preads, p_dt = measure(wide, IO_DEPTH)
        for c in cols:
            assert s_tbl[c].tobytes() == p_tbl[c].tobytes(), \
                f"pipelined wide projection differs from serial in {c!r}"
        assert p_preads * 2 <= s_preads, \
            f"pipelined must issue >=2x fewer data preads " \
            f"({s_preads} serial vs {p_preads} pipelined)"
        report("io/wide_preads_serial_vs_pipelined",
               s_preads / max(p_preads, 1),
               f"{s_preads} -> {p_preads} data preads at io_depth={IO_DEPTH} "
               f"({n_shards} shards x {groups_per_shard} groups, "
               f"{len(cols)} cols), wall {s_dt * 1e3:.1f}ms -> "
               f"{p_dt * 1e3:.1f}ms ({s_dt / max(p_dt, 1e-9):.2f}x)",
               preads=p_st.preads, bytes_read=p_st.bytes_read,
               coalesced_preads=p_st.coalesced_preads,
               wasted_bytes=p_st.wasted_bytes)
        report("io/wide_wall_clock_vs_serial", s_dt / max(p_dt, 1e-9),
               f"byte-identical output, {p_st.coalesced_preads} page reads "
               f"coalesced, {p_st.wasted_bytes}B hole bytes",
               preads=p_st.preads, bytes_read=p_st.bytes_read,
               coalesced_preads=p_st.coalesced_preads,
               wasted_bytes=p_st.wasted_bytes)

        # --- selective point probe (clustered ids -> zone-map pruning) ------
        victim = rows_per_shard + rows_per_group // 2

        def probe(ds):
            return ds.where(C("id") == victim).select(cols)

        ps_tbl, ps_st, _, ps_dt = measure(probe, 1)
        pp_tbl, pp_st, _, pp_dt = measure(probe, IO_DEPTH)
        for c in cols:
            assert ps_tbl[c].tobytes() == pp_tbl[c].tobytes(), \
                f"pipelined probe differs from serial in {c!r}"
        # the probe prunes to one shard, so raw preads (equal footer cost on
        # a cold cache) are the honest comparison here
        assert pp_st.preads <= ps_st.preads, \
            "pipelined probe must not issue more preads than serial"
        report("io/probe_preads_serial_vs_pipelined",
               ps_st.preads / max(pp_st.preads, 1),
               f"point probe: {ps_st.preads} -> {pp_st.preads} preads, "
               f"wall {ps_dt * 1e3:.2f}ms -> {pp_dt * 1e3:.2f}ms",
               preads=pp_st.preads, bytes_read=pp_st.bytes_read,
               pruned_bytes=pp_st.bytes_pruned,
               pages_pruned=pp_st.pages_pruned)

        # --- footer cache: repeated opens of unchanged shards ---------------
        # the unpruned scan opens every shard, so a cold open charges exactly
        # 2 footer preads per shard and a warm one must charge none
        clear_footer_cache()
        t0 = time.perf_counter()
        with dataset(d) as ds:
            ds.select(["id"]).to_table()
            cold = ds.stats
        t_cold = time.perf_counter() - t0
        t0 = time.perf_counter()
        with dataset(d) as ds:
            ds.select(["id"]).to_table()
            warm = ds.stats
        t_warm = time.perf_counter() - t0
        assert warm.footer_cache_hits == n_shards
        assert warm.footer_bytes == 0 and \
            warm.preads == cold.preads - 2 * n_shards, \
            "a warm open must issue zero footer preads"
        report("io/footer_cache_reopen_speedup", t_cold / max(t_warm, 1e-9),
               f"reopen: {cold.preads} -> {warm.preads} preads "
               f"({n_shards} footer parses cached), "
               f"{t_cold * 1e3:.2f}ms -> {t_warm * 1e3:.2f}ms",
               preads=warm.preads, bytes_read=warm.bytes_read,
               footer_cache_hits=warm.footer_cache_hits)

        # --- backend matrix: local vs async-batched vs object store ---------
        # the same wide projection over the same shards served three ways,
        # with 20 ms of injected per-request latency on the fake object
        # store. Serialized single-range fetches (remote io_depth=1) pay one
        # RTT per coalesced read; the async batched backend overlaps in-
        # flight ranges, so it must finish >= 2x faster AND the store must
        # have seen >= 2 concurrent requests — the hermetic CI proof that
        # batching actually happened.
        from repro.core import backend as _backend
        from repro.testing import FakeObjectStore

        latency = 0.02
        uris = [f"bullion://shards/part-{s:04d}.bln" for s in range(n_shards)]
        with FakeObjectStore(td, latency=latency) as store:
            _backend.configure_object_store(store.endpoint)
            try:
                clear_footer_cache()
                with dataset(uris) as ds:   # warm the remote footer cache
                    ds.select(["id"]).head(1).to_table()

                t0 = time.perf_counter()
                with dataset(uris) as ds:
                    r_ser = ds.select(cols).to_table(io_depth=1)
                    st_ser = ds.stats
                t_ser = time.perf_counter() - t0

                store.max_in_flight = 0
                t0 = time.perf_counter()
                with dataset(uris) as ds:
                    r_async = ds.select(cols).to_table(io_depth=2 * IO_DEPTH)
                    st_async = ds.stats
                t_async = time.perf_counter() - t0
            finally:
                _backend.configure_object_store(None)
        for c in cols:
            assert s_tbl[c].tobytes() == r_ser[c].tobytes() \
                and s_tbl[c].tobytes() == r_async[c].tobytes(), \
                f"object-store read differs from local in {c!r}"
        assert store.max_in_flight >= 2, \
            f"async batcher must overlap >= 2 in-flight ranges " \
            f"(store saw {store.max_in_flight})"
        assert t_async * 2 <= t_ser, \
            f"async batched backend must be >= 2x faster than serialized " \
            f"range fetches under {latency * 1e3:.0f}ms latency " \
            f"({t_ser * 1e3:.0f}ms serial vs {t_async * 1e3:.0f}ms batched)"
        report("io/backend_object_store_serialized", t_ser * 1e6,
               f"{st_ser.backend_fetches} serialized ranged GETs at "
               f"{latency * 1e3:.0f}ms injected latency",
               backend_fetches=st_ser.backend_fetches,
               backend_retries=st_ser.backend_retries,
               backend_wasted_bytes=st_ser.backend_wasted_bytes,
               bytes_read=st_ser.bytes_read)
        report("io/backend_async_batched_speedup", t_ser / max(t_async, 1e-9),
               f"{st_async.backend_fetches} batched GETs, "
               f"max {store.max_in_flight} in flight, wall "
               f"{t_ser * 1e3:.0f}ms -> {t_async * 1e3:.0f}ms",
               backend_fetches=st_async.backend_fetches,
               backend_retries=st_async.backend_retries,
               backend_wasted_bytes=st_async.backend_wasted_bytes,
               bytes_read=st_async.bytes_read,
               coalesced_preads=st_async.coalesced_preads)
