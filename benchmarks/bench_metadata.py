"""Fig. 5 reproduction: metadata parse time for single-column projection vs
table width. Bullion stays flat (binary map scan over footer views); the
Parquet/thrift-like baseline grows linearly (full footer deserialization)."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionReader, BullionWriter, ColumnSpec

from . import parquet_like


def _bullion_file(path: str, n_cols: int) -> None:
    rng = np.random.default_rng(0)
    schema = [ColumnSpec(f"feature_{c}", "int64") for c in range(n_cols)]
    table = {f"feature_{c}": rng.integers(0, 100, 64).astype(np.int64)
             for c in range(n_cols)}
    w = BullionWriter(path, schema, rows_per_group=64)
    w.write_table(table)
    w.close()


def run(report):
    widths = (100, 1000, 5000, 10000, 20000)
    with tempfile.TemporaryDirectory() as td:
        for n_cols in widths:
            # --- parquet-like: full deserialization then lookup
            footer = parquet_like.build_footer(n_cols)
            t0 = time.perf_counter()
            reps = 3
            for _ in range(reps):
                parquet_like.lookup_column(footer, f"feature_{n_cols // 2}")
            t_pq = (time.perf_counter() - t0) / reps * 1e3

            # --- bullion: footer pread + binary map scan
            path = os.path.join(td, f"w{n_cols}.bln")
            _bullion_file(path, n_cols)
            t0 = time.perf_counter()
            for _ in range(reps):
                r = BullionReader(path)
                fv = r.footer
                ci = fv.column_index(f"feature_{n_cols // 2}")
                s, e = fv.chunk_pages(0, ci)
                fv.page_extent(s)
                r.close()
            t_bln = (time.perf_counter() - t0) / reps * 1e3

            report(f"metadata_parse/parquet_like/{n_cols}cols", t_pq * 1e3,
                   f"{t_pq:.2f}ms")
            report(f"metadata_parse/bullion/{n_cols}cols", t_bln * 1e3,
                   f"{t_bln:.2f}ms speedup={t_pq / max(t_bln, 1e-9):.0f}x")
