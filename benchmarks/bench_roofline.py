"""Roofline summary rows derived from the multi-pod dry-run artifacts
(deliverable g). Reads artifacts/dryrun/*.json — run
`python -m repro.launch.dryrun --all --both-meshes` first (already done and
committed under artifacts/)."""

from __future__ import annotations

import glob
import json
import os

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


def run(report):
    for mesh in ("16x16", "2x16x16"):
        ok = skip = err = 0
        worst = (None, 1.0)
        best = (None, 0.0)
        dominant = {"compute": 0, "memory": 0, "collective": 0}
        for path in sorted(glob.glob(os.path.join(ARTIFACTS, f"*__{mesh}.json"))):
            if os.path.basename(path).count("__") != 2:
                continue
            with open(path) as f:
                r = json.load(f)
            if r["status"] == "ok":
                ok += 1
                t = r["roofline"]
                dominant[t["dominant"]] += 1
                frac = t["roofline_frac"]
                cell = f'{r["arch"]}/{r["shape"]}'
                if r["shape"] == "train_4k":
                    if frac < worst[1]:
                        worst = (cell, frac)
                    if frac > best[1]:
                        best = (cell, frac)
            elif r["status"] == "skipped":
                skip += 1
            else:
                err += 1
        report(f"dryrun/{mesh}/cells_compiled", ok,
               f"{ok} ok / {skip} principled skips / {err} errors")
        report(f"dryrun/{mesh}/dominant_bottlenecks", dominant["collective"],
               f"collective={dominant['collective']} memory={dominant['memory']} "
               f"compute={dominant['compute']}")
        if worst[0]:
            report(f"dryrun/{mesh}/train_frac_range", best[1],
                   f"best {best[0]}={best[1]:.3f}, worst {worst[0]}={worst[1]:.3f}")

    # hillclimb before/after (tagged artifacts)
    pairs = [
        ("rwkv6-7b train_4k", "rwkv6_7b__train_4k__16x16.json",
         "rwkv6-7b__train_4k__16x16__h5_nosp.json"),
        ("chameleon-34b train_4k", "chameleon_34b__train_4k__16x16.json",
         "chameleon-34b__train_4k__16x16__h2_mb2.json"),
        ("llama3.2-1b train_4k", "llama3_2_1b__train_4k__16x16.json",
         "llama3_2-1b__train_4k__16x16__h3_mb1.json"),
    ]
    for label, base_f, opt_f in pairs:
        try:
            base = json.load(open(os.path.join(ARTIFACTS, base_f)))
            opt = json.load(open(os.path.join(ARTIFACTS, opt_f)))
        except FileNotFoundError:
            continue
        b, o = base["roofline"], opt["roofline"]
        report(f"perf/{label.split()[0]}/frac_gain",
               o["roofline_frac"] / max(b["roofline_frac"], 1e-9),
               f"frac {b['roofline_frac']:.3f} -> {o['roofline_frac']:.3f}; "
               f"bound {b['bound_s']:.3g}s -> {o['bound_s']:.3g}s "
               f"({b['dominant']} -> {o['dominant']})")
