"""Dataset-service benchmark: bloom-sketch point lookups + prepared plans.

Two probes of the serving path:

* **Point-lookup latency.** Equality probes on an *unclustered* id column,
  where zone maps are useless (every group spans the full value range) and
  the per-chunk bloom sketches carry the pruning. Reports us_per_call plus
  the pruning evidence — ``groups_pruned_sketch`` and the data preads the
  surviving probe actually issued — so a sketch regression (suddenly
  scanning every group) shows up in the CSV immediately.
* **Prepared-plan throughput.** The same query shape fired repeatedly at a
  ``DatasetServer``: after the first miss every call hits the prepared-plan
  LRU and skips optimize/lower, so the derived probes/sec tracks the
  execution-only cost of a served point lookup.

``BULLION_BENCH_SMOKE=1`` shrinks the dataset for CI smoke runs (same code
path and CSV schema, smaller constants)."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionWriter, ColumnSpec
from repro.dataset import clear_footer_cache, dataset
from repro.scan import C
from repro.serve import DatasetServer


def _write_shards(d: str, n_shards: int, rows_per_shard: int,
                  rows_per_group: int, page_rows: int) -> np.ndarray:
    """Unclustered ids (a permutation of the full keyspace striped across
    shards) + float payload. Returns the id column, concatenated."""
    os.makedirs(d)
    schema = [ColumnSpec("id", "int64"), ColumnSpec("val", "float32")]
    rng = np.random.default_rng(7)
    ids = rng.permutation(n_shards * rows_per_shard * 2)  # gaps => absences
    all_ids = []
    for s in range(n_shards):
        part = ids[s * rows_per_shard:(s + 1) * rows_per_shard].astype(
            np.int64)
        all_ids.append(part)
        w = BullionWriter(os.path.join(d, f"part-{s:04d}.bln"), schema,
                          rows_per_group=rows_per_group, page_rows=page_rows)
        w.write_table({"id": part,
                       "val": rng.random(rows_per_shard).astype(np.float32)})
        w.close()
    return np.concatenate(all_ids)


def run(report):
    smoke = bool(os.environ.get("BULLION_BENCH_SMOKE"))
    n_shards = 2 if smoke else 4
    rows_per_group = 512 if smoke else 2048
    groups_per_shard = 4 if smoke else 8
    rows_per_shard = rows_per_group * groups_per_shard
    page_rows = max(1, rows_per_group // 8)
    n_probes = 16 if smoke else 64

    with tempfile.TemporaryDirectory() as td:
        d = os.path.join(td, "shards")
        ids = _write_shards(d, n_shards, rows_per_shard, rows_per_group,
                            page_rows)
        rng = np.random.default_rng(11)
        victims = rng.choice(ids, size=n_probes, replace=False)
        n_groups = n_shards * groups_per_shard

        # --- bloom-sketch point lookups (unclustered ids) -------------------
        clear_footer_cache()
        t0 = time.perf_counter()
        with dataset(d) as ds:
            for v in victims:
                tbl = ds.where(C("id") == int(v)).to_table()
                assert tbl["id"].tolist() == [int(v)]
            st = ds.stats
        dt = time.perf_counter() - t0
        # without sketches every probe would decode all groups; the sketch
        # path must refute most of them outright
        assert st.groups_pruned_sketch > n_probes * (n_groups // 2), \
            f"sketches pruned only {st.groups_pruned_sketch} groups " \
            f"across {n_probes} probes of {n_groups} groups"
        report("serve/bloom_point_lookup", dt / n_probes * 1e6,
               f"{n_probes} probes, {st.groups_pruned_sketch} groups "
               f"sketch-pruned of {n_probes * n_groups} examined, "
               f"{st.preads} preads total",
               preads=st.preads, bytes_read=st.bytes_read,
               groups_pruned_sketch=st.groups_pruned_sketch,
               pruned_bytes=st.bytes_pruned, pages_pruned=st.pages_pruned)

        # --- prepared-plan repeated queries ---------------------------------
        clear_footer_cache()
        victim = int(victims[0])
        with DatasetServer({"bench": d}, max_workers=2) as srv:
            srv.query("bench", where=C("id") == victim)   # warm: cache miss
            t0 = time.perf_counter()
            for _ in range(n_probes):
                res = srv.query("bench", where=C("id") == victim)
                assert res.cache_hit and res.rows == 1
            dt = time.perf_counter() - t0
            stats = srv.stats()
        assert stats["plan_cache"]["hits"] >= n_probes
        io = stats["datasets"]["bench"]["io"]
        report("serve/prepared_plan_probe", dt / n_probes * 1e6,
               f"{n_probes / max(dt, 1e-9):.0f} probes/sec served, "
               f"{stats['plan_cache']['hits']} plan-cache hits, "
               f"{stats['plan_cache']['misses']} misses",
               preads=io["preads"], bytes_read=io["bytes_read"],
               groups_pruned_sketch=io["groups_pruned_sketch"],
               footer_cache_hits=io["footer_cache_hits"])
