"""§2.4 reproduction: storage quantization. Bytes on disk for FP32 vs
BF16/FP8/INT8 columns (through the full page-encode path), worst-case error,
dual-FP16 reconstruction, and device-side fused dequant throughput (Pallas
kernel, interpret mode)."""

from __future__ import annotations

import time

import numpy as np

from repro.core import (EncodeContext, QuantMode, QuantSpec, affine_spec_for,
                        dequantize, quantize, rejoin_dual_fp16, suggest_spec)
from repro.core.encodings import encode_array


def run(report):
    rng = np.random.default_rng(0)
    emb = np.tanh(rng.normal(size=65536).astype(np.float32))  # (-1,1) embeddings
    ctx = EncodeContext()
    base = len(encode_array(emb, ctx))

    for mode in (QuantMode.BF16, QuantMode.FP16, QuantMode.FP8_E4M3,
                 QuantMode.INT8_AFFINE):
        spec = affine_spec_for(emb, mode) if "AFFINE" in mode.name \
            else QuantSpec(mode)
        q = quantize(emb, spec)
        blob = len(encode_array(q, ctx))
        err = float(np.abs(dequantize(q, spec) - emb).max())
        report(f"quant/bytes_ratio/{mode.name}", base / blob,
               f"{base / blob:.2f}x smaller, max_err={err:.2e}")

    # dual-FP16 decomposition (the paper's FP32 mitigation)
    hi = quantize(emb, QuantSpec(QuantMode.DUAL_FP16_HI))
    lo = quantize(emb, QuantSpec(QuantMode.DUAL_FP16_LO))
    err = float(np.abs(rejoin_dual_fp16(hi, lo) - emb).max())
    report("quant/dual_fp16_max_err", err, f"max_err={err:.2e} (2 cols, 1:1 join)")

    # per-feature mixed precision policy
    spec = suggest_spec(emb, rel_tolerance=5e-3)
    report("quant/suggested_mode", float(int(spec.mode)),
           f"policy picked {spec.mode.name} at tol=5e-3")

    # fused dequant kernel throughput (interpret mode — structural check)
    from repro.kernels.dequant import dequant
    q8 = quantize(emb, affine_spec_for(emb, QuantMode.INT8_AFFINE))
    qm = np.tile(q8.reshape(256, 256), (2, 1))
    spec8 = affine_spec_for(emb, QuantMode.INT8_AFFINE)
    t0 = time.perf_counter()
    out = dequant(qm, np.full(256, spec8.scale, np.float32),
                  np.full(256, spec8.zero, np.float32))
    out.block_until_ready()
    dt = time.perf_counter() - t0
    report("quant/dequant_kernel_MBps", qm.nbytes / dt / 1e6,
           f"{qm.nbytes / dt / 1e6:.1f} MB/s (interpret mode)")
