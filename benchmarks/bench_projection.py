"""§2.3 + Table 1 reproduction: wide-table projection. Training reads ~10% of
a wide ads table's columns; Bullion touches only those pages (plus a flat
footer). Also shows §2.5 column reordering: hot columns laid out adjacently
coalesce into fewer preads."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionReader, BullionWriter, ColumnSpec
from repro.data.synthetic import write_ads_table


def run(report):
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "wide.bln")
        n_sparse, n_dense = 72, 24   # 100 columns total in miniature
        write_ads_table(path, n_rows=4096, n_sparse=n_sparse, n_dense=n_dense,
                        seq_len=32, rows_per_group=1024)
        size = os.path.getsize(path)
        hot = [f"clk_seq_{i}" for i in range(6)] + \
              [f"dense_{i}" for i in range(3)] + ["label"]   # ~10%

        t0 = time.perf_counter()
        with BullionReader(path) as r:
            rows = 0
            for tbl in r.project(hot):
                rows += len(tbl["label"])
            stats10 = r.stats
        t10 = time.perf_counter() - t0

        t0 = time.perf_counter()
        with BullionReader(path) as r:
            all_cols = r.column_names
            for tbl in r.project(all_cols):
                pass
            stats100 = r.stats
        t100 = time.perf_counter() - t0

        report("projection/bytes_10pct_vs_full",
               stats100.bytes_read / stats10.bytes_read,
               f"{stats100.bytes_read / stats10.bytes_read:.1f}x fewer bytes "
               f"({stats10.bytes_read}B vs {stats100.bytes_read}B of {size}B file)")
        report("projection/time_10pct_vs_full", t100 / max(t10, 1e-9),
               f"{t100 / max(t10, 1e-9):.1f}x faster")

        # §2.5 column reordering: hot columns adjacent -> coalesced preads
        reordered = os.path.join(td, "wide_reordered.bln")
        cold = None

        def reorder(names):
            return hot + [n for n in names if n not in hot]

        rng = np.random.default_rng(0)
        from repro.data.synthetic import SyntheticClickSeq
        # rebuild with layout reordering
        from repro.core.sparse_delta import SyntheticClickSeq as SCS
        import repro.data.synthetic as synth
        schema = [ColumnSpec("user_id", "int64"), ColumnSpec("ts", "int64")]
        table = {"user_id": np.sort(rng.integers(0, 512, 4096)).astype(np.int64),
                 "ts": np.arange(4096, dtype=np.int64)}
        gen = SCS(seq_len=32)
        for i in range(n_sparse):
            schema.append(ColumnSpec(f"clk_seq_{i}", "list<int64>",
                                     sparse_delta=True))
            table[f"clk_seq_{i}"] = gen.generate(4096, seed=i)
        for i in range(n_dense):
            schema.append(ColumnSpec(f"dense_{i}", "float32"))
            table[f"dense_{i}"] = rng.normal(size=4096).astype(np.float32)
        schema.append(ColumnSpec("label", "int8"))
        table["label"] = (rng.random(4096) < 0.03).astype(np.int8)
        w = BullionWriter(reordered, schema, rows_per_group=1024,
                          column_order_udf=reorder)
        w.write_table(table)
        w.close()

        with BullionReader(reordered) as r:
            for tbl in r.project(hot):
                pass
            stats_re = r.stats

        report("projection/preads_hot_reordered",
               stats10.preads / max(stats_re.preads, 1),
               f"{stats10.preads} preads -> {stats_re.preads} with column "
               "reordering (coalesced)")
