"""§2.2 reproduction: sliding-window delta encoding for long-sequence sparse
features (Fig. 3/4). Compares bytes + encode/decode throughput against the
plain list layout (offsets+values, cascaded) and chunked-zstd."""

from __future__ import annotations

import time

import numpy as np

from repro.core import EncodeContext
from repro.core import pages as pages_mod
from repro.core.sparse_delta import SyntheticClickSeq, decode_page, encode_page


def run(report):
    gen = SyntheticClickSeq(seq_len=256, new_per_step_max=4)
    rows = gen.generate(4096, seed=7)
    raw_bytes = sum(r.nbytes for r in rows)
    ctx = EncodeContext()

    t0 = time.perf_counter()
    delta_blob = encode_page(rows, ctx)
    t_enc = time.perf_counter() - t0
    t0 = time.perf_counter()
    out = decode_page(delta_blob)
    t_dec = time.perf_counter() - t0
    assert all(np.array_equal(a, b) for a, b in zip(out, rows))

    plain_blob, _ = pages_mod.build_list_page(rows, ctx, use_sparse_delta=False)

    values = np.concatenate(rows)
    try:
        import zstandard as zstd
        zstd_blob = zstd.ZstdCompressor(level=3).compress(values.tobytes())
        zstd_note = f"zstd {raw_bytes / len(zstd_blob):.1f}x"
    except ImportError:  # optional dep (same zlib-fallback policy as encodings)
        import zlib
        zstd_blob = zlib.compress(values.tobytes(), 6)
        zstd_note = f"zlib {raw_bytes / len(zstd_blob):.1f}x (zstd absent)"

    r_delta = raw_bytes / len(delta_blob)
    r_plain = raw_bytes / len(plain_blob)
    report("sparse_delta/ratio_sliding_window", r_delta,
           f"{r_delta:.1f}x vs plain {r_plain:.1f}x vs {zstd_note}")
    report("sparse_delta/encode_MBps", raw_bytes / t_enc / 1e6,
           f"{raw_bytes / t_enc / 1e6:.0f} MB/s")
    report("sparse_delta/decode_MBps", raw_bytes / t_dec / 1e6,
           f"{raw_bytes / t_dec / 1e6:.0f} MB/s")

    # non-sliding (random) rows: delta should gracefully match plain
    rng = np.random.default_rng(0)
    rand_rows = [rng.integers(0, 1 << 20, 256).astype(np.int64)
                 for _ in range(1024)]
    blob_r = encode_page(rand_rows, ctx)
    raw_r = sum(r.nbytes for r in rand_rows)
    report("sparse_delta/ratio_random_fallback", raw_r / len(blob_r),
           f"{raw_r / len(blob_r):.2f}x (no pattern -> base vectors)")
