"""§2.5 / Fig. 7 reproduction: quality-aware row reordering. Reading the
top-10% quality samples from a presorted meta table is a sequential prefix
(few preads); the unsorted layout scatters them across every row group."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import (BullionReader, BullionWriter, ColumnSpec,
                        MultimodalSample, quality_filtered_read,
                        write_multimodal_dataset)


def _samples(n, rng):
    return [MultimodalSample(
        text=b"caption %d" % i,
        quality=float(rng.random()),
        embedding=rng.normal(size=64).astype(np.float32),
        frames=rng.integers(0, 256, 256, dtype=np.uint8).tobytes(),
        media_key=i) for i in range(n)]


def run(report):
    rng = np.random.default_rng(0)
    n = 4096
    samples = _samples(n, rng)
    cols = ["text", "quality", "embedding", "frames"]
    with tempfile.TemporaryDirectory() as td:
        sorted_path = os.path.join(td, "meta_sorted.bln")
        write_multimodal_dataset(sorted_path, os.path.join(td, "m.media"),
                                 samples, rows_per_group=256)

        # unsorted baseline: same rows, no quality presort
        unsorted_path = os.path.join(td, "meta_unsorted.bln")
        schema = [ColumnSpec("text", "string"),
                  ColumnSpec("quality", "float32"),
                  ColumnSpec("embedding", "list<float32>"),
                  ColumnSpec("frames", "string"),
                  ColumnSpec("media_key", "media_ref")]
        w = BullionWriter(unsorted_path, schema, rows_per_group=256)
        w.write_table({
            "text": [s.text for s in samples],
            "quality": np.asarray([s.quality for s in samples], np.float32),
            "embedding": [s.embedding for s in samples],
            "frames": [s.frames for s in samples],
            "media_key": np.arange(n, dtype=np.uint64)})
        w.close()

        t0 = time.perf_counter()
        tables, stats_sorted = quality_filtered_read(sorted_path, cols, 0.10)
        t_sorted = time.perf_counter() - t0
        got = sum(len(t["quality"]) for t in tables)

        # unsorted: must scan quality everywhere, then fetch qualifying rows'
        # groups (scattered -> most groups touched)
        t0 = time.perf_counter()
        with BullionReader(unsorted_path) as r:
            q = r.read_column("quality")
            thresh = np.quantile(q, 0.9)
            want_groups = set()
            fv = r.footer
            rpg = int(fv.meta[4])
            for row in np.flatnonzero(q >= thresh):
                want_groups.add(int(row) // rpg)
            rows_read = 0
            for tbl in r.project(cols, groups=sorted(want_groups)):
                rows_read += len(tbl["quality"])
            stats_unsorted = r.stats
        t_unsorted = time.perf_counter() - t0

        report("multimodal/bytes_reduction_top10pct",
               stats_unsorted.bytes_read / stats_sorted.bytes_read,
               f"{stats_unsorted.bytes_read / stats_sorted.bytes_read:.1f}x fewer bytes "
               f"({stats_sorted.bytes_read}B vs {stats_unsorted.bytes_read}B), "
               f"preads {stats_sorted.preads} vs {stats_unsorted.preads}, "
               f"groups 1-prefix vs {len(want_groups)}/{fv.n_groups}")
        report("multimodal/walltime_speedup",
               t_unsorted / max(t_sorted, 1e-9),
               f"{t_unsorted / max(t_sorted, 1e-9):.1f}x faster ({got} rows)")
