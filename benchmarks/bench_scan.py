"""Scan subsystem benchmark: zone-map pruning vs full-column scans.

A selective predicate (one value out of a sorted 64k-row id column,
selectivity ~0.0015%) must touch only the one row group whose zone map
admits it: preads, bytes, and latency all collapse versus the full-column
``find_rows`` baseline, with identical row-id results. Also reports the
quality-threshold read (§2.5): presorted quality + zone maps turn a
threshold scan into a prefix read."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionReader, BullionWriter, ColumnSpec, quality_sort
from repro.scan import C


def _write(path: str, n_rows: int, rows_per_group: int,
           sort_by_quality: bool) -> None:
    """Zone maps prune along whatever the write path clustered: sorted ids
    for point probes, or quality-presorted rows (§2.5) for threshold reads."""
    rng = np.random.default_rng(0)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("quality", "float32"),
        ColumnSpec("payload", "float32"),
    ]
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      sort_udf=quality_sort("quality") if sort_by_quality
                      else None)
    w.write_table({
        "id": np.arange(n_rows, dtype=np.int64),
        "quality": rng.random(n_rows).astype(np.float32),
        "payload": rng.normal(size=n_rows).astype(np.float32),
    })
    w.close()


def run(report):
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "scan.bln")
        n_rows, rows_per_group = 65536, 512
        _write(path, n_rows, rows_per_group, sort_by_quality=False)
        victim = 12345

        # baseline: full-column decode + isin (the seed's find_rows path)
        t0 = time.perf_counter()
        with BullionReader(path) as r:
            data = r.read_column("id", drop_deleted=False, dequant=False)
            base_rows = np.flatnonzero(np.isin(np.asarray(data), [victim]))
            base_bytes = r.stats.bytes_read - r.stats.footer_bytes
            base_preads = r.stats.preads
        t_base = time.perf_counter() - t0

        # pruned: zone maps skip every group but the victim's
        t0 = time.perf_counter()
        with BullionReader(path) as r:
            rows = r.find_rows("id", [victim])
            scan_bytes = r.stats.bytes_read - r.stats.footer_bytes
            scan_preads = r.stats.preads
            plan = r.scanner.plan(C("id") == victim)
        t_scan = time.perf_counter() - t0

        assert np.array_equal(np.sort(rows), np.sort(base_rows)), \
            "pruned scan and brute force disagree"
        sel = len(rows) / n_rows
        report("scan/selectivity_pct", 100 * sel, f"{100 * sel:.4f}% of rows")
        report("scan/groups_pruned",
               len(plan.pruned_groups),
               f"{len(plan.pruned_groups)}/{len(plan.groups) + len(plan.pruned_groups)} "
               "row groups skipped before any pread")
        report("scan/bytes_pruned_vs_full", base_bytes / max(scan_bytes, 1),
               f"{base_bytes / max(scan_bytes, 1):.1f}x fewer data bytes "
               f"({scan_bytes}B vs {base_bytes}B)")
        report("scan/preads_pruned_vs_full", base_preads / max(scan_preads, 1),
               f"{base_preads} preads -> {scan_preads}")
        report("scan/time_pruned_vs_full", t_base / max(t_scan, 1e-9),
               f"{t_base / max(t_scan, 1e-9):.1f}x faster "
               f"({t_scan * 1e3:.2f}ms vs {t_base * 1e3:.2f}ms)")

        # §2.5 quality-threshold read: presorted quality -> prefix of groups
        path = os.path.join(td, "scan_sorted.bln")
        _write(path, n_rows, rows_per_group, sort_by_quality=True)
        with BullionReader(path) as r:
            plan = r.scanner.plan(C("quality") >= 0.9)
            for b in r.scanner.scan(C("quality") >= 0.9, columns=["payload"]):
                pass
            thresh_bytes = r.stats.bytes_read - r.stats.footer_bytes
        with BullionReader(path) as r:
            for tbl in r.project(["quality", "payload"]):
                pass
            full_bytes = r.stats.bytes_read - r.stats.footer_bytes
        report("scan/quality_threshold_bytes_vs_full",
               full_bytes / max(thresh_bytes, 1),
               f"top-10% quality read touches {thresh_bytes}B vs {full_bytes}B "
               f"({len(plan.groups)}/{len(plan.groups) + len(plan.pruned_groups)} groups)")
