"""Scan subsystem benchmark: lazy Dataset plans vs full-column scans.

A selective predicate (one value out of a sorted 64k-row id column,
selectivity ~0.0015%) must touch only the one row group whose zone map
admits it: preads, bytes, and latency all collapse versus the full-column
baseline, with *byte-identical* results (the PR-2 acceptance check). The
same plan then runs unchanged over a 4-shard directory dataset. Also
reports the quality-threshold read (§2.5) and the plan-proven pruned bytes
now tracked in the ``pruned_bytes`` CSV column.

``BULLION_BENCH_SMOKE=1`` shrinks the dataset for CI smoke runs (same code
path and CSV schema, smaller constants)."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionReader, BullionWriter, ColumnSpec, quality_sort
from repro.dataset import dataset
from repro.scan import C


def _write(path: str, n_rows: int, rows_per_group: int,
           sort_by_quality: bool, id_base: int = 0, seed: int = 0,
           page_rows=None) -> None:
    """Zone maps prune along whatever the write path clustered: sorted ids
    for point probes, or quality-presorted rows (§2.5) for threshold reads."""
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("quality", "float32"),
        ColumnSpec("payload", "float32"),
    ]
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      page_rows=page_rows,
                      sort_udf=quality_sort("quality") if sort_by_quality
                      else None)
    w.write_table({
        "id": np.arange(id_base, id_base + n_rows, dtype=np.int64),
        "quality": rng.random(n_rows).astype(np.float32),
        "payload": rng.normal(size=n_rows).astype(np.float32),
    })
    w.close()


def run(report):
    smoke = bool(os.environ.get("BULLION_BENCH_SMOKE"))
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "scan.bln")
        n_rows, rows_per_group = (8192 if smoke else 65536), 512
        _write(path, n_rows, rows_per_group, sort_by_quality=False)
        victim = n_rows // 5 - 1

        # baseline: legacy find_rows + project gather (full decode on v0-style
        # access: read, locate, re-read the matching group)
        t0 = time.perf_counter()
        with BullionReader(path) as r:
            data = r.read_column("id", drop_deleted=False, dequant=False)
            base_rows = np.flatnonzero(np.isin(np.asarray(data), [victim]))
            legacy = []
            for g, local in r.locate_rows(base_rows):
                (tbl,) = r.project(["id", "payload"], groups=[g])
                legacy.append({k: v[local] for k, v in tbl.items()})
            legacy = {k: np.concatenate([t[k] for t in legacy])
                      for k in ("id", "payload")}
            base_bytes = r.stats.bytes_read - r.stats.footer_bytes
            base_preads = r.stats.preads
        t_base = time.perf_counter() - t0

        # Dataset plan: zone maps skip every group but the victim's.
        # scan_batches() delivers data + row ids in a single pass.
        t0 = time.perf_counter()
        with dataset(path) as ds:
            q = ds.where(C("id") == victim).select(["id", "payload"])
            batches = list(q.scan_batches())
            got = {k: np.concatenate([b.table[k] for b in batches])
                   for k in ("id", "payload")}
            rows = np.concatenate([b.row_ids for b in batches])
            st = ds.stats
            scan_bytes = st.bytes_read - st.footer_bytes
            scan_preads = st.preads
            pruned_bytes = st.bytes_pruned
            pruned_pages = st.pages_pruned
            plan = q.physical_plan()
        t_scan = time.perf_counter() - t0

        # acceptance: byte-identical to the legacy path, no more data bytes
        assert got["id"].tobytes() == legacy["id"].tobytes(), \
            "Dataset plan and legacy find_rows+project disagree"
        assert got["payload"].tobytes() == legacy["payload"].tobytes()
        assert np.array_equal(np.sort(rows), np.sort(base_rows))
        assert scan_bytes <= base_bytes, "plan read more than the legacy path"

        sel = len(rows) / n_rows
        report("scan/selectivity_pct", 100 * sel, f"{100 * sel:.4f}% of rows")
        report("scan/groups_pruned", plan.groups_pruned,
               f"{plan.groups_pruned}/{plan.groups_total} row groups "
               "skipped before any pread", pruned_bytes=pruned_bytes,
               pages_pruned=pruned_pages)
        report("scan/bytes_pruned_vs_full", base_bytes / max(scan_bytes, 1),
               f"{base_bytes / max(scan_bytes, 1):.1f}x fewer data bytes "
               f"({scan_bytes}B vs {base_bytes}B)", pruned_bytes=pruned_bytes,
               pages_pruned=pruned_pages)
        report("scan/preads_pruned_vs_full", base_preads / max(scan_preads, 1),
               f"{base_preads} preads -> {scan_preads}")
        report("scan/time_pruned_vs_full", t_base / max(t_scan, 1e-9),
               f"{t_base / max(t_scan, 1e-9):.1f}x faster "
               f"({t_scan * 1e3:.2f}ms vs {t_base * 1e3:.2f}ms)")

        # the same plan, unchanged, over a 4-shard directory dataset
        shard_dir = os.path.join(td, "shards")
        os.makedirs(shard_dir)
        per_shard = n_rows // 4
        for s in range(4):
            _write(os.path.join(shard_dir, f"part-{s:04d}.bln"), per_shard,
                   rows_per_group, sort_by_quality=False,
                   id_base=s * per_shard, seed=s)
        with dataset(shard_dir) as ds:
            q = ds.where(C("id") == victim).select(["id", "payload"])
            sb = list(q.scan_batches())
            sharded = {k: np.concatenate([b.table[k] for b in sb])
                       for k in ("id", "payload")}
            srows = np.concatenate([b.row_ids for b in sb])
            sbytes = ds.stats.bytes_read - ds.stats.footer_bytes
            spruned = ds.stats.bytes_pruned
            sharded_plan = q.physical_plan()
        assert sharded["id"].tobytes() == legacy["id"].tobytes(), \
            "multi-shard plan disagrees with the single-file result"
        assert np.array_equal(srows, rows)
        report("scan/multi_shard_bytes_vs_full", base_bytes / max(sbytes, 1),
               f"4-shard dir: {len(sharded_plan.tasks)} task(s), "
               f"{sharded_plan.groups_pruned}/{sharded_plan.groups_total} "
               f"groups pruned, {sbytes}B read", pruned_bytes=spruned)

        # page-granular pruning (multi-page chunks): recluster an unclustered
        # dataset through write_to(sort_by="id"), then run the same
        # ~0.0015%-selectivity point probe against a single-page layout and
        # an 8-pages-per-group layout. Group pruning is identical for both
        # (same zone maps, same clustering); the multi-page layout *also*
        # skips the non-matching pages inside the surviving group, so it must
        # decode strictly fewer bytes, with pages_pruned > 0 in the CSV.
        unclustered = os.path.join(td, "page_base.bln")
        _write(unclustered, n_rows, rows_per_group, sort_by_quality=True)
        layouts: dict = {}
        for label, pr in (("single", rows_per_group),
                          ("multi", max(1, rows_per_group // 8))):
            out_dir = os.path.join(td, f"reclustered_{label}")
            with dataset(unclustered) as ds:
                ds.select(["id", "payload"]).write_to(
                    out_dir, sort_by="id", rows_per_group=rows_per_group,
                    page_rows=pr)
            with dataset(out_dir) as ds:
                q = ds.where(C("id") == victim).select(["id", "payload"])
                tbl = q.to_table()
                st = ds.stats
                layouts[label] = {
                    "table": tbl,
                    "data_bytes": st.bytes_read - st.footer_bytes,
                    "pruned_bytes": st.bytes_pruned,
                    "pages_pruned": st.pages_pruned,
                }
        single, multi = layouts["single"], layouts["multi"]
        assert multi["table"]["id"].tobytes() == \
            single["table"]["id"].tobytes(), \
            "multi-page layout changed the probe's result rows"
        assert multi["table"]["payload"].tobytes() == \
            single["table"]["payload"].tobytes()
        assert multi["data_bytes"] < single["data_bytes"], \
            "page-granular pruning must decode strictly fewer bytes than " \
            f"single-page ({multi['data_bytes']}B vs {single['data_bytes']}B)"
        assert multi["pages_pruned"] > single["pages_pruned"] >= 0
        report("scan/page_granular_bytes_vs_single_page",
               single["data_bytes"] / max(multi["data_bytes"], 1),
               f"reclustered probe: {multi['data_bytes']}B decoded vs "
               f"{single['data_bytes']}B single-page, "
               f"{multi['pages_pruned']} pages pruned",
               pruned_bytes=multi["pruned_bytes"],
               pages_pruned=multi["pages_pruned"])

        # §2.5 quality-threshold read: presorted quality -> prefix of groups
        path = os.path.join(td, "scan_sorted.bln")
        _write(path, n_rows, rows_per_group, sort_by_quality=True)
        with dataset(path) as ds:
            q = ds.where(C("quality") >= 0.9).select(["payload"])
            plan = q.physical_plan()
            for _ in q.to_batches():
                pass
            st = ds.stats
            thresh_bytes = st.bytes_read - st.footer_bytes
            thresh_pruned = st.bytes_pruned
        with dataset(path) as ds:
            ds.select(["quality", "payload"]).to_table()
            st = ds.stats
            full_bytes = st.bytes_read - st.footer_bytes
        kept = plan.groups_total - plan.groups_pruned
        report("scan/quality_threshold_bytes_vs_full",
               full_bytes / max(thresh_bytes, 1),
               f"top-10% quality read touches {thresh_bytes}B vs {full_bytes}B "
               f"({kept}/{plan.groups_total} groups)",
               pruned_bytes=thresh_pruned)
