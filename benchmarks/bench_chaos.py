"""Self-healing read path benchmarks: fault survival + verification cost.

Three probes, all hard-asserted (a chaos probe that silently stops
injecting faults measures nothing):

* **degraded pipeline** — one on-disk bit flip under ``full`` verification
  and the ``skip`` corruption policy: the scan must return exactly the
  surviving rows, charge the quarantined page's row count to
  ``IOStats.degraded_rows``, and — after an in-place repair — serve the
  full dataset again *in the same process* (the quarantine entry
  self-invalidates when the repaired footer re-parses).
* **EIO fallback** — an injected ``EIO`` inside the pipelined scheduler's
  coalesced read; the prefetch fallback re-reads on the direct path and
  the result must stay byte-identical.
* **verify overhead** — the acceptance gate: steady-state ``sample``-mode
  verification (memo warm after the first pass) must cost < 5% wall clock
  over ``off`` on a wide projection. Min-of-N on both sides with retries
  absorbs scheduler noise; ``full`` mode's cost is reported as informational
  derived output, not gated.

``BULLION_BENCH_SMOKE=1`` shrinks the datasets (same code paths and CSV
schema)."""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionWriter, ColumnSpec
from repro.core import integrity as _integrity
from repro.core.footer import Sec, read_footer
from repro.dataset import clear_footer_cache, dataset
from repro.testing import chaos

OVERHEAD_GATE = 0.05          # sample-vs-off wall-clock ratio - 1
_ATTEMPTS = 5                 # timing retries before failing the gate


def _write(path: str, *, n: int, n_payload: int, rows_per_group: int,
           page_rows: int) -> None:
    schema = [ColumnSpec("id", "int64")] + \
        [ColumnSpec(f"f{i:02d}", "float32") for i in range(n_payload)]
    rng = np.random.default_rng(7)
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      page_rows=page_rows)
    w.write_table({
        "id": np.arange(n, dtype=np.int64),
        **{f"f{i:02d}": rng.random(n).astype(np.float32)
           for i in range(n_payload)},
    })
    w.close()


def _flip_page(path: str, page: int) -> int:
    """Flip one byte of a page on disk; returns the page's row count."""
    fv, _ = read_footer(path)
    off, size = fv.page_extent(page)
    rows = int(fv.arr(Sec.PAGE_ROWS, np.uint32)[page])
    with open(path, "r+b") as f:
        f.seek(off + size // 2)
        b = f.read(1)
        f.seek(off + size // 2)
        f.write(bytes([b[0] ^ 0xFF]))
    clear_footer_cache()
    return rows


def _scan_wall(path: str, cols) -> float:
    """One full-projection scan, warm footer cache: the steady state a
    training loader lives in (cold opens would reset the sample memo and
    measure full-mode hashing instead)."""
    t0 = time.perf_counter()
    with dataset(path) as ds:
        ds.select(cols).to_table()
    return time.perf_counter() - t0


def run(report):
    smoke = bool(os.environ.get("BULLION_BENCH_SMOKE"))
    n = 20_000 if smoke else 200_000
    n_payload = 6 if smoke else 12
    rows_per_group = 2048
    page_rows = 512
    cols = ["id"] + [f"f{i:02d}" for i in range(n_payload)]

    with tempfile.TemporaryDirectory() as td:
        # -- degraded pipeline: flip, skip, account, repair, recover -------
        p = os.path.join(td, "degraded.bln")
        _write(p, n=n, n_payload=n_payload, rows_per_group=rows_per_group,
               page_rows=page_rows)
        dropped = _flip_page(p, 0)
        _integrity.set_verify_policy("full")
        _integrity.set_corruption_policy("skip")
        try:
            t0 = time.perf_counter()
            with dataset(p) as ds:
                table = ds.select(["id"]).to_table()
                st = ds.stats
            wall = time.perf_counter() - t0
            assert len(table["id"]) == n - dropped, \
                f"skip returned {len(table['id'])} rows, want {n - dropped}"
            assert st.degraded_rows == dropped, \
                f"degraded_rows={st.degraded_rows}, want {dropped}"
            assert st.pages_quarantined == 1
            # in-place repair is picked up without a process restart
            _write(p, n=n, n_payload=n_payload,
                   rows_per_group=rows_per_group, page_rows=page_rows)
            with dataset(p) as ds:
                assert len(ds.select(["id"]).to_table()["id"]) == n
        finally:
            _integrity.set_verify_policy(None)
            _integrity.set_corruption_policy(None)
            _integrity.QUARANTINE.clear()
        report("chaos_skip_degraded_scan", wall * 1e6,
               derived=f"recovered_after_repair rows_dropped={dropped}",
               pages_verified=st.pages_verified,
               checksum_failures=st.checksum_failures,
               pages_quarantined=st.pages_quarantined,
               degraded_rows=st.degraded_rows)

        # -- EIO fallback under the pipelined scheduler --------------------
        p2 = os.path.join(td, "eio.bln")
        _write(p2, n=n, n_payload=n_payload, rows_per_group=rows_per_group,
               page_rows=page_rows)
        with dataset(p2) as ds:
            expect = ds.select(["id"]).to_table()["id"]
        _integrity.set_verify_policy("full")
        try:
            # keep the footer cache warm from the expectation read: the
            # first pread under chaos is then a *data* read, so ordinal 0
            # targets the coalesced run, not the footer fetch
            with chaos() as ctl:
                fault = ctl.inject("eio", ordinal=0)
                t0 = time.perf_counter()
                with dataset(p2) as ds:
                    got = ds.select(["id"]).to_table(io_depth=4)["id"]
                    st = ds.stats
                wall = time.perf_counter() - t0
            assert fault.fired == 1, "EIO fault never fired"
            np.testing.assert_array_equal(got, expect)
            assert st.pages_quarantined == 0
        finally:
            _integrity.set_verify_policy(None)
            _integrity.QUARANTINE.clear()
        report("chaos_eio_fallback_scan", wall * 1e6,
               derived="byte_identical_after_eio",
               pages_verified=st.pages_verified,
               pages_quarantined=st.pages_quarantined)

        # -- verification overhead on a wide projection --------------------
        p3 = os.path.join(td, "wide.bln")
        _write(p3, n=n, n_payload=n_payload, rows_per_group=rows_per_group,
               page_rows=page_rows)
        ratio = full_ratio = None
        for _ in range(_ATTEMPTS):
            _integrity.set_verify_policy("off")
            off_w = min(_scan_wall(p3, cols) for _ in range(3))
            _integrity.set_verify_policy("sample")
            _scan_wall(p3, cols)        # warm the per-footer memo
            sample_w = min(_scan_wall(p3, cols) for _ in range(3))
            _integrity.set_verify_policy("full")
            full_w = min(_scan_wall(p3, cols) for _ in range(3))
            _integrity.set_verify_policy(None)
            ratio = sample_w / off_w - 1.0
            full_ratio = full_w / off_w - 1.0
            if ratio < OVERHEAD_GATE:
                break
        assert ratio < OVERHEAD_GATE, \
            (f"sample-mode verification overhead {ratio * 100:.2f}% "
             f"exceeds {OVERHEAD_GATE * 100:.0f}% on a wide projection")
        # count what steady-state sample mode actually hashes (memo warm)
        with dataset(p3) as ds:
            _integrity.set_verify_policy("sample")
            try:
                ds.select(cols).to_table()
                st = ds.stats
            finally:
                _integrity.set_verify_policy(None)
        report("chaos_verify_overhead_wide", sample_w * 1e6,
               derived=(f"sample_overhead={ratio * 100:+.2f}% "
                        f"full_overhead={full_ratio * 100:+.2f}%"),
               pages_verified=st.pages_verified,
               checksum_failures=st.checksum_failures)
