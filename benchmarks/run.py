# One function per paper table/figure. Prints
# ``name,us_per_call,pruned_bytes,pages_pruned,preads,bytes_read,
# footer_cache_hits,derived`` CSV; ``pruned_bytes`` is the plan-proven
# avoided I/O (IOStats.bytes_pruned) and ``pages_pruned`` the page reads
# those proofs skipped (IOStats.pages_pruned — group- plus page-granular
# zone maps), so pruning regressions at either granularity show up in the
# perf trajectory. ``preads``/``bytes_read`` track the I/O a probe actually
# issued (the pipelined scheduler's coalescing win) and
# ``footer_cache_hits`` the shard opens served without a metadata pread;
# all blank for suites where they don't apply.
#
# ``--only scan,compact`` restricts to matching suites (substring match on
# the label or module name — select the I/O suite with ``--only bench_io``;
# the bare key "io" also matches deletion/quantization/projection);
# ``BULLION_BENCH_SMOKE=1`` makes the suites that honor it (scan, compact,
# bench_io) shrink their datasets — the CI smoke mode that keeps the
# perf-trajectory CSV accumulating on every push.
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> None:
    from . import (bench_cascade, bench_compact, bench_deletion, bench_io,
                   bench_metadata, bench_multimodal, bench_projection,
                   bench_quantization, bench_roofline, bench_scan,
                   bench_sparse_delta)

    ap = argparse.ArgumentParser(description="Bullion benchmark suites")
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings; run only suites whose "
                         "label or module matches (e.g. --only scan,compact)")
    args = ap.parse_args(argv)

    def report(name: str, value: float, derived: str = "",
               pruned_bytes=None, pages_pruned=None, preads=None,
               bytes_read=None, footer_cache_hits=None) -> None:
        def cell(v):
            return "" if v is None else str(int(v))
        pruned, pages = cell(pruned_bytes), cell(pages_pruned)
        pr, br, fch = cell(preads), cell(bytes_read), cell(footer_cache_hits)
        print(f"{name},{value:.6g},{pruned},{pages},{pr},{br},{fch},"
              f"{derived}", flush=True)

    print("name,us_per_call,pruned_bytes,pages_pruned,preads,bytes_read,"
          "footer_cache_hits,derived")
    suites = [
        ("metadata  (Fig. 5)", bench_metadata),
        ("deletion  (§2.1)", bench_deletion),
        ("sparse_delta (§2.2, Figs. 3-4)", bench_sparse_delta),
        ("quantization (§2.4, Fig. 6)", bench_quantization),
        ("multimodal (§2.5, Fig. 7)", bench_multimodal),
        ("cascade   (§2.6, Table 2)", bench_cascade),
        ("projection (§2.3, Table 1)", bench_projection),
        ("scan      (zone maps / pushdown)", bench_scan),
        ("compact   (write_to sink / recluster)", bench_compact),
        ("io        (pipelined scheduler / footer cache)", bench_io),
        ("roofline  (dry-run artifacts)", bench_roofline),
    ]
    if args.only:
        keys = [k.strip() for k in args.only.split(",") if k.strip()]
        suites = [(label, mod) for label, mod in suites
                  if any(k in label or k in mod.__name__ for k in keys)]
        if not suites:
            sys.exit(f"--only {args.only!r} matched no suites")
    failures = 0
    for label, mod in suites:
        t0 = time.time()
        try:
            mod.run(report)
            print(f"# {label}: done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {label}: FAILED\n{traceback.format_exc()}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
