# One function per paper table/figure. Prints
# ``name,us_per_call,<STAT_COLUMNS...>,derived`` CSV; ``pruned_bytes`` is
# the plan-proven avoided I/O (IOStats.bytes_pruned) and ``pages_pruned``
# the page reads those proofs skipped (IOStats.pages_pruned — group- plus
# page-granular zone maps), so pruning regressions at either granularity
# show up in the perf trajectory. ``preads``/``bytes_read`` track the I/O a
# probe actually issued, ``coalesced_preads``/``wasted_bytes`` the pipelined
# scheduler's batching win and its hole-read cost, and
# ``footer_cache_hits`` the shard opens served without a metadata pread;
# all blank for suites where they don't apply. ``STAT_FIELDS`` maps each
# stat column to the ``IOStats`` field it mirrors (regression-tested, so
# the CSV schema can't silently drift from the accounting).
#
# ``--only scan,compact`` restricts to matching suites (substring match on
# the label or module name — select the I/O suite with ``--only bench_io``;
# the bare key "io" also matches deletion/quantization/projection);
# ``--trace out.json`` wraps each suite in a span and writes one merged
# Chrome trace_event JSON (open in Perfetto / chrome://tracing) covering
# every instrumented stage the suites exercised;
# ``BULLION_BENCH_SMOKE=1`` makes the suites that honor it (scan, compact,
# bench_io, bench_serve) shrink their datasets — the CI smoke mode that
# keeps the perf-trajectory CSV accumulating on every push.
from __future__ import annotations

import argparse
import sys
import time
import traceback

# CSV stat column -> the IOStats field it reports (order = column order
# between ``us_per_call`` and ``derived``)
STAT_FIELDS = {
    "pruned_bytes": "bytes_pruned",
    "pages_pruned": "pages_pruned",
    "groups_pruned_sketch": "groups_pruned_sketch",
    "preads": "preads",
    "bytes_read": "bytes_read",
    "footer_cache_hits": "footer_cache_hits",
    "coalesced_preads": "coalesced_preads",
    "wasted_bytes": "wasted_bytes",
    "backend_fetches": "backend_fetches",
    "backend_retries": "backend_retries",
    "backend_wasted_bytes": "backend_wasted_bytes",
    "pages_verified": "pages_verified",
    "checksum_failures": "checksum_failures",
    "pages_quarantined": "pages_quarantined",
    "degraded_rows": "degraded_rows",
}
STAT_COLUMNS = tuple(STAT_FIELDS)


def main(argv=None) -> None:
    from . import (bench_cascade, bench_chaos, bench_compact, bench_deletion,
                   bench_io, bench_metadata, bench_multimodal,
                   bench_projection, bench_quantization, bench_roofline,
                   bench_scan, bench_serve, bench_sparse_delta)

    ap = argparse.ArgumentParser(description="Bullion benchmark suites")
    ap.add_argument("--only", default=None,
                    help="comma-separated substrings; run only suites whose "
                         "label or module matches (e.g. --only scan,compact)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record spans across all suites and write one "
                         "merged Chrome trace_event JSON (Perfetto) to PATH")
    ap.add_argument("--baseline", default=None, metavar="OUT.json",
                    help="also write every probe's timing + stats as a "
                         "machine-readable baseline JSON")
    ap.add_argument("--compare", default=None, metavar="BASELINE.json",
                    help="diff this run against a recorded baseline; "
                         "warn-only (CI trend signal, not a gate)")
    ap.add_argument("--tolerance", type=float, default=35.0,
                    help="--compare flags probes whose us_per_call moved "
                         "more than this many percent (default 35)")
    args = ap.parse_args(argv)

    results: dict[str, dict] = {}

    def report(name: str, value: float, derived: str = "", **stats) -> None:
        bad = set(stats) - set(STAT_COLUMNS)
        if bad:
            raise TypeError(f"unknown stat column(s) {sorted(bad)}; "
                            f"expected one of {list(STAT_COLUMNS)}")
        cells = ",".join("" if stats.get(c) is None else str(int(stats[c]))
                         for c in STAT_COLUMNS)
        results[name] = {
            "us_per_call": value,
            "stats": {c: int(stats[c]) for c in STAT_COLUMNS
                      if stats.get(c) is not None},
            "derived": derived,
        }
        print(f"{name},{value:.6g},{cells},{derived}", flush=True)

    print("name,us_per_call," + ",".join(STAT_COLUMNS) + ",derived")
    suites = [
        ("metadata  (Fig. 5)", bench_metadata),
        ("deletion  (§2.1)", bench_deletion),
        ("sparse_delta (§2.2, Figs. 3-4)", bench_sparse_delta),
        ("quantization (§2.4, Fig. 6)", bench_quantization),
        ("multimodal (§2.5, Fig. 7)", bench_multimodal),
        ("cascade   (§2.6, Table 2)", bench_cascade),
        ("projection (§2.3, Table 1)", bench_projection),
        ("scan      (zone maps / pushdown)", bench_scan),
        ("compact   (write_to sink / recluster)", bench_compact),
        ("io        (pipelined scheduler / footer cache)", bench_io),
        ("chaos     (self-healing read path)", bench_chaos),
        ("serve     (dataset service / bloom probes)", bench_serve),
        ("roofline  (dry-run artifacts)", bench_roofline),
    ]
    if args.only:
        keys = [k.strip() for k in args.only.split(",") if k.strip()]
        suites = [(label, mod) for label, mod in suites
                  if any(k in label or k in mod.__name__ for k in keys)]
        if not suites:
            sys.exit(f"--only {args.only!r} matched no suites")
    scope = tracer = None
    if args.trace:
        from repro.obs import trace as _trace
        # a forwarding scope, not enable(): a concurrent BULLION_TRACE
        # recording keeps seeing every span
        scope = _trace.collect()
        tracer = scope.__enter__()
    failures = 0
    for label, mod in suites:
        t0 = time.time()
        try:
            if tracer is not None:
                with tracer.span(f"bench.{mod.__name__.rsplit('.', 1)[-1]}",
                                 "bench"):
                    mod.run(report)
            else:
                mod.run(report)
            print(f"# {label}: done in {time.time() - t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {label}: FAILED\n{traceback.format_exc()}", flush=True)
    if scope is not None:
        from repro.obs.export import write_trace
        scope.__exit__(None, None, None)
        write_trace(args.trace, tracer.spans, dropped=tracer.dropped)
        print(f"# trace: {args.trace} ({len(tracer.spans)} span(s), "
              f"{tracer.dropped} dropped)", flush=True)
    if args.baseline:
        _write_baseline(args.baseline, results)
    if args.compare:
        _compare_baseline(args.compare, results, args.tolerance)
    if failures:
        sys.exit(1)


def _write_baseline(path: str, results: dict) -> None:
    import json
    import os
    payload = {
        "schema": 1,
        "smoke": bool(os.environ.get("BULLION_BENCH_SMOKE")),
        "stat_columns": list(STAT_COLUMNS),
        "results": results,
    }
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"# baseline: {path} ({len(results)} probe(s))", flush=True)


def _compare_baseline(path: str, results: dict, tolerance: float) -> None:
    """Warn-only diff against a recorded baseline. Timings on shared CI
    runners are noisy, so regressions print as ``# compare:`` commentary
    for the perf-trajectory log rather than failing the run; the exact
    I/O counters (preads, bytes, pruning) are the stable signal and get
    flagged on ANY drift."""
    import json
    with open(path) as f:
        base = json.load(f)
    old = base.get("results", {})
    flagged = 0
    for name, rec in sorted(results.items()):
        prev = old.get(name)
        if prev is None:
            print(f"# compare: {name}: new probe (no baseline)", flush=True)
            continue
        was, now = prev["us_per_call"], rec["us_per_call"]
        if was > 0:
            delta = (now - was) / was * 100.0
            if abs(delta) > tolerance:
                flagged += 1
                print(f"# compare: {name}: us_per_call {was:.6g} -> "
                      f"{now:.6g} ({delta:+.1f}%, tolerance "
                      f"{tolerance:g}%)", flush=True)
        for col, v in rec["stats"].items():
            pv = prev.get("stats", {}).get(col)
            if pv is not None and pv != v:
                flagged += 1
                print(f"# compare: {name}: {col} {pv} -> {v}", flush=True)
    gone = sorted(set(old) - set(results))
    for name in gone:
        print(f"# compare: {name}: probe missing from this run", flush=True)
    print(f"# compare: {len(results)} probe(s) vs {path}: "
          f"{flagged} drift(s), {len(gone)} missing", flush=True)


if __name__ == "__main__":
    main()
