"""§2.1 reproduction: deletion-compliance I/O. Clustered (per-user) deletes
touch one row group's pages; Bullion rewrites only those pages + footer
in place, vs the legacy full-file rewrite. Also reports the Merkle
incremental-vs-monolithic checksum work."""

from __future__ import annotations

import os
import shutil
import tempfile

import numpy as np

from repro.core import Compliance, delete_rows, verify_deleted
from repro.data.synthetic import write_ads_table


def run(report):
    with tempfile.TemporaryDirectory() as td:
        base = os.path.join(td, "ads.bln")
        # 256 row groups: user-clustered deletes touch ~1 group per user (the
        # paper's production regime — delete requests hit a small clustered
        # slice of each file while the file itself is large)
        write_ads_table(base, n_rows=65536, n_sparse=6, n_dense=10,
                        seq_len=24, rows_per_group=256)
        size = os.path.getsize(base)

        for frac_label, n_users in (("one_user", 1), ("2pct", 16), ("8pct", 64)):
            path = os.path.join(td, f"del_{frac_label}.bln")
            shutil.copy(base, path)
            # users are sorted -> each user's rows are contiguous (the
            # production layout the paper assumes)
            from repro.core import BullionReader
            with BullionReader(path) as r:
                uid = r.read_column("user_id")
            # pick users from the middle of the id range: FOR/dict-masked
            # slots decode to page-min/0 placeholders, which would otherwise
            # collide with the smallest ids and read as phantom occurrences
            all_users = np.unique(uid)
            users = all_users[len(all_users) // 2: len(all_users) // 2 + n_users]
            rows = np.flatnonzero(np.isin(uid, users))
            stats = delete_rows(path, rows, Compliance.LEVEL2)
            audit = verify_deleted(path, "user_id", users)
            assert audit["visible_rows"] == 0
            reduction = stats.bytes_full_rewrite / max(stats.bytes_rewritten, 1)
            data_red = stats.bytes_full_rewrite / max(stats.bytes_rewritten_data, 1)
            report(f"deletion/L2_data_io_reduction/{frac_label}", data_red,
                   f"{data_red:.1f}x data-only (the paper's comparison); "
                   f"{reduction:.1f}x incl. footer metadata rewrite "
                   f"({stats.rows_deleted} rows, "
                   f"{stats.pages_masked_in_place} in-place, "
                   f"{stats.pages_relocated} relocated, "
                   f"raw_left={audit['raw_occurrences']})")
            hash_ratio = stats.hash_ops_monolithic / max(stats.hash_ops_incremental, 1)
            report(f"deletion/merkle_hash_reduction/{frac_label}", hash_ratio,
                   f"{hash_ratio:.1f}x fewer hash ops")

        # L1 (deletion-vector only) as the cheap-but-noncompliant reference
        path = os.path.join(td, "del_l1.bln")
        shutil.copy(base, path)
        from repro.core import BullionReader
        with BullionReader(path) as r:
            uid = r.read_column("user_id")
        mid = np.unique(uid)[len(np.unique(uid)) // 2:][:5]
        rows = np.flatnonzero(np.isin(uid, mid))
        stats = delete_rows(path, rows, Compliance.LEVEL1)
        audit = verify_deleted(path, "user_id", mid)
        report("deletion/L1_raw_occurrences", audit["raw_occurrences"],
               f"visible={audit['visible_rows']} raw={audit['raw_occurrences']} "
               "(L1 hides but does NOT erase)")
