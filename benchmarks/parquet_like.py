"""A Parquet/Thrift-style metadata baseline (for Fig. 5's comparison).

Parquet footers hold one ColumnMetaData struct per column, and readers must
deserialize ALL of them before locating any column (thrift compact protocol:
varint-tagged fields decoded sequentially). We reproduce that access pattern:
a varint-encoded struct stream, decoded column-by-column in Python, the same
O(n_cols) shape Zeng et al. measured. Bullion's footer (FooterView) answers
the same lookup with two preads + a binary search over numpy views.
"""

from __future__ import annotations

import struct

import numpy as np


def _write_varint(buf: bytearray, v: int) -> None:
    while True:
        b = v & 0x7F
        v >>= 7
        if v:
            buf.append(b | 0x80)
        else:
            buf.append(b)
            return


def _read_varint(mv: bytes, off: int) -> tuple[int, int]:
    out = shift = 0
    while True:
        b = mv[off]
        off += 1
        out |= (b & 0x7F) << shift
        if not b & 0x80:
            return out, off
        shift += 7


def build_footer(n_cols: int, seed: int = 0) -> bytes:
    """Thrift-ish footer: per column {id, offset, size, n_values, encoding,
    min, max, name} with varint framing."""
    rng = np.random.default_rng(seed)
    buf = bytearray()
    _write_varint(buf, n_cols)
    off = 0
    for c in range(n_cols):
        size = int(rng.integers(1 << 10, 1 << 20))
        name = f"feature_{c}".encode()
        for v in (c, off, size, int(rng.integers(1, 1 << 20)),
                  int(rng.integers(0, 8))):
            _write_varint(buf, v)
        buf += struct.pack("<qq", int(rng.integers(-1 << 40, 1 << 40)),
                           int(rng.integers(-1 << 40, 1 << 40)))
        _write_varint(buf, len(name))
        buf += name
        off += size
    return bytes(buf)


def parse_footer(footer: bytes) -> list[dict]:
    """Full deserialization — what a Parquet reader must do before projecting."""
    n, off = _read_varint(footer, 0)
    cols = []
    for _ in range(n):
        cid, off = _read_varint(footer, off)
        data_off, off = _read_varint(footer, off)
        size, off = _read_varint(footer, off)
        nvals, off = _read_varint(footer, off)
        enc, off = _read_varint(footer, off)
        mn, mx = struct.unpack_from("<qq", footer, off)
        off += 16
        nlen, off = _read_varint(footer, off)
        name = footer[off:off + nlen].decode()
        off += nlen
        cols.append({"id": cid, "offset": data_off, "size": size,
                     "n_values": nvals, "encoding": enc, "min": mn, "max": mx,
                     "name": name})
    return cols


def lookup_column(footer: bytes, name: str) -> dict:
    """Parquet-style projection: parse everything, then find the column."""
    for col in parse_footer(footer):
        if col["name"] == name:
            return col
    raise KeyError(name)
