"""Materialization-sink benchmark: compaction, purge, recluster.

Writes an *unclustered* dataset (shuffled ids — zone maps can prove nothing),
deletes ~10% of rows with deletion vectors only (merge-on-read: bytes stay on
disk), then drives ``Dataset.write_to``:

* compaction throughput (rows/s) for the streaming rewrite,
* on-disk size before vs after the physical purge,
* pre/post-recluster plan-proven ``pruned_bytes`` on the paper benchmark's
  0.0015%-selectivity point probe (one id out of 65536): the sort_by rewrite
  is what turns zone maps from useless to near-perfect on the probe column,
* parallel (``parallelism=4``) vs serial rewrite equivalence.

``BULLION_BENCH_SMOKE=1`` shrinks the dataset for CI smoke runs (same code
path, same CSV schema, smaller constants).
"""

from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from repro.core import BullionWriter, ColumnSpec, Compliance, delete_rows, \
    verify_deleted
from repro.dataset import dataset
from repro.scan import C

SMOKE = bool(os.environ.get("BULLION_BENCH_SMOKE"))


def _write_unclustered(path: str, n_rows: int, rows_per_group: int,
                       seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    ids = rng.permutation(n_rows).astype(np.int64)
    w = BullionWriter(path, [
        ColumnSpec("id", "int64"),
        ColumnSpec("quality", "float32"),
        ColumnSpec("payload", "float32"),
    ], rows_per_group=rows_per_group)
    w.write_table({
        "id": ids,
        "quality": rng.random(n_rows).astype(np.float32),
        "payload": rng.normal(size=n_rows).astype(np.float32),
    })
    w.close()
    return ids


def run(report):
    n_rows = 8192 if SMOKE else 65536
    rows_per_group = 512
    victim = n_rows // 3                      # survives the delete below
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "hot.bln")
        ids = _write_unclustered(path, n_rows, rows_per_group)

        # merge-on-read delete of ~10%: DVs only, data still on disk
        erased = np.arange(n_rows - n_rows // 10, n_rows)
        delete_rows(path, np.flatnonzero(np.isin(ids, erased)),
                    level=Compliance.LEVEL1)
        size_before = os.path.getsize(path)
        audit = verify_deleted(path, "id", erased)
        assert audit["raw_occurrences"] > 0, "L1 delete must leave raw bytes"

        # unclustered probe: the zone maps can prune (almost) nothing
        with dataset(path) as ds:
            pre = ds.where(C("id") == victim).select(["payload"]) \
                .physical_plan()

        # compact + recluster: purge DV rows, sort by id, re-encode
        out = os.path.join(td, "compacted")
        t0 = time.perf_counter()
        with dataset(path) as ds:
            res = ds.write_to(out, shard_rows=n_rows // 4, sort_by="id")
        t_compact = time.perf_counter() - t0

        report("compact/rows_per_s", res.rows / max(t_compact, 1e-9),
               f"{res.rows} rows -> {res.shards} shard(s) "
               f"in {t_compact * 1e3:.0f}ms")
        report("compact/size_purge_ratio",
               size_before / max(res.bytes_written, 1),
               f"{size_before}B (10% DV-deleted) -> {res.bytes_written}B "
               "after physical purge")

        # compliance: the purge physically erased every DV'd row
        for p in res.paths:
            a = verify_deleted(p, "id", erased)
            assert a["raw_occurrences"] == 0 and a["visible_rows"] == 0, \
                f"purge left deleted rows in {p}: {a}"

        # recluster: the same 0.0015%-selectivity probe now prunes
        with dataset(out) as ds:
            q = ds.where(C("id") == victim).select(["payload"])
            post = q.physical_plan()
            got = q.to_table()["payload"]
        with dataset(path) as ds:
            expect = ds.where(C("id") == victim).select(["payload"]) \
                .to_table()["payload"]
        assert np.array_equal(got, expect), "recluster changed the result"
        # sketches already refute most groups on the *unclustered* probe
        # (value membership needs no clustering), so the recluster's win is
        # measured on what sort_by actually changes: groups the zone maps
        # alone can prove away
        pre_zone = pre.groups_pruned - pre.groups_pruned_sketch
        post_zone = post.groups_pruned - post.groups_pruned_sketch
        assert post_zone > pre_zone, \
            "sort_by must strictly improve zone-map pruning on the probe " \
            f"column (zone-proven groups {pre_zone} -> {post_zone})"
        report("compact/probe_pruned_bytes_post_recluster", post.bytes_pruned,
               f"{post.groups_pruned}/{post.groups_total} groups pruned "
               f"(was {pre.groups_pruned}/{pre.groups_total} unclustered)",
               pruned_bytes=post.bytes_pruned)
        report("compact/probe_pruned_gain",
               post.bytes_pruned / max(pre.bytes_pruned, 1),
               f"{pre.bytes_pruned}B -> {post.bytes_pruned}B plan-proven "
               "prunable on the point probe")

        # parallel rewrite: identical output tables, wall-clock comparison
        out_par = os.path.join(td, "compacted_par")
        t0 = time.perf_counter()
        with dataset(path) as ds:
            res_par = ds.write_to(out_par, shard_rows=n_rows // 4,
                                  sort_by="id", parallelism=4)
        t_par = time.perf_counter() - t0
        with dataset(out) as a, dataset(out_par) as b:
            ta, tb = a.to_table(), b.to_table()
            assert all(np.array_equal(ta[k], tb[k]) for k in ta), \
                "parallel rewrite diverged from serial"
        assert res_par.rows == res.rows
        # determinism is the contract; wall-clock parity is workload-bound.
        # On a hot page cache the decode path is GIL-bound, so this ratio
        # hovers near/below 1 — the pool's payoff is I/O-latency-bound
        # storage (cold files, network filesystems), which a tmpfs
        # microbenchmark cannot show. Tracked so regressions in pool
        # overhead still surface in the trajectory.
        report("compact/parallel_rewrite_ratio", t_compact / max(t_par, 1e-9),
               f"serial {t_compact * 1e3:.0f}ms vs parallelism=4 "
               f"{t_par * 1e3:.0f}ms, identical output")
