"""Test-environment shims.

``hypothesis`` and ``zstandard`` are optional in the container this repo
targets. The seed property-based tests only use a narrow slice of the
hypothesis API, so when the real package is missing we install a minimal
deterministic stand-in (fixed seed, fixed example count) rather than skipping
whole test modules. With the real hypothesis installed, the shim is inert.
"""

from __future__ import annotations

import sys
import types

try:
    import hypothesis  # noqa: F401
except ImportError:
    import numpy as np

    class _Strategy:
        def __init__(self, draw_fn):
            self._draw_fn = draw_fn

        def draw(self, rng):
            return self._draw_fn(rng)

    class _DataObject:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.draw(self._rng)

    class _DataStrategy(_Strategy):
        def __init__(self):
            super().__init__(lambda rng: _DataObject(rng))

    def _integers(min_value, max_value):
        return _Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    def _floats(min_value, max_value):
        return _Strategy(
            lambda rng: float(min_value + (max_value - min_value) * rng.random()))

    def _sampled_from(options):
        opts = list(options)
        return _Strategy(lambda rng: opts[int(rng.integers(0, len(opts)))])

    def _sets(elements, min_size=0, max_size=None):
        cap = min_size + 8 if max_size is None else max_size

        def draw(rng):
            out = set()
            for _ in range(200):
                if len(out) >= min_size and (
                        len(out) >= cap or rng.random() < 0.3):
                    break
                out.add(elements.draw(rng))
            return out

        return _Strategy(draw)

    def _composite(fn):
        def make(*args, **kw):
            def draw_fn(rng):
                return fn(lambda s: s.draw(rng), *args, **kw)
            return _Strategy(draw_fn)
        return make

    _DEFAULT_EXAMPLES = 25

    def _given(*strategies, **kw_strategies):
        def deco(fn):
            # plain zero-arg wrapper: pytest must not see the drawn arguments
            # as fixtures, so the original signature is deliberately hidden
            def wrapper():
                n = getattr(wrapper, "_shim_max_examples", _DEFAULT_EXAMPLES)
                for ex in range(n):
                    rng = np.random.default_rng(ex)
                    drawn = [s.draw(rng) for s in strategies]
                    kw = {k: s.draw(rng) for k, s in kw_strategies.items()}
                    fn(*drawn, **kw)

            wrapper.__name__ = fn.__name__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    def _settings(max_examples=_DEFAULT_EXAMPLES, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    mod = types.ModuleType("hypothesis")
    mod.given = _given
    mod.settings = _settings
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = _integers
    st_mod.floats = _floats
    st_mod.sampled_from = _sampled_from
    st_mod.sets = _sets
    st_mod.composite = _composite
    st_mod.data = lambda: _DataStrategy()
    mod.strategies = st_mod
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod
