"""Bullion-backed data pipeline tests."""

import numpy as np

from repro.data import BullionLoader, write_ads_table, write_lm_corpus
from repro.data.loader import LoaderState


def test_loader_batches_and_shapes(tmp_path):
    path = str(tmp_path / "c.bln")
    write_lm_corpus(path, n_docs=64, vocab=128, doc_len=256, rows_per_group=16)
    loader = BullionLoader(path, batch_size=4, seq_len=64)
    it = iter(loader)
    seen = []
    for _ in range(10):
        batch, cursor = next(it)
        assert batch.shape == (4, 65)
        assert batch.dtype == np.int32
        assert batch.min() >= 0 and batch.max() < 128
        seen.append(batch)
    # deterministic stream: batches differ (not stuck)
    assert not np.array_equal(seen[0], seen[1])
    loader.close()


def test_loader_rank_sharding(tmp_path):
    path = str(tmp_path / "c.bln")
    write_lm_corpus(path, n_docs=64, vocab=128, doc_len=256, rows_per_group=8)
    l0 = BullionLoader(path, batch_size=2, seq_len=64, rank=0, world=2)
    l1 = BullionLoader(path, batch_size=2, seq_len=64, rank=1, world=2)
    b0, _ = next(iter(l0))
    b1, _ = next(iter(l1))
    assert not np.array_equal(b0, b1)  # disjoint row groups
    l0.close(); l1.close()


def test_loader_cursor_resume(tmp_path):
    path = str(tmp_path / "c.bln")
    write_lm_corpus(path, n_docs=64, vocab=128, doc_len=256, rows_per_group=8)
    loader = BullionLoader(path, batch_size=2, seq_len=64)
    it = iter(loader)
    batches, cursors = [], []
    for _ in range(6):
        b, c = next(it)
        batches.append(b)
        cursors.append(c)
    loader.close()
    # resume from cursor 2: group-aligned semantics — the resumed stream
    # restarts exactly at the cursor's group boundary
    cur = cursors[2]
    resumed = BullionLoader(path, batch_size=2, seq_len=64,
                            state=LoaderState(cur.epoch, cur.group))
    rb, _ = next(iter(resumed))
    from repro.core import BullionReader
    with BullionReader(path) as r:
        docs = []
        for tbl in r.project(["tokens"], groups=range(cur.group, cur.group + 4)):
            docs.extend(tbl["tokens"])
    stream = np.concatenate([np.asarray(d, np.int32) for d in docs])
    expect = stream[: 2 * 65].reshape(2, 65)
    assert np.array_equal(rb, expect), "resume diverged from group boundary"
    resumed.close()


def test_ads_table_roundtrip(tmp_path):
    path = str(tmp_path / "ads.bln")
    stats = write_ads_table(path, n_rows=1024, n_sparse=3, n_dense=2,
                            seq_len=16, rows_per_group=256)
    assert stats["rows"] == 1024
    from repro.core import BullionReader
    with BullionReader(path) as r:
        assert len(r.column_names) == 3 + 2 + 3
        seqs = r.read_column("clk_seq_0")
        assert len(seqs) == 1024 and all(len(s) == 16 for s in seqs)
