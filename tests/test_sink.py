"""Materialization sink (Dataset.write_to) + parallel execution tests:
round-trip equality, v0->v1 upgrade, compliance purge audited with
verify_deleted, resharding row counts, recluster pruning gains, streaming
writer mode, stats-driven encoding advisor, multi-shard delete_where, and
parallel == serial determinism."""

import os

import numpy as np
import pytest

from repro.core import (BullionReader, BullionWriter, ColumnSpec, Compliance,
                        QuantMode, QuantSpec, delete_rows, delete_where,
                        verify_deleted)
from repro.core.encodings import (advise_candidates, blob_encoding_name,
                                  choose_encoding)
from repro.dataset import dataset
from repro.scan import C, stats_record


def _write(path, *, n=2000, rows_per_group=250, collect_stats=True, seed=0,
           shuffle_ids=False):
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("score", "float32"),
        ColumnSpec("qx", "float32", quant=QuantSpec(QuantMode.BF16)),
        ColumnSpec("tag", "string"),
        ColumnSpec("seq", "list<int64>"),
    ]
    ids = np.arange(n, dtype=np.int64)
    if shuffle_ids:
        ids = rng.permutation(ids)
    table = {
        "id": ids,
        "score": rng.random(n).astype(np.float32),
        "qx": rng.normal(size=n).astype(np.float32),
        "tag": [b"t%d" % (i % 7) for i in range(n)],
        "seq": [np.arange(i % 5, dtype=np.int64) for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      collect_stats=collect_stats)
    w.write_table(table)
    w.close()
    return table


def _assert_tables_equal(got, expect, idx=None):
    for k, v in got.items():
        e = expect[k] if idx is None else (
            expect[k][idx] if isinstance(expect[k], np.ndarray)
            else [expect[k][i] for i in idx])
        if isinstance(v, np.ndarray):
            assert np.array_equal(v, np.asarray(e)), k
        elif v and isinstance(v[0], np.ndarray):
            assert len(v) == len(e) and \
                all(np.array_equal(a, b) for a, b in zip(v, e)), k
        else:
            assert v == list(e), k


# ---------------------------------------------------------------------------
# tentpole: write_to round trips, purges, reshards, reclusters
# ---------------------------------------------------------------------------


def test_compact_round_trip_table_in_table_out(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        res = ds.write_to(out)
    assert res.rows == 2000 and res.shards == 1
    assert res.bytes_written == sum(os.path.getsize(p) for p in res.paths)
    with dataset(out) as ds:
        got = ds.dequantized(False).to_table()
    with dataset(path) as ds:
        raw = ds.dequantized(False).to_table()
    # storage-exact round trip: same quant spec re-quantizes to the same bits
    for k in got:
        if isinstance(got[k], np.ndarray):
            assert np.array_equal(got[k], raw[k]), k
    with dataset(out) as ds:
        _assert_tables_equal(
            ds.select(["id", "score", "tag", "seq"]).to_table(), table)


def test_write_to_composes_with_plan(tmp_path):
    """Filters, projections, and head limits all shape the output."""
    path = str(tmp_path / "t.bln")
    table = _write(path)
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        res = ds.where((C("id") >= 500) & (C("id") < 900)) \
            .select(["id", "tag"]).write_to(out)
    assert res.rows == 400
    with dataset(out) as ds:
        assert ds.column_names == ["id", "tag"]
        got = ds.to_table()
    idx = np.arange(500, 900)
    assert np.array_equal(got["id"], table["id"][idx])
    assert got["tag"] == [table["tag"][i] for i in idx]
    out2 = str(tmp_path / "out2")
    with dataset(path) as ds:
        assert ds.select(["id"]).head(123).write_to(out2).rows == 123


def test_v0_upgrades_to_v1_via_write_to(tmp_path):
    path = str(tmp_path / "v0.bln")
    _write(path, collect_stats=False)
    with BullionReader(path) as r:
        assert not r.footer.has_stats
    out = str(tmp_path / "v1")
    with dataset(path) as ds:
        res = ds.write_to(out)
    with BullionReader(res.paths[0]) as r:
        assert r.footer.has_stats and r.footer.format_version >= 1
    with dataset(out) as ds:
        phys = ds.where(C("id") == 7).select(["score"]).physical_plan()
        assert phys.groups_pruned > 0 and phys.bytes_pruned > 0


def test_purge_physically_erases_deleted_rows(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    victims = np.arange(100, 160)
    delete_rows(path, victims, level=Compliance.LEVEL1)   # DV-only
    audit = verify_deleted(path, "id", victims)
    assert audit["visible_rows"] == 0 and audit["raw_occurrences"] == 60
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        res = ds.write_to(out, shard_rows=700)
    assert res.rows == 1940
    for p in res.paths:
        a = verify_deleted(p, "id", victims)
        assert a["visible_rows"] == 0 and a["raw_occurrences"] == 0
    with dataset(out) as ds:
        assert ds.count_rows() == 1940
        assert ds.drop_deleted(False).count_rows() == 1940  # no DVs at all


def test_resharding_row_counts(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        res = ds.write_to(out, shard_rows=600, rows_per_group=200)
    assert res.shards == 4
    assert res.rows_per_shard == [600, 600, 600, 200]
    assert [os.path.basename(p) for p in res.paths] == \
        [f"part-{i:05d}.bln" for i in range(4)]
    for p, want in zip(res.paths, res.rows_per_shard):
        with BullionReader(p) as r:
            assert r.num_rows == want
    with dataset(out) as ds:
        assert ds.n_shards == 4
        assert np.array_equal(ds.select(["id"]).to_table()["id"], table["id"])


def test_recluster_strictly_improves_pruning(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path, shuffle_ids=True)
    victim = 1234
    with dataset(path) as ds:
        pre = ds.where(C("id") == victim).select(["score"]).physical_plan()
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        ds.write_to(out, sort_by="id")
    with dataset(out) as ds:
        q = ds.where(C("id") == victim).select(["score"])
        post = q.physical_plan()
        # sketches already refute most groups on the unclustered probe
        # (value membership needs no clustering), so measure the recluster
        # win on what sort_by actually changes: groups the zone maps alone
        # can prove away
        pre_zone = pre.groups_pruned - pre.groups_pruned_sketch
        post_zone = post.groups_pruned - post.groups_pruned_sketch
        assert post_zone > pre_zone
        # the reclustered probe still returns the right row
        got = q.to_table()["score"]
    src = int(np.flatnonzero(table["id"] == victim)[0])
    assert np.array_equal(got, table["score"][src:src + 1])
    # sorted output: ids are monotone
    with dataset(out) as ds:
        ids = ds.select(["id"]).to_table()["id"]
    assert np.array_equal(ids, np.sort(table["id"]))


def test_recluster_with_sort_udf(tmp_path):
    from repro.core import quality_sort
    path = str(tmp_path / "t.bln")
    table = _write(path)
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        ds.select(["id", "score"]).write_to(out, sort_by=quality_sort("score"))
    with dataset(out) as ds:
        got = ds.to_table()
    order = np.argsort(-table["score"], kind="stable")
    assert np.array_equal(got["id"], table["id"][order])
    assert np.array_equal(got["score"], table["score"][order])


def test_write_to_validation_errors(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    out = str(tmp_path / "out")
    with dataset(path) as ds:
        with pytest.raises(ValueError, match="shard_rows"):
            ds.write_to(out, shard_rows=0)
        with pytest.raises(KeyError, match="sort_by"):
            ds.select(["id"]).write_to(out, sort_by="score")
        ds.write_to(out)
        # refuses to mix datasets in a non-empty output directory
        with pytest.raises(FileExistsError, match="already holds"):
            ds.write_to(out)


def test_write_to_empty_result_still_opens(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    out = str(tmp_path / "empty")
    with dataset(path) as ds:
        res = ds.where(C("id") == 10 ** 9).select(["id", "tag"]).write_to(out)
    assert res.rows == 0 and res.shards == 1
    with dataset(out) as ds:
        assert ds.count_rows() == 0
        tbl = ds.to_table()
        assert tbl["id"].dtype == np.int64 and len(tbl["id"]) == 0


# ---------------------------------------------------------------------------
# parallel execution: identical results, shared by reads and the sink
# ---------------------------------------------------------------------------


def test_parallel_terminals_match_serial(tmp_path):
    d = str(tmp_path / "shards")
    os.makedirs(d)
    for s in range(3):
        _write(os.path.join(d, f"part-{s:04d}.bln"), n=1000, seed=s)
    pred = (C("score") >= 0.2) & (C("score") < 0.7)
    with dataset(d) as ds:
        q = ds.where(pred).select(["id", "score", "tag"])
        serial = q.to_table()
        serial_ids = q.row_ids()
    with dataset(d) as ds:
        q = ds.where(pred).select(["id", "score", "tag"])
        par = q.to_table(parallelism=4)
        par_ids = q.row_ids(parallelism=4)
        assert q.count_rows(parallelism=4) == len(serial_ids)
        batches = list(q.scan_batches(parallelism=4))
    assert np.array_equal(serial_ids, par_ids)
    assert np.array_equal(serial["id"], par["id"])
    assert np.array_equal(serial["score"], par["score"])
    assert serial["tag"] == par["tag"]
    assert np.array_equal(np.concatenate([b.row_ids for b in batches]),
                          serial_ids)


def test_parallel_head_limit_matches_serial(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    with dataset(path) as ds:
        got = ds.select(["id"]).head(300).to_table(parallelism=4)["id"]
    assert np.array_equal(got, table["id"][:300])


def test_parallel_write_to_identical_output(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path, shuffle_ids=True)
    a, b = str(tmp_path / "a"), str(tmp_path / "b")
    with dataset(path) as ds:
        ra = ds.write_to(a, shard_rows=700)
    with dataset(path) as ds:
        rb = ds.write_to(b, shard_rows=700, parallelism=4)
    assert ra.rows == rb.rows and ra.rows_per_shard == rb.rows_per_shard
    for pa, pb in zip(ra.paths, rb.paths):
        with open(pa, "rb") as fa, open(pb, "rb") as fb:
            assert fa.read() == fb.read()     # byte-identical shards


# ---------------------------------------------------------------------------
# streaming writer + stats-driven encoding advisor
# ---------------------------------------------------------------------------


def test_stream_writer_matches_batch_writer(tmp_path):
    rng = np.random.default_rng(3)
    schema = [ColumnSpec("a", "int64"), ColumnSpec("s", "string")]
    tbl = {"a": rng.integers(0, 50, 1000),
           "s": [b"x%d" % (i % 3) for i in range(1000)]}
    batch, stream = str(tmp_path / "b.bln"), str(tmp_path / "s.bln")
    w = BullionWriter(batch, schema, rows_per_group=64)
    w.write_table(tbl)
    w.close()
    w = BullionWriter(stream, schema, rows_per_group=64, stream=True)
    for lo in range(0, 1000, 37):                # ragged incremental writes
        w.write_table({k: v[lo:lo + 37] for k, v in tbl.items()})
    info = w.close()
    assert info["rows"] == 1000 and info["groups"] == 16
    with open(batch, "rb") as fb, open(stream, "rb") as fs:
        assert fb.read() == fs.read()
    with pytest.raises(ValueError, match="stream"):
        BullionWriter(str(tmp_path / "x.bln"), schema, stream=True,
                      sort_udf=lambda t: np.arange(1))


def test_writer_close_is_idempotent(tmp_path):
    for stream in (False, True):
        p = str(tmp_path / f"c{stream}.bln")
        w = BullionWriter(p, [ColumnSpec("a", "int64")], rows_per_group=4,
                          stream=stream)
        w.write_table({"a": np.arange(10)})
        first = w.close()
        size = os.path.getsize(p)
        assert w.close() == first              # second close must not rewrite
        assert os.path.getsize(p) == size
        with dataset(p) as ds:
            assert np.array_equal(ds.to_table()["a"], np.arange(10))


def test_failed_write_to_cleans_up_and_is_retryable(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    out = str(tmp_path / "out")

    def bad_sort(tbl):
        raise RuntimeError("sort exploded")

    with dataset(path) as ds:
        with pytest.raises(RuntimeError, match="sort exploded"):
            ds.write_to(out, shard_rows=500, sort_by=bad_sort)
        assert os.listdir(out) == []           # no partial shards left
        res = ds.write_to(out, shard_rows=500)  # retry is not blocked
    assert res.rows == 2000
    with dataset(out) as ds:
        assert np.array_equal(ds.select(["id"]).to_table()["id"], table["id"])


def test_output_schema_sniffs_sparse_delta_across_shards(tmp_path):
    from repro.dataset.sink import output_schema
    d = str(tmp_path / "shards")
    os.makedirs(d)
    schema = [ColumnSpec("seq", "list<int64>", sparse_delta=True)]
    # shard 0: unrelated rows -> the size guard ships plain LIST pages;
    # shard 1: window-sharing rows -> sparse delta wins and is recorded
    rng = np.random.default_rng(0)
    w = BullionWriter(os.path.join(d, "part-0000.bln"), schema,
                      rows_per_group=64)
    w.write_table({"seq": [rng.integers(0, 2 ** 40, 64) for _ in range(64)]})
    w.close()
    base = np.arange(4096, dtype=np.int64)
    w = BullionWriter(os.path.join(d, "part-0001.bln"), schema,
                      rows_per_group=64)
    w.write_table({"seq": [base[i:i + 128] for i in range(64)]})
    w.close()
    from repro.core import PageType, Sec
    from repro.core.reader import BullionReader as BR
    with BR(os.path.join(d, "part-0000.bln")) as r:
        flags0 = r.footer.arr(Sec.PAGE_FLAGS, np.uint8)
    with BR(os.path.join(d, "part-0001.bln")) as r:
        flags1 = r.footer.arr(Sec.PAGE_FLAGS, np.uint8)
    assert not (flags0 & 0x7F == int(PageType.SPARSE_DELTA)).any()
    assert (flags1 & 0x7F == int(PageType.SPARSE_DELTA)).any()
    with dataset(d) as ds:
        (spec,) = output_schema(ds._source, ("seq",), True)
        assert spec.sparse_delta    # shard 0 alone would say False


def test_advise_candidates_families():
    const = stats_record(np.zeros(500, np.int64) + 7)
    assert "constant" in advise_candidates(const, 500, np.dtype(np.int64))
    lowcard = stats_record(np.arange(500, dtype=np.int64) % 4)
    assert "dictionary" in advise_candidates(lowcard, 500, np.dtype(np.int64))
    unique = stats_record(np.arange(500, dtype=np.int64) + 10 ** 12)
    assert "bitshuffle" in advise_candidates(unique, 500, np.dtype(np.int64))
    narrow = stats_record(np.repeat(
        np.arange(250, dtype=np.int64), 2) + 10 ** 12)
    assert "for" in advise_candidates(narrow, 500, np.dtype(np.int64))
    wide = stats_record(
        np.random.default_rng(0).integers(0, 2 ** 40, 500))
    assert advise_candidates(wide, 500, np.dtype(np.int64)) is None
    assert advise_candidates(None, 500, np.dtype(np.int64)) is None


def test_advisor_agrees_with_sampling_cascade(tmp_path):
    """For clear-cut chunks (constant, low-cardinality, all-unique narrow
    range) the advisor's restricted list contains the full cascade's pick,
    and the restricted choice stays lossless."""
    from repro.core import EncodeContext
    from repro.core.encodings import decode_blob, encode_array

    rng = np.random.default_rng(1)
    for arr in (np.full(2000, 9, np.int64),
                rng.integers(0, 3, 2000),
                np.arange(2000, dtype=np.int64) + 5_000_000):
        rec = stats_record(arr)
        advised = advise_candidates(rec, len(arr), arr.dtype)
        assert advised is not None
        assert choose_encoding(arr) in advised
        blob = encode_array(arr, EncodeContext(candidates=advised))
        assert np.array_equal(decode_blob(blob), arr)


def test_write_to_advisor_output_decodes_identically(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with_adv, without = str(tmp_path / "adv"), str(tmp_path / "noadv")
    with dataset(path) as ds:
        ds.write_to(with_adv)
    with dataset(path) as ds:
        ds.write_to(without, use_advisor=False)
    with dataset(with_adv) as da, dataset(without) as db:
        ta, tb = da.to_table(), db.to_table()
    for k in ta:
        if isinstance(ta[k], np.ndarray):
            assert np.array_equal(ta[k], tb[k]), k


# ---------------------------------------------------------------------------
# multi-shard delete_where + stale-handle protection
# ---------------------------------------------------------------------------


def test_delete_where_fans_across_shards(tmp_path):
    d = str(tmp_path / "shards")
    os.makedirs(d)
    for s in range(3):
        t = _write(os.path.join(d, f"part-{s:04d}.bln"), n=1000, seed=s)
        # shard-local ids 0..999 in every shard -> matches span all shards
        assert np.array_equal(np.sort(t["id"]), np.arange(1000))
    st = delete_where(d, C("id") < 10, level=Compliance.LEVEL2)
    assert st.rows_deleted == 30                 # 10 rows in each of 3 shards
    assert st.pages_touched > 0
    with dataset(d) as ds:
        assert ds.where(C("id") < 10).count_rows() == 0
        assert ds.count_rows() == 2970
    for s in range(3):
        a = verify_deleted(os.path.join(d, f"part-{s:04d}.bln"), "id",
                           np.arange(1, 10))    # 0 is the masking value
        assert a["visible_rows"] == 0 and a["raw_occurrences"] == 0


def test_delete_where_only_rewrites_matching_shards(tmp_path):
    d = str(tmp_path / "shards")
    os.makedirs(d)
    paths = []
    for s in range(3):                # disjoint id ranges per shard
        p = os.path.join(d, f"part-{s:04d}.bln")
        paths.append(p)
        w = BullionWriter(p, [ColumnSpec("id", "int64")], rows_per_group=250)
        w.write_table({"id": np.arange(s * 1000, (s + 1) * 1000)})
        w.close()
    before = [open(p, "rb").read() for p in paths]
    st = delete_where(d, (C("id") >= 1500) & (C("id") < 1600))
    assert st.rows_deleted == 100     # all in shard 1 (global->local mapped)
    after = [open(p, "rb").read() for p in paths]
    assert after[0] == before[0] and after[2] == before[2]
    assert after[1] != before[1]
    with dataset(d) as ds:
        assert ds.count_rows() == 2900
        got = ds.where((C("id") >= 1400) & (C("id") < 1700)).to_table()["id"]
    assert np.array_equal(got, np.r_[np.arange(1400, 1500),
                                     np.arange(1600, 1700)])


def test_delete_where_invalidates_stale_dataset(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    ds = dataset(path)
    st = ds.delete_where(C("id") < 5)
    assert st.rows_deleted == 5
    with pytest.raises(ValueError, match="stale"):
        ds.count_rows()
    # reopening observes the deletion
    with dataset(path) as fresh:
        assert fresh.count_rows() == 1995
    # no-match deletes leave the dataset usable
    ds2 = dataset(path)
    assert ds2.delete_where(C("id") == 10 ** 9).rows_deleted == 0
    assert ds2.count_rows() == 1995
    ds2.close()


# ---------------------------------------------------------------------------
# discovery error messages (empty dir / glob / missing path)
# ---------------------------------------------------------------------------


def test_dataset_over_missing_and_empty_sources_raises_clearly(tmp_path):
    empty = tmp_path / "empty"
    empty.mkdir()
    with pytest.raises(FileNotFoundError, match="no Bullion shards"):
        dataset(str(empty))
    with pytest.raises(FileNotFoundError, match="matched no files"):
        dataset(str(tmp_path / "nothing-*.bln"))
    with pytest.raises(FileNotFoundError, match="does not exist"):
        dataset(str(tmp_path / "missing_dir"))
    with pytest.raises(FileNotFoundError, match="empty dataset path list"):
        dataset([])
