"""Observability substrate tests: span tracer (nesting, threads, disabled
no-op), metrics registry, Chrome trace export, explain(analyze=True) /
profile() reconciliation with IOStats, BULLION_TRACE end-to-end, and the
benchmark-CSV <-> IOStats schema sync regression."""

import dataclasses
import json
import os
import subprocess
import sys
import threading

import numpy as np
import pytest

from repro.core import BullionWriter, ColumnSpec
from repro.core.reader import IOStats
from repro.dataset import dataset
from repro.obs import export, metrics, trace
from repro.scan import C


def _write(path, *, n=1000, rows_per_group=100):
    rng = np.random.default_rng(0)
    w = BullionWriter(path, [ColumnSpec("id", "int64"),
                             ColumnSpec("score", "float32")],
                      rows_per_group=rows_per_group)
    w.write_table({"id": np.arange(n, dtype=np.int64),
                   "score": rng.random(n).astype(np.float32)})
    w.close()
    return path


@pytest.fixture
def shard(tmp_path):
    return _write(str(tmp_path / "t.bln"))


@pytest.fixture(autouse=True)
def _isolate_tracer():
    """Save/restore the process-wide tracer slot: CI runs the suite under
    BULLION_TRACE, and tests that install/disable must not leak."""
    prev = trace.current()
    yield
    trace.install(prev)


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_records_name_args_and_duration():
    with trace.collect() as tr:
        with trace.span("unit.op", cat="test", pages=3) as sp:
            sp.set(bytes=128)
    (rec,) = tr.spans
    assert rec.name == "unit.op" and rec.cat == "test"
    assert rec.args == {"pages": 3, "bytes": 128}
    assert rec.dur >= 0.0 and rec.tid == threading.get_ident()


def test_nested_spans_both_record_and_nest_by_time():
    with trace.collect() as tr:
        with trace.span("outer"):
            with trace.span("inner"):
                pass
    by_name = {s.name: s for s in tr.spans}
    assert set(by_name) == {"outer", "inner"}
    o, i = by_name["outer"], by_name["inner"]
    # inner finished first (records append on exit) and sits inside outer
    assert tr.spans[0].name == "inner"
    assert o.ts <= i.ts and i.ts + i.dur <= o.ts + o.dur + 1e-9


def test_collect_forwards_to_enclosing_tracer():
    with trace.collect() as outer:
        with trace.span("before"):
            pass
        with trace.collect() as inner:
            with trace.span("scoped"):
                pass
        with trace.span("after"):
            pass
    assert [s.name for s in inner.spans] == ["scoped"]
    # the outer recording saw everything, including the scoped block
    assert [s.name for s in outer.spans] == ["before", "scoped", "after"]


def test_collect_restores_previous_tracer_state():
    trace.install(None)
    with trace.collect():
        assert trace.enabled()
    assert not trace.enabled()


def test_traced_decorator():
    @trace.traced(cat="test")
    def work(x):
        return x + 1

    trace.install(None)
    assert work(1) == 2                 # disabled: plain call
    with trace.collect() as tr:
        assert work(2) == 3
    assert len(tr.spans) == 1
    assert tr.spans[0].name.endswith("work")


def test_span_cap_counts_dropped():
    with trace.collect(max_spans=2) as tr:
        for _ in range(5):
            with trace.span("x"):
                pass
    assert len(tr.spans) == 2 and tr.dropped == 3


def test_aggregate_sums_numeric_args_only():
    with trace.collect() as tr:
        for i in range(3):
            with trace.span("s", pages=2, label="text", ok=True):
                pass
    agg = tr.aggregate()["s"]
    assert agg.count == 3 and agg.args == {"pages": 6}
    assert agg.seconds >= 0.0


def test_disabled_mode_allocates_no_spans(shard):
    trace.install(None)
    ds = dataset(shard).where(C("id") >= 500)
    before = trace.allocations()
    tbl = ds.to_table(parallelism=2, io_depth=2)
    assert len(tbl["id"]) == 500
    assert trace.allocations() == before, \
        "disabled tracing must not allocate Span objects on the scan path"
    assert trace.span("x") is trace.NULL_SPAN
    ds.close()


def test_thread_safety_under_parallel_scan(shard):
    ds = dataset(shard)
    with trace.collect() as tr:
        ds.to_table(parallelism=4, io_depth=4)
    execs = [s for s in tr.spans if s.name == "exec.task"]
    assert len(execs) == 10             # one per row group, none lost
    assert len({s.tid for s in tr.spans}) >= 2   # pool + scheduler threads
    # every record is fully formed (no torn concurrent appends)
    for s in tr.spans:
        assert isinstance(s.name, str) and s.dur >= 0.0
    ds.close()


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_and_histogram_basics():
    reg = metrics.MetricsRegistry()
    reg.counter("c").inc()
    reg.counter("c").inc(4)
    assert reg.counter("c").value == 5
    h = reg.histogram("h")
    for v in (1, 2, 3, 100):
        h.observe(v)
    assert h.count == 4 and h.sum == 106 and h.min == 1 and h.max == 100
    assert h.percentile(50) == 4.0      # rank-2 value (2) -> (2, 4] bucket
    assert h.percentile(100) == 128.0   # 100 lands in the (64, 128] bucket
    snap = reg.snapshot()
    assert snap["c"] == 5 and snap["h"]["count"] == 4


def test_histogram_underflow_bucket():
    h = metrics.Histogram("u")
    h.observe(0)
    h.observe(-3)
    h.observe(8)
    assert h.buckets()[0.0] == 2 and h.buckets()[16.0] == 1
    assert h.percentile(50) == 0.0


def test_absorb_iostats_counts_nonzero_fields():
    reg = metrics.MetricsRegistry()
    st = IOStats(preads=3, bytes_read=700, metadata_seconds=0.5)
    metrics.absorb_iostats(st, registry=reg)
    metrics.absorb_iostats(st, registry=reg)
    snap = reg.snapshot()
    assert snap["bullion.io.preads"] == 6
    assert snap["bullion.io.bytes_read"] == 1400
    assert snap["bullion.io.metadata_seconds"] == 1.0
    assert "bullion.io.wasted_bytes" not in snap    # zero fields stay absent


def test_dataset_close_absorbs_iostats_into_registry(shard):
    before = metrics.counter("bullion.io.preads").value
    ds = dataset(shard)
    ds.to_table()
    st = ds.stats
    ds.close()
    assert st.preads > 0
    assert metrics.counter("bullion.io.preads").value >= before + st.preads


# ---------------------------------------------------------------------------
# IOStats aggregation + benchmark CSV schema sync
# ---------------------------------------------------------------------------

def test_iostats_merge_sum_delta_cover_every_field():
    ones = IOStats(**{f.name: 1 for f in dataclasses.fields(IOStats)})
    twos = IOStats.sum([ones, ones])
    for f in dataclasses.fields(IOStats):
        assert getattr(twos, f.name) == 2, f.name
    assert dataclasses.asdict(twos.delta(ones)) == dataclasses.asdict(ones)
    three = IOStats(preads=1).merge(IOStats(preads=2))
    assert three.preads == 3
    assert IOStats.sum([]) == IOStats()


def test_bench_csv_columns_match_iostats_fields():
    """The run.py CSV schema must not drift from IOStats: every stat column
    maps to a real field, in declared order."""
    sys.path.insert(0, os.path.dirname(os.path.dirname(__file__)))
    try:
        from benchmarks.run import STAT_COLUMNS, STAT_FIELDS
    finally:
        sys.path.pop(0)
    field_names = {f.name for f in dataclasses.fields(IOStats)}
    assert STAT_COLUMNS == tuple(STAT_FIELDS)
    for col, field in STAT_FIELDS.items():
        assert field in field_names, \
            f"CSV column {col!r} maps to unknown IOStats field {field!r}"


# ---------------------------------------------------------------------------
# explain(analyze=True) / profile() / trace export
# ---------------------------------------------------------------------------

def _parse_io_line(text):
    (line,) = [ln for ln in text.splitlines() if ln.strip().startswith("io:")]
    out = {}
    for tok in line.split(":", 1)[1].split():
        k, v = tok.split("=")
        out[k] = float(v) if "." in v else int(v)
    return out


def test_explain_analyze_reconciles_with_iostats(shard):
    ds = dataset(shard).where(C("id") >= 500)
    before = ds.stats
    text = ds.explain(analyze=True)
    after = ds.stats
    delta = after.delta(before)
    got = _parse_io_line(text)
    for f in dataclasses.fields(IOStats):
        want = getattr(delta, f.name)
        assert got[f.name] == pytest.approx(want, abs=1e-6), f.name
    assert "Execution (analyze=True):" in text
    assert "rows out: 500" in text
    # per-stage lines show the traced pipeline
    assert "exec.task" in text and "decode.decode" in text
    ds.close()


def test_explain_analyze_counts_pruning_on_fresh_instance(shard):
    text = dataset(shard).where(C("id") < 100).explain(analyze=True)
    assert "plan.lower" in text and "scan.plan" in text
    got = _parse_io_line(text)
    assert got["bytes_pruned"] > 0 and got["pages_pruned"] > 0


def test_profile_writes_valid_chrome_trace(shard, tmp_path):
    out = str(tmp_path / "trace.json")
    ds = dataset(shard).select(["id"])
    prof = ds.profile(out, parallelism=2, io_depth=2)
    assert prof.spans and prof.dropped == 0
    doc = json.load(open(out))
    events = doc["traceEvents"]
    assert all(e["ph"] in ("M", "X") for e in events)
    x = [e for e in events if e["ph"] == "X"]
    assert {e["name"] for e in x} >= {"exec.task", "decode.pread",
                                      "decode.decode"}
    for e in x:
        assert e["ts"] >= 0 and e["dur"] >= 0
        json.dumps(e["args"])           # args survived JSON coercion
    # thread-name metadata for every tid that emitted events
    named = {e["tid"] for e in events if e["ph"] == "M"
             and e["name"] == "thread_name"}
    assert {e["tid"] for e in x} <= named
    assert prof.aggregate()["exec.task"].count == 10
    ds.close()


def test_chrome_trace_coerces_numpy_args():
    rec = trace.SpanRecord("s", "c", 0.0, 1e-3, 1, "t",
                           {"n": np.int64(4), "f": np.float32(0.5),
                            "s": "x", "o": object()})
    doc = export.chrome_trace([rec], dropped=2)
    args = doc["traceEvents"][-1]["args"]
    assert args["n"] == 4 and args["f"] == 0.5 and args["s"] == "x"
    assert isinstance(args["o"], str)
    assert doc["bullionDroppedSpans"] == 2
    json.dumps(doc)


def test_bullion_trace_env_end_to_end(shard, tmp_path):
    """BULLION_TRACE=path on a fresh interpreter writes a loadable Chrome
    trace at exit covering a real scan."""
    out = str(tmp_path / "env-trace.json")
    code = (
        "from repro.dataset import dataset\n"
        f"ds = dataset({shard!r})\n"
        "ds.to_table(io_depth=2)\n"
        "ds.close()\n"
    )
    env = dict(os.environ, BULLION_TRACE=out,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(os.path.dirname(
                       os.path.abspath(__file__))), "src"),
                    os.environ.get("PYTHONPATH", "")]))
    subprocess.run([sys.executable, "-c", code], check=True, env=env,
                   timeout=120)
    doc = json.load(open(out))
    names = {e["name"] for e in doc["traceEvents"] if e["ph"] == "X"}
    assert {"plan.optimize", "plan.lower", "exec.task"} <= names


def test_trace_cap_env_rejects_garbage(monkeypatch):
    monkeypatch.setenv("BULLION_TRACE_CAP", "lots")
    with pytest.raises(ValueError, match="BULLION_TRACE_CAP"):
        trace._default_cap()
    monkeypatch.setenv("BULLION_TRACE_CAP", "64")
    assert trace._default_cap() == 64
