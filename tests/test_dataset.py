"""Unified lazy Dataset API tests: plan round-trips over v0/v1 files with
pruning on/off, multi-file directory datasets, schema checking, context
managers, head/with_rows/count_rows terminals, pruned-byte accounting."""

import os

import numpy as np
import pytest

from repro.core import (BullionReader, BullionWriter, ColumnSpec, Compliance,
                        QuantMode, QuantSpec, delete_rows)
from repro.dataset import (Dataset, SchemaMismatchError, dataset, discover,
                           split_conjuncts)
from repro.scan import C, In


def _write(path, *, n=2000, rows_per_group=250, collect_stats=True, seed=0,
           id_base=0):
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("score", "float32"),
        ColumnSpec("qx", "float32", quant=QuantSpec(QuantMode.BF16)),
        ColumnSpec("tag", "string"),
    ]
    table = {
        "id": np.arange(id_base, id_base + n, dtype=np.int64),
        "score": rng.random(n).astype(np.float32),
        "qx": rng.normal(size=n).astype(np.float32),
        "tag": [b"t%d" % (i % 7) for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      collect_stats=collect_stats)
    w.write_table(table)
    w.close()
    return table


def _write_shards(d, n_shards=4, rows_each=1000, rows_per_group=250):
    os.makedirs(d, exist_ok=True)
    tables = []
    for s in range(n_shards):
        tables.append(_write(os.path.join(d, f"part-{s:04d}.bln"),
                             n=rows_each, rows_per_group=rows_per_group,
                             seed=s, id_base=s * rows_each))
    return tables


# ---------------------------------------------------------------------------
# plan round-trips: v0 vs v1, pruning on vs off, legacy vs Dataset
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("collect_stats", [True, False],
                         ids=["v1-pruned", "v0-unpruned"])
def test_plan_roundtrip_matches_brute_force(tmp_path, collect_stats):
    path = str(tmp_path / "t.bln")
    table = _write(path, collect_stats=collect_stats)
    pred = (C("id") >= 300) & (C("id") < 900) & (C("score") < 0.5)
    expect = np.flatnonzero((table["id"] >= 300) & (table["id"] < 900)
                            & (table["score"] < 0.5))
    with dataset(path) as ds:
        q = ds.where(pred).select(["id", "score", "tag"])
        tbl = q.to_table()
        assert np.array_equal(tbl["id"], table["id"][expect])
        assert np.array_equal(tbl["score"], table["score"][expect])
        assert tbl["tag"] == [table["tag"][i] for i in expect]
        assert np.array_equal(q.row_ids(), expect)
        assert q.count_rows() == len(expect)
        phys = q.physical_plan()
        if collect_stats:
            assert phys.groups_pruned > 0 and phys.bytes_pruned > 0
        else:
            assert phys.groups_pruned == 0 and phys.bytes_pruned == 0


def test_v0_and_v1_results_identical(tmp_path):
    v0, v1 = str(tmp_path / "v0.bln"), str(tmp_path / "v1.bln")
    _write(v0, collect_stats=False)
    _write(v1, collect_stats=True)
    pred = (C("score") >= 0.25) & (C("score") < 0.3) | (C("id") < 40)
    for builder in (lambda ds: ds.where(pred).select(["id", "score"]),
                    lambda ds: ds.select(["qx"]).head(123),
                    lambda ds: ds.with_rows([3, 777, 1999]).select(["id"])):
        with dataset(v0) as d0, dataset(v1) as d1:
            t0, t1 = builder(d0).to_table(), builder(d1).to_table()
            for k in t0:
                assert np.array_equal(np.asarray(t0[k]), np.asarray(t1[k]))


def test_dataset_byte_identical_to_legacy_with_no_more_io(tmp_path):
    """Acceptance: where+select+to_table == legacy find_rows+project gather,
    byte for byte, reading no more data bytes."""
    path = str(tmp_path / "t.bln")
    _write(path)
    victim = 1234

    with BullionReader(path) as r:
        rows = r.find_rows("id", [victim])
        gathered = []
        for g, local in r.locate_rows(rows):
            (tbl,) = r.project(["id", "score"], groups=[g])
            gathered.append({k: v[local] for k, v in tbl.items()})
        legacy = {k: np.concatenate([t[k] for t in gathered])
                  for k in ("id", "score")}
        legacy_bytes = r.stats.bytes_read - r.stats.footer_bytes

    with dataset(path) as ds:
        got = ds.where(C("id") == victim).select(["id", "score"]).to_table()
        ds_bytes = ds.stats.bytes_read - ds.stats.footer_bytes
    assert got["id"].tobytes() == legacy["id"].tobytes()
    assert got["score"].tobytes() == legacy["score"].tobytes()
    assert ds_bytes <= legacy_bytes


def test_where_chaining_splits_conjuncts(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        q = ds.where(C("id") >= 100).where(C("id") < 200).where(C("score") >= 0)
        opt = q.plan()
        assert len(opt.conjuncts) == 3
        assert opt.pred_columns == ("id", "score")
        # projection narrowing: predicate columns join the read set once
        assert q.select(["tag", "id"]).plan().read_columns == \
            ("tag", "id", "score")
        assert q.count_rows() == 100
    assert split_conjuncts(None) == ()


# ---------------------------------------------------------------------------
# terminals: head / with_rows / count_rows / to_batches / dequantized
# ---------------------------------------------------------------------------


def test_head_limit_prunes_trailing_groups(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    with dataset(path) as ds:
        q = ds.select(["id"]).head(300)
        phys = q.physical_plan()
        assert len(phys.tasks) == 2            # 250-row groups -> 2 needed
        assert phys.groups_pruned == 6 and phys.bytes_pruned > 0
        tbl = q.to_table()
        assert np.array_equal(tbl["id"], table["id"][:300])
        assert q.count_rows() == 300
        assert len(ds.select(["id"]).head(0).to_table()["id"]) == 0


def test_with_rows_reads_only_their_groups(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    want = np.asarray([5, 260, 1999])
    with dataset(path) as ds:
        q = ds.with_rows(want).select(["id", "tag"])
        phys = q.physical_plan()
        assert [t.group for t in phys.tasks] == [0, 1, 7]
        assert phys.groups_pruned == 5
        tbl = q.to_table()
        assert np.array_equal(tbl["id"], table["id"][want])
        assert np.array_equal(q.row_ids(), want)
        # with_rows composes with where (AND semantics)
        both = ds.with_rows(want).where(C("id") >= 1000)
        assert np.array_equal(both.row_ids(), [1999])


def test_head_with_rows_counts_only_visible_pins(tmp_path):
    """A head limit must not be charged for pinned rows that deletion
    vectors hide — otherwise later groups are wrongly pruned."""
    path = str(tmp_path / "t.bln")
    table = _write(path, n=1000, rows_per_group=100)
    delete_rows(path, np.arange(0, 180), level=Compliance.LEVEL1)
    want = np.arange(0, 300)                  # 180 of these are deleted
    with dataset(path) as ds:
        got = ds.with_rows(want).select(["id"]).head(100).to_table()["id"]
        assert np.array_equal(got, table["id"][180:280])


def test_empty_result_has_typed_columns(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        tbl = ds.where(C("id") == 10**9) \
            .select(["id", "score", "qx", "tag"]).to_table()
        assert tbl["id"].dtype == np.int64 and tbl["id"].size == 0
        assert tbl["score"].dtype == np.float32
        assert tbl["qx"].dtype == np.float32           # logical domain
        assert ds.select(["qx"]).dequantized(False).head(0) \
            .to_table()["qx"].dtype != np.float32      # storage domain
        assert tbl["tag"] == []


def test_scan_batches_single_pass_ids_and_data(tmp_path):
    d = str(tmp_path / "shards")
    tables = _write_shards(d, n_shards=2)
    all_ids = np.concatenate([t["id"] for t in tables])
    with dataset(d) as ds:
        q = ds.where(C("id") >= 900).where(C("id") < 1100).select(["id"])
        batches = list(q.scan_batches())
        rows = np.concatenate([b.row_ids for b in batches])
        ids = np.concatenate([b.table["id"] for b in batches])
        assert np.array_equal(rows, np.arange(900, 1100))
        assert np.array_equal(ids, all_ids[900:1100])
        assert {b.shard for b in batches} == {0, 1}
        # one scan = one pruned-bytes credit
        assert ds.stats.bytes_pruned == q.physical_plan().bytes_pruned


def test_read_group_honors_pinned_rows(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    with dataset(path) as ds:
        q = ds.with_rows([5, 7, 300]).select(["id"])
        assert np.array_equal(q.read_group(0)["id"], table["id"][[5, 7]])
        assert np.array_equal(q.read_group(1)["id"], [table["id"][300]])
        assert q.read_group(2) is None         # no pinned rows there


def test_tasks_then_terminal_credits_pruned_bytes_once(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        q = ds.where(C("id") == 7).select(["id"])
        q.tasks()
        q.to_table()
        q.row_ids()
        assert ds.stats.bytes_pruned == q.physical_plan().bytes_pruned


def test_count_rows_without_predicate_reads_no_data(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        assert ds.count_rows() == 2000
        assert ds.stats.preads == 0            # footer-only: no reader opened
    delete_rows(path, np.arange(100, 150), level=Compliance.LEVEL1)
    with dataset(path) as ds:
        assert ds.count_rows() == 1950
        assert ds.drop_deleted(False).count_rows() == 2000
        assert ds.stats.preads == 0
        assert all(r is None for r in ds._source._readers)


def test_to_batches_fixed_size(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    with dataset(path) as ds:
        batches = list(ds.select(["id", "tag"]).to_batches(300))
        sizes = [len(b["id"]) for b in batches]
        assert sizes == [300] * 6 + [200]
        assert np.array_equal(np.concatenate([b["id"] for b in batches]),
                              table["id"])
        assert [t for b in batches for t in b["tag"]] == table["tag"]
        # natural batches: one per row group
        assert [len(b["id"]) for b in ds.select(["id"]).to_batches()] == \
            [250] * 8
        with pytest.raises(ValueError):
            next(ds.select(["id"]).to_batches(0))


def test_dequantized_toggle(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        logical = ds.select(["qx"]).to_table()["qx"]
        raw = ds.select(["qx"]).dequantized(False).to_table()["qx"]
        assert logical.dtype == np.float32
        assert raw.dtype != np.float32          # BF16 storage dtype
        # predicates still evaluate in the logical domain on raw reads
        n = len(ds.where(C("qx") >= 0).select(["qx"])
                .dequantized(False).to_table()["qx"])
        assert n == int((logical >= 0).sum())


# ---------------------------------------------------------------------------
# multi-file datasets
# ---------------------------------------------------------------------------


def test_directory_dataset_matches_per_shard_reads(tmp_path):
    d = str(tmp_path / "shards")
    tables = _write_shards(d, n_shards=4)
    all_ids = np.concatenate([t["id"] for t in tables])
    all_scores = np.concatenate([t["score"] for t in tables])
    with dataset(d) as ds:
        assert ds.n_shards == 4
        assert ds.num_rows == 4000
        assert ds.count_rows() == 4000
        tbl = ds.select(["id", "score"]).to_table()
        assert np.array_equal(tbl["id"], all_ids)
        assert np.array_equal(tbl["score"], all_scores)
        # the same plan that runs on one file runs unchanged over shards
        pred = (C("id") >= 1500) & (C("id") < 2500) & (C("score") < 0.5)
        expect = np.flatnonzero((all_ids >= 1500) & (all_ids < 2500)
                                & (all_scores < 0.5))
        q = ds.where(pred).select(["id"])
        assert np.array_equal(q.row_ids(), expect)
        assert np.array_equal(q.to_table()["id"], all_ids[expect])
        # shards 0 and 3 hold no matching ids: pruned without any pread
        shards_hit = {t.shard for t in q.physical_plan().tasks}
        assert shards_hit == {1, 2}


def test_glob_and_list_datasets(tmp_path):
    d = str(tmp_path / "shards")
    _write_shards(d, n_shards=4)
    paths = discover(os.path.join(d, "part-*.bln"))
    assert len(paths) == 4
    with dataset(os.path.join(d, "part-*.bln")) as ds:
        assert ds.n_shards == 4
    with dataset(paths[:2]) as ds:
        assert ds.num_rows == 2000
    # globs skip non-Bullion matches, same as directory discovery
    with open(os.path.join(d, "part-junk.bln"), "wb") as f:
        f.write(b"_SUCCESS marker, not a shard")
    with dataset(os.path.join(d, "part-*.bln")) as ds:
        assert ds.n_shards == 4
    with pytest.raises(FileNotFoundError, match="no Bullion"):
        discover(os.path.join(d, "part-junk*"))


def test_multi_shard_head_and_with_rows(tmp_path):
    d = str(tmp_path / "shards")
    tables = _write_shards(d, n_shards=4)
    all_ids = np.concatenate([t["id"] for t in tables])
    with dataset(d) as ds:
        assert np.array_equal(ds.select(["id"]).head(1100).to_table()["id"],
                              all_ids[:1100])
        want = np.asarray([0, 999, 1000, 3999])
        got = ds.with_rows(want).select(["id"]).to_table()["id"]
        assert np.array_equal(got, all_ids[want])


def test_loader_streams_every_shard(tmp_path):
    """A directory dataset must feed the loader all shards' groups, not
    shard 0 repeated (global group index = shard offset + local group)."""
    from repro.data import BullionLoader
    from repro.data.synthetic import write_lm_corpus
    d = str(tmp_path / "lm")
    os.makedirs(d)
    write_lm_corpus(os.path.join(d, "a.bln"), n_docs=64, doc_len=64,
                    rows_per_group=16, seed=0)
    write_lm_corpus(os.path.join(d, "b.bln"), n_docs=64, doc_len=64,
                    rows_per_group=16, seed=1)
    ld = BullionLoader(d, batch_size=2, seq_len=32, column="tokens")
    try:
        assert ld.n_groups == 8
        assert ld._groups == list(range(8))
        got = ld._read_group(5)            # shard b, local group 1
        with dataset(os.path.join(d, "b.bln")) as ds:
            tbl = ds.select(["tokens"])._with_groups([1]).to_table()
            expect = np.concatenate(
                [np.asarray(t, np.int32) for t in tbl["tokens"]])
        assert np.array_equal(got, expect)
    finally:
        ld.close()


def test_schema_mismatch_shard_raises(tmp_path):
    d = str(tmp_path / "shards")
    _write_shards(d, n_shards=3)
    bad = os.path.join(d, "part-9999.bln")
    w = BullionWriter(bad, [ColumnSpec("other", "int32")], rows_per_group=10)
    w.write_table({"other": np.arange(10, dtype=np.int32)})
    w.close()
    with pytest.raises(SchemaMismatchError, match="part-9999"):
        dataset(d)


def test_directory_discovery_skips_non_bullion(tmp_path):
    d = str(tmp_path / "shards")
    _write_shards(d, n_shards=2)
    with open(os.path.join(d, "README.txt"), "w") as f:
        f.write("not a shard")
    with dataset(d) as ds:
        assert ds.n_shards == 2
    with pytest.raises(FileNotFoundError):
        dataset(str(tmp_path / "empty_dir_missing"))


# ---------------------------------------------------------------------------
# lifecycle: idempotent close, context managers, aborted plans
# ---------------------------------------------------------------------------


def test_reader_close_idempotent(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    r = BullionReader(path)
    r.close()
    r.close()                                   # must not raise
    assert r.closed
    with pytest.raises(ValueError, match="closed"):
        r._pread(0, 1)


def test_dataset_context_manager_closes_after_aborted_plan(tmp_path):
    d = str(tmp_path / "shards")
    _write_shards(d, n_shards=2)
    with dataset(d) as ds:
        for _ in ds.select(["id"]).to_batches():
            break                               # abort mid-execution
        live = [r for r in ds._source._readers if r is not None]
        assert live
    assert all(r is None for r in ds._source._readers)
    ds.close()                                  # idempotent on Dataset too
    # stats survive the close (retired accounting)
    assert ds.stats.preads > 0


def test_dataset_reopens_after_close(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    ds = dataset(path)
    ds.close()
    assert np.array_equal(ds.select(["id"]).head(10).to_table()["id"],
                          table["id"][:10])
    ds.close()


def test_scanner_context_manager(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    r = BullionReader(path)
    with r.scanner as sc:
        assert len(sc.plan(C("id") == 3).groups) == 1
    assert r.closed


# ---------------------------------------------------------------------------
# pruned-byte accounting + explain
# ---------------------------------------------------------------------------


def test_pruned_bytes_accounting(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        q = ds.where(C("id") == 77).select(["score"])
        phys = q.physical_plan()
        assert phys.bytes_pruned > 0
        assert phys.bytes_pruned < phys.bytes_total
        q.to_table()
        assert ds.stats.bytes_pruned == phys.bytes_pruned
        # legacy Scanner.scan credits the same accounting
    with BullionReader(path) as r:
        list(r.scanner.scan(C("id") == 77, columns=["score"]))
        assert r.stats.bytes_pruned == phys.bytes_pruned


def test_raw_scan_aligned_after_compact_delete(tmp_path):
    """drop_deleted=False always means raw row space: compact-deleted (RLE)
    pages are re-aligned so row_ids and every column agree in length."""
    path = str(tmp_path / "rle.bln")
    flags = np.repeat(np.arange(50), 20).astype(np.int64)
    w = BullionWriter(path, [ColumnSpec("flag", "int64")], rows_per_group=500)
    w.write_table({"flag": flags})
    w.close()
    delete_rows(path, np.arange(100, 120), level=Compliance.LEVEL2)
    with dataset(path) as ds:
        batches = list(ds.drop_deleted(False).select(["flag"]).scan_batches())
        for b in batches:
            assert len(b.row_ids) == len(b.table["flag"])
        raw = np.concatenate([b.table["flag"] for b in batches])
        assert len(raw) == 1000
        assert np.array_equal(np.flatnonzero(raw == 10),
                              np.arange(200, 220))   # no index shift
        assert not (raw[100:120] == 5).any()         # erased rows read 0


def test_legacy_shims_credit_pruned_bytes(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with BullionReader(path) as r:
        list(r.project(["score"], predicate=C("id") == 77))
        assert r.stats.bytes_pruned > 0
    with BullionReader(path) as r:
        r.find_rows("id", [77])
        assert r.stats.bytes_pruned > 0


def test_explain_smoke(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        text = ds.where((C("id") >= 5) & (C("score") < 0.5)) \
            .select(["tag"]).head(9).explain()
        assert "LogicalPlan" in text and "PhysicalPlan" in text
        assert "2 conjunct(s)" in text
        assert repr(ds.select(["id"]))


def test_unknown_column_errors_at_plan_time(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with dataset(path) as ds:
        with pytest.raises(KeyError, match="nope"):
            ds.select(["nope"]).plan()
        with pytest.raises(KeyError, match="nope"):
            ds.where(C("nope") == 1).count_rows()
        # the count_rows metadata fast path validates too
        with pytest.raises(KeyError, match="nope"):
            ds.select(["nope"]).count_rows()


# ---------------------------------------------------------------------------
# legacy shims stay equivalent
# ---------------------------------------------------------------------------


def test_legacy_shims_delegate_to_plans(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    with BullionReader(path) as r:
        assert np.array_equal(r.read_column("id"), table["id"])
        assert np.array_equal(r.find_rows("id", [55, 1700]), [55, 1700])
        assert np.array_equal(
            r.find_rows("tag", [b"t3"]), np.arange(3, 2000, 7))
        got = np.concatenate(
            [t["score"] for t in r.project(["score"], predicate=C("id") < 10)])
        assert np.allclose(got, table["score"][:10])
    with dataset(path) as ds:
        assert np.array_equal(
            ds.where(In("id", [55, 1700])).drop_deleted(False).row_ids(),
            [55, 1700])
