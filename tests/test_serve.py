"""Dataset service tests: plan fingerprints, prepared-plan cache, shared
footer state, concurrent serving, tenant io_depth budgets, socket clients,
and the lazy LM-engine re-export."""

import threading
import time

import numpy as np
import pytest

from repro.core import BullionWriter, ColumnSpec
from repro.dataset import clear_footer_cache, dataset
from repro.dataset.plan import LogicalPlan
from repro.scan import C
from repro.serve import DatasetServer, ServeClient, ServeError, TenantBudget

N_ROWS = 4096
N_SHARDS = 2


@pytest.fixture
def shards(tmp_path):
    """Two shards, unclustered ids + payload + a string column."""
    clear_footer_cache()
    d = tmp_path / "shards"
    d.mkdir()
    rng = np.random.default_rng(42)
    ids = rng.permutation(2 * N_ROWS)[:N_ROWS].astype(np.int64)
    schema = [ColumnSpec("id", "int64"), ColumnSpec("val", "float32"),
              ColumnSpec("tag", "string")]
    per = N_ROWS // N_SHARDS
    for s in range(N_SHARDS):
        w = BullionWriter(str(d / f"part-{s:04d}.bln"), schema,
                          rows_per_group=512, page_rows=128)
        sl = slice(s * per, (s + 1) * per)
        w.write_table({
            "id": ids[sl],
            "val": (ids[sl] * 2).astype(np.float32),
            "tag": [b"tag-%d" % v for v in ids[sl]],
        })
        w.close()
    return str(d), ids


# ---------------------------------------------------------------------------
# plan fingerprints (satellite: canonical, conjunct-order stable)
# ---------------------------------------------------------------------------


def test_fingerprint_conjunct_order_invariant():
    a, b = C("x") > 3, C("y") == 7
    p1 = LogicalPlan(columns=("x", "y"), predicate=a & b)
    p2 = LogicalPlan(columns=("x", "y"), predicate=b & a)
    assert p1.fingerprint() == p2.fingerprint()
    # Or children normalize too
    p3 = LogicalPlan(predicate=(a | b) & (b | a))
    p4 = LogicalPlan(predicate=(b | a) & (a | b))
    assert p3.fingerprint() == p4.fingerprint()


def test_fingerprint_distinguishes_plans():
    base = LogicalPlan(columns=("x",), predicate=C("x") == 1)
    assert base.fingerprint() != \
        LogicalPlan(columns=("x",), predicate=C("x") == 2).fingerprint()
    assert base.fingerprint() != \
        LogicalPlan(columns=("y",), predicate=C("x") == 1).fingerprint()
    assert base.fingerprint() != \
        LogicalPlan(columns=("x",), predicate=C("x") == 1,
                    limit=10).fingerprint()
    assert LogicalPlan(predicate=None).fingerprint() != \
        LogicalPlan(predicate=~(C("x") == 1)).fingerprint()


# ---------------------------------------------------------------------------
# prepared-plan cache
# ---------------------------------------------------------------------------


def test_repeat_query_hits_prepared_cache(shards):
    d, ids = shards
    victim = int(ids[100])
    with DatasetServer({"t": d}) as srv:
        r1 = srv.query("t", where=C("id") == victim, columns=["id", "val"])
        r2 = srv.query("t", where=C("id") == victim, columns=["id", "val"])
        assert not r1.cache_hit and r2.cache_hit
        assert r1.fingerprint == r2.fingerprint
        assert r1.table["id"].tolist() == r2.table["id"].tolist() == [victim]
        st = srv.stats()
        assert st["plan_cache"]["hits"] == 1
        assert st["plan_cache"]["misses"] == 1
        assert st["queries"] == 2 and st["errors"] == 0


def test_conjunct_order_shares_cache_entry(shards):
    d, ids = shards
    victim = int(ids[7])
    a, b = C("id") == victim, C("val") > -1.0
    with DatasetServer({"t": d}) as srv:
        r1 = srv.query("t", where=a & b)
        r2 = srv.query("t", where=b & a)
        assert r2.cache_hit and r1.fingerprint == r2.fingerprint
        assert r1.table["id"].tolist() == r2.table["id"].tolist()


def test_cache_lru_eviction(shards):
    d, ids = shards
    with DatasetServer({"t": d}, plan_cache_size=2) as srv:
        for v in ids[:3]:
            srv.query("t", where=C("id") == int(v))
        st = srv.stats()["plan_cache"]
        assert st["size"] == 2 and st["misses"] == 3
        # oldest entry evicted: querying it again is a miss
        r = srv.query("t", where=C("id") == int(ids[0]))
        assert not r.cache_hit


def test_explain_reports_prepared_state(shards):
    d, ids = shards
    with DatasetServer({"t": d}) as srv:
        q = dict(columns=["id"], where=C("id") == int(ids[0]))
        first = srv.explain("t", **q)
        again = srv.explain("t", **q)
        assert first.startswith("Prepared[t ") and " miss]" in \
            first.splitlines()[0]
        assert " hit]" in again.splitlines()[0]
        assert "by value sketch" in first


def test_unknown_dataset_raises(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        with pytest.raises(KeyError, match="unknown dataset"):
            srv.query("nope")
        with pytest.raises(ValueError, match="already attached"):
            srv.attach("t", d)


# ---------------------------------------------------------------------------
# concurrent serving
# ---------------------------------------------------------------------------


def test_concurrent_mixed_workload_deterministic(shards):
    d, ids = shards
    victims = [int(v) for v in ids[::97][:8]]
    # expected answers via the plain dataset API
    with dataset(d) as ds:
        want_probe = {v: ds.where(C("id") == v).to_table() for v in victims}
        want_proj = ds.select(["id", "val"]).to_table()

    with DatasetServer({"t": d}, max_workers=4) as srv:
        results, errors = [], []

        def worker(i):
            try:
                for j in range(6):
                    if (i + j) % 3 == 0:
                        r = srv.query("t", columns=["id", "val"],
                                      tenant=f"tenant-{i % 2}")
                        results.append(("proj", None, r))
                    else:
                        v = victims[(i * 7 + j) % len(victims)]
                        r = srv.query("t", where=C("id") == v,
                                      columns=["id", "val", "tag"],
                                      tenant=f"tenant-{i % 2}")
                        results.append(("probe", v, r))
            except Exception as e:     # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(6)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        for kind, v, r in results:
            if kind == "proj":
                assert r.table["id"].tobytes() == want_proj["id"].tobytes()
                assert r.table["val"].tobytes() == want_proj["val"].tobytes()
            else:
                assert r.table["id"].tolist() == \
                    want_probe[v]["id"].tolist()
                assert r.table["tag"] == [b"tag-%d" % v]
        st = srv.stats()
        assert st["errors"] == 0
        assert st["plan_cache"]["hits"] > 0
        # footers were parsed exactly once per shard and shared by every
        # session: repeating a full query batch adds zero footer bytes
        footer0 = st["datasets"]["t"]["io"]["footer_bytes"]
        for v in victims:
            srv.query("t", where=C("id") == v, columns=["id", "val", "tag"])
        srv.query("t", columns=["id", "val"])
        assert srv.stats()["datasets"]["t"]["io"]["footer_bytes"] == footer0


def test_submit_is_async(shards):
    d, ids = shards
    with DatasetServer({"t": d}) as srv:
        futs = [srv.submit("t", where=C("id") == int(v))
                for v in ids[:4]]
        rows = sorted(f.result(10).table["id"][0] for f in futs)
        assert rows == sorted(int(v) for v in ids[:4])


# ---------------------------------------------------------------------------
# tenant io_depth budgets
# ---------------------------------------------------------------------------


def test_tenant_budget_clamps_and_blocks():
    b = TenantBudget(4)
    assert b.acquire(100) == 4          # clamped to the budget, not rejected
    b.release(4)
    assert b.acquire(1) == 1
    got = []

    def blocked():
        got.append(b.acquire(4))        # must wait for the release below
        b.release(4)

    t = threading.Thread(target=blocked)
    t.start()
    t.join(0.05)
    assert t.is_alive() and not got     # still waiting
    b.release(1)
    t.join(5)
    assert got == [4] and b.waits == 1
    with pytest.raises(ValueError):
        TenantBudget(0)


def test_tenant_budget_bounds_concurrency_under_load(shards):
    d, ids = shards
    depth = 4
    with DatasetServer({"t": d}, max_workers=8,
                       tenant_io_depth=depth, default_io_depth=2) as srv:
        # hold 3 of 4 permits so in-flight queries (wanting 2 each) must
        # block on the budget — deterministic contention, however fast the
        # probes themselves run
        budget = srv.tenant_budget("noisy")
        held = budget.acquire(3)
        futs = [srv.submit("t", where=C("id") == int(v), tenant="noisy",
                           io_depth=2)
                for v in ids[:12]]
        deadline = time.time() + 10
        while budget.waits == 0 and time.time() < deadline:
            time.sleep(0.005)
        budget.release(held)
        for f in futs:
            f.result(30)
        st = srv.stats()["tenants"]["noisy"]
        assert st["io_depth"] == depth
        assert st["peak_in_flight"] <= depth
        assert st["waits"] > 0          # queries blocked on the held permits
        # an isolated tenant has its own untouched budget
        srv.query("t", where=C("id") == int(ids[0]), tenant="quiet")
        assert srv.stats()["tenants"]["quiet"]["waits"] == 0


# ---------------------------------------------------------------------------
# socket front-end
# ---------------------------------------------------------------------------


def test_socket_roundtrip_matches_inprocess(shards):
    d, ids = shards
    victim = int(ids[321])
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        with ServeClient(path) as cli:
            assert cli.ping()
            assert cli.datasets() == ["t"]
            res = cli.query("t", where=C("id") == victim)
            want = srv.query("t", where=C("id") == victim)
            assert res.table["id"].tolist() == want.table["id"].tolist()
            assert res.table["val"].tolist() == want.table["val"].tolist()
            assert res.table["tag"] == [b"tag-%d" % victim]   # bytes rows
            assert res.rows == 1 and res.fingerprint == want.fingerprint
            assert "Prepared[t" in cli.explain("t",
                                               where=C("id") == victim)
            assert cli.stats()["queries"] >= 2
            with pytest.raises(ServeError, match="unknown dataset"):
                cli.query("nope")
            # the error did not poison the session
            assert cli.ping()


def test_socket_concurrent_clients(shards):
    d, ids = shards
    victims = [int(v) for v in ids[:6]]
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        out, errors = {}, []

        def client(v):
            try:
                with ServeClient(path) as cli:
                    out[v] = cli.query(
                        "t", where=C("id") == v).table["id"].tolist()
            except Exception as e:     # pragma: no cover - failure path
                errors.append(e)

        threads = [threading.Thread(target=client, args=(v,))
                   for v in victims]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors
        assert out == {v: [v] for v in victims}


def test_server_close_idempotent(shards):
    d, _ = shards
    srv = DatasetServer({"t": d})
    srv.serve()
    srv.close()
    srv.close()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit("t")


# ---------------------------------------------------------------------------
# LM engine re-export stays lazy
# ---------------------------------------------------------------------------


def test_serve_engine_reexport():
    import repro.serve as serve
    assert "ServeEngine" in serve.__all__
    # the dataset service half imported above without pulling in the LM
    # stack; the attribute itself resolves lazily from serve.lm
    from repro.serve import ServeEngine
    from repro.serve.lm import ServeEngine as Direct
    assert ServeEngine is Direct
