"""Property-based tests for the cascading encoding framework (§2.6) and the
per-encoding deletion-masking rules (§2.1)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.encodings import (BY_NAME, EncodeContext, blob_encoding_name,
                                  decode_blob, decode_strings, encode_array,
                                  encode_strings, mask_blob)

DTYPES = [np.int64, np.int32, np.uint32, np.uint64, np.int16, np.uint8]


@st.composite
def int_arrays(draw):
    dtype = draw(st.sampled_from(DTYPES))
    n = draw(st.integers(1, 400))
    info = np.iinfo(dtype)
    kind = draw(st.sampled_from(["random", "runs", "small", "constant",
                                 "sorted"]))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    if kind == "random":
        arr = rng.integers(info.min, info.max, n, dtype=np.int64 if info.min < 0 else np.uint64)
    elif kind == "runs":
        arr = np.repeat(rng.integers(0, 50, max(n // 7, 1)), 7)[:n]
    elif kind == "small":
        arr = rng.integers(0, 100, n)
    elif kind == "constant":
        arr = np.full(n, int(rng.integers(0, 1000)))
    else:
        arr = np.sort(rng.integers(0, 10**6, n))
    # clip bounds must be representable in arr's dtype (int64/uint64), not
    # just the target dtype — np.clip(int64_arr, 0, uint64_max) overflows
    ainfo = np.iinfo(arr.dtype)
    return np.clip(arr, max(info.min, ainfo.min),
                   min(info.max, ainfo.max)).astype(dtype)


@st.composite
def float_arrays(draw):
    dtype = draw(st.sampled_from([np.float32, np.float64]))
    n = draw(st.integers(1, 300))
    rng = np.random.default_rng(draw(st.integers(0, 2**31)))
    kind = draw(st.sampled_from(["random", "decimal", "smooth", "constant"]))
    if kind == "random":
        arr = rng.normal(size=n) * 10.0 ** float(rng.integers(-3, 6))
    elif kind == "decimal":
        arr = np.round(rng.random(n) * 1000, 2)
    elif kind == "smooth":
        arr = np.cumsum(rng.normal(0, 0.01, n))
    else:
        arr = np.full(n, float(rng.random()))
    return arr.astype(dtype)


@settings(max_examples=60, deadline=None)
@given(int_arrays())
def test_int_roundtrip(arr):
    blob = encode_array(arr)
    out = decode_blob(blob)
    assert out.dtype == arr.dtype
    assert np.array_equal(out, arr)


@settings(max_examples=40, deadline=None)
@given(float_arrays())
def test_float_roundtrip(arr):
    blob = encode_array(arr)
    out = decode_blob(blob)
    assert out.dtype == arr.dtype
    assert np.array_equal(out, arr, equal_nan=True)


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 500), st.floats(0.0, 1.0))
def test_bool_roundtrip(seed, n, p):
    rng = np.random.default_rng(seed)
    arr = rng.random(n) < p
    out = decode_blob(encode_array(arr))
    assert np.array_equal(out, arr)


@settings(max_examples=30, deadline=None)
@given(int_arrays(), st.data())
def test_mask_size_criterion_and_erasure(arr, data):
    """§2.1: masking never grows the page; survivors decode unchanged."""
    if len(arr) < 3:
        return
    blob = encode_array(arr)
    k = data.draw(st.integers(1, min(8, len(arr))))
    pos = np.asarray(sorted(data.draw(
        st.sets(st.integers(0, len(arr) - 1), min_size=k, max_size=k))))
    masked = mask_blob(blob, pos, len(arr))
    if masked is None:
        return  # DV-only fallback is allowed (relocation path covers it)
    assert len(masked) == len(blob)  # the paper's size criterion
    out = decode_blob(masked)
    keep = np.ones(len(arr), bool)
    keep[pos] = False
    if len(out) == len(arr):          # masked in place
        assert np.array_equal(out[keep], arr[keep])
    else:                             # compact-deleted (RLE)
        assert np.array_equal(out, arr[keep])


@pytest.mark.parametrize("enc_name", ["fixed_bit_width", "varint", "for",
                                      "dictionary", "trivial"])
def test_native_mask_in_place(enc_name):
    """The paper's five maskable encodings must mask without decode-reencode."""
    rng = np.random.default_rng(0)
    # low cardinality so dictionary is applicable; fine for the rest too
    arr = rng.integers(0, 16, 256).astype(np.int64)
    enc = BY_NAME[enc_name]
    blob = enc.encode(arr, EncodeContext(candidates=(enc_name,)))
    assert blob is not None
    masked = mask_blob(blob, np.array([0, 100, 255]), len(arr))
    assert masked is not None and len(masked) == len(blob)


def test_strings_roundtrip():
    strings = [b"http://example.com/%d" % i for i in range(200)] + [b"", b"\xff" * 5]
    assert decode_strings(encode_strings(strings)) == strings


def test_cascade_never_worse_than_trivial():
    rng = np.random.default_rng(1)
    for arr in [rng.integers(0, 2**60, 1000).astype(np.int64),
                rng.normal(size=1000).astype(np.float32)]:
        blob = encode_array(arr)
        assert len(blob) <= arr.nbytes + 128


def test_every_registered_encoding_has_unique_eid():
    from repro.core.encodings import REGISTRY
    assert len(REGISTRY) >= 14
    names = [e.name for e in REGISTRY.values()]
    assert len(set(names)) == len(names)
