"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitunpack import bitunpack, bitunpack_ref, pack_bp32
from repro.kernels.dequant import dequant, dequant_ref
from repro.kernels.filter import range_mask, range_mask_ref
from repro.kernels.flash_attention import attention_ref, flash_attention


@pytest.mark.parametrize("width", [1, 2, 3, 5, 8, 13, 16, 24, 31, 32])
def test_bitunpack_widths(width):
    rng = np.random.default_rng(width)
    n = 32 * 256
    hi = (1 << width) - 1 if width < 32 else 0xFFFFFFFF
    vals = (rng.integers(0, 1 << 31, n) & hi).astype(np.uint32)
    planes = pack_bp32(vals, width)
    out = np.asarray(bitunpack(planes, width, n_values=n))
    assert np.array_equal(out, vals)
    assert np.array_equal(bitunpack_ref(planes, width)[:n], vals)


def test_bitunpack_ragged_length():
    rng = np.random.default_rng(0)
    n = 32 * 256 + 7 * 32  # not a multiple of the block
    vals = rng.integers(0, 1 << 11, n).astype(np.uint32)
    out = np.asarray(bitunpack(pack_bp32(vals, 11), 11, n_values=n))
    assert np.array_equal(out, vals)


@pytest.mark.parametrize("n_cols,n", [(1, 2048), (3, 4096), (5, 2048 + 777)])
def test_filter_range_mask(n_cols, n):
    rng = np.random.default_rng(n_cols)
    cols = rng.normal(size=(n_cols, n)).astype(np.float32)
    lo = rng.normal(size=n_cols).astype(np.float32) - 0.5
    hi = lo + rng.random(n_cols).astype(np.float32) * 2
    out = range_mask(cols, lo, hi)
    assert np.array_equal(out, range_mask_ref(cols, lo, hi))
    assert out.shape == (n,)


def test_filter_range_mask_nan_and_inf():
    cols = np.array([[0.0, np.nan, 1.0, -np.inf, np.inf, 0.5]], np.float32)
    lo = np.array([-np.inf], np.float32)
    hi = np.array([np.inf], np.float32)
    out = range_mask(cols, lo, hi)
    assert np.array_equal(out, [True, False, True, True, True, True])  # NaN fails
    out2 = range_mask(cols, np.array([0.4], np.float32),
                      np.array([0.6], np.float32))
    assert np.array_equal(out2, [False, False, False, False, False, True])


@pytest.mark.parametrize("dtype", [np.int8, np.uint8, np.int16])
def test_dequant_affine(dtype):
    rng = np.random.default_rng(1)
    info = np.iinfo(dtype)
    q = rng.integers(info.min, info.max, (130, 70)).astype(dtype)
    scale = rng.random(70).astype(np.float32) + 0.1
    zero = rng.normal(size=70).astype(np.float32)
    out = np.asarray(dequant(q, scale, zero, out_dtype=jnp.float32))
    ref = np.asarray(dequant_ref(jnp.asarray(q), jnp.asarray(scale),
                                 jnp.asarray(zero), jnp.float32))
    assert np.allclose(out, ref, atol=1e-3)


def test_dequant_bf16_bits():
    import ml_dtypes
    rng = np.random.default_rng(2)
    f = rng.normal(size=(256, 128)).astype(np.float32)
    u16 = f.astype(ml_dtypes.bfloat16).view(np.uint16)
    out = np.asarray(dequant(u16, np.ones(128, np.float32),
                             np.zeros(128, np.float32), out_dtype=jnp.float32))
    assert np.allclose(out, f, atol=0.02)


@pytest.mark.parametrize("shape,causal,window", [
    ((2, 2, 256, 64), True, 0),
    ((1, 2, 384, 128), True, 0),
    ((1, 1, 256, 64), False, 0),
    ((2, 1, 256, 64), True, 64),
    ((1, 1, 200, 80), True, 0),       # ragged S and D (padding path)
])
def test_flash_attention(shape, causal, window):
    rng = np.random.default_rng(0)
    B, H, S, D = shape
    q, k, v = (jnp.asarray(rng.normal(size=shape), jnp.float32)
               for _ in range(3))
    out = flash_attention(q, k, v, causal=causal, window=window)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert float(jnp.abs(out - ref).max()) < 3e-5


def test_flash_attention_bf16():
    rng = np.random.default_rng(0)
    shape = (1, 2, 256, 128)
    q, k, v = (jnp.asarray(rng.normal(size=shape), jnp.bfloat16)
               for _ in range(3))
    out = flash_attention(q, k, v)
    ref = attention_ref(q, k, v)
    assert float(jnp.abs(out.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max()) < 3e-2
