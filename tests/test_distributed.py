"""Distributed correctness on 8 virtual devices (subprocess — smoke tests and
benches must keep seeing 1 device, so XLA_FLAGS is set only in the child)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str) -> str:
    r = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}, cwd=REPO,
        timeout=560)
    assert r.returncode == 0 and "OK" in r.stdout, (r.stdout[-2000:],
                                                    r.stderr[-3000:])
    return r.stdout


def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,4) mesh must produce the same loss/params
    as the single-device run — SPMD is an implementation detail."""
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        import repro.configs as configs
        from repro.models import zoo
        from repro.models.base import spec_tree
        from repro.distributed import make_dist
        from repro.train import AdamWConfig, adamw_init, make_train_step

        cfg = configs.get_smoke("llama3_2_1b").scaled(compute_dtype="float32")
        rng = jax.random.PRNGKey(0)
        batch = {"tokens": jax.random.randint(rng, (4, 33), 0, cfg.vocab)}

        # single device reference
        m0 = zoo.build(cfg)
        p0 = m0.init(rng)
        o0 = adamw_init(p0)
        s0 = jax.jit(make_train_step(m0, AdamWConfig(lr=1e-3)))
        p0b, o0b, met0 = s0(p0, o0, batch)

        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        dist = make_dist(mesh)
        m1 = zoo.build(cfg, dist)
        specs = spec_tree(m1.decl, dist.rules, mesh)
        put = lambda t, s: jax.device_put(t, NamedSharding(mesh, s))
        p1 = jax.tree.map(put, m0.init(rng), specs)
        o1 = adamw_init(p1)
        b1 = {"tokens": jax.device_put(batch["tokens"],
                                       NamedSharding(mesh, PS("data", None)))}
        with mesh:
            s1 = jax.jit(make_train_step(m1, AdamWConfig(lr=1e-3)))
            p1b, o1b, met1 = s1(p1, o1, b1)
        dl = abs(float(met0["loss"]) - float(met1["loss"]))
        assert dl < 2e-4, dl
        err = max(float(jnp.abs(a - b).max()) for a, b in
                  zip(jax.tree.leaves(p0b), jax.tree.leaves(p1b)))
        assert err < 2e-4, err
        print("OK", dl, err)
    """))


def test_moe_shard_map_matches_local():
    """EP/TP chunked MoE under shard_map == local dense compute (no drops)."""
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as PS
        import repro.configs as configs
        from repro.models import zoo
        from repro.models.base import spec_tree
        from repro.distributed import make_dist

        for arch in ("mixtral_8x22b", "deepseek_moe_16b"):
            cfg = configs.get_smoke(arch).scaled(compute_dtype="float32",
                                                 capacity_factor=64.0)
            rng = jax.random.PRNGKey(0)
            batch = {"tokens": jax.random.randint(rng, (4, 17), 0, cfg.vocab)}
            m0 = zoo.build(cfg)
            p0 = m0.init(rng)
            l0 = float(jax.jit(m0.loss)(p0, batch))

            mesh = jax.make_mesh((2, 4), ("data", "model"))
            dist = make_dist(mesh)
            m1 = zoo.build(cfg, dist)
            specs = spec_tree(m1.decl, dist.rules, mesh)
            p1 = jax.tree.map(lambda t, s: jax.device_put(t, NamedSharding(mesh, s)),
                              p0, specs)
            b1 = {"tokens": jax.device_put(batch["tokens"],
                                           NamedSharding(mesh, PS("data", None)))}
            with mesh:
                l1 = float(jax.jit(m1.loss)(p1, b1))
            # small tolerance: the load-balance aux loss is computed per data
            # shard then averaged (nonlinear in shard composition), and f32
            # reduction orders differ — the LM term itself matches exactly
            assert abs(l0 - l1) < 2e-3, (arch, l0, l1)
        print("OK")
    """))


def test_production_mesh_shapes():
    _run(textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        assert dict(m1.shape) == {"data": 16, "model": 16}
        m2 = make_production_mesh(multi_pod=True)
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        print("OK")
    """))


def test_dryrun_single_cell_small():
    """The dry-run path end-to-end on the real 512-device mesh (small arch)."""
    _run(textwrap.dedent("""
        from repro.launch.dryrun import run_cell
        import tempfile
        rec = run_cell("llama3.2-1b", "decode_32k", multi_pod=True,
                       out_dir=tempfile.mkdtemp())
        assert rec["status"] == "ok", rec.get("error")
        assert rec["n_devices"] == 512
        assert rec["roofline"]["bound_s"] > 0
        print("OK")
    """))
