"""Scan subsystem tests: stats roundtrip, zone-map pruning vs brute force,
stat-less backward compatibility, predicate algebra, loader/deletion
integration."""

import os

import numpy as np
import pytest

from repro.core import (BullionReader, BullionWriter, ColumnSpec, QuantMode,
                        QuantSpec, delete_where)
from repro.core.footer import FORMAT_V0, FORMAT_VERSION, Sec, read_footer
from repro.scan import (C, HAS_MINMAX, In, LIST_ELEMENTS, STAT_DTYPE,
                        conjunctive_ranges, evaluate, merge_records,
                        stats_record)


def _write(path, *, n=4000, rows_per_group=500, collect_stats=True, seed=0,
           page_rows=None):
    """Clustered synthetic table: sorted ids -> disjoint per-group ranges."""
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("score", "float32"),
        ColumnSpec("cat", "int32"),
        ColumnSpec("seq", "list<int64>"),
        ColumnSpec("tag", "string"),
    ]
    table = {
        "id": np.arange(n, dtype=np.int64),
        "score": rng.random(n).astype(np.float32),
        "cat": rng.integers(0, 8, n).astype(np.int32),
        "seq": [rng.integers(0, 50, int(rng.integers(0, 6))).astype(np.int64)
                for _ in range(n)],
        "tag": [b"t%d" % (i % 13) for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      collect_stats=collect_stats, page_rows=page_rows)
    w.write_table(table)
    w.close()
    return table


# ---------------------------------------------------------------------------
# stats roundtrip through the footer
# ---------------------------------------------------------------------------


def test_stats_roundtrip(tmp_path):
    # single-page layout: chunk stats == page stats, distinct counts exact
    path = str(tmp_path / "t.bln")
    table = _write(path, n=2000, rows_per_group=500, page_rows=500)
    fv, _ = read_footer(path)
    assert fv.format_version == FORMAT_VERSION
    assert fv.has_stats
    cs, ps = fv.chunk_stats(), fv.page_stats()
    assert cs is not None and ps is not None
    assert len(cs) == fv.n_groups * fv.n_cols
    assert len(ps) == fv.n_pages
    n_cols = fv.n_cols
    for g in range(fv.n_groups):
        lo, hi = g * 500, (g + 1) * 500
        rec = cs[g * n_cols + fv.column_index("id")]
        assert int(rec["flags"]) & HAS_MINMAX
        assert rec["min"] == lo and rec["max"] == hi - 1
        assert int(rec["distinct"]) == 500
        assert int(rec["null_count"]) == 0
        srec = cs[g * n_cols + fv.column_index("score")]
        chunk = table["score"][lo:hi]
        assert srec["min"] <= chunk.min() and srec["max"] >= chunk.max()
        # list stats describe the elements
        lrec = cs[g * n_cols + fv.column_index("seq")]
        assert int(lrec["flags"]) & LIST_ELEMENTS
        # string columns carry only a distinct estimate
        trec = cs[g * n_cols + fv.column_index("tag")]
        assert not (int(trec["flags"]) & HAS_MINMAX)
        assert int(trec["distinct"]) == 13
    # page stats agree with chunk stats (single-page layout: the degenerate
    # case where a chunk is exactly one page)
    for g in range(fv.n_groups):
        for c in range(n_cols):
            s, e = fv.chunk_pages(g, c)
            assert e - s == 1
            assert ps[s] == cs[g * n_cols + c]


def test_stats_nan_null_count(tmp_path):
    path = str(tmp_path / "nan.bln")
    x = np.array([1.0, np.nan, 3.0, np.nan, 2.0] * 10, np.float32)
    w = BullionWriter(path, [ColumnSpec("x", "float32")], rows_per_group=50)
    w.write_table({"x": x})
    w.close()
    fv, _ = read_footer(path)
    rec = fv.chunk_stats()[0]
    assert int(rec["null_count"]) == 20
    assert rec["min"] == 1.0 and rec["max"] == 3.0


def test_stats_quantized_column_matches_decoded_domain(tmp_path):
    """Zone maps of quantized columns must bound what dequant=True returns."""
    path = str(tmp_path / "q.bln")
    rng = np.random.default_rng(3)
    x = (rng.normal(size=1000) * 5).astype(np.float32)
    w = BullionWriter(path, [ColumnSpec("x", "float32",
                                        quant=QuantSpec(QuantMode.BF16))],
                      rows_per_group=250)
    w.write_table({"x": x})
    w.close()
    with BullionReader(path) as r:
        decoded = r.read_column("x")
        cs = r.footer.chunk_stats()
        for g in range(r.footer.n_groups):
            chunk = decoded[g * 250:(g + 1) * 250]
            assert cs[g]["min"] <= chunk.min()
            assert cs[g]["max"] >= chunk.max()


def test_merge_records():
    a = stats_record(np.arange(10))
    b = stats_record(np.arange(100, 110))
    m = merge_records([a, b])
    assert m["min"] == 0 and m["max"] == 109
    assert int(m["flags"]) & HAS_MINMAX


def test_int64_outer_bounds():
    """float64-unrepresentable int64 extremes must round *outward*."""
    v = np.array([2**63 - 1, 2**63 - 2, 0], np.int64)
    rec = stats_record(v)
    assert float(rec["max"]) >= float(2**63 - 1)
    assert float(rec["min"]) <= 0


# ---------------------------------------------------------------------------
# pruning correctness vs brute force
# ---------------------------------------------------------------------------


def _brute_force(table, pred):
    return np.flatnonzero(evaluate(pred, table))


@pytest.mark.parametrize("pred_fn,desc", [
    (lambda: C("id") == 1234, "one group survives"),
    (lambda: C("id") >= 10**9, "all groups pruned"),
    (lambda: C("id") >= 0, "no group pruned"),
    (lambda: (C("id") >= 900) & (C("id") < 1600), "range straddles groups"),
    (lambda: In("id", [5, 1999, 3999]), "IN across groups"),
    (lambda: (C("score") >= 0.99) | (C("id") < 10), "OR of ranges"),
    (lambda: ~(C("id") < 3500), "NOT pushes through zone maps"),
    (lambda: (C("cat") == 3) & (C("score") < 0.25), "unclustered conjunct"),
])
def test_pruned_scan_matches_brute_force(tmp_path, pred_fn, desc):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    pred = pred_fn()
    scalar = {k: v for k, v in table.items() if isinstance(v, np.ndarray)}
    expect = _brute_force(scalar, pred)
    with BullionReader(path) as r:
        got = r.scanner.find_rows(pred)
        assert np.array_equal(np.sort(got), expect), desc
        plan = r.scanner.plan(pred)
        # pruning must never drop a group containing a match
        bounds = np.arange(0, 4001, 500)
        need = set(np.searchsorted(bounds, expect, side="right") - 1)
        assert need <= set(plan.groups), desc


def test_pruning_actually_prunes(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with BullionReader(path) as r:
        plan = r.scanner.plan(C("id") == 1234)
        assert plan.groups == [2]
        assert len(plan.pruned_groups) == 7
        assert plan.pages_pruned > 0
        empty = r.scanner.plan(C("id") >= 10**9)
        assert empty.groups == [] and empty.selectivity_bound == 0.0
        full = r.scanner.plan(C("id") >= 0)
        assert full.selectivity_bound == 1.0


def test_pruned_scan_reads_fewer_bytes(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    with BullionReader(path) as r:
        r.find_rows("id", [1234])
        pruned = r.stats.bytes_read - r.stats.footer_bytes
    with BullionReader(path) as r:
        r.read_column("id", drop_deleted=False, dequant=False)
        full = r.stats.bytes_read - r.stats.footer_bytes
    assert pruned < full / 4


def test_scan_payload_columns_and_project_predicate(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    pred = (C("id") >= 990) & (C("id") < 1010)
    with BullionReader(path) as r:
        batches = list(r.scanner.scan(pred, columns=["score", "tag", "id"]))
        ids = np.concatenate([b.row_ids for b in batches])
        scores = np.concatenate([b.table["score"] for b in batches])
        tags = [t for b in batches for t in b.table["tag"]]
        assert np.array_equal(ids, np.arange(990, 1010))
        assert np.allclose(scores, table["score"][990:1010], atol=1e-6)
        assert tags == table["tag"][990:1010]
        # project(predicate=...) yields the same filtered tables
        out = list(r.project(["score"], predicate=pred))
        got = np.concatenate([t["score"] for t in out])
        assert np.allclose(got, table["score"][990:1010], atol=1e-6)


def test_scan_kernel_path_matches_numpy(tmp_path):
    path = str(tmp_path / "t.bln")
    table = _write(path)
    pred = (C("score") >= 0.25) & (C("score") < 0.75)
    with BullionReader(path) as r:
        via_kernel = r.scanner.find_rows(pred, use_kernel=True)
        via_numpy = r.scanner.find_rows(pred, use_kernel=False)
        assert np.array_equal(via_kernel, via_numpy)
        assert np.array_equal(np.sort(via_kernel),
                              _brute_force({"score": table["score"]}, pred))
        # kernel path rejects non-range predicates instead of silently
        # falling back
        with pytest.raises(ValueError):
            r.scanner.find_rows(C("id") != 3, use_kernel=True)


def test_scan_kernel_strict_bound_on_exact_value(tmp_path):
    """x < v with v an actual stored float32 must exclude v on both paths."""
    path = str(tmp_path / "b.bln")
    x = np.linspace(0, 1, 1000).astype(np.float32)
    w = BullionWriter(path, [ColumnSpec("x", "float32")], rows_per_group=250)
    w.write_table({"x": x})
    w.close()
    v = float(x[500])
    with BullionReader(path) as r:
        got = r.scanner.find_rows(C("x") < v, use_kernel=True)
        assert np.array_equal(np.sort(got), np.flatnonzero(x < v))


def test_find_rows_with_deletion_vectors(tmp_path):
    from repro.core import Compliance, delete_rows
    path = str(tmp_path / "t.bln")
    _write(path)
    delete_rows(path, np.arange(1200, 1300), level=Compliance.LEVEL1)
    with BullionReader(path) as r:
        # raw row space: DV'd rows still reported (legacy find_rows contract)
        raw = r.scanner.find_rows((C("id") >= 1190) & (C("id") < 1310))
        assert np.array_equal(np.sort(raw), np.arange(1190, 1310))
        # visible row space: DV'd rows dropped, ids still global/raw
        vis = r.scanner.find_rows((C("id") >= 1190) & (C("id") < 1310),
                                  drop_deleted=True)
        assert np.array_equal(np.sort(vis), np.concatenate(
            [np.arange(1190, 1200), np.arange(1300, 1310)]))


# ---------------------------------------------------------------------------
# stat-less (v0) backward compatibility
# ---------------------------------------------------------------------------


def test_statless_file_backward_compat(tmp_path):
    path = str(tmp_path / "v0.bln")
    table = _write(path, collect_stats=False)
    fv, _ = read_footer(path)
    assert fv.format_version == FORMAT_V0
    assert not fv.has_stats
    assert fv.chunk_stats() is None and fv.page_stats() is None
    with BullionReader(path) as r:
        # every group survives planning (nothing to prune with)...
        plan = r.scanner.plan(C("id") == 1234)
        assert plan.groups == list(range(8)) and plan.pruned_groups == []
        # ...and results are still exact
        assert np.array_equal(r.find_rows("id", [1234]), [1234])
        got = r.scanner.find_rows((C("score") >= 0.9))
        assert np.array_equal(np.sort(got),
                              np.flatnonzero(table["score"] >= 0.9))


def test_statless_sections_absent(tmp_path):
    path = str(tmp_path / "v0.bln")
    _write(path, collect_stats=False)
    fv, _ = read_footer(path)
    assert not fv.has(Sec.PAGE_STATS) and not fv.has(Sec.CHUNK_STATS)


# ---------------------------------------------------------------------------
# predicate algebra / zone-map soundness
# ---------------------------------------------------------------------------


def test_predicate_evaluator_matches_numpy():
    rng = np.random.default_rng(1)
    tbl = {"a": rng.integers(-50, 50, 500), "b": rng.random(500)}
    pred = ((C("a") > -10) & (C("a") <= 10)) | ~(C("b") < 0.5) | In("a", [42])
    ref = (((tbl["a"] > -10) & (tbl["a"] <= 10)) | ~(tbl["b"] < 0.5)
           | np.isin(tbl["a"], [42]))
    assert np.array_equal(evaluate(pred, tbl), ref)


def test_predicate_rejects_list_columns():
    with pytest.raises(TypeError):
        evaluate(C("x") == 1, {"x": [np.arange(3)]})


def test_find_rows_on_string_column(tmp_path):
    """Legacy find_rows contract: membership probes on string columns keep
    working via the full-decode path (predicates are scalar-only)."""
    path = str(tmp_path / "t.bln")
    _write(path, n=1000, rows_per_group=250)
    with BullionReader(path) as r:
        got = r.find_rows("tag", [b"t3"])
        assert np.array_equal(got, np.arange(3, 1000, 13))


def test_list_column_predicate_raises_consistently(tmp_path):
    """Element-level zone maps must not prune list-column predicates into
    silently-empty results: in-range and out-of-range values both raise."""
    path = str(tmp_path / "t.bln")
    _write(path, n=1000, rows_per_group=250)
    with BullionReader(path) as r:
        with pytest.raises(TypeError):
            r.scanner.find_rows(C("seq") == 2)        # inside element range
        with pytest.raises(TypeError):
            r.scanner.find_rows(C("seq") == -5)       # outside element range


def test_conjunctive_ranges():
    r = conjunctive_ranges((C("a") >= 1) & (C("a") < 5) & (C("b") == 2.5))
    assert r["a"][0] == 1 and r["a"][1] < 5
    assert r["b"] == (2.5, 2.5)
    assert conjunctive_ranges(C("a") != 3) is None
    assert conjunctive_ranges((C("a") > 0) | (C("b") > 0)) is None


def test_zone_map_soundness_fuzz():
    """maybe_any must never return False for a page that contains a match."""
    rng = np.random.default_rng(7)
    ops = ["==", "!=", "<", "<=", ">", ">="]
    from repro.scan.predicate import Cmp, Not, Or, And
    for trial in range(200):
        data = rng.integers(-20, 20, 50)
        stats = {"x": stats_record(data)}
        v = int(rng.integers(-25, 25))
        leaf = Cmp("x", ops[trial % 6], v)
        pred = [leaf, Not(leaf), And(leaf, Cmp("x", "<=", v + 3)),
                Or(leaf, Cmp("x", ">", v))][trial % 4]
        mask = evaluate(pred, {"x": data})
        if mask.any():
            assert pred.maybe_any(stats), (pred, v, data)


# ---------------------------------------------------------------------------
# loader + deletion integration
# ---------------------------------------------------------------------------


def test_loader_quality_threshold_stream(tmp_path):
    from repro.data.loader import BullionLoader
    from repro.data.synthetic import write_lm_corpus
    path = str(tmp_path / "lm.bln")
    write_lm_corpus(path, n_docs=256, doc_len=256, rows_per_group=32)
    thresh = 0.5
    ld = BullionLoader(path, batch_size=2, seq_len=64, column="tokens",
                       predicate=C("quality") >= thresh)
    # quality presorting (§2.5) makes the survivor set a prefix of the file
    assert ld._groups == list(range(len(ld._groups)))
    assert 0 < len(ld._groups) < ld.n_groups
    it = iter(ld)
    batch, cursor = next(it)
    assert batch.shape == (2, 65)
    ld.close()
    # the stream must only contain tokens from qualifying docs
    with BullionReader(path) as r:
        rows = r.scanner.find_rows(C("quality") >= thresh)
        tables = list(r.project(["tokens"], predicate=C("quality") >= thresh))
        n_docs = sum(len(t["tokens"]) for t in tables)
        assert n_docs == len(rows)


def test_loader_close_does_not_deadlock(tmp_path):
    """close() while the producer is blocked on a full prefetch queue."""
    from repro.data.loader import BullionLoader
    from repro.data.synthetic import write_lm_corpus
    path = str(tmp_path / "lm.bln")
    write_lm_corpus(path, n_docs=128, doc_len=256, rows_per_group=16)
    for trial in range(3):
        ld = BullionLoader(path, batch_size=1, seq_len=32, prefetch=1,
                           column="tokens")
        it = iter(ld)
        next(it)            # producer now racing to refill a tiny queue
        ld.close()          # must not deadlock
        assert ld._thread is None


def test_delete_where_prunes_and_erases(tmp_path):
    from repro.core import verify_deleted
    path = str(tmp_path / "t.bln")
    _write(path)
    st = delete_where(path, (C("id") >= 700) & (C("id") < 705))
    assert st.rows_deleted == 5
    assert verify_deleted(path, "id", np.arange(700, 705)) == \
        {"visible_rows": 0, "raw_occurrences": 0}
    # empty predicate delete is a no-op
    st2 = delete_where(path, C("id") == 10**9)
    assert st2.rows_deleted == 0


def test_raw_scan_row_ids_after_compact_delete(tmp_path):
    """RLE pages compact-delete (§2.1): the decoded raw array shrinks, so
    raw-space row ids must be re-aligned through the deletion vector —
    otherwise delete_where would erase the wrong rows."""
    from repro.core import Compliance, delete_rows
    path = str(tmp_path / "rle.bln")
    flags = np.repeat(np.arange(50), 20).astype(np.int64)  # RLE-friendly
    w = BullionWriter(path, [ColumnSpec("flag", "int64")], rows_per_group=500)
    w.write_table({"flag": flags})
    w.close()
    delete_rows(path, np.arange(100, 120), level=Compliance.LEVEL2)
    with BullionReader(path) as r:
        # rows 200-219 hold flag==10; compacted decode must not shift them
        raw = r.scanner.find_rows(C("flag") == 10)
        assert np.array_equal(raw, np.arange(200, 220))
        vis = r.scanner.find_rows(C("flag") == 10, drop_deleted=True)
        assert np.array_equal(vis, np.arange(200, 220))
        # the erased flag==5 rows are gone from both row spaces
        assert len(r.scanner.find_rows(C("flag") == 5, drop_deleted=True)) == 0
    # predicate delete after compaction erases the right rows
    st = delete_where(path, C("flag") == 10)
    assert st.rows_deleted == 20
    with BullionReader(path) as r:
        visible = r.read_column("flag")
        assert not (np.asarray(visible) == 10).any()
        assert (np.asarray(visible) == 11).sum() == 20  # neighbors untouched


def test_predicate_on_quantized_column_with_raw_payload(tmp_path):
    """Predicates always evaluate in the dequantized domain (the domain the
    zone maps describe) even when the caller materializes raw values."""
    path = str(tmp_path / "q.bln")
    from repro.core import affine_spec_for
    x = (np.arange(1000) / 1000).astype(np.float32)
    spec = affine_spec_for(x, QuantMode.UINT8_AFFINE)
    w = BullionWriter(path, [ColumnSpec("x", "float32", quant=spec)],
                      rows_per_group=250)
    w.write_table({"x": x})
    w.close()
    with BullionReader(path) as r:
        dq = r.read_column("x")                    # dequantized domain
        expect = np.flatnonzero(dq >= 0.5)
        got = r.scanner.find_rows(C("x") >= 0.5, drop_deleted=True)
        assert np.array_equal(np.sort(got), expect)
        # dequant=False payload: raw uint8 values, same row selection
        out = list(r.project(["x"], predicate=C("x") >= 0.5, dequant=False))
        raw = np.concatenate([t["x"] for t in out])
        assert raw.dtype == np.uint8 and len(raw) == len(expect)


def test_zone_maps_widened_after_physical_masking(tmp_path):
    """L2 masking overwrites victims in place (zero or an encoding-specific
    placeholder like the FOR base); zone maps are widened to include 0 and
    raw scans must keep matching what is physically on disk."""
    path = str(tmp_path / "t.bln")
    schema = [ColumnSpec("id", "int64")]
    w = BullionWriter(path, schema, rows_per_group=100)
    w.write_table({"id": np.arange(1000, 2000, dtype=np.int64)})
    w.close()
    delete_where(path, C("id") == 1550)
    with BullionReader(path) as r:
        raw = r.read_column("id", drop_deleted=False, dequant=False)
        masked_val = int(raw[550])
        assert masked_val != 1550            # physically erased
        # pruned raw scan still finds every physically-present occurrence
        got = r.scanner.find_rows(C("id") == masked_val)
        assert np.array_equal(np.sort(got), np.flatnonzero(raw == masked_val))
        cs = r.footer.chunk_stats()
        assert cs[5]["min"] == 0.0           # widened for the touched chunk
        assert cs[4]["min"] == 1400.0        # untouched groups unchanged
