"""Training substrate: optimizer, microbatching, checkpointing, compression."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import zoo
from repro.train import AdamWConfig, adamw_init, make_train_step
from repro.train.checkpoint import CheckpointManager
from repro.train.compression import bf16_grads, topk_compress, topk_init

RNG = jax.random.PRNGKey(0)


def _setup():
    cfg = configs.get_smoke("llama3_2_1b").scaled(compute_dtype="float32")
    m = zoo.build(cfg)
    params = m.init(RNG)
    return cfg, m, params


def _batch(cfg, B=4, S=32, seed=1):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                         (B, S + 1), 0, cfg.vocab)}


def test_loss_decreases_over_steps():
    cfg, m, params = _setup()
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr=3e-3, warmup_steps=2,
                                                  total_steps=30)))
    batch = _batch(cfg)
    losses = []
    for i in range(25):
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_microbatched_grads_match_full_batch():
    cfg, m, params = _setup()
    batch = _batch(cfg, B=8)
    loss_full, g_full = jax.value_and_grad(m.loss)(params, batch)

    step4 = make_train_step(m, AdamWConfig(), microbatches=4)
    # recover accumulated grads by diffing against a zero-lr update? simpler:
    # reimplement the accumulation here via the factory's internals:
    def resplit(x):
        return x.reshape((4, x.shape[0] // 4) + x.shape[1:])
    mb = jax.tree.map(resplit, batch)
    acc = jax.tree.map(lambda p: jnp.zeros_like(p), params)
    tot = 0.0
    for i in range(4):
        one = jax.tree.map(lambda x: x[i], mb)
        li, gi = jax.value_and_grad(m.loss)(params, one)
        acc = jax.tree.map(jnp.add, acc, gi)
        tot += li
    acc = jax.tree.map(lambda g: g / 4, acc)
    err = max(float(jnp.abs(a - b).max())
              for a, b in zip(jax.tree.leaves(acc), jax.tree.leaves(g_full)))
    assert err < 5e-5, err
    assert abs(float(tot) / 4 - float(loss_full)) < 1e-4


def test_checkpoint_roundtrip_and_resume_equality(tmp_path):
    cfg, m, params = _setup()
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr=1e-3)))
    batch = _batch(cfg)
    for _ in range(3):
        params, opt, _ = step(params, opt, batch)
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    mgr.save(3, (params, opt), extra={"epoch": 0, "group": 1})
    (p2, o2), manifest = mgr.restore((params, opt))
    assert manifest["step"] == 3
    # continue both and compare exactly
    pa, oa, _ = step(params, opt, batch)
    pb, ob, _ = step(jax.tree.map(jnp.asarray, p2), jax.tree.map(jnp.asarray, o2), batch)
    for a, b in zip(jax.tree.leaves(pa), jax.tree.leaves(pb)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_latest(tmp_path):
    cfg, m, params = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"p": params})
    assert mgr.latest_step() == 4
    steps = mgr._complete_steps()
    assert steps == [3, 4]


def test_async_checkpoint(tmp_path):
    cfg, m, params = _setup()
    mgr = CheckpointManager(str(tmp_path), keep=1, async_save=True)
    mgr.save(7, {"p": params})
    mgr.wait()
    assert mgr.latest_step() == 7


def test_gradient_compression():
    cfg, m, params = _setup()
    g = jax.grad(m.loss)(params, _batch(cfg))
    gb = bf16_grads(g)
    for a, b in zip(jax.tree.leaves(g), jax.tree.leaves(gb)):
        assert a.dtype == b.dtype
        assert float(jnp.abs(a - b).max()) < 0.02 * float(jnp.abs(a).max() + 1e-3)
    res = topk_init(params)
    sparse, res2 = topk_compress(g, res, fraction=0.05)
    for s, orig, r in zip(jax.tree.leaves(sparse), jax.tree.leaves(g),
                          jax.tree.leaves(res2)):
        nz = float((s != 0).mean())
        assert nz <= 0.2  # sparsified
        # error feedback: sent + residual == grad
        assert float(jnp.abs((s + r) - orig).max()) < 1e-5


def test_elastic_reshard_plan():
    import subprocess, sys, textwrap
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        import repro.configs as configs
        from repro.models import zoo
        from repro.train.elastic import reshard_plan, shardings_for
        m = zoo.build(configs.get_smoke("llama3_2_1b"))
        mesh8 = jax.make_mesh((2, 4), ("data", "model"))
        mesh4 = jax.make_mesh((1, 4), ("data", "model"))
        plan = reshard_plan(m.decl, mesh8, mesh4)
        assert plan["old_devices"] == 8 and plan["new_devices"] == 4
        sh = shardings_for(m.decl, mesh4)
        assert len(jax.tree.leaves(sh)) == len(jax.tree.leaves(m.decl))
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env={**os.environ, "PYTHONPATH": "src"},
                       cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert "OK" in r.stdout, r.stderr[-2000:]
