"""MoE internals: chunked weight layout, routing/capacity semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.moe import (_dispatch, _route, moe_chunking, unchunk)


def test_chunking_cases():
    assert moe_chunking(8, 16) == (2, 16)    # Mixtral: expert-TP halves
    assert moe_chunking(64, 16) == (1, 64)   # DeepSeek: pure EP
    assert moe_chunking(16, 16) == (1, 16)
    assert moe_chunking(4, 16) == (4, 16)


def test_unchunk_roundtrip():
    rng = np.random.default_rng(0)
    E, d, ff, tp = 4, 8, 12, 4
    dense_g = rng.normal(size=(E, d, ff)).astype(np.float32)
    # build chunks the way the decl stores them: chunk e*tp+j = ff slice j
    ff_tp = ff // tp
    chunks = np.stack([dense_g[e, :, j * ff_tp:(j + 1) * ff_tp]
                       for e in range(E) for j in range(tp)])
    assert np.allclose(unchunk(jnp.asarray(chunks), E, ff_axis=2), dense_g)

    dense_d = rng.normal(size=(E, ff, d)).astype(np.float32)
    chunks_d = np.stack([dense_d[e, j * ff_tp:(j + 1) * ff_tp, :]
                         for e in range(E) for j in range(tp)])
    assert np.allclose(unchunk(jnp.asarray(chunks_d), E, ff_axis=1), dense_d)


def test_route_normalizes_topk():
    rng = jax.random.PRNGKey(0)
    xt = jax.random.normal(rng, (32, 16))
    router = jax.random.normal(rng, (16, 8))
    w, idx, aux = _route(xt, router, 2)
    assert w.shape == (32, 2) and idx.shape == (32, 2)
    assert np.allclose(np.asarray(w).sum(-1), 1.0, atol=1e-5)
    assert float(aux) > 0


def test_dispatch_capacity_drops():
    # all tokens to expert 0 with capacity 2: only 2 slots filled
    idx = jnp.zeros((8, 1), jnp.int32)
    xt = jnp.arange(8, dtype=jnp.float32)[:, None] + 1.0
    buf, slot, keep = _dispatch(xt, idx, E=4, C=2)
    assert int(keep.sum()) == 2
    assert buf.shape == (4, 2, 1)
    assert float(buf[0].sum()) == 1.0 + 2.0  # first two tokens kept
    assert float(buf[1:].sum()) == 0.0


def test_dispatch_no_drops_with_capacity():
    rng = jax.random.PRNGKey(1)
    idx = jax.random.randint(rng, (64, 2), 0, 4)
    xt = jax.random.normal(rng, (64, 8))
    buf, slot, keep = _dispatch(xt, idx, E=4, C=64)
    assert bool(keep.all())
