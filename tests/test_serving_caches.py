"""Property-based serving-cache tests: rolling windows, long decode runs, and
cross-arch cache/pure-forward agreement under random schedules."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro.configs as configs
from repro.models import transformer as tf
from repro.models import zoo
from repro.models.transformer import Ctx, _rolling_pos

RNG = jax.random.PRNGKey(0)


@settings(max_examples=40, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 64))
def test_rolling_pos_invariants(pos, W):
    """Slot i holds the latest absolute position p <= pos with p % W == i."""
    kv_pos = np.asarray(_rolling_pos(jnp.asarray(pos), W))
    for i, p in enumerate(kv_pos):
        assert p % W == i or p < 0
        assert p <= pos
        assert p + W > pos  # within the last W positions


@pytest.mark.parametrize("arch", ["gemma3_12b", "recurrentgemma_9b",
                                  "mixtral_8x22b"])
def test_long_decode_past_window(arch):
    """Decode 3x past the window; every step must match full forward."""
    cfg = configs.get_smoke(arch).scaled(compute_dtype="float32",
                                         capacity_factor=32.0, window=6)
    m = zoo.build(cfg)
    params = m.init(RNG)
    B, P, total = 1, 4, 22
    tok = jax.random.randint(RNG, (B, total), 0, cfg.vocab)

    positions = jnp.arange(total, dtype=jnp.int32)
    ctx = Ctx(cfg=cfg, dist=None, mode="prefill", positions=positions)
    x = tf.embed_tokens(params, tok, cfg, jnp.float32)
    x, _, _ = tf.forward(params, x, cfg, ctx)
    ref = tf.logits_fn(params, x, cfg)
    scale = float(jnp.abs(ref).max()) + 1e-6

    cache = m.init_cache(B, total, dtype=jnp.float32)
    lg, cache = m.prefill(params, {"tokens": tok[:, :P]}, cache)
    dec = jax.jit(m.decode_step)
    for i in range(total - P - 1):
        lg, cache = dec(params, cache, tok[:, P + i:P + i + 1])
        err = float(jnp.abs(lg - ref[:, P + i]).max())
        assert err < 2e-3 * scale + 1e-4, (arch, i, err)


def test_prefill_longer_than_window_fills_rolling_buffer():
    cfg = configs.get_smoke("mixtral_8x22b").scaled(
        compute_dtype="float32", capacity_factor=32.0, window=4)
    m = zoo.build(cfg)
    params = m.init(RNG)
    B, P = 1, 11   # prompt nearly 3x the window
    tok = jax.random.randint(RNG, (B, P + 3), 0, cfg.vocab)
    positions = jnp.arange(P + 3, dtype=jnp.int32)
    ctx = Ctx(cfg=cfg, dist=None, mode="prefill", positions=positions)
    x = tf.embed_tokens(params, tok, cfg, jnp.float32)
    x, _, _ = tf.forward(params, x, cfg, ctx)
    ref = tf.logits_fn(params, x, cfg)
    cache = m.init_cache(B, P + 3, dtype=jnp.float32)
    lg, cache = m.prefill(params, {"tokens": tok[:, :P]}, cache)
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(lg - ref[:, P - 1]).max()) < 2e-3 * scale + 1e-4
    for i in range(2):
        lg, cache = m.decode_step(params, cache, tok[:, P + i:P + i + 1])
        err = float(jnp.abs(lg - ref[:, P + i]).max())
        assert err < 2e-3 * scale + 1e-4, (i, err)
