"""Writer/reader/footer/quantization/multimodal format tests."""

import os

import numpy as np
import pytest

from repro.core import (BullionReader, BullionWriter, ColumnSpec, MediaStore,
                        MultimodalSample, QuantMode, QuantSpec,
                        quality_filtered_read, quality_sort, read_footer,
                        rejoin_dual_fp16, write_multimodal_dataset)


@pytest.fixture
def table(tmp_path):
    rng = np.random.default_rng(0)
    n = 3000
    schema = [
        ColumnSpec("a", "int64"),
        ColumnSpec("b", "float32", quant=QuantSpec(QuantMode.BF16)),
        ColumnSpec("c", "list<int64>"),
        ColumnSpec("d", "string"),
        ColumnSpec("e", "int8"),
    ]
    data = {
        "a": rng.integers(0, 10**6, n),
        "b": rng.normal(size=n).astype(np.float32),
        "c": [rng.integers(0, 100, int(rng.integers(0, 20))).astype(np.int64)
              for _ in range(n)],
        "d": [b"s%d" % (i % 97) for i in range(n)],
        "e": rng.integers(-100, 100, n).astype(np.int8),
    }
    path = str(tmp_path / "t.bln")
    w = BullionWriter(path, schema, rows_per_group=512)
    w.write_table(data)
    stats = w.close()
    return path, data, stats


def test_roundtrip(table):
    path, data, stats = table
    with BullionReader(path) as r:
        assert r.num_rows == len(data["a"])
        assert np.array_equal(r.read_column("a"), data["a"])
        assert np.abs(r.read_column("b") - data["b"]).max() < 0.01
        got_c = r.read_column("c")
        assert all(np.array_equal(x, y) for x, y in zip(got_c, data["c"]))
        assert r.read_column("d") == data["d"]
        assert np.array_equal(r.read_column("e"), data["e"])


def test_projection_reads_only_needed_pages(table):
    path, data, _ = table
    with BullionReader(path) as r:
        for tbl in r.project(["a"]):
            pass
        partial = r.stats.bytes_read
    with BullionReader(path) as r:
        for tbl in r.project(r.column_names):
            pass
        full = r.stats.bytes_read
    assert partial < full / 2


def test_footer_zero_copy_lookup(table):
    path, _, _ = table
    fv, _ = read_footer(path)
    assert fv.column_index("c") == 2
    with pytest.raises(KeyError):
        fv.column_index("nope")
    assert fv.column_names() == ["a", "b", "c", "d", "e"]
    assert fv.n_groups == 6


def test_group_iteration_order(table):
    path, data, _ = table
    with BullionReader(path) as r:
        seen = 0
        for tbl in r.project(["a"], groups=[1, 3]):
            n = len(tbl["a"])
            assert np.array_equal(tbl["a"], data["a"][512 * (1 if seen == 0 else 3):][:n])
            seen += 1
    assert seen == 2


def test_quality_sort_and_filtered_read(tmp_path):
    rng = np.random.default_rng(0)
    samples = [MultimodalSample(
        text=b"t%d" % i, quality=float(rng.random()),
        embedding=rng.normal(size=8).astype(np.float32),
        frames=bytes([i % 256] * 16), media_key=i) for i in range(1000)]
    meta = str(tmp_path / "m.bln")
    media = str(tmp_path / "m.media")
    write_multimodal_dataset(meta, media, samples, rows_per_group=100)
    tables, stats = quality_filtered_read(meta, ["quality"], 0.1)
    q = np.concatenate([t["quality"] for t in tables])
    assert len(q) == 100
    top = np.sort([s.quality for s in samples])[::-1][:100]
    assert np.allclose(np.sort(q)[::-1], top, atol=1e-6)
    blobs = MediaStore(media).read([5])
    assert blobs[5] == samples[5].frames * 8


def test_dual_fp16(tmp_path):
    from repro.core import quantize
    rng = np.random.default_rng(0)
    x = rng.normal(size=4096).astype(np.float32)
    hi = quantize(x, QuantSpec(QuantMode.DUAL_FP16_HI))
    lo = quantize(x, QuantSpec(QuantMode.DUAL_FP16_LO))
    err = np.abs(rejoin_dual_fp16(hi, lo) - x).max()
    assert err < 1e-5


def test_checksum_stored(table):
    path, _, _ = table
    fv, _ = read_footer(path)
    assert fv.file_checksum != 0
