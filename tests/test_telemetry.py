"""Production-telemetry tests: the structured query log, wire-propagated
traces + merged client/server profiles, Prometheus metrics exposition, and
wire-protocol error handling (one broken session must never take the
server down — and must leave a query-log record behind)."""

import json
import socket
import struct
import threading

import numpy as np
import pytest

from repro.core import BullionWriter, ColumnSpec
from repro.dataset import clear_footer_cache, dataset
from repro.obs import querylog, trace
from repro.obs.expose import parse_prometheus_text, prometheus_text
from repro.scan import C
from repro.serve import DatasetServer, ServeClient
from repro.serve.wire import MAX_MESSAGE

N_ROWS = 2048


@pytest.fixture(autouse=True)
def _isolate_tracer():
    """CI runs the suite under BULLION_TRACE; keep installs from leaking."""
    prev = trace.current()
    yield
    trace.install(prev)


@pytest.fixture
def shards(tmp_path):
    clear_footer_cache()
    d = tmp_path / "shards"
    d.mkdir()
    ids = np.arange(N_ROWS, dtype=np.int64)
    w = BullionWriter(str(d / "part-0000.bln"),
                      [ColumnSpec("id", "int64"),
                       ColumnSpec("val", "float32")],
                      rows_per_group=512, page_rows=128)
    w.write_table({"id": ids, "val": (ids * 2).astype(np.float32)})
    w.close()
    return str(d), ids


# ---------------------------------------------------------------------------
# query log: served queries
# ---------------------------------------------------------------------------

def test_served_query_record_reconciles_with_iostats(shards):
    """The acceptance criterion: a served query's record carries stage
    timings and byte/pread counts that reconcile *exactly* with the
    IOStats delta the execution charged (serial io_depth=1, sole query)."""
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        # first query pays the lazy shard open: its delta carries the
        # footer preads on top of the data reads, and the record says so
        cold = srv.query("t", columns=["id", "val"], io_depth=1)
        assert srv.query_log.records()[0].io["footer_bytes"] > 0
        res = srv.query("t", columns=["id", "val"], io_depth=1,
                        collect_spans=True)
        assert res.rows == N_ROWS
        rec = srv.query_log.records()[1]
        assert rec.origin == "serve" and rec.outcome == "ok"
        assert rec.dataset == "t" and rec.tenant == "default"
        assert rec.rows == N_ROWS and rec.fingerprint == cold.fingerprint
        assert rec.cache_hit is True
        assert rec.result_bytes == sum(a.nbytes
                                       for a in res.table.values())
        assert rec.wall_seconds > 0
        assert rec.io["footer_bytes"] == 0      # warm: data preads only
        # exact I/O reconciliation: every byte the reader pulled is either
        # a byte the decode stage consumed or a coalescing hole; every page
        # read is either its own pread or was merged into a neighbor's
        io, st = rec.io, rec.stages["decode.pread"]
        assert st["bytes"] + io["wasted_bytes"] == io["bytes_read"]
        assert st["pages"] == io["preads"] + io["coalesced_preads"]
        assert st["calls"] >= 1 and st["seconds"] > 0
        assert "decode.decode" in rec.stages


def test_query_log_ring_eviction_and_summary(shards):
    d, _ = shards
    log = querylog.QueryLog(capacity=3)
    with DatasetServer({"t": d}, query_log=log) as srv:
        for _ in range(5):
            srv.query("t", columns=["id"], head=4)
        with pytest.raises(KeyError):
            srv.query("nope")
        s = srv.query_log.summary()
        assert s["total"] == 6 and s["errors"] == 1
        assert s["retained"] == 3 and s["capacity"] == 3
        assert s["by_dataset"]["t"]["queries"] >= 2
        assert len(srv.query_log) == 3


def test_error_query_leaves_error_record(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        with pytest.raises(KeyError):
            srv.query("missing")
        (rec,) = srv.query_log.records()
        assert rec.outcome == "error" and "missing" in rec.error
        assert rec.dataset == "missing"


def test_slow_query_promotes_span_tree(shards):
    """BULLION_SLOW_MS: with the threshold armed (here: 0 — everything is
    slow) the serve path runs every query under a scoped tracer and the
    record arrives with its full span list attached."""
    d, _ = shards
    log = querylog.QueryLog(slow_seconds=0.0)
    with DatasetServer({"t": d}, query_log=log) as srv:
        srv.query("t", columns=["id"])
        (rec,) = srv.query_log.records()
        assert rec.slow is True
        assert rec.stages and "serve.query" in rec.stages
        assert rec.spans, "slow record must carry the promoted span tree"
        names = {s["name"] for s in rec.spans}
        assert "serve.query" in names
        assert srv.query_log.slow == 1


def test_slow_ms_env_validation(monkeypatch):
    monkeypatch.setenv("BULLION_SLOW_MS", "250")
    assert querylog.QueryLog().slow_seconds == 0.25
    monkeypatch.setenv("BULLION_SLOW_MS", "bogus")
    with pytest.raises(ValueError, match="BULLION_SLOW_MS"):
        querylog.QueryLog()
    monkeypatch.setenv("BULLION_SLOW_MS", "-5")
    with pytest.raises(ValueError, match=">= 0"):
        querylog.QueryLog()


def test_record_json_roundtrips(shards):
    """Every record must survive the JSONL sink: json.dumps(to_dict())."""
    d, _ = shards
    log = querylog.QueryLog(slow_seconds=0.0)   # force stages + spans
    with DatasetServer({"t": d}, query_log=log) as srv:
        srv.query("t", where=C("id") == 7, io_depth=1)
        (rec,) = srv.query_log.records()
        line = json.dumps(rec.to_dict())
        back = json.loads(line)
        assert back["rows"] == rec.rows and back["io"] == rec.io


# ---------------------------------------------------------------------------
# query log: local runs
# ---------------------------------------------------------------------------

def test_local_run_records_into_jsonl_sink(shards, tmp_path, monkeypatch):
    """BULLION_QUERY_LOG end-to-end: local Dataset terminals record, the
    sink accumulates one JSON line per query."""
    d, _ = shards
    sink = tmp_path / "q.jsonl"
    monkeypatch.setattr(querylog, "LOG",
                        querylog.QueryLog(sink_path=str(sink)))
    assert querylog.local_enabled()
    with dataset(d) as ds:
        t = ds.where(C("id") < 100).select(["val"]).to_table()
        assert len(t["val"]) == 100
    lines = [json.loads(ln) for ln in sink.read_text().splitlines()]
    (rec,) = lines
    assert rec["origin"] == "local" and rec["outcome"] == "ok"
    assert rec["rows"] == 100 and rec["io"]["preads"] > 0
    assert rec["fingerprint"]
    querylog.LOG.close()


def test_local_recording_off_by_default(shards, monkeypatch):
    d, _ = shards
    monkeypatch.setattr(querylog, "LOG", querylog.QueryLog())
    assert not querylog.local_enabled()
    with dataset(d) as ds:
        ds.select(["id"]).head(4).to_table()
    assert len(querylog.LOG) == 0
    # programmatic enable, no env
    monkeypatch.setattr(querylog, "_local", True)
    with dataset(d) as ds:
        ds.select(["id"]).head(4).to_table()
    (rec,) = querylog.LOG.records()
    assert rec.origin == "local" and rec.rows == 4


def test_local_error_recorded(shards, monkeypatch):
    """An execution-time failure still leaves a structured record (plan
    validation errors fire before execution starts and stay unlogged —
    nothing ran, nothing to account)."""
    d, _ = shards
    monkeypatch.setattr(querylog, "LOG", querylog.QueryLog())
    monkeypatch.setattr(querylog, "_local", True)
    with dataset(d) as ds:
        with pytest.raises(ValueError, match="io_depth"):
            ds.select(["id"]).to_table(io_depth=0)
    rec = querylog.LOG.records()[-1]
    assert rec.outcome == "error" and "io_depth" in rec.error


# ---------------------------------------------------------------------------
# wire-propagated traces + merged profile
# ---------------------------------------------------------------------------

def test_client_profile_merges_server_spans(shards, tmp_path):
    d, ids = shards
    victim = int(ids[99])
    out = tmp_path / "merged.json"
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        with ServeClient(path, trace=True) as cli:
            res = cli.query("t", where=C("id") == victim)
            assert res.trace_id == cli.trace_id
            prof = cli.profile(str(out))
    # one file, one trace id, both sides present
    doc = json.loads(out.read_text())
    assert doc["bullionTraceId"] == cli.trace_id
    names = {ev["name"] for ev in doc["traceEvents"]
             if ev.get("ph") == "X"}
    assert "client.rpc" in names and "serve.query" in names
    # the server's spans sit on offset tracks, labeled as such
    server_evs = [ev for ev in doc["traceEvents"]
                  if ev.get("ph") == "X" and ev["name"] == "serve.query"]
    client_evs = [ev for ev in doc["traceEvents"]
                  if ev.get("ph") == "X" and ev["name"] == "client.rpc"]
    assert server_evs and client_evs
    assert all(ev["tid"] >= (1 << 24) for ev in server_evs)
    # same process -> same wall epoch: the query's server span nests
    # inside the client RPC that carried it
    rpc = [ev for ev in client_evs if ev["args"].get("op") == "query"]
    sq = server_evs[0]
    assert any(ev["ts"] <= sq["ts"] and
               sq["ts"] + sq["dur"] <= ev["ts"] + ev["dur"] + 1
               for ev in rpc)
    # the server stamped the propagated id on its span
    assert sq["args"]["trace_id"] == cli.trace_id
    # aggregate view over the merged spans works too
    assert "serve.query" in prof.aggregate()


def test_server_record_carries_wire_trace_id(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        with ServeClient(path, trace=True) as cli:
            cli.query("t", columns=["id"], head=2)
        rec = srv.query_log.records()[-1]
        assert rec.trace_id == cli.trace_id


def test_untraced_client_gets_no_spans(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        with ServeClient(path) as cli:
            cli.query("t", columns=["id"], head=2)
            with pytest.raises(RuntimeError, match="trace=True"):
                cli.profile()


def test_span_wall_clock_codec_roundtrip():
    with trace.collect() as tr:
        with trace.span("unit.op", cat="test", pages=np.int64(3)):
            pass
    (rec,) = tr.spans
    d = trace.span_to_dict(rec, wall=True)
    json.dumps(d)                       # wire-safe (numpy args coerced)
    back = trace.span_from_dict(d, wall=True)
    assert back.name == rec.name and back.tid == rec.tid
    assert abs(back.ts - rec.ts) < 1e-3
    assert back.args["pages"] == 3


# ---------------------------------------------------------------------------
# metrics exposition
# ---------------------------------------------------------------------------

def test_metrics_text_is_parseable_prometheus(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        srv.query("t", columns=["id"], head=4)
        text = srv.metrics_text()
    samples = parse_prometheus_text(text)     # raises on malformed lines
    assert samples["bullion_serve_queries"] >= 1
    q50 = 'bullion_serve_wall_seconds{quantile="0.5"}'
    assert q50 in samples
    assert samples["bullion_serve_wall_seconds_count"] >= 1
    assert text.endswith("\n")


def test_metrics_over_the_wire(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        with ServeClient(path) as cli:
            cli.query("t", columns=["id"], head=4)
            samples = parse_prometheus_text(cli.metrics_text())
            assert samples["bullion_serve_queries"] >= 1
            recs = cli.server_log(10)
            assert recs and recs[-1]["origin"] == "serve"


def test_prometheus_parser_rejects_malformed():
    with pytest.raises(ValueError):
        parse_prometheus_text("this is { not prometheus\n")
    assert parse_prometheus_text("# just a comment\n") == {}
    assert parse_prometheus_text("ok_metric 1.5\n") == {"ok_metric": 1.5}


def test_prometheus_render_empty_snapshot():
    assert prometheus_text({}) == ""


# ---------------------------------------------------------------------------
# wire-protocol error paths
# ---------------------------------------------------------------------------

def _raw_conn(path):
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    s.settimeout(5.0)
    s.connect(path)
    return s


def _session_dead(sock):
    """After a fatal frame the server must close the session: the next
    read sees EOF (no reply, no crash)."""
    try:
        return sock.recv(1) == b""
    except (ConnectionError, OSError):
        return True


@pytest.mark.parametrize("frame", [
    struct.pack("<I", 16) + b"!!not json here!",          # garbage body
    struct.pack("<I", 11) + b"[1, 2, 3]\n\n",             # JSON, not a dict
    struct.pack("<I", MAX_MESSAGE + 1),                   # oversized prefix
    struct.pack("<I", 4096) + b"trunc",                   # truncated frame
], ids=["garbage", "non-dict", "oversized", "truncated"])
def test_malformed_frame_kills_session_not_server(shards, frame):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        s = _raw_conn(path)
        s.sendall(frame)
        if frame.endswith(b"trunc"):
            s.shutdown(socket.SHUT_WR)     # peer vanishes mid-frame
        assert _session_dead(s)
        s.close()
        # the server survives and still answers new sessions
        with ServeClient(path) as cli:
            assert cli.ping()
            assert cli.query("t", columns=["id"], head=1).rows == 1
        # ... and the broken session left a wire-error record
        wire_recs = [r for r in srv.query_log.records()
                     if r.origin == "serve.wire"]
        assert wire_recs and wire_recs[0].outcome == "error"


def test_unknown_op_is_answered_and_logged(shards):
    d, _ = shards
    with DatasetServer({"t": d}) as srv:
        path = srv.serve()
        from repro.serve import wire
        s = _raw_conn(path)
        wire.send_msg(s, {"op": "self_destruct"})
        resp = wire.recv_msg(s)
        assert resp == {"ok": False, "error": "unknown op 'self_destruct'"}
        # recoverable: the same session keeps working
        wire.send_msg(s, {"op": "ping"})
        assert wire.recv_msg(s)["ok"]
        s.close()
        rec = [r for r in srv.query_log.records()
               if r.origin == "serve.wire"][0]
        assert "self_destruct" in rec.error


def test_send_msg_refuses_oversized_frame(monkeypatch):
    from repro.serve import wire

    class _Null:
        def sendall(self, data):          # pragma: no cover - must not run
            raise AssertionError("oversized frame was sent")

    monkeypatch.setattr(wire, "MAX_MESSAGE", 4096)
    with pytest.raises(ValueError, match="exceeds"):
        wire.send_msg(_Null(), {"pad": "x" * 8192})


# ---------------------------------------------------------------------------
# hot path stays allocation-free; stats/explain surface drops
# ---------------------------------------------------------------------------

def test_serve_hot_path_allocates_no_spans(shards):
    """With no sink, no slow threshold, no tracer, and no span request,
    serving must not allocate a single Span object (the PR's perf
    criterion, extending the scan-path assertion in test_obs)."""
    d, _ = shards
    trace.install(None)
    with DatasetServer({"t": d}) as srv:
        assert srv.query_log.slow_seconds is None or \
            pytest.skip("BULLION_SLOW_MS set in this environment")
        srv.query("t", columns=["id"], head=8)      # warm the plan cache
        before = trace.allocations()
        res = srv.query("t", columns=["id"], head=8)
        assert res.cache_hit and res.rows == 8
        assert trace.allocations() == before, \
            "default serve path must not allocate Span objects"
        # the query log still recorded both queries (records are not spans)
        assert len(srv.query_log) == 2


def test_stats_reports_trace_and_query_log(shards):
    d, _ = shards
    trace.install(None)
    with DatasetServer({"t": d}) as srv:
        srv.query("t", columns=["id"], head=2)
        st = srv.stats()
        assert st["trace"] == {"installed": False, "spans": 0, "dropped": 0}
        assert st["query_log"]["total"] == 1
        tr = trace.Tracer(max_spans=1)
        trace.install(tr)
        with trace.span("a"):
            pass
        with trace.span("b"):
            pass
        st = srv.stats()
        assert st["trace"]["installed"] and st["trace"]["dropped"] == 1


def test_explain_analyze_reports_span_drops(shards):
    d, _ = shards
    trace.install(None)
    with dataset(d) as ds:
        text = ds.select(["id"]).explain(analyze=True)
    assert "spans:" in text and "dropped" in text
