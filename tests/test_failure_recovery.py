"""Large-scale runnability: rank-failure re-queueing and corpus coverage."""

import numpy as np

from repro.core import BullionReader
from repro.data import BullionLoader, write_lm_corpus


def _groups_of(loader, n_iters):
    seen = set()
    it = iter(loader)
    for _ in range(n_iters):
        _, cursor = next(it)
        seen.add(cursor.group - 1)
    loader.close()
    return seen


def test_rank_partition_covers_all_groups(tmp_path):
    """World-of-4 ranks partition the row groups disjointly and exhaustively
    — the property failure recovery relies on."""
    path = str(tmp_path / "c.bln")
    write_lm_corpus(path, n_docs=64, vocab=128, doc_len=256, rows_per_group=4)
    with BullionReader(path) as r:
        n_groups = r.footer.n_groups
    world = 4
    assigned = {}
    for rank in range(world):
        l = BullionLoader(path, batch_size=1, seq_len=32, rank=rank,
                          world=world)
        mine = l._my_groups(0)
        l.close()
        for g in mine:
            assert g not in assigned, f"group {g} double-assigned"
            assigned[g] = rank
    assert set(assigned) == set(range(n_groups))


def test_failed_rank_groups_recoverable_by_survivor(tmp_path):
    """Simulate rank 3 of 4 dying: a survivor re-runs the dead rank's group
    list and reproduces byte-identical batches (deterministic, group-aligned
    reads make re-queueing trivial)."""
    path = str(tmp_path / "c.bln")
    write_lm_corpus(path, n_docs=64, vocab=128, doc_len=256, rows_per_group=4)

    dead = BullionLoader(path, batch_size=2, seq_len=64, rank=3, world=4)
    it = iter(dead)
    original = [next(it)[0] for _ in range(3)]
    dead.close()

    # survivor takes over rank 3's schedule
    survivor = BullionLoader(path, batch_size=2, seq_len=64, rank=3, world=4)
    it2 = iter(survivor)
    replay = [next(it2)[0] for _ in range(3)]
    survivor.close()
    for a, b in zip(original, replay):
        assert np.array_equal(a, b)
