"""Deletion compliance (§2.1): levels, stacking, audits, Merkle maintenance."""

import os
import shutil

import numpy as np
import pytest

from repro.core import (BullionReader, BullionWriter, ColumnSpec, Compliance,
                        MerkleTree, delete_rows, page_hash, verify_deleted)
from repro.core.footer import Sec


@pytest.fixture
def ads(tmp_path):
    from repro.data.synthetic import write_ads_table
    path = str(tmp_path / "ads.bln")
    write_ads_table(path, n_rows=4096, n_sparse=4, n_dense=4, seq_len=16,
                    rows_per_group=512)
    return path


def test_level2_physically_erases(ads):
    with BullionReader(ads) as r:
        uid = r.read_column("user_id")
    victims = np.unique(uid)[:3]
    rows = np.flatnonzero(np.isin(uid, victims))
    stats = delete_rows(ads, rows, Compliance.LEVEL2)
    audit = verify_deleted(ads, "user_id", victims)
    assert audit["visible_rows"] == 0
    assert audit["raw_occurrences"] == 0          # the regulatory requirement
    assert stats.bytes_rewritten < stats.bytes_full_rewrite / 2


def test_level1_hides_but_keeps(ads):
    with BullionReader(ads) as r:
        uid = r.read_column("user_id")
    victims = np.unique(uid)[:1]
    rows = np.flatnonzero(np.isin(uid, victims))
    delete_rows(ads, rows, Compliance.LEVEL1)
    audit = verify_deleted(ads, "user_id", victims)
    assert audit["visible_rows"] == 0
    assert audit["raw_occurrences"] == len(rows)  # still physically present


def test_column_alignment_after_stacked_deletes(ads):
    with BullionReader(ads) as r:
        uid = r.read_column("user_id")
        ts = r.read_column("ts")
        seqs = r.read_column("clk_seq_0")
    keep = np.ones(len(uid), bool)
    for v in np.unique(uid)[[0, 5, 9]]:
        rows = np.flatnonzero(np.isin(uid, [v]))
        delete_rows(ads, rows, Compliance.LEVEL2)
        keep[rows] = False
    with BullionReader(ads) as r:
        assert np.array_equal(r.read_column("ts"), ts[keep])
        assert np.array_equal(r.read_column("user_id"), uid[keep])
        got = r.read_column("clk_seq_0")
        want = [s for s, k in zip(seqs, keep) if k]
        assert all(np.array_equal(a, b) for a, b in zip(got, want))


def test_repeat_delete_same_page(ads):
    """Same page hit twice (incl. positions already deleted)."""
    rows1 = np.arange(10, 20)
    rows2 = np.arange(15, 30)  # overlaps rows1
    delete_rows(ads, rows1, Compliance.LEVEL2)
    delete_rows(ads, rows2, Compliance.LEVEL2)
    with BullionReader(ads) as r:
        ts = r.read_column("ts")
    assert len(ts) == 4096 - 20
    assert not np.isin(np.arange(10, 30), ts).any()


def test_merkle_incremental_matches_recompute():
    rng = np.random.default_rng(0)
    pages = [rng.bytes(100) for _ in range(24)]
    cks = np.asarray([page_hash(p) for p in pages], np.uint64)
    starts = np.arange(0, 25, 4, dtype=np.uint64)  # 6 groups of 4
    t1 = MerkleTree(cks.copy(), starts, 6, 1)
    t2 = MerkleTree(cks.copy(), starts, 6, 1)
    new_page = rng.bytes(100)
    t1.update_page(9, new_page)              # incremental
    t2.pages[9] = np.uint64(page_hash(new_page))
    t2.full_recompute()                      # monolithic
    assert t1.root == t2.root
    assert np.array_equal(t1.groups, t2.groups)


def test_footer_checksums_updated_on_delete(ads):
    from repro.core import read_footer
    fv0, _ = read_footer(ads)
    root0 = fv0.file_checksum
    delete_rows(ads, np.arange(5), Compliance.LEVEL2)
    fv1, _ = read_footer(ads)
    assert fv1.file_checksum != root0


def test_level0_refuses():
    with pytest.raises(ValueError):
        delete_rows("/nonexistent", np.array([1]), Compliance.LEVEL0)
