"""Per-architecture smoke tests (reduced same-family configs on CPU):
forward/train loss finiteness + shapes, and the strong invariant —
prefill+decode with caches reproduces full-forward logits."""

import jax
import jax.numpy as jnp
import pytest

import repro.configs as configs
from repro.models import transformer as tf
from repro.models import zoo
from repro.models.transformer import Ctx

RNG = jax.random.PRNGKey(0)


def _batch(cfg, B, S):
    tok = jax.random.randint(RNG, (B, S), 0, cfg.vocab)
    batch = {"tokens": tok}
    if cfg.encoder is not None:
        batch["frames"] = jax.random.normal(RNG, (B, cfg.encoder.seq, cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_train_step_finite(arch):
    cfg = configs.get_smoke(arch).scaled(compute_dtype="float32")
    m = zoo.build(cfg)
    params = m.init(RNG)
    loss = jax.jit(m.loss)(params, _batch(cfg, 2, 17))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # one grad step moves the loss
    g = jax.grad(m.loss)(params, _batch(cfg, 2, 17))
    gn = sum(float(jnp.abs(x).sum()) for x in jax.tree.leaves(g))
    assert gn > 0 and jnp.isfinite(gn)


def _full_logits(m, cfg, params, batch):
    tokens = batch["tokens"]
    positions = jnp.arange(tokens.shape[1], dtype=jnp.int32)
    ctx = Ctx(cfg=cfg, dist=None, mode="prefill", positions=positions)
    if m.is_encdec:
        from repro.models import encdec as ed
        enc = ed.encode(params, batch["frames"], cfg, ctx)
        ek, ev = ed.cross_kv(params, enc)
        x = tf.embed_tokens(params, tokens, cfg, jnp.float32)
        x, _ = ed.decode_blocks(params, x, cfg, ctx, ek, ev)
    else:
        x = tf.embed_tokens(params, tokens, cfg, jnp.float32)
        x, _, _ = tf.forward(params, x, cfg, ctx)
    return tf.logits_fn(params, x, cfg)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_decode_matches_full_forward(arch):
    cfg = configs.get_smoke(arch).scaled(compute_dtype="float32",
                                         capacity_factor=16.0)
    m = zoo.build(cfg)
    params = m.init(RNG)
    B, S = 2, 12
    batch = _batch(cfg, B, S + 2)
    tok = batch["tokens"]
    ref = _full_logits(m, cfg, params, batch)
    cache = m.init_cache(B, S + 4, dtype=jnp.float32)
    pb = dict(batch)
    pb["tokens"] = tok[:, :S]
    lg, cache = m.prefill(params, pb, cache)
    scale = float(jnp.abs(ref).max()) + 1e-6
    assert float(jnp.abs(lg - ref[:, S - 1]).max()) < 1e-3 * scale + 1e-4
    for i in range(2):
        lg, cache = m.decode_step(params, cache, tok[:, S + i:S + i + 1])
        err = float(jnp.abs(lg - ref[:, S + i]).max())
        assert err < 1e-3 * scale + 1e-4, (arch, i, err)


def test_windowed_cache_rolls():
    """Decoding past the window must match full forward (rolling buffer)."""
    cfg = configs.get_smoke("mixtral_8x22b").scaled(
        compute_dtype="float32", capacity_factor=16.0, window=8)
    m = zoo.build(cfg)
    params = m.init(RNG)
    B, P, extra = 1, 6, 8            # decode well past the window
    tok = jax.random.randint(RNG, (B, P + extra), 0, cfg.vocab)
    ref = _full_logits(m, cfg, params, {"tokens": tok})
    cache = m.init_cache(B, P + extra, dtype=jnp.float32)
    lg, cache = m.prefill(params, {"tokens": tok[:, :P]}, cache)
    for i in range(extra - 1):
        lg, cache = m.decode_step(params, cache, tok[:, P + i:P + i + 1])
        err = float(jnp.abs(lg - ref[:, P + i]).max())
        assert err < 1e-3 * (float(jnp.abs(ref).max()) + 1e-6) + 1e-4, (i, err)


def test_rwkv_chunked_matches_scan():
    import numpy as np
    from repro.models.rwkv6 import wkv_chunked, wkv_scan
    rng = np.random.default_rng(0)
    B, T, H, D = 2, 128, 3, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, T, H, D)), jnp.float32)
               for _ in range(3))
    w = jnp.asarray(1.0 / (1.0 + np.exp(-rng.normal(1.0, 0.5, (B, T, H, D)))),
                    jnp.float32)  # mild decays in (0,1)
    u = jnp.asarray(rng.normal(size=(H, D)), jnp.float32)
    s0 = jnp.asarray(rng.normal(size=(B, H, D, D)), jnp.float32)
    y1, s1 = wkv_scan(r, k, v, w, u, s0)
    y2, s2 = wkv_chunked(r, k, v, w, u, s0, chunk=32)
    assert float(jnp.abs(y1 - y2).max()) < 2e-3, float(jnp.abs(y1 - y2).max())
    assert float(jnp.abs(s1 - s2).max()) < 2e-3


def test_param_counts_full_configs():
    """Full configs must land near their nameplate sizes."""
    expected = {
        "llama3_2_1b": (1.0e9, 1.6e9),
        "gemma3_12b": (10e9, 14e9),
        "minicpm3_4b": (3.4e9, 5e9),
        "starcoder2_15b": (14e9, 17e9),
        "mixtral_8x22b": (120e9, 150e9),
        "deepseek_moe_16b": (14e9, 20e9),
        "recurrentgemma_9b": (7.5e9, 11e9),
        "chameleon_34b": (30e9, 37e9),
        "rwkv6_7b": (6e9, 9e9),
        "whisper_base": (5e7, 1.2e8),
    }
    for arch, (lo, hi) in expected.items():
        m = zoo.build(configs.get(arch))
        assert lo <= m.n_params <= hi, (arch, m.n_params)
