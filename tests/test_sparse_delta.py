"""§2.2 sliding-window delta encoding: roundtrip + compression properties."""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import EncodeContext
from repro.core.sparse_delta import (SyntheticClickSeq, decode_page,
                                     encode_page)


def test_sliding_window_roundtrip_and_ratio():
    rows = SyntheticClickSeq(seq_len=128).generate(512, seed=3)
    blob = encode_page(rows, EncodeContext())
    out = decode_page(blob)
    assert all(np.array_equal(a, b) for a, b in zip(out, rows))
    raw = sum(r.nbytes for r in rows)
    assert raw / len(blob) > 20  # sliding windows compress dramatically


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 2**31), st.integers(1, 60), st.integers(0, 32))
def test_arbitrary_ragged_roundtrip(seed, n_rows, max_len):
    """No assumed structure at all — ragged random rows must roundtrip."""
    rng = np.random.default_rng(seed)
    rows = [rng.integers(-2**40, 2**40, int(rng.integers(0, max_len + 1)))
            .astype(np.int64) for _ in range(n_rows)]
    out = decode_page(encode_page(rows, EncodeContext()))
    assert len(out) == len(rows)
    assert all(np.array_equal(a, b) for a, b in zip(out, rows))


def test_mixed_pattern_roundtrip():
    """Alternating base vectors and shifted windows + length changes."""
    rng = np.random.default_rng(7)
    base = rng.integers(0, 1000, 64).astype(np.int64)
    rows = [base]
    for i in range(100):
        if i % 10 == 9:
            rows.append(rng.integers(0, 1000, 64).astype(np.int64))  # reset
        else:
            new = rng.integers(0, 1000, rng.integers(0, 3)).astype(np.int64)
            rows.append(np.concatenate([new, rows[-1]])[:64])
    out = decode_page(encode_page(rows, EncodeContext()))
    assert all(np.array_equal(a, b) for a, b in zip(out, rows))
