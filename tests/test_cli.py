"""``bullion`` CLI tests: inspect output, fsck clean/corrupt verdicts
across format versions (v0 stat-less through v3 sketched, plus
deletion-masked and quantized files), and the log/metrics printers —
including their ``--socket`` mode against a live server."""

import json

import numpy as np
import pytest

from repro import cli
from repro.core import BullionWriter, ColumnSpec, Compliance, delete_rows
from repro.core.footer import Sec, read_footer
from repro.core.quantization import QuantMode, QuantSpec
from repro.dataset import clear_footer_cache
from repro.serve import DatasetServer


def _write(path, *, n=600, collect_stats=True, quant=False, lists=False,
           rows_per_group=128, page_rows=64):
    clear_footer_cache()
    schema = [ColumnSpec("id", "int64"), ColumnSpec("tag", "string")]
    if quant:
        schema.append(ColumnSpec(
            "q", "float32",
            quant=QuantSpec(QuantMode.INT8_AFFINE, scale=0.5, zero=10.0)))
    else:
        schema.append(ColumnSpec("q", "float32"))
    if lists:
        schema.append(ColumnSpec("seq", "list<int64>"))
    w = BullionWriter(str(path), schema, rows_per_group=rows_per_group,
                      collect_stats=collect_stats, page_rows=page_rows)
    ids = np.arange(n, dtype=np.int64)
    table = {"id": ids, "tag": [b"t%d" % v for v in ids],
             "q": (ids % 50).astype(np.float32)}
    if lists:
        table["seq"] = [np.arange(v % 5, dtype=np.int64) for v in ids]
    w.write_table(table)
    w.close()
    return str(path)


@pytest.fixture
def shard(tmp_path):
    return _write(tmp_path / "a.bln", lists=True)


# ---------------------------------------------------------------------------
# inspect
# ---------------------------------------------------------------------------

def test_inspect_reports_layout(shard, capsys):
    assert cli.main(["inspect", shard]) == 0
    out = capsys.readouterr().out
    assert "bullion v3" in out and "rows=600" in out
    for name in ("id", "tag", "q", "seq"):
        assert name in out
    assert "META" in out and "PAGE_CHECKSUM" in out
    assert "group 0:" in out


def test_inspect_pages_table(shard, capsys):
    assert cli.main(["inspect", "--pages", shard]) == 0
    out = capsys.readouterr().out
    assert "zone map" in out and "sketch" in out
    assert "page" in out and "scalar" in out


def test_inspect_quantized_column(tmp_path, capsys):
    p = _write(tmp_path / "q.bln", quant=True)
    assert cli.main(["inspect", p]) == 0
    out = capsys.readouterr().out
    assert "int8_affine" in out


def test_inspect_missing_path_is_usage_error(tmp_path, capsys):
    assert cli.main(["inspect", str(tmp_path / "nope.bln")]) == 2
    assert "does not exist" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# fsck
# ---------------------------------------------------------------------------

def test_fsck_clean_across_format_versions(tmp_path, capsys):
    paths = [
        _write(tmp_path / "v0.bln", collect_stats=False),   # v0: no stats
        _write(tmp_path / "v3.bln", lists=True),            # v3: sketched
        _write(tmp_path / "quant.bln", quant=True),
    ]
    deleted = _write(tmp_path / "del.bln")
    delete_rows(deleted, np.arange(0, 600, 7))
    paths.append(deleted)
    l1 = _write(tmp_path / "dv.bln")                        # DV-only delete
    delete_rows(l1, np.arange(0, 600, 11), level=Compliance.LEVEL1)
    paths.append(l1)
    assert cli.main(["fsck", "-v"] + paths) == 0
    out = capsys.readouterr().out
    assert "5 shard(s) clean" in out
    assert "CORRUPT" not in out


def test_fsck_detects_flipped_page_byte(shard, capsys):
    fv, _ = read_footer(shard)
    off = int(fv.arr(Sec.PAGE_OFFSET, np.uint64)[0])
    with open(shard, "r+b") as f:
        f.seek(off + 5)
        b = f.read(1)
        f.seek(off + 5)
        f.write(bytes([b[0] ^ 0xFF]))
    assert cli.main(["fsck", shard]) == 1
    out = capsys.readouterr().out
    assert "checksum mismatch" in out and "CORRUPT" in out


def test_fsck_detects_truncated_data_region(tmp_path, capsys):
    """A page extent pointing past the data region makes the shard
    unusable: ``read_footer`` refuses it outright (torn-write guard), so
    fsck reports exit 2, not a per-page corruption finding."""
    p = _write(tmp_path / "t.bln")
    fv, foot_off = read_footer(p)
    from repro.dataset.source import invalidate_cached_footer
    invalidate_cached_footer(p)
    # grow the recorded size of the last page beyond the data region
    raw = open(p, "rb").read()
    off, size = fv._dir[int(Sec.PAGE_SIZE)]
    sizes = np.frombuffer(fv.raw(Sec.PAGE_SIZE), np.uint64).copy()
    sizes[-1] += 10_000_000
    patched = bytearray(raw)
    patched[foot_off + off:foot_off + off + size] = sizes.tobytes()
    open(p, "wb").write(bytes(patched))
    assert cli.main(["fsck", p]) == 2
    out = capsys.readouterr().out
    assert "UNUSABLE" in out
    # exit code is the contract; re-check it was unusable, not usage
    assert cli.main(["fsck", p]) == 2


def test_fsck_missing_path_is_usage_error(tmp_path):
    assert cli.main(["fsck", str(tmp_path / "missing")]) == 2


# ---------------------------------------------------------------------------
# log + metrics printers
# ---------------------------------------------------------------------------

def test_log_pretty_prints_jsonl(tmp_path, capsys):
    sink = tmp_path / "q.jsonl"
    recs = [
        {"ts": 1e9, "origin": "serve", "dataset": "ads", "tenant": "a",
         "fingerprint": "abcdef0123456789", "cache_hit": True, "rows": 42,
         "wall_seconds": 0.0123, "outcome": "ok", "slow": False},
        {"ts": 1e9, "origin": "serve.wire", "dataset": "", "tenant": "-",
         "rows": 0, "wall_seconds": 0.0, "outcome": "error",
         "error": "ValueError: bad frame", "slow": False},
    ]
    sink.write_text("".join(json.dumps(r) + "\n" for r in recs))
    assert cli.main(["log", str(sink)]) == 0
    out = capsys.readouterr().out
    assert "ads" in out and "abcdef012345" in out and "hit" in out
    assert "ValueError: bad frame" in out
    assert "2 record(s), 1 error(s)" in out


def test_log_and_metrics_over_socket(shard, capsys):
    from repro.scan import C
    with DatasetServer({"t": shard}) as srv:
        sock = srv.serve()
        srv.query("t", where=C("id") == 3)
        assert cli.main(["log", "--socket", sock]) == 0
        out = capsys.readouterr().out
        assert "serve" in out and "1 record(s)" in out
        assert cli.main(["metrics", "--socket", sock]) == 0
        out = capsys.readouterr().out
        assert "bullion_serve_queries" in out


def test_metrics_local_renders(capsys):
    assert cli.main(["metrics"]) == 0
    # a fresh registry may be empty; output only has to be well-formed
    from repro.obs.expose import parse_prometheus_text
    parse_prometheus_text(capsys.readouterr().out)
