"""Elastic rescale: checkpoint saved under one mesh restores onto another."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_elastic_restore_roundtrip(tmp_path):
    code = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        import repro.configs as configs
        from repro.models import zoo
        from repro.models.base import spec_tree
        from repro.distributed import make_dist
        from repro.train.checkpoint import CheckpointManager
        from repro.train.elastic import elastic_restore, shardings_for

        cfg = configs.get_smoke("llama3_2_1b").scaled(compute_dtype="float32")
        m = zoo.build(cfg)
        mesh8 = jax.make_mesh((2, 4), ("data", "model"))
        sh8 = shardings_for(m.decl, mesh8)
        params = jax.tree.map(lambda t, s: jax.device_put(t, s),
                              m.init(jax.random.PRNGKey(0)), sh8)

        mgr = CheckpointManager({str(tmp_path)!r}, async_save=False)
        mgr.save(5, params)

        # restore onto a *different* mesh (half the fleet)
        mesh4 = jax.make_mesh((1, 4), ("data", "model"))
        restored, manifest = elastic_restore(mgr, params, m.decl, mesh4)
        assert manifest["step"] == 5
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        # restored arrays carry the new mesh's shardings
        leaf = jax.tree.leaves(restored)[0]
        assert leaf.sharding.mesh.size == 4
        # and the restored params still train
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
        with mesh4:
            m4 = zoo.build(cfg, make_dist(mesh4))
            loss = jax.jit(m4.loss)(restored, {{"tokens": tok}})
        assert bool(jnp.isfinite(loss))
        print("OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, cwd=REPO, timeout=560,
                       env={**os.environ, "PYTHONPATH": os.path.join(REPO, "src")})
    assert r.returncode == 0 and "OK" in r.stdout, (r.stdout[-1500:],
                                                    r.stderr[-2500:])
