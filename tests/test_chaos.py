"""Self-healing read path under injected faults: decode-time checksum
verification (off/sample/full), quarantine + skip/mask degradation with
exact row accounting, transient-fault recovery via one re-read, torn-write
rejection across format versions, crash-safe atomic writes (kill -9 leaves
no torn shard visible), and the ``fsck --json`` report."""

import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro import cli
from repro.core import BullionWriter, ColumnSpec
from repro.core import integrity as _integrity
from repro.core.footer import Sec, ShardCorruptError, read_footer
from repro.core.integrity import QUARANTINE
from repro.dataset import clear_footer_cache, dataset, discover
from repro.obs import metrics as _metrics
from repro.obs import querylog as _querylog
from repro.testing import FakeObjectStore, chaos

SRC = os.path.join(os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
                   "src")


@pytest.fixture(autouse=True)
def _reset():
    """Integrity state is process-wide; every test starts and ends clean."""
    _integrity.set_verify_policy(None)
    _integrity.set_corruption_policy(None)
    QUARANTINE.clear()
    clear_footer_cache()
    yield
    _integrity.set_verify_policy(None)
    _integrity.set_corruption_policy(None)
    QUARANTINE.clear()
    clear_footer_cache()
    _querylog.enable_local(False)


def _write(path, *, n=600, rows_per_group=128, page_rows=64,
           collect_stats=True, collect_sketches=None):
    schema = [ColumnSpec("id", "int64"), ColumnSpec("tag", "string"),
              ColumnSpec("q", "float32")]
    ids = np.arange(n, dtype=np.int64)
    w = BullionWriter(str(path), schema, rows_per_group=rows_per_group,
                      page_rows=page_rows, collect_stats=collect_stats,
                      collect_sketches=collect_sketches)
    w.write_table({"id": ids, "tag": [b"t%d" % v for v in ids],
                   "q": (ids % 50).astype(np.float32)})
    w.close()
    return str(path)


def _flip_page(path, page):
    """Flip one byte inside a physical page's on-disk extent."""
    fv, _ = read_footer(path)
    off, size = fv.page_extent(page)
    assert size > 0
    with open(path, "r+b") as f:
        f.seek(off + size // 2)
        b = f.read(1)
        f.seek(off + size // 2)
        f.write(bytes([b[0] ^ 0xFF]))
    clear_footer_cache()     # the flip changes mtime anyway; be explicit


def _counter(name):
    return _metrics.counter(name).value


# ---------------------------------------------------------------------------
# decode-time verification: policies + the raise path
# ---------------------------------------------------------------------------

def test_full_raise_names_shard_group_page(tmp_path):
    """Acceptance: one flipped byte under full+raise raises
    ShardCorruptError naming (shard, group, page)."""
    p = _write(tmp_path / "a.bln")
    _flip_page(p, 0)         # group 0, column "id", ordinal 0
    _integrity.set_verify_policy("full")
    with pytest.raises(ShardCorruptError) as ei:
        with dataset(p) as ds:
            ds.to_table()
    err = ei.value
    assert err.path == p and err.group == 0 and err.page == 0
    assert "group 0" in str(err) and "page 0" in str(err)
    assert p in str(err)
    # the persistent mismatch is quarantined for this footer object
    assert QUARANTINE.summary()["quarantined_pages"] == 1


def test_verify_off_skips_hashing(tmp_path):
    p = _write(tmp_path / "a.bln")
    _integrity.set_verify_policy("off")
    with dataset(p) as ds:
        ds.to_table()
        assert ds.stats.pages_verified == 0


def test_sample_verifies_once_per_footer_cache_entry(tmp_path):
    p = _write(tmp_path / "a.bln")
    _integrity.set_verify_policy("sample")
    with dataset(p) as ds:
        ds.to_table()
        first = ds.stats.pages_verified
    assert first > 0
    # a second open shares the cached FooterView -> memo already warm
    with dataset(p) as ds:
        ds.to_table()
        assert ds.stats.pages_verified == 0
    # full mode re-verifies every read
    _integrity.set_verify_policy("full")
    with dataset(p) as ds:
        ds.to_table()
        ds.to_table()
        assert ds.stats.pages_verified == 2 * first


# ---------------------------------------------------------------------------
# degradation: skip (drop rows, exact accounting) and mask (zero fill)
# ---------------------------------------------------------------------------

def test_skip_drops_page_rows_with_exact_accounting(tmp_path):
    """Acceptance: skip returns the remaining rows; degraded_rows equals
    exactly the quarantined page's row count; the query record is marked
    degraded; a repaired shard serves clean without a process restart."""
    p = _write(tmp_path / "a.bln")
    fv, _ = read_footer(p)
    page_rows = int(fv.arr(Sec.PAGE_ROWS, np.uint32)[0])
    _flip_page(p, 0)         # rows [0, page_rows) of group 0
    _integrity.set_verify_policy("full")
    _integrity.set_corruption_policy("skip")
    _querylog.enable_local(True)
    with dataset(p) as ds:
        table = ds.to_table()
        st = ds.stats
    assert st.degraded_rows == page_rows == 64
    assert st.pages_quarantined == 1
    np.testing.assert_array_equal(table["id"],
                                  np.arange(page_rows, 600, dtype=np.int64))
    # every column dropped the same row range: result stayed rectangular
    assert len(table["tag"]) == len(table["q"]) == 600 - page_rows
    rec = _querylog.LOG.records()[-1]
    assert rec.degraded and rec.io["degraded_rows"] == page_rows
    # out-of-band repair: rewrite in place; quarantine self-invalidates
    # because the fresh file parses to a new footer object
    _write(tmp_path / "a.bln")
    with dataset(p) as ds:
        table = ds.to_table()
        assert len(table["id"]) == 600
        assert ds.stats.degraded_rows == 0


def test_mask_zero_fills_and_keeps_shape(tmp_path):
    p = _write(tmp_path / "a.bln")
    fv, _ = read_footer(p)
    c = fv.column_index("q")
    s, _e = fv.chunk_pages(0, c)
    _flip_page(p, s)         # first page of q's group-0 chunk: rows 0..63
    _integrity.set_verify_policy("full")
    _integrity.set_corruption_policy("mask")
    with dataset(p) as ds:
        table = ds.to_table()
        st = ds.stats
    assert len(table["id"]) == 600
    assert st.degraded_rows == 64
    assert (np.asarray(table["q"][:64]) == 0.0).all()
    np.testing.assert_array_equal(
        np.asarray(table["q"][64:]),
        (np.arange(64, 600) % 50).astype(np.float32))
    np.testing.assert_array_equal(table["id"], np.arange(600))


# ---------------------------------------------------------------------------
# chaos harness: transient faults recover via the one re-read
# ---------------------------------------------------------------------------

def test_transient_bitflip_recovers_without_quarantine(tmp_path):
    p = _write(tmp_path / "a.bln")
    expect = np.arange(600, dtype=np.int64)
    _integrity.set_verify_policy("full")
    before = _counter("bullion.integrity.reread_recovered")
    with chaos() as ctl:
        f = ctl.inject("bitflip", ordinal=0, byte=5)
        with dataset(p) as ds:
            table = ds.to_table()
            st = ds.stats
    assert f.fired == 1
    np.testing.assert_array_equal(table["id"], expect)
    assert st.checksum_failures >= 1
    assert st.pages_quarantined == 0
    assert _counter("bullion.integrity.reread_recovered") > before
    assert QUARANTINE.summary()["quarantined_pages"] == 0


def test_persistent_bitflip_quarantines(tmp_path):
    """The same fault on the read *and* the re-read is real corruption."""
    p = _write(tmp_path / "a.bln")
    _integrity.set_verify_policy("full")
    with chaos() as ctl:
        ctl.inject("bitflip", ordinal=0, times=-1, byte=5)
        with pytest.raises(ShardCorruptError):
            with dataset(p) as ds:
                ds.to_table()
    assert QUARANTINE.summary()["quarantined_pages"] >= 1


def test_eio_fallback_under_prefetch(tmp_path):
    """An EIO inside the prefetch scheduler's coalesced read falls back to
    the direct path; the query still answers correctly."""
    p = _write(tmp_path / "a.bln")
    _integrity.set_verify_policy("full")
    with chaos() as ctl:
        f = ctl.inject("eio", ordinal=0)
        with dataset(p) as ds:
            table = ds.to_table(io_depth=4)
    assert f.fired == 1
    np.testing.assert_array_equal(table["id"], np.arange(600))


def test_stale_footer_race_is_detected(tmp_path):
    """A reader holding a stale footer across a shard rewrite must surface
    corruption, not silently decode the wrong bytes."""
    p = _write(tmp_path / "a.bln")
    _integrity.set_verify_policy("full")
    with chaos() as ctl:
        ctl.inject("stale_footer", section="footer", ordinal=0, times=-1)
        with dataset(p) as ds:          # records the pre-rewrite tail
            ds.to_table()
        # out-of-band rewrite with different content, same path
        _write(tmp_path / "a.bln", n=600, rows_per_group=64, page_rows=32)
        clear_footer_cache()
        with pytest.raises(ShardCorruptError):
            with dataset(p) as ds:      # served the stale tail
                ds.to_table()


def test_truncated_pread_recovers(tmp_path):
    p = _write(tmp_path / "a.bln")
    _integrity.set_verify_policy("full")
    with chaos() as ctl:
        f = ctl.inject("truncate", ordinal=0, keep=0.5)
        with dataset(p) as ds:
            table = ds.to_table()
            st = ds.stats
    assert f.fired == 1
    np.testing.assert_array_equal(table["id"], np.arange(600))
    assert st.pages_quarantined == 0


# ---------------------------------------------------------------------------
# remote: corrupt response bodies against the fake object store
# ---------------------------------------------------------------------------

@pytest.fixture
def store(tmp_path):
    from repro.core import backend as _backend
    os.makedirs(tmp_path / "bucket", exist_ok=True)
    local = _write(tmp_path / "bucket" / "part-00000.bln")
    with FakeObjectStore(str(tmp_path)) as s:
        _backend.configure_object_store(s.endpoint)
        s.local_path = local
        s.shard_uri = "bullion://bucket/part-00000.bln"
        try:
            yield s
        finally:
            _backend.configure_object_store(None)
            clear_footer_cache()


def test_remote_corrupt_body_recovers_with_one_refetch(store):
    with dataset(store.shard_uri) as ds:
        ds.to_table()                    # warm the footer cache cleanly
    store.inject(corrupt=True)           # next data GET flips one byte
    with dataset(store.shard_uri) as ds:
        table = ds.to_table()
        st = ds.stats
    np.testing.assert_array_equal(table["id"], np.arange(600))
    assert st.checksum_failures >= 1
    assert st.pages_quarantined == 0


def test_remote_persistent_corruption_quarantines(store):
    with dataset(store.shard_uri) as ds:
        ds.to_table()
    store.inject(corrupt=True, count=8)  # original fetch AND the re-read
    with pytest.raises(ShardCorruptError):
        with dataset(store.shard_uri) as ds:
            ds.to_table()
    assert QUARANTINE.summary()["quarantined_pages"] >= 1
    store.clear_faults()


# ---------------------------------------------------------------------------
# torn writes: open rejects, fsck exits 2 — across format versions
# ---------------------------------------------------------------------------

_VERSIONS = {
    "v0": dict(collect_stats=False),
    "v2": dict(collect_sketches=False),
    "v3": dict(),
}

_TEARS = {
    "truncated_footer": lambda raw: raw[:-24],
    "zeroed_magic": lambda raw: raw[:-8] + b"\0" * 8,
    "footer_len_past_eof": lambda raw: raw[:-16]
    + (len(raw) + 1024).to_bytes(8, "little") + raw[-8:],
    "mid_data_truncation": lambda raw: raw[:len(raw) // 3],
}


@pytest.mark.parametrize("version", sorted(_VERSIONS))
@pytest.mark.parametrize("tear", sorted(_TEARS))
def test_torn_file_rejected_on_open(tmp_path, version, tear):
    p = _write(tmp_path / "a.bln", **_VERSIONS[version])
    raw = open(p, "rb").read()
    open(p, "wb").write(_TEARS[tear](raw))
    clear_footer_cache()
    with pytest.raises(ShardCorruptError):
        read_footer(p)
    assert cli.main(["fsck", p]) == 2


def test_bad_page_extents_rejected_on_open(tmp_path):
    """A footer whose page extents run past the data region is refused at
    parse time (same guard fsck used to discover lazily)."""
    p = _write(tmp_path / "a.bln")
    fv, foot_off = read_footer(p)
    clear_footer_cache()
    raw = open(p, "rb").read()
    off, size = fv._dir[int(Sec.PAGE_SIZE)]
    sizes = np.frombuffer(fv.raw(Sec.PAGE_SIZE), np.uint64).copy()
    sizes[-1] += 10_000_000
    patched = bytearray(raw)
    patched[foot_off + off:foot_off + off + size] = sizes.tobytes()
    open(p, "wb").write(bytes(patched))
    with pytest.raises(ShardCorruptError):
        read_footer(p)


# ---------------------------------------------------------------------------
# crash-safe writes
# ---------------------------------------------------------------------------

def test_writer_leaves_no_tmp_on_success(tmp_path):
    p = _write(tmp_path / "a.bln")
    assert os.path.exists(p)
    assert not os.path.exists(p + ".tmp")


def test_kill9_mid_write_leaves_no_torn_shard(tmp_path):
    """Acceptance: a process killed -9 between shard writes leaves either
    a complete shard or nothing ``dataset()`` can see."""
    out = tmp_path / "out"
    os.makedirs(out)
    child = (
        "import os, signal, sys\n"
        "import numpy as np\n"
        "from repro.core.writer import BullionWriter, ColumnSpec\n"
        "out = sys.argv[1]\n"
        "schema = [ColumnSpec('id', 'int64')]\n"
        "w = BullionWriter(os.path.join(out, 'part-00000.bln'), schema,\n"
        "                  rows_per_group=100)\n"
        "w.write_table({'id': np.arange(500, dtype=np.int64)})\n"
        "w.close()\n"
        "w2 = BullionWriter(os.path.join(out, 'part-00001.bln'), schema,\n"
        "                   rows_per_group=100)\n"
        "w2.write_table({'id': np.arange(500, dtype=np.int64)})\n"
        "os.kill(os.getpid(), signal.SIGKILL)\n"   # no close(): torn
    )
    env = dict(os.environ, PYTHONPATH=SRC)
    res = subprocess.run([sys.executable, "-c", child, str(out)], env=env,
                         capture_output=True, timeout=60)
    assert res.returncode == -signal.SIGKILL, res.stderr.decode()
    # only the completed shard is a dataset member; the torn write is at
    # most a .tmp file the discovery layer refuses to see
    assert discover(str(out)) == [str(out / "part-00000.bln")]
    with dataset(str(out)) as ds:
        assert ds.count_rows() == 500
    leftovers = sorted(os.listdir(out))
    assert "part-00001.bln" not in leftovers
    assert cli.main(["fsck", str(out)]) == 0


# ---------------------------------------------------------------------------
# fsck --json
# ---------------------------------------------------------------------------

def test_fsck_json_reports_categories(tmp_path, capsys):
    p = _write(tmp_path / "a.bln")
    _flip_page(p, 0)
    assert cli.main(["fsck", "--json", p]) == 1
    rep = json.loads(capsys.readouterr().out)
    assert rep["exit"] == 1 and rep["errors"] >= 1 and rep["unusable"] == 0
    (shard,) = rep["shards"]
    assert shard["path"] == p and shard["unusable"] is None
    cats = shard["categories"]
    assert cats["checksums"]["failed"] == 1
    assert "checksum mismatch" in cats["checksums"]["first_failure"]
    assert cats["checksums"]["checks"] > cats["checksums"]["failed"]
    # unaffected categories ran clean
    assert cats["extents"]["failed"] == 0


def test_fsck_json_torn_file_is_unusable(tmp_path, capsys):
    p = _write(tmp_path / "a.bln")
    raw = open(p, "rb").read()
    open(p, "wb").write(raw[:-8] + b"\0" * 8)
    clear_footer_cache()
    assert cli.main(["fsck", "--json", p]) == 2
    rep = json.loads(capsys.readouterr().out)
    assert rep["exit"] == 2 and rep["unusable"] == 1
    (shard,) = rep["shards"]
    assert "magic" in shard["unusable"]
    assert shard["categories"]["open"]["failed"] == 1


def test_fsck_json_clean(tmp_path, capsys):
    p = _write(tmp_path / "a.bln")
    assert cli.main(["fsck", "--json", p]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["exit"] == 0 and rep["errors"] == 0
    assert rep["shards"][0]["failures"] == 0


# ---------------------------------------------------------------------------
# serving: degradation is visible on the wire and in stats()
# ---------------------------------------------------------------------------

def test_server_reports_degradation(tmp_path):
    from repro.serve import DatasetServer
    from repro.serve.client import ServeClient
    p = _write(tmp_path / "a.bln")
    _flip_page(p, 0)
    _integrity.set_verify_policy("full")
    _integrity.set_corruption_policy("skip")
    with DatasetServer({"t": p}) as srv:
        sock = srv.serve()
        with ServeClient(sock) as c:
            res = c.query("t", columns=["id"])
            assert res.degraded and res.degraded_rows == 64
            assert res.rows == 600 - 64
            st = c.stats()
    assert st["integrity"]["verify_policy"] == "full"
    assert st["integrity"]["on_corrupt"] == "skip"
    assert st["integrity"]["quarantined_pages"] == 1
    assert st["query_log"]["degraded"] >= 1
