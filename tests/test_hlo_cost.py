"""Trip-count-aware HLO cost model: the roofline's foundation."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_cost import analyze


def _text(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_matmul_flops_exact():
    x = jax.ShapeDtypeStruct((256, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 128), jnp.float32)
    a = analyze(_text(lambda x, w: x @ w, x, w), 1)
    assert a["flops"] == 2 * 256 * 512 * 128


def test_scan_multiplies_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=12)
        return y

    single = analyze(_text(lambda x, w: x @ w, x, w), 1)["flops"]
    scanned_f = analyze(_text(scanned, x, w), 1)["flops"]
    assert abs(scanned_f / single - 12) < 0.01


def test_nested_scan():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def nested(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    single = analyze(_text(lambda x, w: x @ w, x, w), 1)["flops"]
    nested_f = analyze(_text(nested, x, w), 1)["flops"]
    assert abs(nested_f / single - 15) < 0.01


def test_grad_counts_more_than_forward():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    fwd = analyze(_text(lambda x, w: jnp.sum(jnp.tanh(x @ w)), x, w), 1)["flops"]
    bwd = analyze(_text(jax.grad(lambda x, w: jnp.sum(jnp.tanh(x @ w)),
                                 argnums=1), x, w), 1)["flops"]
    assert bwd >= 2 * fwd  # fwd + two bwd matmuls (minus dx maybe dropped)


def test_bytes_scale_with_scan():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(x):
        def body(c, _):
            return jnp.tanh(c), None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    one = analyze(_text(lambda x: jnp.tanh(x), x), 1)["bytes"]
    ten = analyze(_text(scanned, x), 1)["bytes"]
    assert ten > 5 * one
