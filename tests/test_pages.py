"""Multi-page chunk tests: page-index round-trips, page-granular pruning,
mixed-version datasets (v0 / single-page v1 / multi-page v2 in one glob),
compaction round-trips, loader shard striping, plan-time column errors."""

import os
import struct

import numpy as np
import pytest

from repro.core import BullionReader, BullionWriter, ColumnSpec
from repro.core.deletion import verify_deleted
from repro.core.footer import (FORMAT_V0, FORMAT_V1, FORMAT_V2,
                               FooterBuilder, MAGIC, Sec, read_footer)
from repro.dataset import dataset
from repro.dataset.plan import ColumnNotFoundError
from repro.scan import C


def _write(path, *, n=1000, rows_per_group=256, page_rows=None,
           collect_stats=True, id_base=0, seed=0):
    """Clustered table (sorted ids) with scalar, list, and string columns."""
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("val", "float32"),
        ColumnSpec("seq", "list<int64>"),
        ColumnSpec("tag", "string"),
    ]
    table = {
        "id": np.arange(id_base, id_base + n, dtype=np.int64),
        "val": rng.random(n).astype(np.float32),
        "seq": [rng.integers(0, 50, int(rng.integers(0, 5))).astype(np.int64)
                for _ in range(n)],
        "tag": [b"t%d" % (i % 7) for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      page_rows=page_rows, collect_stats=collect_stats)
    w.write_table(table)
    w.close()
    return table


def _strip_page_index(path):
    """Rewrite the footer without ``Sec.CHUNK_PAGE_COUNT`` (and with the
    matching pre-v2 version word), emulating a file written before the page
    index existed. Only valid for single-page-per-chunk files."""
    fv, foot_off = read_footer(path)
    fb = FooterBuilder()
    for sid in Sec:
        if fv.has(sid) and sid != Sec.CHUNK_PAGE_COUNT:
            fb.put(sid, bytes(fv.raw(sid)))
    meta = fv.meta.copy()
    meta[7] = FORMAT_V1 if fv.has_stats else FORMAT_V0
    fb.put(Sec.META, meta)
    footer = fb.build()
    with open(path, "r+b") as f:
        f.seek(foot_off)
        f.write(footer)
        f.write(struct.pack("<Q", len(footer)) + MAGIC)
        f.truncate()


def _assert_tables_equal(got, want):
    assert np.array_equal(got["id"], want["id"])
    assert np.allclose(got["val"], want["val"])
    assert all(np.array_equal(a, b) for a, b in zip(got["seq"], want["seq"]))
    assert got["tag"] == want["tag"]


# ---------------------------------------------------------------------------
# format round-trips
# ---------------------------------------------------------------------------


def test_multipage_roundtrip_all_kinds(tmp_path):
    path = str(tmp_path / "mp.bln")
    table = _write(path, n=1000, rows_per_group=256, page_rows=32)
    fv, _ = read_footer(path)
    for g in range(fv.n_groups):
        rows = int(fv.arr(Sec.ROWS_PER_GROUP, np.uint32)[g])
        for c in range(fv.n_cols):
            s, e = fv.chunk_pages(g, c)
            assert e - s == -(-rows // 32)          # ceil(rows / page_rows)
            assert int(fv.chunk_page_rows(g, c).sum()) == rows
    with dataset(path) as ds:
        _assert_tables_equal(ds.to_table(), table)


def test_page_rows_clamped_to_group(tmp_path):
    path = str(tmp_path / "one.bln")
    _write(path, n=500, rows_per_group=250, page_rows=10_000)
    fv, _ = read_footer(path)
    for g in range(fv.n_groups):
        for c in range(fv.n_cols):
            s, e = fv.chunk_pages(g, c)
            assert e - s == 1                       # degenerate single-page


def test_reads_file_without_page_index(tmp_path):
    """Pre-v2 footers (no CHUNK_PAGE_COUNT) read as one page per chunk."""
    path = str(tmp_path / "v1.bln")
    table = _write(path, n=600, rows_per_group=200, page_rows=200)
    _strip_page_index(path)
    fv, _ = read_footer(path)
    assert not fv.has(Sec.CHUNK_PAGE_COUNT)
    assert fv.format_version == FORMAT_V1
    assert fv.chunk_pages(1, 2) == (fv.chunk_pages(1, 2)[0],
                                    fv.chunk_pages(1, 2)[0] + 1)
    with dataset(path) as ds:
        _assert_tables_equal(ds.to_table(), table)
    with dataset(path) as ds:
        got = ds.where(C("id") == 321).select(["id", "val"]).to_table()
        assert got["id"].tolist() == [321]


# ---------------------------------------------------------------------------
# page-granular pruning
# ---------------------------------------------------------------------------


def test_page_pruning_reads_fewer_bytes_same_rows(tmp_path):
    layouts = {}
    for label, pr in (("single", 512), ("multi", 64)):
        path = str(tmp_path / f"{label}.bln")
        _write(path, n=4096, rows_per_group=512, page_rows=pr, seed=1)
        with dataset(path) as ds:
            q = ds.where(C("id") == 1234).select(["id", "val"])
            tbl = q.to_table()
            phys = q.physical_plan()
            st = ds.stats
            layouts[label] = (tbl, phys, st.bytes_read - st.footer_bytes,
                              st.pages_pruned)
    (stbl, sphys, sbytes, spages) = layouts["single"]
    (mtbl, mphys, mbytes, mpages) = layouts["multi"]
    assert np.array_equal(mtbl["id"], stbl["id"])
    assert np.array_equal(mtbl["val"], stbl["val"])
    # same group pruning, plus page pruning inside the surviving group
    assert mphys.groups_pruned == sphys.groups_pruned
    assert mphys.pages_pruned > sphys.pages_pruned
    assert mbytes < sbytes
    assert mpages > 0
    assert any(t.pages is not None for t in mphys.tasks)
    assert "page-subset task(s)" in dataset(str(tmp_path / "multi.bln")) \
        .where(C("id") == 1234).explain()


def test_page_pruning_row_ids_stay_raw(tmp_path):
    """Row ids from a page-subset scan are global raw ids, identical to an
    unpruned evaluation of the same predicate."""
    path = str(tmp_path / "ids.bln")
    table = _write(path, n=2048, rows_per_group=512, page_rows=64, seed=2)
    pred = (C("id") >= 700) & (C("id") <= 707)
    with dataset(path) as ds:
        ids = ds.where(pred).row_ids()
    expect = np.flatnonzero((table["id"] >= 700) & (table["id"] <= 707))
    assert np.array_equal(ids, expect)


def test_page_pruning_with_deletions(tmp_path):
    path = str(tmp_path / "del.bln")
    _write(path, n=2048, rows_per_group=512, page_rows=64, seed=3)
    with dataset(path) as ds:
        ds.delete_where(C("id").isin([100, 101, 1500]))
    with dataset(path) as ds:
        got = ds.where((C("id") >= 99) & (C("id") <= 103)) \
            .select(["id"]).to_table()
        assert got["id"].tolist() == [99, 102, 103]
        assert ds.stats.pages_pruned > 0


def test_with_rows_drop_does_not_overcount_pruning(tmp_path):
    """A group kept by the predicate (with page-level pruning credited) but
    dropped by with_rows location must charge only the *remaining* pages."""
    path = str(tmp_path / "acct.bln")
    _write(path, n=2048, rows_per_group=512, page_rows=64, seed=4)
    with dataset(path) as ds:
        # predicate pins group 0 (with a page subset); the pinned row lives
        # in group 2, so group 0 is then dropped by row location
        q = ds.where(C("id") == 5).with_rows([1500])
        phys = q.physical_plan()
        assert 0 <= phys.pages_pruned <= phys.pages_total
        assert 0 <= phys.bytes_pruned <= phys.bytes_total
        assert q.count_rows() == 0


def test_stat_less_files_stay_v0_shaped(tmp_path):
    """collect_stats=False (the backward-compat target) writes a true v0
    layout: one page per chunk, FORMAT_V0 version word — regardless of the
    BULLION_PAGE_ROWS environment; an *explicit* multi-page request without
    stats is stamped as a stat-less v2, never a fake v0."""
    p0 = str(tmp_path / "v0.bln")
    _write(p0, n=600, rows_per_group=200, collect_stats=False)
    fv, _ = read_footer(p0)
    assert fv.format_version == FORMAT_V0 and not fv.has_stats
    for g in range(fv.n_groups):
        for c in range(fv.n_cols):
            s, e = fv.chunk_pages(g, c)
            assert e - s == 1
    p2 = str(tmp_path / "v2_nostats.bln")
    table = _write(p2, n=600, rows_per_group=200, page_rows=50,
                   collect_stats=False)
    fv2, _ = read_footer(p2)
    assert fv2.format_version == FORMAT_V2 and not fv2.has_stats
    assert fv2.chunk_pages(0, 0) == (0, 4)
    with dataset(p2) as ds:
        _assert_tables_equal(ds.to_table(), table)


def test_level1_then_level2_delete_keeps_pages_readable(tmp_path):
    """An L1 (DV-only) delete followed by an L2 delete on the same page must
    not accept a compact in-place mask that removes only the new rows — the
    decoded length would track neither page convention. The page relocates
    with the prior DV rows unioned in, and every column stays readable."""
    from repro.core.deletion import Compliance, delete_rows
    path = str(tmp_path / "l1l2.bln")
    rng = np.random.default_rng(6)
    # irregular runs -> RLE pages whose compact mask rule would fire
    vals = np.repeat(rng.integers(1, 40, 60),
                     rng.integers(2, 20, 60))[:446].astype(np.int64)
    w = BullionWriter(path, [ColumnSpec("x", "int64")], rows_per_group=446,
                      page_rows=446)
    w.write_table({"x": vals})
    w.close()
    delete_rows(path, np.array([0, 1, 2]), Compliance.LEVEL1)
    delete_rows(path, np.array([10, 11, 12]), Compliance.LEVEL2)
    with dataset(path) as ds:
        got = ds.select(["x"]).to_table()["x"]
    keep = np.ones(len(vals), bool)
    keep[[0, 1, 2, 10, 11, 12]] = False
    assert np.array_equal(got, vals[keep])


# ---------------------------------------------------------------------------
# mixed-version datasets
# ---------------------------------------------------------------------------


@pytest.fixture
def mixed_dir(tmp_path):
    """One glob holding a v0 shard (stat-less, no page index), a single-page
    v1 shard (stats, no page index), and a multi-page v2 shard."""
    d = tmp_path / "mixed"
    d.mkdir()
    t0 = _write(str(d / "part-000.bln"), n=600, rows_per_group=200,
                page_rows=200, collect_stats=False, id_base=0, seed=10)
    _strip_page_index(str(d / "part-000.bln"))
    t1 = _write(str(d / "part-001.bln"), n=600, rows_per_group=200,
                page_rows=200, collect_stats=True, id_base=600, seed=11)
    _strip_page_index(str(d / "part-001.bln"))
    t2 = _write(str(d / "part-002.bln"), n=600, rows_per_group=200,
                page_rows=25, collect_stats=True, id_base=1200, seed=12)
    fvs = [read_footer(str(d / f"part-{i:03d}.bln"))[0] for i in range(3)]
    assert fvs[0].format_version == FORMAT_V0 and not fvs[0].has_stats
    assert fvs[1].format_version == FORMAT_V1 and fvs[1].has_stats
    assert fvs[2].has(Sec.CHUNK_PAGE_COUNT)
    tables = {k: (list(t0[k]) + list(t1[k]) + list(t2[k]))
              if isinstance(t0[k], list)
              else np.concatenate([t0[k], t1[k], t2[k]])
              for k in t0}
    return str(d), tables


def test_mixed_versions_scan_matches_serial_single_page(mixed_dir):
    d, tables = mixed_dir
    pred = (C("id") >= 550) & (C("id") < 1300)
    with dataset(os.path.join(d, "part-*.bln")) as ds:
        serial = ds.where(pred).select(["id", "val", "seq", "tag"]) \
            .to_table()
    with dataset(os.path.join(d, "part-*.bln")) as ds:
        parallel = ds.where(pred).select(["id", "val", "seq", "tag"]) \
            .to_table(parallelism=4)
    keep = (tables["id"] >= 550) & (tables["id"] < 1300)
    want = {
        "id": tables["id"][keep],
        "val": tables["val"][keep],
        "seq": [s for s, k in zip(tables["seq"], keep) if k],
        "tag": [t for t, k in zip(tables["tag"], keep) if k],
    }
    _assert_tables_equal(serial, want)
    _assert_tables_equal(parallel, want)


def test_mixed_versions_compact_and_audit(mixed_dir, tmp_path):
    d, tables = mixed_dir
    out = str(tmp_path / "compacted")
    with dataset(os.path.join(d, "part-*.bln")) as ds:
        res = ds.write_to(out, shard_rows=700, page_rows=50)
    assert res.rows == len(tables["id"])
    with dataset(out) as ds:
        _assert_tables_equal(ds.to_table(), tables)
    # compliance delete on the compacted output; the purge audit must still
    # hold on the multi-page layout
    victims = [10, 650, 1250]
    with dataset(out) as ds:
        ds.delete_where(C("id").isin(victims))
    for path in sorted(os.listdir(out)):
        audit = verify_deleted(os.path.join(out, path), "id", victims)
        assert audit["visible_rows"] == 0
        assert audit["raw_occurrences"] == 0
    with dataset(out) as ds:
        left = ds.select(["id"]).to_table()["id"]
    assert not np.isin(left, victims).any()
    assert len(left) == len(tables["id"]) - len(victims)


# ---------------------------------------------------------------------------
# loader rank striping
# ---------------------------------------------------------------------------


def _loader_shards(loader):
    return {loader._tasks[g].shard for g in loader._my_groups(0)}


def test_loader_stripes_ranks_across_shards(tmp_path):
    from repro.data.loader import BullionLoader
    from repro.data.synthetic import write_lm_corpus
    d = tmp_path / "corpus"
    d.mkdir()
    for s in range(4):
        write_lm_corpus(str(d / f"part-{s:03d}.bln"), n_docs=32, vocab=64,
                        doc_len=64, rows_per_group=8, seed=s)
    loaders = [BullionLoader(str(d), batch_size=2, seq_len=16,
                             rank=r, world=2) for r in range(2)]
    try:
        shard_sets = [_loader_shards(ld) for ld in loaders]
        assert shard_sets[0] & shard_sets[1] == set()      # disjoint files
        assert shard_sets[0] | shard_sets[1] == {0, 1, 2, 3}
        covered = set(loaders[0]._my_groups(0)) | set(loaders[1]._my_groups(0))
        assert covered == set(loaders[0]._groups)          # nothing dropped
    finally:
        for ld in loaders:
            ld.close()


def test_loader_never_starves_a_rank_when_pruning_empties_shards(tmp_path):
    """Shard striping must consider only *surviving* shards: with a
    predicate whose zone maps prune one shard entirely, both ranks still
    get work (group-striping fallback) instead of one rank spinning with
    zero groups."""
    from repro.data.loader import BullionLoader
    from repro.scan import C as Col
    d = tmp_path / "lopsided"
    d.mkdir()
    # doc_id is clustered per shard: shard 0 holds [0, 32), shard 1 [1000+)
    for s, base in ((0, 0), (1, 1000)):
        w = BullionWriter(str(d / f"part-{s:03d}.bln"),
                          [ColumnSpec("doc_id", "int64"),
                           ColumnSpec("tokens", "list<int32>")],
                          rows_per_group=8)
        w.write_table({
            "doc_id": np.arange(base, base + 32, dtype=np.int64),
            "tokens": [np.arange(16, dtype=np.int32)] * 32,
        })
        w.close()
    loaders = [BullionLoader(str(d), batch_size=2, seq_len=4, rank=r,
                             world=2, predicate=Col("doc_id") < 100)
               for r in range(2)]
    try:
        mine = [set(ld._my_groups(0)) for ld in loaders]
        assert mine[0] and mine[1]                  # no starved rank
        assert mine[0] & mine[1] == set()
        assert mine[0] | mine[1] == set(loaders[0]._groups)
    finally:
        for ld in loaders:
            ld.close()


def test_page_pruning_skipped_when_col0_boundaries_disagree(tmp_path):
    """Defensive planner guard: a (foreign/corrupted) footer whose column-0
    page boundaries disagree with the read columns must fall back to
    whole-chunk reads — never emit a page subset the executor would map
    through the wrong row ranges (``selected_raw_rows`` anchors on column
    0)."""
    path = str(tmp_path / "skew.bln")
    w = BullionWriter(path, [ColumnSpec("a", "int64"),
                             ColumnSpec("b", "int64")],
                      rows_per_group=512, page_rows=64)
    w.write_table({"a": np.arange(1024, dtype=np.int64),
                   "b": np.arange(1024, dtype=np.int64)})
    w.close()
    with dataset(path) as ds:                 # positive control: aligned
        phys = ds.where(C("b") == 700).select(["b"]).physical_plan()
        assert any(t.pages is not None for t in phys.tasks)
    fv, foot_off = read_footer(path)
    rows = fv.arr(Sec.PAGE_ROWS, np.uint32).copy()
    s, _ = fv.chunk_pages(1, 0)               # column 0's chunk in group 1
    rows[s], rows[s + 1] = 32, 96             # same sum, shifted boundary
    fb = FooterBuilder()
    for sid in Sec:
        if fv.has(sid):
            fb.put(sid, bytes(fv.raw(sid)))
    fb.put(Sec.PAGE_ROWS, rows)
    footer = fb.build()
    with open(path, "r+b") as f:
        f.seek(foot_off)
        f.write(footer)
        f.write(struct.pack("<Q", len(footer)) + MAGIC)
        f.truncate()
    with dataset(path) as ds:
        q = ds.where(C("b") == 700).select(["b"])   # row 700 -> group 1
        phys = q.physical_plan()
        assert phys.tasks and all(t.pages is None for t in phys.tasks)
        assert q.to_table()["b"].tolist() == [700]  # still correct, unpruned


def test_loader_falls_back_to_group_striping(tmp_path):
    from repro.data.loader import BullionLoader
    from repro.data.synthetic import write_lm_corpus
    path = str(tmp_path / "single.bln")
    write_lm_corpus(path, n_docs=32, vocab=64, doc_len=64, rows_per_group=8)
    loaders = [BullionLoader(path, batch_size=2, seq_len=16,
                             rank=r, world=2) for r in range(2)]
    try:
        mine = [set(ld._my_groups(0)) for ld in loaders]
        assert mine[0] & mine[1] == set()
        assert mine[0] | mine[1] == set(loaders[0]._groups)
        assert mine[0] and mine[1]                  # both ranks get work
    finally:
        for ld in loaders:
            ld.close()


# ---------------------------------------------------------------------------
# plan-time schema errors
# ---------------------------------------------------------------------------


def test_missing_column_error_names_column_and_shard(tmp_path):
    path = str(tmp_path / "err.bln")
    _write(path, n=100, rows_per_group=50)
    with dataset(path) as ds:
        with pytest.raises(ColumnNotFoundError) as ei:
            ds.select(["id", "nope"]).to_table()
        assert "nope" in str(ei.value) and "err.bln" in str(ei.value)
        with pytest.raises(KeyError):               # stays a KeyError
            ds.select(["nope"]).to_table()
        with pytest.raises(ColumnNotFoundError) as ei:
            ds.where(C("ghost") > 1).count_rows()
        assert "ghost" in str(ei.value) and "err.bln" in str(ei.value)
