"""Storage-backend tests: ``bullion://`` object-store shards end to end.

Everything runs against the in-process ``FakeObjectStore`` (threaded HTTP
server over a temp directory) so the whole matrix — byte parity with local
reads, async batched overlap, retry/backoff behavior under injected
latency / 5xx / truncated-body faults, ETag-validated footer caching, and
the CLI surfaces — is hermetic.
"""

import os
import time

import numpy as np
import pytest

from repro.core import backend as _backend
from repro.core.reader import IOStats
from repro.core.writer import BullionWriter, ColumnSpec
from repro.dataset import cached_footer, clear_footer_cache, dataset, discover
from repro.obs import metrics as _metrics
from repro.obs import querylog as _querylog
from repro.obs import trace as _trace
from repro.scan import C
from repro.testing import FakeObjectStore

N_SHARDS = 3
ROWS = 2048
GROUP = 512
COLS = ["id", "v", "w"]


def _write_bucket(root, *, n_shards=N_SHARDS, rows=ROWS):
    bucket = os.path.join(root, "bucket")
    os.makedirs(bucket, exist_ok=True)
    schema = [ColumnSpec("id", "int64"), ColumnSpec("v", "float32"),
              ColumnSpec("w", "float32")]
    paths = []
    for s in range(n_shards):
        rng = np.random.default_rng(s)
        p = os.path.join(bucket, f"part-{s:04d}.bln")
        w = BullionWriter(p, schema, rows_per_group=GROUP)
        w.write_table({
            "id": np.arange(s * rows, (s + 1) * rows, dtype=np.int64),
            "v": rng.random(rows).astype(np.float32),
            "w": rng.random(rows).astype(np.float32),
        })
        w.close()
        paths.append(p)
    return paths


@pytest.fixture
def store(tmp_path):
    """A running fake object store over a freshly written bucket, already
    configured as the process endpoint; undone (and the footer cache
    cleared) on teardown."""
    paths = _write_bucket(str(tmp_path))
    clear_footer_cache()
    with FakeObjectStore(str(tmp_path)) as s:
        _backend.configure_object_store(s.endpoint)
        s.local_paths = paths
        s.uris = [f"bullion://bucket/part-{i:04d}.bln"
                  for i in range(len(paths))]
        try:
            yield s
        finally:
            _backend.configure_object_store(None)
            clear_footer_cache()


def _counter(name):
    return _metrics.counter(name).value


# ---------------------------------------------------------------------------
# byte parity + accounting
# ---------------------------------------------------------------------------

def test_remote_reads_byte_identical_to_local(store):
    with dataset(store.local_paths) as ds:
        local = ds.select(COLS).to_table()
    for depth in (1, 4):
        clear_footer_cache()
        with dataset(store.uris) as ds:
            remote = ds.select(COLS).to_table(io_depth=depth)
            st = ds.stats
        for c in COLS:
            assert local[c].tobytes() == remote[c].tobytes(), (depth, c)
        # remote I/O is charged to the backend counters, never to the
        # local-pread ones bench accounting relies on
        assert st.preads == 0
        assert st.backend_fetches > 0
        assert st.bytes_read > 0


def test_remote_predicate_and_head_match_local(store):
    victim = ROWS + GROUP // 2
    with dataset(store.local_paths) as ds:
        l_pred = ds.where(C("id") >= victim).select(["id", "v"]).to_table()
        l_head = ds.select(["id"]).head(700).to_table()
    with dataset(store.uris) as ds:
        r_pred = ds.where(C("id") >= victim).select(["id", "v"]) \
            .to_table(io_depth=3)
        r_head = ds.select(["id"]).head(700).to_table(io_depth=3)
    assert l_pred["id"].tobytes() == r_pred["id"].tobytes()
    assert l_pred["v"].tobytes() == r_pred["v"].tobytes()
    assert l_head["id"].tobytes() == r_head["id"].tobytes()


def test_mixed_local_and_remote_shard_list(store):
    spec = [store.local_paths[0], *store.uris[1:]]
    with dataset(store.local_paths) as ds:
        local = ds.select(COLS).to_table()
    with dataset(spec) as ds:
        mixed = ds.select(COLS).to_table(io_depth=4)
        st = ds.stats
    for c in COLS:
        assert local[c].tobytes() == mixed[c].tobytes(), c
    assert st.preads > 0 and st.backend_fetches > 0


# ---------------------------------------------------------------------------
# async batched overlap + speedup
# ---------------------------------------------------------------------------

def test_async_batcher_overlaps_and_beats_serialized(store):
    # 8 groups per shard: at io_depth=8 the remote run-span cap (depth//2)
    # splits each shard into >= 2 runs, so a batch really holds concurrent
    # ranges (4-group shards collapse to one run each and would serialize)
    _write_bucket(store.root, rows=8 * GROUP)
    clear_footer_cache()
    store.latency = 0.02
    with dataset(store.uris) as ds:      # warm the remote footer cache
        ds.select(["id"]).head(1).to_table()

    t0 = time.perf_counter()
    with dataset(store.uris) as ds:
        serial = ds.select(COLS).to_table(io_depth=1)
    t_serial = time.perf_counter() - t0

    store.max_in_flight = 0
    t0 = time.perf_counter()
    with dataset(store.uris) as ds:
        batched = ds.select(COLS).to_table(io_depth=8)
    t_batched = time.perf_counter() - t0

    for c in COLS:
        assert serial[c].tobytes() == batched[c].tobytes(), c
    assert store.max_in_flight >= 2, \
        f"expected overlapped ranges, store saw {store.max_in_flight}"
    assert t_batched * 2 <= t_serial, \
        f"batched {t_batched * 1e3:.0f}ms vs serial {t_serial * 1e3:.0f}ms"


# ---------------------------------------------------------------------------
# errors: missing keys, unreachable stores, malformed URIs
# ---------------------------------------------------------------------------

def test_missing_key_raises_filenotfound(store):
    with pytest.raises(FileNotFoundError, match="not found"):
        with dataset("bullion://bucket/nope.bln"):
            pass


def test_unreachable_endpoint_raises_filenotfound(store):
    _backend.configure_object_store("http://127.0.0.1:9")   # discard port
    with pytest.raises(FileNotFoundError, match="unreachable"):
        with dataset(store.uris[0]):
            pass


def test_no_endpoint_configured_raises_filenotfound(store, monkeypatch):
    _backend.configure_object_store(None)
    monkeypatch.delenv("BULLION_OBJECT_STORE", raising=False)
    with pytest.raises(FileNotFoundError, match="endpoint"):
        with dataset(store.uris[0]):
            pass


def test_malformed_uri_rejected_at_discover(store):
    with pytest.raises(ValueError, match="bullion://bucket/key"):
        discover("bullion://only-a-bucket")


# ---------------------------------------------------------------------------
# fault injection: 5xx, truncation, backoff caps, exhausted retries
# ---------------------------------------------------------------------------

def _warm_remote(store):
    """Scan once so every shard's footer is cached before faults are queued
    (footer-tail GETs carry Range headers and would consume them)."""
    with dataset(store.uris) as ds:
        ds.select(["id"]).to_table()


def test_5xx_retries_then_succeeds(store, monkeypatch):
    monkeypatch.setenv("BULLION_BACKEND_BACKOFF", "0.001")
    _warm_remote(store)
    before = _counter("bullion.backend.retries")
    store.inject(count=2, status=503)
    with dataset(store.uris) as ds:
        tbl = ds.select(COLS).to_table(io_depth=1)
        st = ds.stats
    assert len(tbl["id"]) == N_SHARDS * ROWS
    assert st.backend_retries >= 2
    assert _counter("bullion.backend.retries") - before >= 2


@pytest.mark.parametrize("depth", [1, 4])
def test_truncated_body_retries_transparently(store, monkeypatch, depth):
    monkeypatch.setenv("BULLION_BACKEND_BACKOFF", "0.001")
    _warm_remote(store)
    with dataset(store.local_paths) as ds:
        local = ds.select(COLS).to_table()
    store.inject(count=2, truncate=0.5)
    with dataset(store.uris) as ds:
        tbl = ds.select(COLS).to_table(io_depth=depth)
        st = ds.stats
    for c in COLS:
        assert local[c].tobytes() == tbl[c].tobytes(), c
    assert st.backend_retries >= 2
    store.clear_faults()


def test_retry_backoff_is_capped(store, monkeypatch):
    # uncapped exponential would sleep ~0.2 + 0.4 + 0.8 s; the cap clamps
    # every delay to 50 ms (±25% jitter), so three retries stay well under
    monkeypatch.setenv("BULLION_BACKEND_RETRIES", "3")
    monkeypatch.setenv("BULLION_BACKEND_BACKOFF", "0.2")
    monkeypatch.setenv("BULLION_BACKEND_BACKOFF_CAP", "0.05")
    _warm_remote(store)
    store.inject(count=3, status=503)
    t0 = time.perf_counter()
    with dataset(store.uris) as ds:
        tbl = ds.select(["id"]).to_table(io_depth=1)
    elapsed = time.perf_counter() - t0
    assert len(tbl["id"]) == N_SHARDS * ROWS
    assert elapsed < 0.8, f"backoff cap not honored: {elapsed:.2f}s"


def test_exhausted_retries_fall_back_per_run_then_succeed(store, monkeypatch):
    """A failed batched run fails only the tasks it covered: they fall back
    to direct reads (which see a drained fault queue here) and the query
    still returns correct bytes."""
    monkeypatch.setenv("BULLION_BACKEND_RETRIES", "0")   # any fault exhausts
    monkeypatch.setenv("BULLION_BACKEND_BACKOFF", "0.001")
    _warm_remote(store)
    with dataset(store.local_paths) as ds:
        local = ds.select(COLS).to_table()
    store.inject(count=1, status=503)
    with dataset(store.uris) as ds:
        tbl = ds.select(COLS).to_table(io_depth=8)
    for c in COLS:
        assert local[c].tobytes() == tbl[c].tobytes(), c


def test_exhausted_retries_fail_query_with_log_record(store, monkeypatch):
    monkeypatch.setenv("BULLION_BACKEND_RETRIES", "1")
    monkeypatch.setenv("BULLION_BACKEND_BACKOFF", "0.001")
    _warm_remote(store)
    store.inject(count=500, status=503)   # persistent: fallbacks fail too
    _querylog.enable_local(True)
    try:
        base = _querylog.LOG.total
        with pytest.raises(OSError):
            with dataset(store.uris) as ds:
                ds.select(COLS).to_table(io_depth=1)
        recs = [r for r in _querylog.LOG.records() if r.outcome == "error"]
        assert _querylog.LOG.total > base
        assert recs and "503" in (recs[-1].error or "")
    finally:
        _querylog.enable_local(False)
        store.clear_faults()


# ---------------------------------------------------------------------------
# remote footer cache: URI keys, (ETag, length) validation
# ---------------------------------------------------------------------------

def test_remote_footer_cache_hits_by_etag(store):
    uri = store.uris[0]
    fv1, off1, hit1 = cached_footer(uri)
    assert not hit1
    ranges_after_miss = store.range_requests
    fv2, off2, hit2 = cached_footer(uri)
    assert hit2 and fv2 is fv1 and off2 == off1
    # a hit costs HEAD(s) only — no new range GETs
    assert store.range_requests == ranges_after_miss

    with dataset(store.uris) as ds:
        ds.select(["id"]).head(1).to_table()
    with dataset(store.uris) as ds:
        ds.select(["id"]).head(1).to_table()
        assert ds.stats.footer_cache_hits == len(store.uris)


def test_remote_footer_cache_invalidates_on_rewrite(store):
    uri = store.uris[0]
    path = store.local_paths[0]
    _, _, hit = cached_footer(uri)
    assert not hit
    _, _, hit = cached_footer(uri)
    assert hit
    # rewrite the object: ETag (mtime+size) changes, entry must invalidate
    _write_bucket(os.path.dirname(os.path.dirname(path)), n_shards=1,
                  rows=ROWS + GROUP)
    fv, _, hit = cached_footer(uri)
    assert not hit
    assert fv.num_rows == ROWS + GROUP


# ---------------------------------------------------------------------------
# CLI over URIs
# ---------------------------------------------------------------------------

def test_cli_inspect_and_fsck_accept_uris(store, capsys):
    from repro.cli import main
    assert main(["inspect", "--pages", store.uris[0]]) == 0
    out = capsys.readouterr().out
    assert store.uris[0] in out and "group 0:" in out
    assert main(["fsck", "-v", store.uris[0]]) == 0
    out = capsys.readouterr().out
    assert "clean" in out


def test_cli_reports_missing_remote_objects(store, capsys):
    from repro.cli import main
    assert main(["inspect", "bullion://bucket/missing.bln"]) == 2
    err = capsys.readouterr().err
    assert "not found" in err
    assert main(["fsck", "bullion://bucket/missing.bln"]) == 1
    out = capsys.readouterr().out
    assert "unreadable footer" in out


# ---------------------------------------------------------------------------
# IOStats plumbing for the backend counters
# ---------------------------------------------------------------------------

def test_backend_counters_flow_through_merge_sum_delta():
    a = IOStats(backend_fetches=2, backend_retries=1, backend_wasted_bytes=10)
    b = IOStats(backend_fetches=3, backend_wasted_bytes=5)
    total = IOStats.sum([a, b])
    assert (total.backend_fetches, total.backend_retries,
            total.backend_wasted_bytes) == (5, 1, 15)
    d = total.delta(a)
    assert (d.backend_fetches, d.backend_retries,
            d.backend_wasted_bytes) == (3, 0, 5)


def test_remote_coalescing_charges_backend_wasted_bytes(store):
    # skip the middle column: the unread "v" pages sit between wanted "id"
    # and "w" pages, and a huge gap coalesces ranges right across them
    with dataset(store.uris, coalesce_gap=4 * 1024 * 1024) as ds:
        ds.select(["id", "w"]).to_table(io_depth=1)
        st = ds.stats
    assert st.coalesced_preads > 0
    assert st.backend_wasted_bytes > 0
    assert st.wasted_bytes == 0       # hole bytes stay in the remote bucket


# ---------------------------------------------------------------------------
# satellite: partial-prefetch reconciliation (PrefetchReader fallback)
# ---------------------------------------------------------------------------

def test_partial_prefetch_reconciliation_local(tmp_path):
    """With a predicate gating payload reads, only predicate pages are
    prefetched; payload pages go through the PrefetchReader fallback. The
    fallback charges preads/coalesced_preads exactly like the serial path,
    so decode-span pages reconcile with the IOStats delta."""
    paths = _write_bucket(str(tmp_path), n_shards=1)
    clear_footer_cache()
    before_fb = _metrics.counter("bullion.io.prefetch_fallback_pages").value
    with dataset(paths) as ds:
        before = ds.stats
        with _trace.collect() as tr:
            ds.where(C("id") >= GROUP).select(COLS).to_table(io_depth=3)
        st = ds.stats.delta(before)
    pages = sum(s.args.get("pages", 0) for s in tr.spans
                if s.name == "decode.pread")
    span_bytes = sum(s.args.get("bytes", 0) for s in tr.spans
                     if s.name == "decode.pread")
    footer_preads = 2 if st.footer_bytes else 0
    assert pages == (st.preads - footer_preads) + st.coalesced_preads
    assert span_bytes + st.wasted_bytes == st.bytes_read - st.footer_bytes
    fallback = _metrics.counter("bullion.io.prefetch_fallback_pages").value \
        - before_fb
    assert fallback > 0, "predicate plan should exercise the fallback path"
