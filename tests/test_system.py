"""End-to-end behaviour tests: the full Bullion -> loader -> train -> delete
-> retrain lifecycle, plus the serving engine."""

import os

import jax
import jax.numpy as jnp
import numpy as np

import repro.configs as configs
from repro.data import BullionLoader, write_lm_corpus
from repro.models import zoo
from repro.serve import ServeEngine
from repro.train import AdamWConfig, adamw_init, make_train_step


def test_train_from_bullion_then_delete_then_train(tmp_path):
    """GDPR lifecycle: train on a Bullion corpus, physically delete some
    documents, keep training on the same file without rewriting it."""
    from repro.core import BullionReader, Compliance, delete_rows

    corpus = str(tmp_path / "c.bln")
    write_lm_corpus(corpus, n_docs=64, vocab=128, doc_len=256,
                    rows_per_group=8)
    cfg = configs.get_smoke("llama3_2_1b").scaled(compute_dtype="float32",
                                                  vocab=128)
    m = zoo.build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(m, AdamWConfig(lr=2e-3)))

    loader = BullionLoader(corpus, batch_size=2, seq_len=64)
    it = iter(loader)
    losses = []
    for _ in range(8):
        batch, _ = next(it)
        params, opt, metrics = step(params, opt, {"tokens": jnp.asarray(batch)})
        losses.append(float(metrics["loss"]))
    loader.close()

    # user deletes documents 3..7 (by doc_id)
    with BullionReader(corpus) as r:
        rows = r.find_rows("doc_id", np.arange(3, 8))
    delete_rows(corpus, rows, Compliance.LEVEL2)
    with BullionReader(corpus) as r:
        assert r.num_rows == 64  # logical rows tracked via DV
        ids = r.read_column("doc_id")
        assert len(ids) == 59 and not np.isin(np.arange(3, 8), ids).any()

    loader = BullionLoader(corpus, batch_size=2, seq_len=64)
    it = iter(loader)
    for _ in range(4):
        batch, _ = next(it)
        params, opt, metrics = step(params, opt, {"tokens": jnp.asarray(batch)})
        assert np.isfinite(float(metrics["loss"]))
    loader.close()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_serving_engine_generates(tmp_path):
    cfg = configs.get_smoke("llama3_2_1b").scaled(compute_dtype="float32")
    m = zoo.build(cfg)
    params = m.init(jax.random.PRNGKey(0))
    eng = ServeEngine(m, params, max_seq=64)
    prompts = np.asarray(jax.random.randint(jax.random.PRNGKey(1), (3, 16),
                                            0, cfg.vocab), np.int32)
    out = eng.generate(prompts, max_new_tokens=8)
    assert out["tokens"].shape == (3, 8)
    assert out["decode_tok_per_s"] > 0
    # greedy decode is deterministic
    out2 = eng.generate(prompts, max_new_tokens=8)
    assert np.array_equal(out["tokens"], out2["tokens"])


def test_train_driver_cli(tmp_path):
    from repro.launch.train import main
    losses = main(["--arch", "llama3.2-1b", "--smoke", "--steps", "12",
                   "--batch", "2", "--seq", "32",
                   "--data", str(tmp_path / "d"),
                   "--ckpt", str(tmp_path / "ck"),
                   "--ckpt-every", "6", "--log-every", "6"])
    assert len(losses) == 12
    assert all(np.isfinite(l) for l in losses)
