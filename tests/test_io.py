"""Pipelined I/O subsystem tests: scheduler output parity with serial
execution (v0/v1/v2 and mixed-version globs), bounded read-ahead and early
exit, coalesce-gap configuration and hole accounting, the process-wide
footer cache (hits, invalidation, concurrency), and the loader/sink wiring.
"""

import os
import struct
import threading

import numpy as np
import pytest

from repro.core import BullionReader, BullionWriter, ColumnSpec
from repro.core.footer import (FORMAT_V0, FORMAT_V1, FooterBuilder, MAGIC,
                               Sec, read_footer)
from repro.core.reader import COALESCE_GAP, default_coalesce_gap
from repro.dataset import (clear_footer_cache, dataset, cached_footer,
                           invalidate_cached_footer)
from repro.scan import C

COLS = ["id", "val", "seq", "tag"]


def _data_preads(st):
    """Preads net of footer reads (2 per shard whose footer was charged —
    a cache-hit open charges neither the preads nor the footer bytes)."""
    return st.preads - (2 if st.footer_bytes else 0)


def _write(path, *, n=1000, rows_per_group=256, page_rows=None,
           collect_stats=True, id_base=0, seed=0):
    """Clustered table (sorted ids) with scalar, list, and string columns."""
    rng = np.random.default_rng(seed)
    schema = [
        ColumnSpec("id", "int64"),
        ColumnSpec("val", "float32"),
        ColumnSpec("seq", "list<int64>"),
        ColumnSpec("tag", "string"),
    ]
    table = {
        "id": np.arange(id_base, id_base + n, dtype=np.int64),
        "val": rng.random(n).astype(np.float32),
        "seq": [rng.integers(0, 50, int(rng.integers(0, 5))).astype(np.int64)
                for _ in range(n)],
        "tag": [b"t%d" % (i % 7) for i in range(n)],
    }
    w = BullionWriter(path, schema, rows_per_group=rows_per_group,
                      page_rows=page_rows, collect_stats=collect_stats)
    w.write_table(table)
    w.close()
    return table


def _strip_page_index(path):
    """Rewrite the footer without ``Sec.CHUNK_PAGE_COUNT`` (pre-v2 file)."""
    fv, foot_off = read_footer(path)
    fb = FooterBuilder()
    for sid in Sec:
        if fv.has(sid) and sid != Sec.CHUNK_PAGE_COUNT:
            fb.put(sid, bytes(fv.raw(sid)))
    meta = fv.meta.copy()
    meta[7] = FORMAT_V1 if fv.has_stats else FORMAT_V0
    fb.put(Sec.META, meta)
    footer = fb.build()
    with open(path, "r+b") as f:
        f.seek(foot_off)
        f.write(footer)
        f.write(struct.pack("<Q", len(footer)) + MAGIC)
        f.truncate()
    invalidate_cached_footer(path)


def _assert_tables_equal(got, want):
    assert np.array_equal(got["id"], want["id"])
    assert np.allclose(got["val"], want["val"])
    assert all(np.array_equal(a, b) for a, b in zip(got["seq"], want["seq"]))
    assert got["tag"] == want["tag"]


@pytest.fixture
def mixed_dir(tmp_path):
    """A glob of a v0 shard, a single-page v1 shard, and a multi-page v2
    shard — the full backward-compat read matrix."""
    d = tmp_path / "mixed"
    d.mkdir()
    t0 = _write(str(d / "part-000.bln"), n=600, rows_per_group=200,
                page_rows=200, collect_stats=False, id_base=0, seed=10)
    _strip_page_index(str(d / "part-000.bln"))
    t1 = _write(str(d / "part-001.bln"), n=600, rows_per_group=200,
                page_rows=200, collect_stats=True, id_base=600, seed=11)
    _strip_page_index(str(d / "part-001.bln"))
    t2 = _write(str(d / "part-002.bln"), n=600, rows_per_group=200,
                page_rows=25, collect_stats=True, id_base=1200, seed=12)
    tables = {k: (list(t0[k]) + list(t1[k]) + list(t2[k]))
              if isinstance(t0[k], list)
              else np.concatenate([t0[k], t1[k], t2[k]])
              for k in t0}
    return os.path.join(str(d), "part-*.bln"), tables


# ---------------------------------------------------------------------------
# pipelined == serial
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("io_depth,parallelism", [(2, 1), (4, 1), (3, 4)])
def test_pipelined_byte_identical_mixed_versions(mixed_dir, io_depth,
                                                 parallelism):
    glob, tables = mixed_dir
    with dataset(glob) as ds:
        serial = ds.select(COLS).to_table()
    with dataset(glob) as ds:
        piped = ds.select(COLS).to_table(io_depth=io_depth,
                                         parallelism=parallelism)
    _assert_tables_equal(piped, serial)
    assert piped["id"].tobytes() == serial["id"].tobytes()
    assert piped["val"].tobytes() == serial["val"].tobytes()
    _assert_tables_equal(serial, tables)


def test_pipelined_predicate_and_rows_match_serial(mixed_dir):
    glob, tables = mixed_dir
    pred = (C("id") >= 550) & (C("id") < 1300)
    with dataset(glob) as ds:
        serial = ds.where(pred).select(COLS).to_table()
    with dataset(glob) as ds:
        piped = ds.where(pred).select(COLS).to_table(io_depth=3)
    _assert_tables_equal(piped, serial)
    with dataset(glob) as ds:
        rows = ds.where(pred).drop_deleted(False).row_ids(io_depth=2)
    with dataset(glob) as ds:
        pinned_serial = ds.with_rows(rows).select(["id"]).to_table()
        assert np.array_equal(np.sort(pinned_serial["id"]),
                              np.sort(tables["id"][(tables["id"] >= 550)
                                                   & (tables["id"] < 1300)]))
    with dataset(glob) as ds:
        pinned_piped = ds.with_rows(rows).select(["id"]) \
            .to_table(io_depth=4, parallelism=2)
    assert pinned_piped["id"].tobytes() == pinned_serial["id"].tobytes()


def test_pipelined_with_deletions_matches_serial(tmp_path):
    path = str(tmp_path / "del.bln")
    _write(path, n=2048, rows_per_group=512, page_rows=64, seed=3)
    with dataset(path) as ds:
        ds.delete_where(C("id").isin([5, 700, 1500]))
    with dataset(path) as ds:
        serial = ds.select(COLS).to_table()
    with dataset(path) as ds:
        piped = ds.select(COLS).to_table(io_depth=3)
    _assert_tables_equal(piped, serial)
    assert not np.isin(piped["id"], [5, 700, 1500]).any()


def test_pipelined_head_limit_early_exit(tmp_path):
    """A head() limit abandons the task stream early; the scheduler thread
    must shut down cleanly and the prefix must match serial execution."""
    d = tmp_path / "shards"
    d.mkdir()
    for s in range(3):
        _write(str(d / f"p{s}.bln"), n=600, rows_per_group=100,
               id_base=600 * s, seed=s)
    with dataset(str(d)) as ds:
        serial = ds.select(["id"]).head(250).to_table()
    with dataset(str(d)) as ds:
        piped = ds.select(["id"]).head(250).to_table(io_depth=4)
    assert piped["id"].tobytes() == serial["id"].tobytes()
    assert len(piped["id"]) == 250


def test_io_depth_one_degenerates_to_serial_stats(tmp_path):
    """``io_depth=1`` must not construct a scheduler: every I/O statistic
    (preads, bytes, coalescing, holes) matches the plain path exactly."""
    path = str(tmp_path / "t.bln")
    _write(path, n=1200, rows_per_group=300)

    def run(**kw):
        clear_footer_cache()
        with dataset(path) as ds:
            ds.select(COLS).to_table(**kw)
            st = ds.stats
        return st

    base, one = run(), run(io_depth=1)
    for f in ("preads", "bytes_read", "footer_bytes", "coalesced_preads",
              "wasted_bytes", "footer_cache_hits"):
        assert getattr(one, f) == getattr(base, f), f
    with dataset(path) as ds:
        with pytest.raises(ValueError):
            ds.select(["id"]).to_table(io_depth=0)


def test_pipelined_wide_projection_halves_preads(tmp_path):
    """Acceptance: >= 2x fewer data preads than serial per-group reads on a
    wide multi-shard projection, byte-identical output."""
    d = tmp_path / "wide"
    d.mkdir()
    schema = [ColumnSpec("id", "int64")] + \
        [ColumnSpec(f"f{i}", "float32") for i in range(5)]
    n, rpg = 2048, 512
    for s in range(2):
        rng = np.random.default_rng(s)
        w = BullionWriter(str(d / f"p{s}.bln"), schema, rows_per_group=rpg)
        w.write_table({"id": np.arange(s * n, (s + 1) * n, dtype=np.int64),
                       **{f"f{i}": rng.random(n).astype(np.float32)
                          for i in range(5)}})
        w.close()
    cols = ["id"] + [f"f{i}" for i in range(5)]

    def run(io_depth):
        clear_footer_cache()
        with dataset(str(d)) as ds:
            tbl = ds.select(cols).to_table(io_depth=io_depth)
            st = ds.stats
        return tbl, st.preads - 2 * 2   # 2 footer preads per cold shard

    serial_tbl, serial_preads = run(1)
    piped_tbl, piped_preads = run(4)
    for c in cols:
        assert piped_tbl[c].tobytes() == serial_tbl[c].tobytes(), c
    assert piped_preads * 2 <= serial_preads, \
        f"{serial_preads} serial vs {piped_preads} pipelined data preads"


# ---------------------------------------------------------------------------
# coalesce gap configuration + hole accounting
# ---------------------------------------------------------------------------


def test_coalesce_gap_env_and_argument(tmp_path, monkeypatch):
    path = str(tmp_path / "gap.bln")
    _write(path, n=512, rows_per_group=256)
    assert default_coalesce_gap() == COALESCE_GAP
    monkeypatch.setenv("BULLION_COALESCE_GAP", "131072")
    assert default_coalesce_gap() == 131072
    monkeypatch.setenv("BULLION_COALESCE_GAP", "nope")
    with pytest.raises(ValueError):
        default_coalesce_gap()
    monkeypatch.setenv("BULLION_COALESCE_GAP", "-1")
    with pytest.raises(ValueError):
        default_coalesce_gap()
    with pytest.raises(ValueError):            # the argument path agrees
        with dataset(path, coalesce_gap=-1) as ds:
            ds.select(["id"]).to_table()

    # gap 0 (via env): only physically contiguous ranges merge — no hole
    # is ever bridged, so projecting around the middle columns ("id" and
    # "seq" skip "val") splits into one read per column run
    gapped = ["id", "seq"]
    monkeypatch.setenv("BULLION_COALESCE_GAP", "0")
    with dataset(path) as ds:
        ds.select(gapped).to_table()
        st0 = ds.stats
    assert st0.wasted_bytes == 0
    monkeypatch.delenv("BULLION_COALESCE_GAP")

    # the dataset() argument overrides the env default per open
    with dataset(path, coalesce_gap=0) as ds:
        ds.select(gapped).to_table()
        st_arg = ds.stats
    assert st_arg.wasted_bytes == 0
    # same layout, same split reads
    assert _data_preads(st_arg) == _data_preads(st0)

    # default gap: the hole across the skipped column bridges and preads
    # collapse, with the hole bytes accounted
    with dataset(path) as ds:
        ds.select(gapped).to_table()
        st = ds.stats
    assert st.coalesced_preads > 0
    assert _data_preads(st) < _data_preads(st0)
    assert st.wasted_bytes > 0


def test_wasted_bytes_accounts_coalescing_holes(tmp_path):
    """Projecting two non-adjacent columns bridges the middle column's
    pages: the hole bytes must land in ``wasted_bytes`` (and only then)."""
    path = str(tmp_path / "holes.bln")
    w = BullionWriter(path, [ColumnSpec("a", "int64"),
                             ColumnSpec("b", "int64"),
                             ColumnSpec("c", "int64")], rows_per_group=512)
    w.write_table({k: np.arange(1024, dtype=np.int64) for k in "abc"})
    w.close()
    with dataset(path) as ds:
        ds.select(["a", "c"]).to_table()
        st = ds.stats
    assert st.coalesced_preads > 0
    assert st.wasted_bytes > 0          # read across b's pages
    with dataset(path, coalesce_gap=0) as ds:
        ds.select(["a", "c"]).to_table()
        split = ds.stats
    assert split.wasted_bytes == 0
    assert split.bytes_read - split.footer_bytes \
        == (st.bytes_read - st.footer_bytes) - st.wasted_bytes


# ---------------------------------------------------------------------------
# footer cache
# ---------------------------------------------------------------------------


def test_footer_cache_hits_and_zero_footer_preads(tmp_path):
    path = str(tmp_path / "cache.bln")
    table = _write(path, n=400, rows_per_group=100)
    clear_footer_cache()
    with dataset(path) as ds:
        cold_tbl = ds.select(COLS).to_table()
        cold = ds.stats
    assert cold.footer_cache_hits == 0 and cold.footer_bytes > 0
    with dataset(path) as ds:
        warm_tbl = ds.select(COLS).to_table()
        warm = ds.stats
    assert warm.footer_cache_hits == 1
    assert warm.footer_bytes == 0       # no footer pread, no re-parse
    assert warm.preads == cold.preads - 2
    _assert_tables_equal(warm_tbl, cold_tbl)
    _assert_tables_equal(warm_tbl, table)


def test_footer_cache_invalidates_on_writer_rewrite(tmp_path):
    """An in-process rewrite at the same path must serve the new footer even
    if filesystem timestamps are too coarse to distinguish the versions."""
    path = str(tmp_path / "rw.bln")
    _write(path, n=100, rows_per_group=50, id_base=0)
    with dataset(path) as ds:
        assert ds.select(["id"]).to_table()["id"][0] == 0
    st = os.stat(path)
    _write(path, n=100, rows_per_group=50, id_base=5000)
    # deliberately restore the old timestamps: only the explicit
    # writer-close invalidation can catch this rewrite
    os.utime(path, ns=(st.st_atime_ns, st.st_mtime_ns))
    with dataset(path) as ds:
        got = ds.select(["id"]).to_table()["id"]
    assert got[0] == 5000


def test_footer_cache_invalidates_on_external_replace(tmp_path):
    """A rewrite that bypasses our writers (different inode/mtime/size) is
    caught by the stat validator alone."""
    p1, p2 = str(tmp_path / "a.bln"), str(tmp_path / "b.bln")
    _write(p1, n=100, rows_per_group=50, id_base=0)
    _write(p2, n=100, rows_per_group=50, id_base=7000)
    with dataset(p1) as ds:
        assert ds.select(["id"]).to_table()["id"][0] == 0
    os.replace(p2, p1)                  # no in-process invalidation hook
    with dataset(p1) as ds:
        got = ds.select(["id"]).to_table()["id"]
    assert got[0] == 7000


def test_footer_cache_invalidates_on_delete_rows(tmp_path):
    from repro.core import Compliance, delete_rows
    path = str(tmp_path / "del.bln")
    _write(path, n=400, rows_per_group=100)
    with dataset(path) as ds:
        assert ds.count_rows() == 400   # footer cached here
    delete_rows(path, np.arange(10), Compliance.LEVEL1)
    with dataset(path) as ds:
        assert ds.count_rows() == 390   # post-delete footer, not the cache


def test_concurrent_datasets_share_one_cached_footer(tmp_path):
    path = str(tmp_path / "conc.bln")
    table = _write(path, n=600, rows_per_group=150)
    clear_footer_cache()
    fv, off, hit = cached_footer(path)
    assert not hit and fv.num_rows == 600
    results: list = [None] * 8

    def worker(i):
        try:
            with dataset(path) as ds:
                results[i] = (ds.stats.footer_cache_hits == 0,
                              ds.select(["id", "val"]).to_table(
                                  io_depth=2 + i % 3))
        except Exception as e:  # pragma: no cover - surfaced by assert
            results[i] = e
    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    for r in results:
        assert not isinstance(r, Exception), r
        miss, tbl = r
        assert not miss                  # every open hit the shared footer
        assert np.array_equal(tbl["id"], table["id"])
        assert np.allclose(tbl["val"], table["val"])


# ---------------------------------------------------------------------------
# loader + sink wiring
# ---------------------------------------------------------------------------


def test_loader_prefetch_pipelined_matches_serial(tmp_path):
    from repro.data.loader import BullionLoader
    from repro.data.synthetic import write_lm_corpus
    d = tmp_path / "corpus"
    d.mkdir()
    for s in range(3):
        write_lm_corpus(str(d / f"part-{s:03d}.bln"), n_docs=24, vocab=64,
                        doc_len=64, rows_per_group=8, seed=s)

    def take(prefetch, k=6):
        loader = BullionLoader(str(d), batch_size=2, seq_len=16,
                               prefetch=prefetch)
        try:
            out = []
            for batch, cursor in loader:
                out.append((batch.copy(), cursor.epoch, cursor.group))
                if len(out) >= k:
                    return out
        finally:
            loader.close()

    serial, piped = take(prefetch=1), take(prefetch=3)
    for (b0, e0, g0), (b1, e1, g1) in zip(serial, piped):
        assert b0.tobytes() == b1.tobytes()
        assert (e0, g0) == (e1, g1)


def test_sink_io_depth_matches_serial(tmp_path):
    src = str(tmp_path / "src")
    os.makedirs(src)
    for s in range(2):
        _write(os.path.join(src, f"p{s}.bln"), n=500, rows_per_group=100,
               id_base=500 * s, seed=s)
    with dataset(src) as ds:
        ds.where(C("id") < 800).select(COLS).write_to(
            str(tmp_path / "out_serial"), shard_rows=300)
    with dataset(src) as ds:
        ds.where(C("id") < 800).select(COLS).write_to(
            str(tmp_path / "out_piped"), shard_rows=300, io_depth=4,
            parallelism=2)
    with dataset(str(tmp_path / "out_serial")) as ds:
        serial = ds.select(COLS).to_table()
    with dataset(str(tmp_path / "out_piped")) as ds:
        piped = ds.select(COLS).to_table()
    _assert_tables_equal(piped, serial)
    # reclustering path (whole-table sort) with a pipelined read side
    with dataset(src) as ds:
        ds.select(COLS).write_to(str(tmp_path / "sorted"), sort_by="val",
                                 io_depth=3)
    with dataset(str(tmp_path / "sorted")) as ds:
        got = ds.select(["val"]).to_table()["val"]
    assert np.all(np.diff(got) >= 0)
