"""Bloom value sketches: build/serialize roundtrip, soundness (no false
negatives), format stamping (V3 sections, V0/V2 back-compat), group- and
page-granular sketch pruning on unclustered ids, deletion widening."""

import os

import numpy as np
import pytest

from repro.core import BullionWriter, ColumnSpec, delete_where
from repro.core.footer import (FORMAT_V0, FORMAT_V2, FORMAT_V3,
                               FORMAT_VERSION, Sec, read_footer)
from repro.dataset import clear_footer_cache, dataset
from repro.scan import C, BloomSketch, canonical_u64
from repro.scan.sketch import NO_SKETCH

# ---------------------------------------------------------------------------
# the sketch itself
# ---------------------------------------------------------------------------


def test_build_roundtrip_no_false_negatives():
    rng = np.random.default_rng(0)
    vals = rng.integers(0, 1 << 40, 3000)
    sk = BloomSketch.build(canonical_u64(vals))
    assert sk is not None
    buf = sk.to_bytes()
    sk2 = BloomSketch.from_buffer(buf, 0)
    assert sk2.nbits == sk.nbits and sk2.n_hash == sk.n_hash
    # soundness: every inserted value must be reported present, both sides
    # of the serialization (may_contain canonicalizes raw literals itself)
    for v in rng.choice(vals, 200, replace=False):
        assert sk.may_contain(int(v))
        assert sk2.may_contain(int(v))


def test_false_positive_rate_sane():
    rng = np.random.default_rng(1)
    present = rng.permutation(1 << 20)[:4000]
    sk = BloomSketch.build(canonical_u64(present))
    absent = np.setdiff1d(np.arange(1 << 16), present)
    fp = sum(sk.may_contain(int(v)) for v in absent[:2000])
    # 8 bits/key, 4 hashes => ~2-3% theoretical FPR; allow generous slack
    assert fp / 2000 < 0.10


def test_empty_sketch_refutes_everything():
    sk = BloomSketch.build(np.array([], dtype=np.uint64))
    assert sk is not None
    for v in (0, 1, -5, 3.25):
        assert not sk.may_contain(v)


def test_canonical_u64_folds_types_and_zero():
    # int 5, float 5.0, np.int64(5) hash identically
    a = canonical_u64(np.array([5], dtype=np.int64))
    b = canonical_u64(np.array([5.0]))
    c = canonical_u64(np.array([5], dtype=np.int32))
    assert a[0] == b[0] == c[0]
    # -0.0 folds onto +0.0 so `== 0` probes never miss a negative zero
    z = canonical_u64(np.array([0.0, -0.0]))
    assert z[0] == z[1]
    sk = BloomSketch.build(canonical_u64(np.array([-0.0])))
    assert sk.may_contain(0.0) and sk.may_contain(0)


def test_oversized_build_returns_none():
    # 8 bits/key: >128Ki distinct keys would blow the MAX_BITS cap
    keys = np.arange(200_000, dtype=np.uint64)
    assert BloomSketch.build(keys) is None


# ---------------------------------------------------------------------------
# format stamping + sections
# ---------------------------------------------------------------------------

SCHEMA = [ColumnSpec("id", "int64"), ColumnSpec("v", "float32")]


def _write(path, *, n=4096, rows_per_group=1024, page_rows=256, seed=0,
           **kw):
    """Unclustered ids: a permutation slice, so every group spans the full
    range (zone maps can't prune equality probes — only sketches can)."""
    rng = np.random.default_rng(seed)
    ids = rng.permutation(2 * n)[:n].astype(np.int64)
    w = BullionWriter(path, SCHEMA, rows_per_group=rows_per_group,
                      page_rows=page_rows, **kw)
    w.write_table({"id": ids, "v": rng.random(n).astype(np.float32)})
    w.close()
    return ids


def test_default_writer_stamps_v3_with_sketch_sections(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path)
    fv, _ = read_footer(path)
    assert FORMAT_VERSION == FORMAT_V3
    assert fv.format_version == FORMAT_V3
    assert fv.has_sketches
    for sid in (Sec.CHUNK_SKETCH, Sec.PAGE_SKETCH, Sec.SKETCH_DATA):
        assert fv.has(sid)
    # one chunk-sketch slot per (group, column); scalar columns populated
    offs = np.frombuffer(fv.raw(Sec.CHUNK_SKETCH), dtype=np.uint64)
    assert len(offs) == fv.n_groups * fv.n_cols
    assert np.all(offs != NO_SKETCH)


def test_sketches_opt_out_stamps_v2(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path, collect_sketches=False)
    fv, _ = read_footer(path)
    assert fv.format_version == FORMAT_V2
    assert fv.has_stats and not fv.has_sketches
    assert fv.chunk_sketch(0, 0) is None


def test_statless_file_stays_v0(tmp_path):
    path = str(tmp_path / "t.bln")
    _write(path, collect_stats=False, page_rows=None)
    fv, _ = read_footer(path)
    assert fv.format_version == FORMAT_V0
    assert not fv.has_stats and not fv.has_sketches


def test_list_and_string_columns_unsketched(tmp_path):
    path = str(tmp_path / "t.bln")
    schema = SCHEMA + [ColumnSpec("seq", "list<int64>"),
                       ColumnSpec("tag", "string")]
    rng = np.random.default_rng(3)
    n = 1024
    w = BullionWriter(path, schema, rows_per_group=512, page_rows=128)
    w.write_table({
        "id": rng.permutation(2 * n)[:n].astype(np.int64),
        "v": rng.random(n).astype(np.float32),
        "seq": [rng.integers(0, 9, 3).astype(np.int64) for _ in range(n)],
        "tag": [b"t%d" % (i % 7) for i in range(n)],
    })
    w.close()
    fv, _ = read_footer(path)
    assert fv.chunk_sketch(0, fv.column_index("id")) is not None
    assert fv.chunk_sketch(0, fv.column_index("seq")) is None
    assert fv.chunk_sketch(0, fv.column_index("tag")) is None


# ---------------------------------------------------------------------------
# pruning: the acceptance probe
# ---------------------------------------------------------------------------


def _mid_range_absent(ids, lo, hi):
    present = set(int(v) for v in ids)
    return next(v for v in range(lo, hi) if v not in present)


def test_point_probe_reads_footer_plus_two_pages(tmp_path):
    # acceptance: `C("id") == k` on an unclustered id column reads the
    # footer + at most 2 data pages (the id page + the payload page)
    clear_footer_cache()
    path = str(tmp_path / "t.bln")
    ids = _write(path, n=8192, rows_per_group=2048, page_rows=256)
    victim = int(ids[5000])
    with dataset(path) as ds:
        q = ds.where(C("id") == victim)
        tbl = q.to_table()
        st = ds.stats
        plan_text = q.explain()
    assert tbl["id"].tolist() == [victim]
    # 2 footer preads per shard; everything beyond is data pages
    assert st.preads - 2 <= 2, \
        f"point probe issued {st.preads} preads (footer is 2)"
    assert st.groups_pruned_sketch >= 2
    assert "by value sketch" in plan_text


def test_absent_probe_reads_nothing(tmp_path):
    clear_footer_cache()
    path = str(tmp_path / "t.bln")
    ids = _write(path, n=8192, rows_per_group=2048, page_rows=256)
    # mid-range so zone maps pass and the sketches do the refuting
    absent = _mid_range_absent(ids, 6000, 12000)
    with dataset(path) as ds:
        tbl = ds.where(C("id") == absent).to_table()
        st = ds.stats
    assert len(tbl["id"]) == 0
    # every group refuted at plan time: no shard reader is even opened, so
    # the query itself issues zero data preads
    assert st.preads <= 2, "absent probe must not read data pages"
    assert st.groups_pruned_sketch == 4


def test_in_probe_uses_sketches(tmp_path):
    clear_footer_cache()
    path = str(tmp_path / "t.bln")
    ids = _write(path, n=8192, rows_per_group=2048, page_rows=256)
    a1 = _mid_range_absent(ids, 6000, 12000)
    a2 = _mid_range_absent(ids, a1 + 1, 16000)
    with dataset(path) as ds:
        tbl = ds.where(C("id").isin([a1, a2])).to_table()
        st = ds.stats
    assert len(tbl["id"]) == 0
    assert st.preads <= 2 and st.groups_pruned_sketch == 4


def test_sketchless_files_scan_unchanged(tmp_path):
    # v2-style (stats, no sketches) and v0 (nothing) files keep planning
    # exactly as before: no sketch pruning, correct results
    for kw, version in (({"collect_sketches": False}, FORMAT_V2),
                        ({"collect_stats": False, "page_rows": None},
                         FORMAT_V0)):
        clear_footer_cache()
        path = str(tmp_path / f"t{version}.bln")
        ids = _write(path, **kw)
        fv, _ = read_footer(path)
        assert fv.format_version == version
        victim = int(ids[123])
        with dataset(path) as ds:
            tbl = ds.where(C("id") == victim).to_table()
            st = ds.stats
        assert tbl["id"].tolist() == [victim]
        assert st.groups_pruned_sketch == 0


def test_quantized_column_sketches_dequantized_domain(tmp_path):
    from repro.core import QuantMode, QuantSpec
    path = str(tmp_path / "q.bln")
    rng = np.random.default_rng(5)
    n = 2048
    vals = rng.permutation(n).astype(np.float32)
    w = BullionWriter(
        path,
        [ColumnSpec("x", "float32", quant=QuantSpec(QuantMode.BF16))],
        rows_per_group=512, page_rows=128)
    w.write_table({"x": vals})
    w.close()
    with dataset(path) as ds:
        # probe a value that survives quantization roundtrip on some row
        got = ds.select(["x"]).to_table()["x"]
    probe = float(got[100])
    clear_footer_cache()
    with dataset(path) as ds:
        tbl = ds.where(C("x") == probe).to_table()
    assert probe in tbl["x"].tolist(), \
        "sketch over the dequantized domain must not refute stored values"


def test_deletion_widens_sketches(tmp_path):
    # an L2 delete masks rows to zero; the touched sketches must admit 0.0
    # so raw-space `== 0` probes still find the masked rows
    clear_footer_cache()
    path = str(tmp_path / "d.bln")
    rng = np.random.default_rng(9)
    n = 4096
    ids = rng.permutation(2 * n)[:n].astype(np.int64)
    # values strictly positive so 0 is absent before the delete
    vals = (rng.random(n).astype(np.float32) + 1.0)
    w = BullionWriter(path, SCHEMA, rows_per_group=1024, page_rows=256)
    w.write_table({"id": ids, "v": vals})
    w.close()
    victim = int(ids[10])
    delete_where(path, C("id") == victim)
    clear_footer_cache()
    with dataset(path) as ds:
        tbl = ds.drop_deleted(False).where(C("v") == 0).to_table()
    assert len(tbl["v"]) >= 1 and np.all(tbl["v"] == 0.0)


def test_groups_pruned_sketch_in_iostats_merge():
    from repro.core.reader import IOStats
    a = IOStats(groups_pruned_sketch=3)
    b = IOStats(groups_pruned_sketch=4)
    assert IOStats.sum([a, b]).groups_pruned_sketch == 7
