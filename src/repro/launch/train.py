"""End-to-end training driver: Bullion data -> loader -> model -> AdamW, with
fault-tolerant checkpointing and auto-resume.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 50 --batch 8 --seq 128

Full-size configs lower the same code path on the production mesh via
repro.launch.dryrun; this driver runs the REDUCED configs end-to-end on
whatever devices exist (CPU here).
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .. import configs
from ..data import BullionLoader, write_lm_corpus
from ..data.loader import LoaderState
from ..models import zoo
from ..train import AdamWConfig, adamw_init, make_train_step
from ..train.checkpoint import CheckpointManager


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--data", default="/tmp/bullion_lm")
    ap.add_argument("--ckpt", default="/tmp/bullion_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--d-model", type=int, default=0,
                    help="override width (0 = config default)")
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch) if args.smoke else configs.get(args.arch)
    cfg = cfg.scaled(compute_dtype="float32")
    if args.d_model:
        cfg = cfg.scaled(d_model=args.d_model,
                         head_dim=args.d_model // cfg.n_heads,
                         d_ff=args.d_model * 4)
    model = zoo.build(cfg)

    os.makedirs(args.data, exist_ok=True)
    corpus = os.path.join(args.data, "corpus.bln")
    if not os.path.exists(corpus):
        stats = write_lm_corpus(corpus, vocab=cfg.vocab,
                                n_docs=max(64, args.batch * 8),
                                doc_len=max(512, args.seq * 4))
        print(f"wrote corpus: {stats}")

    mgr = CheckpointManager(args.ckpt, keep=2)
    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps)
    opt_state = adamw_init(params)
    start_step = 0
    loader_state = LoaderState()

    latest = mgr.latest_step()
    if latest is not None:
        (params, opt_state), manifest = mgr.restore((params, opt_state))
        start_step = manifest["step"]
        loader_state = LoaderState(manifest.get("epoch", 0),
                                   manifest.get("group", 0))
        print(f"resumed from step {start_step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg,
                                      microbatches=args.microbatches))
    loader = BullionLoader(corpus, batch_size=args.batch, seq_len=args.seq,
                           state=loader_state)

    it = iter(loader)
    t0 = time.perf_counter()
    losses = []
    cursor = loader_state
    for step in range(start_step, args.steps):
        batch_np, cursor = next(it)
        batch = {"tokens": jnp.asarray(batch_np)}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
        if (step + 1) % args.log_every == 0:
            dt = time.perf_counter() - t0
            tok_s = args.log_every * args.batch * args.seq / dt
            print(f"step {step+1:5d} loss {np.mean(losses[-args.log_every:]):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} tok/s {tok_s:,.0f}")
            t0 = time.perf_counter()
        if (step + 1) % args.ckpt_every == 0 or step + 1 == args.steps:
            mgr.save(step + 1, (params, opt_state),
                     extra={"epoch": cursor.epoch, "group": cursor.group,
                            "loss": float(metrics["loss"])})
    mgr.wait()
    loader.close()
    first = np.mean(losses[:10]) if len(losses) >= 10 else losses[0]
    last = np.mean(losses[-10:])
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return losses


if __name__ == "__main__":
    main()
