"""Production mesh definitions (TPU v5e numbers).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run sets --xla_force_host_platform_device_count first.
"""

from __future__ import annotations

import jax

# hardware constants used by the roofline analysis (per chip)
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # B/s
ICI_BW = 50e9                  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(data: int = 2, model: int = 4):
    """Small mesh for unit tests (requires >= data*model host devices)."""
    return jax.make_mesh((data, model), ("data", "model"))
