"""Roofline-term derivation from compiled dry-run artifacts.

  compute term    = HLO_FLOPs(per device) / peak_FLOP/s
  memory term     = HLO_bytes(per device) / HBM_bw
  collective term = Σ collective bytes moved per device / ICI link bw

cost_analysis() provides FLOPs/bytes; collective bytes are parsed from the
post-SPMD optimized HLO text (all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute), weighted by ring-algorithm factors derived
from each op's replica group size.
"""

from __future__ import annotations

import math
import re
from collections import defaultdict

from .mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f16": 2, "bf16": 2,
    "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+[0-9]+(?:e[0-9]+m[0-9]+fn?)?)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|[^=]*?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRC_TGT_RE = re.compile(r"source_target_pairs=\{")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


def parse_collectives(hlo_text: str, n_devices: int) -> dict:
    """Sum bytes moved per device per collective kind (ring-algo factors)."""
    by_kind: dict[str, float] = defaultdict(float)
    counts: dict[str, int] = defaultdict(int)
    seen_done = set()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count only the -start
        type_str, kind = m.group(1), m.group(2)
        size = _shape_bytes(type_str)
        if size == 0:
            continue
        n = _group_size(line, n_devices)
        if kind == "all-reduce":
            moved = size * 2 * (n - 1) / max(n, 1)
        elif kind == "all-gather":
            moved = size * (n - 1) / max(n, 1)       # size is the gathered output
        elif kind == "reduce-scatter":
            moved = size * (n - 1)                   # size is the scattered shard
        elif kind == "all-to-all":
            moved = size * (n - 1) / max(n, 1)
        else:  # collective-permute
            moved = size
        by_kind[kind] += moved
        counts[kind] += 1
    return {"bytes_by_kind": dict(by_kind), "counts": dict(counts),
            "total_bytes": sum(by_kind.values())}


def model_flops(cfg, shape, n_params_total: int, n_params_active: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_params_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_params_active * tokens
    tokens = shape.global_batch  # one step
    return 2.0 * n_params_active * tokens


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll_bytes_per_dev: float) -> dict:
    ct = flops_per_dev / PEAK_FLOPS_BF16
    mt = bytes_per_dev / HBM_BW
    xt = coll_bytes_per_dev / ICI_BW
    dom = max((ct, "compute"), (mt, "memory"), (xt, "collective"))[1]
    return {"compute_s": ct, "memory_s": mt, "collective_s": xt,
            "dominant": dom,
            "bound_s": max(ct, mt, xt),
            "roofline_frac": ct / max(ct, mt, xt) if max(ct, mt, xt) > 0 else 0.0}


def active_params(cfg, n_params_total: int) -> int:
    """Active params per token for MoE configs (routed experts scaled by k/E)."""
    if cfg.n_experts == 0:
        return n_params_total
    ff = cfg.moe_ff or cfg.d_ff
    routed_per_layer = 3 * cfg.d_model * ff * cfg.n_experts
    n_moe_layers = sum(rep * sum(1 for b in blocks if b.endswith(":moe"))
                       for blocks, rep in cfg.segments)
    routed_total = routed_per_layer * n_moe_layers
    active_routed = routed_total * cfg.top_k / cfg.n_experts
    return int(n_params_total - routed_total + active_routed)
