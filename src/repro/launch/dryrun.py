import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory/cost/collective artifacts.

  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Artifacts land in artifacts/dryrun/<arch>__<shape>__<mesh>.json and feed the
roofline table in EXPERIMENTS.md.
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from .. import configs
from ..distributed import make_dist
from ..models import zoo
from ..models.base import spec_tree
from ..models.config import SHAPES
from ..train import AdamWConfig, adamw_init, make_train_step
from . import hlo_cost
from .mesh import make_production_mesh
from .roofline import (active_params, model_flops, parse_collectives,
                       roofline_terms)

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def _sds(tree_abstract, tree_spec, mesh):
    return jax.tree.map(
        lambda a, s: jax.ShapeDtypeStruct(a.shape, a.dtype,
                                          sharding=NamedSharding(mesh, s)),
        tree_abstract, tree_spec)


def cache_specs(cache, cfg, dist):
    """Shape-aware KV/state cache shardings (SP when batch is unshardable)."""
    mesh = dist.mesh
    M = mesh.shape["model"]

    def leaf_spec(path, a):
        key = path[-1].key if hasattr(path[-1], "key") else str(path[-1])
        if key in ("enc_k", "enc_v"):
            key = key[-1]  # treat like stacked k/v
        shape = a.shape
        if key == "pos":
            return PS()
        batch_dim = 1 if key in ("k", "v") and len(shape) == 5 else 0
        b_ax = dist.batch_axes_for(shape[batch_dim])
        seq_ax = None
        if b_ax is None and key in ("k", "v", "ckv", "kr") and len(shape) >= 3:
            # sequence parallelism over the cache when batch can't shard
            if shape[batch_dim + 1] % mesh.shape["data"] == 0:
                seq_ax = "data"
        if key in ("k", "v"):
            if len(shape) == 5:   # [L, B, S, H, dh] (enc-dec stacks)
                h_ax = "model" if shape[3] % M == 0 else None
                d_ax = "model" if h_ax is None and shape[4] % M == 0 else None
                return PS(None, b_ax, seq_ax, h_ax, d_ax)
            h_ax = "model" if shape[2] % M == 0 else None
            d_ax = "model" if h_ax is None and shape[3] % M == 0 else None
            return PS(b_ax, seq_ax, h_ax, d_ax)
        if key in ("ckv", "kr"):
            return PS(b_ax, seq_ax, None)
        if key == "S":            # rwkv state [B, H, dk, dv]
            return PS(b_ax, "model" if shape[1] % M == 0 else None, None, None)
        if key in ("tm_prev", "cm_prev"):
            return PS(b_ax, None)
        if key == "h":            # rglru [B, lru]
            return PS(b_ax, "model" if shape[1] % M == 0 else None)
        if key == "conv":         # [B, K-1, lru]
            return PS(b_ax, None, "model" if shape[2] % M == 0 else None)
        return PS(*([None] * len(shape)))

    import jax.tree_util as jtu
    return jtu.tree_map_with_path(leaf_spec, cache)


def _strip_layer_axis(specs_tree):
    return specs_tree


def abstract_cache(cfg, model, batch, seq_len, dtype=jnp.bfloat16):
    cache = jax.eval_shape(lambda: model.init_cache(batch, seq_len, dtype))
    return cache


def build_cell(arch: str, shape_name: str, multi_pod: bool,
               overrides: dict | None = None):
    """Returns (lower_fn, meta). lower_fn() -> lowered."""
    cfg = configs.get(arch)
    _driver_keys = ("microbatches", "no_train_sp", "param_dtype")
    if overrides:
        cfg_over = {k: v for k, v in overrides.items() if k not in _driver_keys}
        if cfg_over:
            cfg = cfg.scaled(**cfg_over)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq_sharded = (shape.kind == "decode"
                   and shape.global_batch < mesh.shape["data"])
    train_sp = (shape.kind in ("train", "prefill")
                and shape.seq_len % mesh.shape["model"] == 0
                and not (overrides or {}).get("no_train_sp"))
    dist = make_dist(mesh, seq_sharded=seq_sharded,
                     train_seq_sharded=train_sp)
    model = zoo.build(cfg, dist)
    B = shape.global_batch
    pspecs = spec_tree(model.decl, dist.rules, mesh)
    # training uses fp32 master weights; serving cells may opt into bf16
    # weights (beyond-paper: §2.4 storage quantization feeds the serving
    # precision directly — weight streaming is decode's memory bound)
    param_dtype = jnp.dtype((overrides or {}).get("param_dtype", "float32"))
    params_sds = _sds(model.abstract_params(param_dtype), pspecs, mesh)
    b_ax = dist.batch_axes_for(B)

    def tok_sds(S):
        return jax.ShapeDtypeStruct((B, S), jnp.int32,
                                    sharding=NamedSharding(mesh, PS(b_ax, None)))

    vocab_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None

    frames_sds = None
    if cfg.encoder is not None:
        frames_sds = jax.ShapeDtypeStruct(
            (B, cfg.encoder.seq, cfg.d_model), jnp.bfloat16,
            sharding=NamedSharding(mesh, PS(b_ax, None, None)))

    if shape.kind == "train":
        opt_specs = {"m": pspecs, "v": pspecs, "step": PS()}
        opt_sds = {"m": params_sds, "v": params_sds,
                   "step": jax.ShapeDtypeStruct((), jnp.int32,
                                                sharding=NamedSharding(mesh, PS()))}
        batch_sds = {"tokens": tok_sds(shape.seq_len + 1)}
        if frames_sds is not None:
            batch_sds["frames"] = frames_sds
        # microbatch so each accumulation step sees <= ~16Ki tokens per data
        # shard: bounds activation/dispatch working sets and lets XLA overlap
        # per-microbatch collectives with the next microbatch's compute.
        data_shards = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
        tokens_per_shard = B * shape.seq_len // data_shards
        mb = 1
        for cand in (1, 2, 4, 8, 16):
            if B % cand == 0 and tokens_per_shard // cand > 16384:
                mb = cand * 2 if B % (cand * 2) == 0 else cand
        mb = (overrides or {}).get("microbatches", mb)
        step = make_train_step(model, AdamWConfig(), microbatches=mb)
        out_shardings = (
            jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs),
            jax.tree.map(lambda s: NamedSharding(mesh, s), opt_specs,
                         is_leaf=lambda x: isinstance(x, PS)),
            NamedSharding(mesh, PS()),
        )
        def lower():
            with mesh:
                return jax.jit(step, out_shardings=out_shardings,
                               donate_argnums=(0, 1)).lower(
                    params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        cache_abs = abstract_cache(cfg, model, B, shape.seq_len)
        cspecs = cache_specs(cache_abs, cfg, dist)
        cache_sds = _sds(cache_abs, cspecs, mesh)
        batch_sds = {"tokens": tok_sds(shape.seq_len)}
        if frames_sds is not None:
            batch_sds["frames"] = frames_sds
        out_shardings = (NamedSharding(mesh, PS(b_ax, vocab_ax)),
                         jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                      is_leaf=lambda x: isinstance(x, PS)))
        def lower():
            with mesh:
                return jax.jit(model.prefill, out_shardings=out_shardings,
                               donate_argnums=(2,)).lower(
                    params_sds, batch_sds, cache_sds)
    else:  # decode
        cache_abs = abstract_cache(cfg, model, B, shape.seq_len)
        cspecs = cache_specs(cache_abs, cfg, dist)
        cache_sds = _sds(cache_abs, cspecs, mesh)
        tokens_sds = tok_sds(1)
        out_shardings = (NamedSharding(mesh, PS(b_ax, vocab_ax)),
                         jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs,
                                      is_leaf=lambda x: isinstance(x, PS)))
        def lower():
            with mesh:
                return jax.jit(model.decode_step, out_shardings=out_shardings,
                               donate_argnums=(1,)).lower(
                    params_sds, cache_sds, tokens_sds)

    meta = {"arch": cfg.name, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "n_devices": int(np.prod(list(mesh.shape.values()))),
            "n_params": model.n_params,
            "n_params_active": active_params(cfg, model.n_params)}
    return lower, meta, cfg, shape


def should_skip(arch: str, shape_name: str) -> str | None:
    cfg = configs.get(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return ("full-attention family: long_500k requires sub-quadratic "
                "attention (see DESIGN.md §Arch-applicability)")
    return None


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_dir: str = ARTIFACT_DIR, overrides: dict | None = None,
             tag: str = "") -> dict:
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag}
    skip = should_skip(arch, shape_name)
    if skip:
        rec.update(status="skipped", reason=skip)
        _save(rec, out_dir, arch, shape_name, mesh_tag, tag)
        return rec
    t0 = time.time()
    try:
        lower, meta, cfg, shape = build_cell(arch, shape_name, multi_pod,
                                             overrides)
        rec.update(meta)
        lowered = lower()
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        xla_cost = compiled.cost_analysis() or {}
        if isinstance(xla_cost, (list, tuple)):  # older jaxlib: one dict per device
            xla_cost = xla_cost[0] if xla_cost else {}
        try:
            mem = compiled.memory_analysis()
            mem_rec = {k: int(getattr(mem, k)) for k in
                       ("argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "generated_code_size_in_bytes")
                       if hasattr(mem, k)}
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}
        text = compiled.as_text()
        # trip-count-aware analysis (XLA's cost_analysis counts while bodies
        # once — see hlo_cost.py); xla_cost kept as a reference field
        hc = hlo_cost.analyze(text, meta["n_devices"])
        flops = hc["flops"]
        bytes_acc = hc["bytes"]
        coll = {"bytes_by_kind": hc["collective_by_kind"],
                "counts": hc["collective_counts"],
                "total_bytes": hc["collective_bytes"]}

        mf = model_flops(cfg, shape, meta["n_params"], meta["n_params_active"])
        mf_per_dev = mf / meta["n_devices"]
        terms = roofline_terms(flops, bytes_acc, coll["total_bytes"])
        rec.update(
            status="ok",
            lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            flops_per_device=flops, bytes_per_device=bytes_acc,
            collectives=coll, memory=mem_rec,
            xla_cost={"flops": float(xla_cost.get("flops", 0.0)),
                      "bytes_accessed": float(xla_cost.get("bytes accessed", 0.0))},
            model_flops_total=mf, model_flops_per_device=mf_per_dev,
            useful_flops_ratio=(mf_per_dev / flops) if flops else None,
            roofline=terms,
        )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    _save(rec, out_dir, arch, shape_name, mesh_tag, tag)
    return rec


def _save(rec, out_dir, arch, shape_name, mesh_tag, tag=""):
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fn = f"{arch.replace('.', '_')}__{shape_name}__{mesh_tag}{suffix}.json"
    with open(os.path.join(out_dir, fn), "w") as f:
        json.dump(rec, f, indent=1, default=str)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    args = ap.parse_args()

    archs = list(configs.ARCHS) if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                rec = run_cell(arch, shape, mp, out_dir=args.out)
                status = rec.get("status")
                extra = ""
                if status == "ok":
                    r = rec["roofline"]
                    extra = (f" compute={r['compute_s']:.3e}s "
                             f"mem={r['memory_s']:.3e}s coll={r['collective_s']:.3e}s"
                             f" dom={r['dominant']} compile={rec['compile_s']}s")
                    mem_rec = rec.get("memory", {})
                    print(f"[mem] {mem_rec}")
                elif status == "error":
                    extra = " " + rec["error"][:200]
                elif status == "skipped":
                    extra = " " + rec["reason"][:80]
                print(f"{arch:18s} {shape:12s} {rec['mesh']:8s} {status}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
