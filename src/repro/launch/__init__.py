# NOTE: intentionally empty — launch modules (dryrun) must be able to set
# XLA_FLAGS before jax is first imported.
