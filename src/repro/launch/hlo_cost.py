"""Trip-count-aware cost model over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, ignoring the
trip count — useless for scan-over-layers / microbatch-accumulation programs
where ~all FLOPs live inside loops. This module re-derives

  * flops               (dot ops; 2*M*N*K, batch dims included)
  * bytes               (operand + result traffic of compute ops, fusion-
                         boundary granularity — a structural HBM proxy)
  * collective bytes    (per-device moved bytes, ring-algorithm factors)

by walking the computation graph and multiplying loop bodies by their parsed
trip counts (jax scans lower to `while` with an i32 induction variable
compared LT against a constant).
"""

from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "f16": 2, "bf16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}
_SHAPE_RE = re.compile(r"([a-z]+[0-9]+(?:e[0-9]+m[0-9]+fn?)?|pred)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+|[\w.\-]+)\s*=\s*")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_OP_KIND_RE = re.compile(
    r"=\s*(?:\([^=]*?\)|[a-z0-9\[\],\s{}]*?)\s*"
    r"([a-z][a-z0-9\-]*)\(")
_CALLEE_RE = re.compile(r"(?:to_apply|calls|body)=(%?[\w.\-]+)")
_COND_RE = re.compile(r"condition=(%?[\w.\-]+)")
_OPERAND_RE = re.compile(r"\(([^)]*)\)")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_DIMS_ATTR = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_ATTR = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

# ops that don't move data (pure aliasing / metadata)
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
             "after-all", "partition-id", "replica-id", "iota",
             "get-dimension-size", "opt-barrier"}


def _shapes_in(s: str) -> list[tuple[str, int]]:
    out = []
    for dtype, dims in _SHAPE_RE.findall(s):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append((dtype, n))
    return out


def _bytes_of(s: str) -> int:
    return sum(n * _DTYPE_BYTES.get(dt, 0) for dt, n in _shapes_in(s))


def _elems_of(s: str) -> int:
    return sum(n for _, n in _shapes_in(s))


@dataclass
class Op:
    name: str
    kind: str
    line: str
    result_type: str


@dataclass
class Computation:
    name: str
    ops: list = field(default_factory=list)
    types: dict = field(default_factory=dict)   # var -> result type str


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    comment = re.compile(r"/\*.*?\*/")
    for raw in text.splitlines():
        line = comment.sub("", raw.rstrip())
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR_RE.match(stripped)
            if m and ("->" in stripped or stripped.startswith("ENTRY")):
                cur = Computation(m.group(1).lstrip("%"))
            continue
        if stripped == "}":
            comps[cur.name] = cur
            cur = None
            continue
        dm = _DEF_RE.match(line)
        if not dm:
            continue
        name = dm.group(1).lstrip("%")
        rest = line[dm.end():]
        km = _OP_KIND_RE.search(line)
        head = rest.split("(")[0].strip().split()
        kind = km.group(1) if km else (head[-1] if head else "unknown")
        # result type = text between '=' and the op kind keyword
        rtype = rest[: rest.find(kind)] if kind in rest else rest
        cur.types[name] = rtype
        cur.ops.append(Op(name, kind, line, rtype))
    return comps


def _operand_names(line: str, kind: str) -> list[str]:
    i = line.find(kind + "(")
    if i < 0:
        return []
    depth = 0
    start = i + len(kind) + 1
    j = start
    while j < len(line):
        if line[j] == "(":
            depth += 1
        elif line[j] == ")":
            if depth == 0:
                break
            depth -= 1
        j += 1
    args = line[start:j]
    names = re.findall(r"%([\w.\-]+)", args)
    if not names:  # HLO without % sigils
        names = [a.strip().split(" ")[-1] for a in args.split(",") if a.strip()]
    return names


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _elems_of(op.result_type)
    operands = _operand_names(op.line, op.kind)
    if not operands:
        return 0.0
    lhs_t = comp.types.get(operands[0], "")
    m = _SHAPE_RE.search(lhs_t)
    if not m:
        return 0.0
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    cm = _DIMS_ATTR.search(op.line)
    k = 1
    if cm and cm.group(1):
        for ci in cm.group(1).split(","):
            ci = int(ci)
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_elems * k


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{(\{[^}]*\})", line)
    if m:
        return max(1, m.group(1).count(",") + 1)
    return default


def _collective_bytes(op: Op, comp: Computation, n_devices: int) -> float:
    size = _bytes_of(op.result_type)
    if size == 0:
        return 0.0
    n = _group_size(op.line, n_devices)
    if op.kind.startswith("all-reduce"):
        return size * 2 * (n - 1) / max(n, 1)
    if op.kind.startswith("all-gather"):
        return size * (n - 1) / max(n, 1)
    if op.kind.startswith("reduce-scatter"):
        return size * (n - 1)
    if op.kind.startswith("all-to-all"):
        return size * (n - 1) / max(n, 1)
    return float(size)  # collective-permute


def _is_inplace_update(callee: "Computation", res_bytes: int) -> bool:
    """Fusion whose root is a dynamic-update-slice producing the full-size
    result: XLA aliases the buffer; only the update slice moves."""
    for op in callee.ops:
        if op.kind == "dynamic-update-slice" and _bytes_of(op.result_type) == res_bytes:
            return True
    return False


_PARAM_IDX_RE = re.compile(r"parameter\((\d+)\)")


def _fusion_operand_bytes(callee: "Computation") -> dict[int, int]:
    """Per-parameter-index *touched* bytes for operands that are only
    dynamic-sliced/gathered inside the fusion (scan bodies slice one layer /
    one step out of stacked arrays — charging the full stack per iteration
    would overcount by the trip count)."""
    param_of: dict[str, int] = {}
    for op in callee.ops:
        if op.kind == "parameter":
            m = _PARAM_IDX_RE.search(op.line)
            if m:
                param_of[op.name] = int(m.group(1))
    sliced: dict[int, int] = {}
    consumers: dict[str, list[Op]] = defaultdict(list)
    for op in callee.ops:
        for o in _operand_names(op.line, op.kind):
            consumers[o].append(op)
    def resolve(uses, depth=0):
        """Follow through layout-only ops (bitcast/reshape/copy)."""
        out = []
        for u in uses:
            if u.kind in ("bitcast", "reshape", "copy", "transpose") and depth < 3:
                out.extend(resolve(consumers.get(u.name, []), depth + 1))
            else:
                out.append(u)
        return out

    for pname, pidx in param_of.items():
        uses = resolve(consumers.get(pname, []))
        if uses and all(u.kind in ("dynamic-slice", "gather") for u in uses):
            sliced[pidx] = sum(_bytes_of(u.result_type) for u in uses)
    return sliced


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _trip_count(while_line: str, cond: Computation | None) -> int:
    """Prefer XLA's own known_trip_count annotation; fall back to parsing the
    condition (ROOT compare(iv, constant(N)), direction=LT)."""
    m = _TRIP_RE.search(while_line)
    if m:
        return max(1, int(m.group(1)))
    if cond is None:
        return 1
    consts = {}
    for op in cond.ops:
        m = _CONST_RE.search(op.line)
        if m:
            consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.kind == "compare" and "direction=LT" in op.line:
            for o in _operand_names(op.line, "compare"):
                if o in consts:
                    return max(1, consts[o])
    return 1


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_by_kind: dict = field(default_factory=lambda: defaultdict(float))
    coll_counts: dict = field(default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] += v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] += v * mult


def _comp_cost(comp: Computation, comps, n_devices, memo, in_fusion=False) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    c = Cost()
    for op in comp.ops:
        kind = op.kind
        if kind == "while":
            callee = _CALLEE_RE.search(op.line)
            condm = _COND_RE.search(op.line)
            cond = comps.get(condm.group(1).lstrip("%")) if condm else None
            trips = _trip_count(op.line, cond)
            if callee and callee.group(1).lstrip("%") in comps:
                body = _comp_cost(comps[callee.group(1).lstrip("%")], comps,
                                  n_devices, memo)
                c.add(body, trips)
            continue
        if kind in ("call", "fusion", "async-start", "custom-call"):
            callee = _CALLEE_RE.search(op.line)
            if callee and callee.group(1).lstrip("%") in comps:
                inner = _comp_cost(comps[callee.group(1).lstrip("%")], comps,
                                   n_devices, memo,
                                   in_fusion=(kind == "fusion"))
                # fusion: inner dot flops count; inner byte traffic does not
                c.flops += inner.flops
                c.coll_bytes += inner.coll_bytes
                for k, v in inner.coll_by_kind.items():
                    c.coll_by_kind[k] += v
                for k, v in inner.coll_counts.items():
                    c.coll_counts[k] += v
                if kind != "fusion":
                    c.bytes += inner.bytes
            # fusion boundary traffic:
            if kind == "fusion":
                callee_comp = comps.get(callee.group(1).lstrip("%")) if callee else None
                res_b = _bytes_of(op.result_type)
                operands = _operand_names(op.line, kind)
                sliced = _fusion_operand_bytes(callee_comp) if callee_comp else {}
                if callee_comp is not None and _is_inplace_update(callee_comp, res_b):
                    # scan-accumulator pattern: DUS into an aliased buffer —
                    # charge only the non-aliased (update) operands, 2x
                    for i, o in enumerate(operands):
                        ob = _bytes_of(comp.types.get(o, ""))
                        if ob != res_b:
                            c.bytes += 2 * min(ob, sliced.get(i, ob))
                else:
                    c.bytes += res_b
                    for i, o in enumerate(operands):
                        ob = _bytes_of(comp.types.get(o, ""))
                        c.bytes += min(ob, sliced.get(i, ob))
            continue
        if kind == "conditional":
            branches = re.search(r"branch_computations=\{([^}]*)\}", op.line)
            if branches:
                costs = []
                for b in branches.group(1).split(","):
                    b = b.strip().lstrip("%")
                    if b in comps:
                        costs.append(_comp_cost(comps[b], comps, n_devices, memo))
                if costs:
                    worst = max(costs, key=lambda x: x.flops + x.bytes)
                    c.add(worst)
            continue
        if any(kind.startswith(cl) for cl in COLLECTIVES):
            if kind.endswith("-done"):
                continue
            cb = _collective_bytes(op, comp, n_devices)
            base = next(cl for cl in COLLECTIVES if kind.startswith(cl))
            c.coll_bytes += cb
            c.coll_by_kind[base] += cb
            c.coll_counts[base] += 1
            c.bytes += _bytes_of(op.result_type)
            continue
        if kind in ("dot", "convolution"):
            c.flops += _dot_flops(op, comp)
        if kind in _FREE_OPS:
            continue
        if in_fusion:
            continue  # inner elementwise traffic is fused away
        if kind == "dynamic-update-slice":
            # in-place on TPU: traffic = read + write of the *update* slice,
            # not the whole aliased buffer
            ops_ = _operand_names(op.line, kind)
            upd = comp.types.get(ops_[1], "") if len(ops_) > 1 else ""
            c.bytes += 2 * _bytes_of(upd)
        elif kind in ("dynamic-slice", "gather"):
            c.bytes += 2 * _bytes_of(op.result_type)   # read source slice + write
        elif kind == "scatter":
            ops_ = _operand_names(op.line, kind)
            upd = comp.types.get(ops_[-1], "") if ops_ else ""
            c.bytes += 2 * _bytes_of(upd)
        elif kind in ("dot", "convolution", "copy", "sort"):
            # memory-bound structural ops: operands + result traffic
            c.bytes += _bytes_of(op.result_type)
            for o in _operand_names(op.line, kind):
                c.bytes += _bytes_of(comp.types.get(o, ""))
        else:
            # generic elementwise op: charge the write only — on the TPU
            # target these fuse into neighbours; counting reads too would
            # treat the CPU backend's unfused HLO as if every intermediate
            # round-tripped HBM (see DESIGN.md §Roofline method)
            c.bytes += _bytes_of(op.result_type)
    memo[comp.name] = c
    return c


def analyze(hlo_text: str, n_devices: int, entry: str | None = None) -> dict:
    comps = parse_module(hlo_text)
    # entry computation: the one starting with ENTRY in text order
    entry_name = None
    for line in hlo_text.splitlines():
        if line.strip().startswith("ENTRY"):
            m = _COMP_HDR_RE.match(line.strip())
            if m:
                entry_name = m.group(1).lstrip("%")
            break
    if entry_name is None or entry_name not in comps:
        entry_name = max(comps, key=lambda k: len(comps[k].ops))
    memo: dict = {}
    c = _comp_cost(comps[entry_name], comps, n_devices, memo)
    return {
        "flops": c.flops,
        "bytes": c.bytes,
        "collective_bytes": c.coll_bytes,
        "collective_by_kind": dict(c.coll_by_kind),
        "collective_counts": dict(c.coll_counts),
        "n_computations": len(comps),
    }
