"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.report [--mesh 16x16] [--tag baseline]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")

ARCH_ORDER = ["whisper-base", "rwkv6-7b", "llama3.2-1b", "gemma3-12b",
              "minicpm3-4b", "starcoder2-15b", "mixtral-8x22b",
              "deepseek-moe-16b", "recurrentgemma-9b", "chameleon-34b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def canon(name: str) -> str:
    return name.replace(".", "-").replace("_", "-")


def load(mesh: str, tag: str = "") -> dict:
    recs = {}
    suffix = f"__{tag}.json" if tag else ".json"
    for path in glob.glob(os.path.join(ARTIFACT_DIR, f"*__{mesh}{suffix}")):
        base = os.path.basename(path)
        if not tag and base.count("__") != 2:
            continue  # skip tagged artifacts in the untagged view
        with open(path) as f:
            r = json.load(f)
        recs[(canon(r.get("arch", "")), r["shape"])] = r
    return recs


def _fmt(v, digits=3):
    if v is None:
        return "-"
    if v == 0:
        return "0"
    return f"{v:.{digits}g}"


def table(mesh: str = "16x16", tag: str = "") -> str:
    recs = load(mesh, tag)
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant | "
        "6ND/HLO | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((canon(arch), shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | missing |")
                continue
            if r["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                             f"SKIP: full-attention family |")
                continue
            if r["status"] != "ok":
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | - | "
                             f"ERROR {r.get('error', '')[:60]} |")
                continue
            t = r["roofline"]
            lines.append(
                f"| {arch} | {shape} | {_fmt(t['compute_s'])} | "
                f"{_fmt(t['memory_s'])} | {_fmt(t['collective_s'])} | "
                f"{t['dominant']} | {_fmt(r.get('useful_flops_ratio'))} | "
                f"{_fmt(t['roofline_frac'], 2)} | |")
    return "\n".join(lines)


def cell_detail(arch: str, shape: str, mesh: str = "16x16", tag: str = "") -> dict:
    recs = load(mesh, tag)
    key = (canon(arch), shape)
    if key not in recs:
        raise KeyError((arch, shape, mesh, tag))
    return recs[key]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    print(table(args.mesh, args.tag))


if __name__ == "__main__":
    main()
