"""Write-time page/chunk statistics (zone maps).

Each page and each (row-group, column) chunk carries one fixed-size record:
min/max over the values a reader would decode, a null (NaN) count, and a
distinct-value estimate. min/max are stored as float64 *outer bounds*: the
recorded min is always <= the true minimum and the recorded max >= the true
maximum, even for int64/uint64 values that float64 cannot represent exactly —
pruning decisions stay sound, they just lose at most one ULP of selectivity.

Records describe the *logical* value domain (post quantize->dequantize for
quantized columns), i.e. exactly what ``BullionReader`` hands back with
``dequant=True``, so predicate evaluation and zone-map pruning agree. The
distinct estimate is exact per page (pages are bounded by the writer's
``page_rows`` budget; the chunk-level merge is an upper bound, not a union
cardinality) and doubles as the input signal for the stats-driven encoding
advisor, which now scores every page independently.
"""

from __future__ import annotations

import numpy as np

STAT_DTYPE = np.dtype([
    ("min", "<f8"),
    ("max", "<f8"),
    ("null_count", "<u8"),
    ("distinct", "<u8"),
    ("flags", "<u8"),
])

HAS_MINMAX = 1       # min/max fields are valid
LIST_ELEMENTS = 2    # stats describe ragged-list *elements*, not rows


def f8_lower(v) -> float:
    """Largest float64 known to be <= v (exact for floats and small ints)."""
    f = np.float64(v)
    if np.isfinite(f) and isinstance(v, (int, np.integer)) and int(f) > int(v):
        f = np.nextafter(f, -np.inf)
    return float(f)


def f8_upper(v) -> float:
    """Smallest float64 known to be >= v."""
    f = np.float64(v)
    if np.isfinite(f) and isinstance(v, (int, np.integer)) and int(f) < int(v):
        f = np.nextafter(f, np.inf)
    return float(f)


def f8_exact(v) -> bool:
    """True when float64(v) == v exactly (no rounding)."""
    f = np.float64(v)
    if not np.isfinite(f):
        return True
    if isinstance(v, (int, np.integer)):
        return int(f) == int(v)
    return True


def empty_record() -> np.ndarray:
    return np.zeros((), STAT_DTYPE)


def stats_record(values, *, is_list: bool = False) -> np.ndarray:
    """Compute one STAT_DTYPE record for a decoded page/chunk.

    ``values``: np.ndarray for scalar pages, list[np.ndarray] for list pages
    (rows are flattened to elements), list[bytes] for string pages (no
    min/max, distinct only).
    """
    rec = empty_record()
    if isinstance(values, list):
        if values and isinstance(values[0], (bytes, bytearray, memoryview)):
            rec["distinct"] = len({bytes(s) for s in values})
            return rec
        values = (np.concatenate([np.asarray(v).ravel() for v in values])
                  if values else np.zeros(0))
        is_list = True
    arr = np.asarray(values).ravel()
    if is_list:
        rec["flags"] = np.uint64(rec["flags"]) | LIST_ELEMENTS
    if arr.size == 0 or arr.dtype.kind not in "iufb":
        return rec
    if arr.dtype.kind == "f":
        nulls = int(np.isnan(arr).sum())
        rec["null_count"] = nulls
        finite = arr[~np.isnan(arr)] if nulls else arr
    else:
        finite = arr
    rec["distinct"] = len(np.unique(arr)) if arr.dtype.kind != "f" \
        else len(np.unique(finite)) + (1 if int(rec["null_count"]) else 0)
    if finite.size == 0:
        return rec  # all-NaN page: no usable min/max
    if arr.dtype.kind in "iub":
        lo, hi = int(finite.min()), int(finite.max())
    else:
        lo, hi = float(finite.min()), float(finite.max())
    rec["min"] = f8_lower(lo)
    rec["max"] = f8_upper(hi)
    rec["flags"] = np.uint64(rec["flags"]) | HAS_MINMAX
    return rec


def merge_records(records) -> np.ndarray:
    """Fold page records into one chunk record (union of zone maps)."""
    out = empty_record()
    recs = [np.asarray(r) for r in records]
    if not recs:
        return out
    with_mm = [r for r in recs if int(r["flags"]) & HAS_MINMAX]
    if with_mm:
        out["min"] = min(float(r["min"]) for r in with_mm)
        out["max"] = max(float(r["max"]) for r in with_mm)
        out["flags"] = np.uint64(out["flags"]) | HAS_MINMAX
    if any(int(r["flags"]) & LIST_ELEMENTS for r in recs):
        out["flags"] = np.uint64(out["flags"]) | LIST_ELEMENTS
    out["null_count"] = sum(int(r["null_count"]) for r in recs)
    # upper bound, not a union cardinality — good enough for an estimate
    out["distinct"] = sum(int(r["distinct"]) for r in recs)
    return out


def widen_to_zero(rec: np.ndarray) -> None:
    """Extend a record's range to include 0 in place.

    Physical deletion (§2.1 L2) masks rows to zeros without re-reading the
    survivors, so the stored zone map must be widened rather than recomputed.
    """
    if int(rec["flags"]) & HAS_MINMAX:
        rec["min"] = min(float(rec["min"]), 0.0)
        rec["max"] = max(float(rec["max"]), 0.0)
