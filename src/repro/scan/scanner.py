"""Statistics-driven pruning scanner: the planning half of the scan path.

``Scanner.plan`` intersects a predicate with the file's chunk zone maps
(``Sec.CHUNK_STATS``): groups that provably contain no matching row are
pruned before any data pread, and the plan accounts the pages and bytes
those groups would have cost. On stat-less (v0) files every group survives
and the scan degrades to a plain filtered read.

Execution — decode, deletion-masking, dequantization, predicate filtering,
payload gathering — lives in ``repro.dataset.executor.execute_group``, the
single pipeline shared with the lazy ``Dataset`` API; ``Scanner.scan`` is a
thin per-group loop over it kept for direct (single-file, eager) use.

Row ids are reported in the file's *raw* row space (deletion vectors do not
renumber rows), which is what ``core.deletion`` consumes for predicate-based
deletes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterator, Optional, Sequence

import numpy as np

from ..core.footer import Sec
from .predicate import Predicate

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from ..core.reader import BullionReader


@dataclass
class ScanPlan:
    """Result of zone-map pruning, before any data I/O."""

    groups: list[int]                     # surviving row groups, in scan order
    pruned_groups: list[int]              # provably-empty row groups
    pages_pruned: int = 0                 # page reads avoided by pruning
    pages_total: int = 0                  # page reads a full scan would issue
    bytes_pruned: int = 0                 # data bytes those pages hold
    bytes_total: int = 0
    group_pages: dict = field(default_factory=dict)   # group -> page count
    group_bytes: dict = field(default_factory=dict)   # group -> data bytes

    @property
    def selectivity_bound(self) -> float:
        total = len(self.groups) + len(self.pruned_groups)
        return len(self.groups) / total if total else 1.0


@dataclass
class ScanBatch:
    """Matching rows of one row group."""

    group: int
    row_ids: np.ndarray                   # global ids, raw row space
    table: dict = field(default_factory=dict)


def _group_stats(fv, group: int, cols: Sequence[str]) -> dict:
    """Map column name -> chunk STAT record (or None on v0 files)."""
    chunk = fv.chunk_stats()
    if chunk is None:
        return {name: None for name in cols}
    n_cols = fv.n_cols
    return {name: chunk[group * n_cols + fv.column_index(name)]
            for name in cols}


def _pages_for(fv, group: int, cols: Sequence[str]) -> list[int]:
    out: list[int] = []
    for name in cols:
        s, e = fv.chunk_pages(group, fv.column_index(name))
        out.extend(range(s, e))
    return out


def plan_scan(fv, pred: Optional[Predicate], columns: Sequence[str] = (),
              groups: Optional[Sequence[int]] = None) -> ScanPlan:
    """Footer-only zone-map planning (needs no open file handle):
    intersect ``pred`` with the chunk zone maps and account the page/byte
    cost of every candidate group. ``pred=None`` prunes nothing."""
    pred_cols = sorted(pred.columns()) if pred is not None else []
    read_cols = list(dict.fromkeys([*pred_cols, *columns]))
    candidates = list(groups) if groups is not None \
        else list(range(fv.n_groups))
    page_size = fv.arr(Sec.PAGE_SIZE, np.uint64)
    plan = ScanPlan(groups=[], pruned_groups=[])
    for g in candidates:
        pages = _pages_for(fv, g, read_cols)
        nbytes = int(sum(int(page_size[p]) for p in pages))
        plan.pages_total += len(pages)
        plan.bytes_total += nbytes
        plan.group_pages[g] = len(pages)
        plan.group_bytes[g] = nbytes
        if pred is None or pred.maybe_any(_group_stats(fv, g, pred_cols)):
            plan.groups.append(g)
        else:
            plan.pruned_groups.append(g)
            plan.pages_pruned += len(pages)
            plan.bytes_pruned += nbytes
    return plan


class Scanner:
    def __init__(self, reader: "BullionReader"):
        self.reader = reader
        self.fv = reader.footer

    def __enter__(self) -> "Scanner":
        return self

    def __exit__(self, *exc) -> None:
        # The scanner context owns the reader's handle: exiting closes it
        # (idempotent), so ``with Scanner(BullionReader(p)) as s:`` cannot
        # leak on an aborted scan. Don't enter a scanner context when the
        # reader must outlive it — close() is shared with the reader.
        self.reader.close()

    # -- planning ---------------------------------------------------------------
    def plan(self, pred: Optional[Predicate], columns: Sequence[str] = (),
             groups: Optional[Sequence[int]] = None) -> ScanPlan:
        """Zone-map pruning: decide which row groups can possibly match.
        ``pred=None`` plans an unpruned scan (all candidates survive) but
        still accounts per-group page/byte costs for downstream planning."""
        return plan_scan(self.fv, pred, columns, groups)

    # -- scanning ---------------------------------------------------------------
    def scan(self, pred: Predicate, columns: Sequence[str] = (),
             groups: Optional[Sequence[int]] = None, *,
             drop_deleted: bool = True, dequant: bool = True,
             use_kernel: Optional[bool] = None) -> Iterator[ScanBatch]:
        """Yield matching rows per surviving group.

        ``columns`` are the payload columns materialized in each batch (the
        predicate's own columns are always available and included when
        requested). Payload pages are only read for groups where at least one
        row survived the filter — the second half of the I/O win.
        """
        from ..dataset.executor import execute_group
        from ..dataset.plan import group_bounds

        plan = self.plan(pred, columns, groups)
        self.reader.stats.bytes_pruned += plan.bytes_pruned
        bounds = group_bounds(self.fv)
        for g in plan.groups:
            res = execute_group(self.reader, g, columns=columns,
                                predicate=pred, drop_deleted=drop_deleted,
                                dequant=dequant, use_kernel=use_kernel)
            if res is None:
                continue
            yield ScanBatch(group=g, row_ids=bounds[g] + res.row_ids,
                            table=res.table)

    def find_rows(self, pred: Predicate, *, drop_deleted: bool = False,
                  use_kernel: Optional[bool] = None) -> np.ndarray:
        """Global row ids (raw row space) whose rows satisfy ``pred``."""
        parts = [b.row_ids for b in self.scan(pred, drop_deleted=drop_deleted,
                                              use_kernel=use_kernel)]
        return np.concatenate(parts) if parts \
            else np.zeros(0, np.int64)
