"""Statistics-driven pruning scanner.

The scan pipeline per row group:

  1. **Prune** — intersect the predicate with the group's chunk zone maps
     (``Sec.CHUNK_STATS``). Groups that provably contain no matching row are
     skipped before any data pread; on stat-less (v0) files every group
     survives and the scan degrades to a plain filtered read.
  2. **Filter** — decode only the *predicate* columns of surviving groups and
     evaluate the predicate. Conjunctive range predicates over float32
     columns dispatch to the Pallas batch filter kernel
     (``repro.kernels.filter``); everything else takes the vectorized NumPy
     path. Groups where no row survives never read their payload columns.
  3. **Project** — decode the requested payload columns and gather the
     surviving rows.

Row ids are reported in the file's *raw* row space (deletion vectors do not
renumber rows), which is what ``core.deletion`` consumes for predicate-based
deletes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterator, Optional, Sequence

import numpy as np

from ..core.footer import Sec
from .predicate import Predicate, conjunctive_ranges, evaluate

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from ..core.reader import BullionReader


@dataclass
class ScanPlan:
    """Result of zone-map pruning, before any data I/O."""

    groups: list[int]                     # surviving row groups, in scan order
    pruned_groups: list[int]              # provably-empty row groups
    pages_pruned: int = 0                 # page reads avoided by pruning
    pages_total: int = 0                  # page reads a full scan would issue

    @property
    def selectivity_bound(self) -> float:
        total = len(self.groups) + len(self.pruned_groups)
        return len(self.groups) / total if total else 1.0


@dataclass
class ScanBatch:
    """Matching rows of one row group."""

    group: int
    row_ids: np.ndarray                   # global ids, raw row space
    table: dict = field(default_factory=dict)


def _f32_shrink(lo: float, hi: float) -> tuple[np.float32, np.float32]:
    """Tightest float32 interval inside the float64 one.

    Exact for float32 column data: a float32 x satisfies lo <= x <= hi iff
    it satisfies the shrunk float32 bounds.
    """
    lo32, hi32 = np.float32(lo), np.float32(hi)
    if np.float64(lo32) < lo:
        lo32 = np.nextafter(lo32, np.float32(np.inf), dtype=np.float32)
    if np.float64(hi32) > hi:
        hi32 = np.nextafter(hi32, np.float32(-np.inf), dtype=np.float32)
    return lo32, hi32


class Scanner:
    def __init__(self, reader: "BullionReader"):
        self.reader = reader
        self.fv = reader.footer

    # -- zone-map access --------------------------------------------------------
    def _group_stats(self, group: int, cols: Sequence[str]) -> dict:
        """Map column name -> chunk STAT record (or None on v0 files)."""
        chunk = self.fv.chunk_stats()
        if chunk is None:
            return {name: None for name in cols}
        n_cols = self.fv.n_cols
        return {name: chunk[group * n_cols + self.fv.column_index(name)]
                for name in cols}

    def _pages_for(self, group: int, cols: Sequence[str]) -> list[int]:
        out: list[int] = []
        for name in cols:
            s, e = self.fv.chunk_pages(group, self.fv.column_index(name))
            out.extend(range(s, e))
        return out

    # -- planning ---------------------------------------------------------------
    def plan(self, pred: Predicate, columns: Sequence[str] = (),
             groups: Optional[Sequence[int]] = None) -> ScanPlan:
        """Zone-map pruning: decide which row groups can possibly match."""
        pred_cols = sorted(pred.columns())
        read_cols = list(dict.fromkeys([*pred_cols, *columns]))
        candidates = list(groups) if groups is not None \
            else list(range(self.fv.n_groups))
        plan = ScanPlan(groups=[], pruned_groups=[])
        for g in candidates:
            n_pages = len(self._pages_for(g, read_cols))
            plan.pages_total += n_pages
            if pred.maybe_any(self._group_stats(g, pred_cols)):
                plan.groups.append(g)
            else:
                plan.pruned_groups.append(g)
                plan.pages_pruned += n_pages
        return plan

    # -- filtering --------------------------------------------------------------
    def _group_keep(self, group: int, col: int = 0) -> Optional[np.ndarray]:
        """Raw-row keep mask from deletion vectors (None = nothing deleted)."""
        s, e = self.fv.chunk_pages(group, col)
        page_rows = self.fv.arr(Sec.PAGE_ROWS, np.uint32)
        parts, any_dv = [], False
        for p in range(s, e):
            dv = self.fv.deletion_vector(p)
            if dv is None:
                parts.append(np.ones(int(page_rows[p]), bool))
            else:
                parts.append(~dv)
                any_dv = True
        return np.concatenate(parts) if any_dv else None

    def _expand_raw(self, group: int, name: str, values):
        """Re-align a drop_deleted=False column to the raw row space.

        Compact-deleted pages (§2.1 RLE rule) physically remove rows, so the
        decoded array is shorter than the group's raw row count and indices
        would otherwise shift. Erased positions read as 0 — the same value
        in-place masking writes — and zone maps of every touched page were
        already widened to include 0, so pruning stays consistent."""
        if not isinstance(values, np.ndarray):
            return values
        rows = int(self.fv.arr(Sec.ROWS_PER_GROUP, np.uint32)[group])
        if len(values) >= rows:
            return values[:rows]
        keep = self._group_keep(group, self.fv.column_index(name))
        out = np.zeros(rows, values.dtype)
        out[np.flatnonzero(keep)] = values
        return out

    def _eval(self, pred: Predicate, tbl: dict,
              use_kernel: Optional[bool]) -> np.ndarray:
        """Predicate -> row mask; Pallas kernel when the predicate compiles
        to conjunctive ranges over float32 columns (exact there), NumPy
        otherwise."""
        ranges = conjunctive_ranges(pred)
        kernel_ok = ranges is not None and all(
            isinstance(tbl[c], np.ndarray) and tbl[c].dtype == np.float32
            for c in ranges)
        if use_kernel and not kernel_ok:
            raise ValueError(
                "kernel filter path requires a conjunctive range predicate "
                "over float32 columns")
        if use_kernel is None:
            use_kernel = kernel_ok
        if not use_kernel:
            return evaluate(pred, tbl)
        from ..kernels.filter import range_mask
        names = list(ranges)
        bounds = [_f32_shrink(*ranges[c]) for c in names]
        cols = np.stack([np.asarray(tbl[c], np.float32) for c in names])
        return range_mask(cols,
                          np.asarray([b[0] for b in bounds], np.float32),
                          np.asarray([b[1] for b in bounds], np.float32))

    # -- scanning ---------------------------------------------------------------
    def scan(self, pred: Predicate, columns: Sequence[str] = (),
             groups: Optional[Sequence[int]] = None, *,
             drop_deleted: bool = True, dequant: bool = True,
             use_kernel: Optional[bool] = None) -> Iterator[ScanBatch]:
        """Yield matching rows per surviving group.

        ``columns`` are the payload columns materialized in each batch (the
        predicate's own columns are always available and included when
        requested). Payload pages are only read for groups where at least one
        row survived the filter — the second half of the I/O win.
        """
        pred_cols = sorted(pred.columns())
        # predicate columns are always evaluated in the dequantized (logical)
        # domain — the domain the zone maps describe; the caller's ``dequant``
        # flag governs only the materialized payload. When the caller wants
        # raw (dequant=False) values of a predicate column, it is re-read in
        # the payload pass rather than served from the evaluation copy.
        reuse = set(pred_cols) if dequant else set()
        payload = [c for c in columns if c not in reuse]
        plan = self.plan(pred, columns, groups)
        rpg = self.fv.arr(Sec.ROWS_PER_GROUP, np.uint32).astype(np.int64)
        bounds = np.concatenate([[0], np.cumsum(rpg)])
        for g in plan.groups:
            (tbl,) = self.reader.project(pred_cols, groups=[g],
                                         drop_deleted=drop_deleted,
                                         dequant=True)
            if not drop_deleted:
                # compact-deleted pages shrink the decoded array; re-align
                # every predicate column to the raw row space first
                tbl = {name: self._expand_raw(g, name, vals)
                       for name, vals in tbl.items()}
            mask = self._eval(pred, tbl, use_kernel)
            if not mask.any():
                continue
            local = np.flatnonzero(mask)
            if drop_deleted:
                keep = self._group_keep(g)
                raw_local = local if keep is None \
                    else np.flatnonzero(keep)[local]
            else:
                raw_local = local
            batch = ScanBatch(group=g, row_ids=bounds[g] + raw_local)
            for name in columns:
                if name in reuse:
                    batch.table[name] = _take(tbl[name], local)
            if payload:
                (ptbl,) = self.reader.project(payload, groups=[g],
                                              drop_deleted=drop_deleted,
                                              dequant=dequant)
                for name in payload:
                    vals = ptbl[name] if drop_deleted \
                        else self._expand_raw(g, name, ptbl[name])
                    batch.table[name] = _take(vals, local)
            yield batch

    def find_rows(self, pred: Predicate, *, drop_deleted: bool = False,
                  use_kernel: Optional[bool] = None) -> np.ndarray:
        """Global row ids (raw row space) whose rows satisfy ``pred``."""
        parts = [b.row_ids for b in self.scan(pred, drop_deleted=drop_deleted,
                                              use_kernel=use_kernel)]
        return np.concatenate(parts) if parts \
            else np.zeros(0, np.int64)


def _take(values, idx: np.ndarray):
    if isinstance(values, np.ndarray):
        return values[idx]
    return [values[i] for i in idx]
