"""Statistics-driven pruning scanner: the planning half of the scan path.

``Scanner.plan`` intersects a predicate with the file's zone maps at two
granularities. Chunk zone maps (``Sec.CHUNK_STATS``) prune whole row groups
that provably contain no matching row; inside surviving groups, per-page
zone maps (``Sec.PAGE_STATS``) prune individual page ordinals — every
column of a group splits at the same row boundaries, so one refuted ordinal
drops one page per read column (``ScanPlan.group_page_sel``). All pruning
happens before any data pread, and the plan accounts the pages and bytes it
avoided. On stat-less (v0) files every group survives and the scan degrades
to a plain filtered read; single-page files simply never page-prune.

Execution — decode, deletion-masking, dequantization, predicate filtering,
payload gathering — lives in ``repro.dataset.executor.execute_group``, the
single pipeline shared with the lazy ``Dataset`` API; ``Scanner.scan`` is a
thin per-group loop over it kept for direct (single-file, eager) use.

Row ids are reported in the file's *raw* row space (deletion vectors do not
renumber rows), which is what ``core.deletion`` consumes for predicate-based
deletes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterator, Optional, Sequence

import numpy as np

from ..core.footer import Sec
from ..obs import trace as _trace
from .predicate import Predicate

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from ..core.reader import BullionReader


@dataclass
class ScanPlan:
    """Result of zone-map pruning, before any data I/O."""

    groups: list[int]                     # surviving row groups, in scan order
    pruned_groups: list[int]              # provably-empty row groups
    groups_pruned_sketch: int = 0         # of those, refuted by value sketch
    pages_pruned: int = 0                 # page reads avoided by pruning
    pages_total: int = 0                  # page reads a full scan would issue
    bytes_pruned: int = 0                 # data bytes those pages hold
    bytes_total: int = 0
    group_pages: dict = field(default_factory=dict)   # group -> page count
    group_bytes: dict = field(default_factory=dict)   # group -> data bytes
    # group -> surviving page ordinals, only for groups where page zone maps
    # pruned a strict subset (absent = read every page of the chunk)
    group_page_sel: dict = field(default_factory=dict)
    # group -> (pages, bytes) already credited to pages/bytes_pruned by
    # page-granular pruning — a later pass dropping the whole group must
    # charge only the remainder, not the full group cost again
    group_avoided: dict = field(default_factory=dict)

    def remaining_cost(self, group: int) -> tuple[int, int]:
        """(pages, bytes) of ``group`` not yet counted as pruned."""
        pages, nbytes = self.group_avoided.get(group, (0, 0))
        return (self.group_pages.get(group, 0) - pages,
                self.group_bytes.get(group, 0) - nbytes)

    @property
    def selectivity_bound(self) -> float:
        total = len(self.groups) + len(self.pruned_groups)
        return len(self.groups) / total if total else 1.0


@dataclass
class ScanBatch:
    """Matching rows of one row group."""

    group: int
    row_ids: np.ndarray                   # global ids, raw row space
    table: dict = field(default_factory=dict)


def _group_stats(fv, group: int, cols: Sequence[str]) -> dict:
    """Map column name -> chunk STAT record (or None on v0 files)."""
    chunk = fv.chunk_stats()
    if chunk is None:
        return {name: None for name in cols}
    n_cols = fv.n_cols
    return {name: chunk[group * n_cols + fv.column_index(name)]
            for name in cols}


def _group_sketches(fv, group: int, cols: Sequence[str]) -> dict:
    """Column name -> chunk BloomSketch, for columns that have one."""
    out = {}
    for name in cols:
        sk = fv.chunk_sketch(group, fv.column_index(name))
        if sk is not None:
            out[name] = sk
    return out


def _pages_for(fv, group: int, cols: Sequence[str]) -> list[int]:
    out: list[int] = []
    for name in cols:
        s, e = fv.chunk_pages(group, fv.column_index(name))
        out.extend(range(s, e))
    return out


def _page_prune(fv, group: int, pred: Predicate, pred_cols: Sequence[str],
                read_cols: Sequence[str], page_size: np.ndarray
                ) -> tuple[Optional[tuple[int, ...]], int, int]:
    """Page-granular refinement inside a group the chunk zone maps kept.

    Every column of a group splits at the same row boundaries (the writer's
    page_rows budget), so page ordinal k is one row range across all read
    columns: an ordinal whose per-page stats refute the predicate drops one
    page *per read column*. Returns (surviving ordinals or None for all,
    pages avoided, bytes avoided); degrades to None (no page pruning) on
    stat-less files, single-page chunks, or — defensively — chunks whose
    page row boundaries disagree."""
    page_stats = fv.page_stats()
    if page_stats is None:
        return None, 0, 0
    page_rows = fv.arr(Sec.PAGE_ROWS, np.uint32)
    starts: dict[str, int] = {}
    # column 0 anchors the executor's ordinal -> raw-row-range mapping
    # (``selected_raw_rows``/``group_keep``), so its boundaries must agree
    # with every read column before any ordinal may be dropped
    s0, e0 = fv.chunk_pages(group, 0)
    first_rows: np.ndarray = page_rows[s0:e0]
    for name in read_cols:
        s, e = fv.chunk_pages(group, fv.column_index(name))
        starts[name] = s
        if not np.array_equal(page_rows[s:e], first_rows):
            return None, 0, 0
    n_ord = len(first_rows)
    if n_ord <= 1:
        return None, 0, 0
    surviving: list[int] = []
    pages_avoided = bytes_avoided = 0
    page_sketches = fv.has(Sec.PAGE_SKETCH)
    for k in range(n_ord):
        stats = {name: page_stats[starts[name] + k] for name in pred_cols}
        keep = pred.maybe_any(stats)
        if keep and page_sketches:
            sks = {}
            for name in pred_cols:
                sk = fv.page_sketch(starts[name] + k)
                if sk is not None:
                    sks[name] = sk
            if sks and pred.sketch_refutes(sks):
                keep = False
        if keep:
            surviving.append(k)
        else:
            pages_avoided += len(read_cols)
            bytes_avoided += int(sum(int(page_size[starts[name] + k])
                                     for name in read_cols))
    if len(surviving) == n_ord:
        return None, 0, 0
    return tuple(surviving), pages_avoided, bytes_avoided


def plan_scan(fv, pred: Optional[Predicate], columns: Sequence[str] = (),
              groups: Optional[Sequence[int]] = None) -> ScanPlan:
    """Footer-only zone-map planning (needs no open file handle):
    intersect ``pred`` with the chunk zone maps — and, inside surviving
    groups, with the per-page zone maps — and account the page/byte cost of
    every candidate group. ``pred=None`` prunes nothing."""
    sp = _trace.span("scan.plan", cat="plan")
    with sp:
        plan = _plan_scan(fv, pred, columns, groups)
        if sp.enabled:
            sp.set(groups_kept=len(plan.groups),
                   groups_pruned=len(plan.pruned_groups),
                   groups_pruned_sketch=plan.groups_pruned_sketch,
                   pages_pruned=plan.pages_pruned,
                   bytes_pruned=plan.bytes_pruned)
    return plan


def _plan_scan(fv, pred: Optional[Predicate], columns: Sequence[str] = (),
               groups: Optional[Sequence[int]] = None) -> ScanPlan:
    pred_cols = sorted(pred.columns()) if pred is not None else []
    read_cols = list(dict.fromkeys([*pred_cols, *columns]))
    candidates = list(groups) if groups is not None \
        else list(range(fv.n_groups))
    page_size = fv.arr(Sec.PAGE_SIZE, np.uint64)
    plan = ScanPlan(groups=[], pruned_groups=[])
    for g in candidates:
        pages = _pages_for(fv, g, read_cols)
        nbytes = int(sum(int(page_size[p]) for p in pages))
        plan.pages_total += len(pages)
        plan.bytes_total += nbytes
        plan.group_pages[g] = len(pages)
        plan.group_bytes[g] = nbytes
        if pred is not None and \
                not pred.maybe_any(_group_stats(fv, g, pred_cols)):
            plan.pruned_groups.append(g)
            plan.pages_pruned += len(pages)
            plan.bytes_pruned += nbytes
            continue
        if pred is not None and fv.has_sketches and \
                pred.sketch_refutes(_group_sketches(fv, g, pred_cols)):
            # the zone maps admitted the group (unclustered columns always
            # do), but the bloom sketch proves the probed value absent
            plan.pruned_groups.append(g)
            plan.groups_pruned_sketch += 1
            plan.pages_pruned += len(pages)
            plan.bytes_pruned += nbytes
            continue
        sel = None
        if pred is not None:
            sel, pages_avoided, bytes_avoided = \
                _page_prune(fv, g, pred, pred_cols, read_cols, page_size)
            if sel is not None and not sel:
                # per-page maps are tighter than their chunk union: every
                # ordinal refuted -> the whole group is provably empty
                plan.pruned_groups.append(g)
                plan.pages_pruned += len(pages)
                plan.bytes_pruned += nbytes
                continue
            if sel is not None:
                plan.group_page_sel[g] = sel
                plan.group_avoided[g] = (pages_avoided, bytes_avoided)
                plan.pages_pruned += pages_avoided
                plan.bytes_pruned += bytes_avoided
        plan.groups.append(g)
    return plan


class Scanner:
    def __init__(self, reader: "BullionReader"):
        self.reader = reader
        self.fv = reader.footer

    def __enter__(self) -> "Scanner":
        return self

    def __exit__(self, *exc) -> None:
        # The scanner context owns the reader's handle: exiting closes it
        # (idempotent), so ``with Scanner(BullionReader(p)) as s:`` cannot
        # leak on an aborted scan. Don't enter a scanner context when the
        # reader must outlive it — close() is shared with the reader.
        self.reader.close()

    # -- planning ---------------------------------------------------------------
    def plan(self, pred: Optional[Predicate], columns: Sequence[str] = (),
             groups: Optional[Sequence[int]] = None) -> ScanPlan:
        """Zone-map pruning: decide which row groups can possibly match.
        ``pred=None`` plans an unpruned scan (all candidates survive) but
        still accounts per-group page/byte costs for downstream planning."""
        return plan_scan(self.fv, pred, columns, groups)

    # -- scanning ---------------------------------------------------------------
    def scan(self, pred: Predicate, columns: Sequence[str] = (),
             groups: Optional[Sequence[int]] = None, *,
             drop_deleted: bool = True, dequant: bool = True,
             use_kernel: Optional[bool] = None) -> Iterator[ScanBatch]:
        """Yield matching rows per surviving group.

        ``columns`` are the payload columns materialized in each batch (the
        predicate's own columns are always available and included when
        requested). Payload pages are only read for groups where at least one
        row survived the filter — the second half of the I/O win.
        """
        from ..dataset.executor import execute_group
        from ..dataset.plan import group_bounds

        plan = self.plan(pred, columns, groups)
        self.reader.stats.bytes_pruned += plan.bytes_pruned
        self.reader.stats.pages_pruned += plan.pages_pruned
        self.reader.stats.groups_pruned_sketch += plan.groups_pruned_sketch
        bounds = group_bounds(self.fv)
        for g in plan.groups:
            res = execute_group(self.reader, g, columns=columns,
                                predicate=pred, drop_deleted=drop_deleted,
                                dequant=dequant, use_kernel=use_kernel,
                                pages=plan.group_page_sel.get(g))
            if res is None:
                continue
            yield ScanBatch(group=g, row_ids=bounds[g] + res.row_ids,
                            table=res.table)

    def find_rows(self, pred: Predicate, *, drop_deleted: bool = False,
                  use_kernel: Optional[bool] = None) -> np.ndarray:
        """Global row ids (raw row space) whose rows satisfy ``pred``."""
        parts = [b.row_ids for b in self.scan(pred, drop_deleted=drop_deleted,
                                              use_kernel=use_kernel)]
        return np.concatenate(parts) if parts \
            else np.zeros(0, np.int64)
