"""Statistics-driven scan subsystem: zone maps, predicate pushdown, pruning.

Design -> paper mapping (Bullion: A Column Store for Machine Learning):

* **§2.3 wide-table projection** — projection already touches only the
  requested columns' pages; this package adds the orthogonal axis: touching
  only the *row groups and pages* a predicate can match. ``scanner.Scanner``
  intersects predicates with per-chunk zone maps before any data pread.
* **§2.1 deletion compliance** — ``core.deletion.delete_where`` locates
  victim rows through the pruning scanner, so compliance deletes (e.g.
  "erase user X") read only the groups whose statistics admit the victim
  instead of decoding the whole column.
* **§2.5 quality-aware organization** — write-path quality presorting makes
  quality zone maps monotone across groups, so threshold reads
  (``BullionLoader(predicate=C("quality") >= t)``) prune to a prefix of the
  file; the statistics are collected by ``BullionWriter`` at write time
  (``scan.stats``).
* **§2.6 cascading encoding selection** — the same per-chunk min/max/
  distinct records are the input signal for a future LEA-style learned
  encoding advisor (see ROADMAP open items).

Layout:

  stats.py      — STAT_DTYPE records, write-time collection helpers
                  (persisted in ``Sec.PAGE_STATS`` / ``Sec.CHUNK_STATS``,
                  format v1; v0 files read fine and simply never prune)
  predicate.py  — predicate AST (Cmp/In/And/Or/Not), ``C`` builder,
                  vectorized NumPy evaluator, sound three-valued zone-map
                  tests, and compilation to conjunctive ranges
  scanner.py    — ScanPlan/Scanner: group pruning with page/byte accounting;
                  execution delegates to the unified ``repro.dataset``
                  pipeline (two-phase predicate-then-payload reads, Pallas
                  batch filter) — see ``repro.dataset.executor``
  sketch.py     — per-chunk/per-page bloom value sketches (format v3,
                  ``Sec.CHUNK_SKETCH``): metadata-resident refutation of
                  equality probes on *unclustered* columns, where zone maps
                  are useless
"""

from .predicate import (And, C, Cmp, In, Not, Or, Predicate, canonical_repr,
                        conjunctive_ranges, evaluate)
from .scanner import ScanBatch, ScanPlan, Scanner, plan_scan
from .sketch import BloomSketch, canonical_u64
from .stats import (HAS_MINMAX, LIST_ELEMENTS, STAT_DTYPE, merge_records,
                    stats_record)

__all__ = [
    "And", "C", "Cmp", "In", "Not", "Or", "Predicate", "canonical_repr",
    "conjunctive_ranges", "evaluate", "ScanBatch", "ScanPlan", "Scanner",
    "plan_scan", "BloomSketch", "canonical_u64", "HAS_MINMAX",
    "LIST_ELEMENTS", "STAT_DTYPE", "merge_records", "stats_record",
]
