"""Predicate AST with a vectorized NumPy evaluator and zone-map tests.

Leaves are ``col <op> literal`` comparisons and ``col IN {...}``; interior
nodes are AND/OR/NOT. Build them directly or through the ``C`` column
builder::

    from repro.scan import C
    pred = (C("quality") >= 0.5) & ~C("label").isin([0])

Each node answers three questions:

* ``mask(table)``        — exact per-row boolean mask (NumPy, vectorized).
* ``maybe_any(stats)``   — could *any* row of a page/chunk match, judged only
                           from its zone-map record. False => safe to prune.
* ``always(stats)``      — do *all* rows provably match. Used to push NOT
                           through zone maps (NOT p prunes where p is always
                           true); conservatively False when unsure.

Zone-map tests are sound under the outer-bound convention of
``scan.stats``: recorded min <= true min, recorded max >= true max, and any
NaNs are counted in ``null_count`` (NaN rows fail every comparison except
``!=``, matching NumPy semantics).

Conjunctions of range comparisons additionally compile to flat per-column
``[lo, hi]`` intervals (``conjunctive_ranges``), the form the Pallas batch
filter kernel (``repro.kernels.filter``) consumes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from .stats import HAS_MINMAX, LIST_ELEMENTS, f8_exact, f8_lower, f8_upper

_OPS = ("==", "!=", "<", "<=", ">", ">=")


def _usable(rec) -> bool:
    """A record prunes rows only if it has min/max over *row* values.

    LIST_ELEMENTS records describe flattened list elements — row-level
    pruning on them would silently drop matches (and predicates on list
    columns must keep raising their TypeError consistently), so they are
    treated as absent."""
    if rec is None:
        return False
    flags = int(rec["flags"])
    return bool(flags & HAS_MINMAX) and not (flags & LIST_ELEMENTS)


class Predicate:
    """Base node. Combine with ``&``, ``|``, ``~``."""

    def columns(self) -> set:
        raise NotImplementedError

    def mask(self, table: dict) -> np.ndarray:
        raise NotImplementedError

    def maybe_any(self, stats: dict) -> bool:
        raise NotImplementedError

    def always(self, stats: dict) -> bool:
        raise NotImplementedError

    def sketch_refutes(self, sketches: dict) -> bool:
        """Do the per-value sketches *prove* no row can match?

        ``sketches`` maps column name -> an object with ``may_contain(v)``
        (``scan.sketch.BloomSketch``). Only equality-shaped leaves can be
        refuted; every other node conservatively answers False ("cannot
        refute"), which keeps the test sound under arbitrary nesting —
        ``Not`` in particular never refutes, because "value absent" says
        nothing about the complement."""
        return False

    def __and__(self, other: "Predicate") -> "Predicate":
        return And(self, other)

    def __or__(self, other: "Predicate") -> "Predicate":
        return Or(self, other)

    def __invert__(self) -> "Predicate":
        return Not(self)


def _column(table: dict, name: str) -> np.ndarray:
    try:
        data = table[name]
    except KeyError:
        raise KeyError(f"predicate column {name!r} not in table") from None
    if isinstance(data, list):
        raise TypeError(
            f"predicate column {name!r} is a list/string column; predicates "
            "support scalar columns only")
    return np.asarray(data)


@dataclass(frozen=True)
class Cmp(Predicate):
    col: str
    op: str
    value: float | int

    def __post_init__(self):
        if self.op not in _OPS:
            raise ValueError(f"bad op {self.op!r}; one of {_OPS}")

    def __repr__(self):
        return f"({self.col} {self.op} {self.value!r})"

    def columns(self) -> set:
        return {self.col}

    def mask(self, table: dict) -> np.ndarray:
        x = _column(table, self.col)
        v = self.value
        if self.op == "==":
            return x == v
        if self.op == "!=":
            return x != v
        if self.op == "<":
            return x < v
        if self.op == "<=":
            return x <= v
        if self.op == ">":
            return x > v
        return x >= v

    def maybe_any(self, stats: dict) -> bool:
        rec = stats.get(self.col)
        if not _usable(rec):
            return True
        lo, hi = float(rec["min"]), float(rec["max"])
        nulls = int(rec["null_count"])
        v_lo, v_hi = f8_lower(self.value), f8_upper(self.value)
        if self.op == "==":
            return not (v_hi < lo or v_lo > hi)
        if self.op == "!=":
            # empty only when every row equals value exactly
            return not (lo == hi == np.float64(self.value)
                        and f8_exact(self.value) and nulls == 0)
        if self.op == "<":
            return not (lo >= v_hi)
        if self.op == "<=":
            return not (lo > v_hi)
        if self.op == ">":
            return not (hi <= v_lo)
        return not (hi < v_lo)          # >=

    def always(self, stats: dict) -> bool:
        rec = stats.get(self.col)
        if not _usable(rec):
            return False
        lo, hi = float(rec["min"]), float(rec["max"])
        nulls = int(rec["null_count"])
        v_lo, v_hi = f8_lower(self.value), f8_upper(self.value)
        if self.op == "!=":
            # NaN != v is True, so nulls don't break universality
            return v_hi < lo or v_lo > hi
        if nulls:
            return False                # NaN rows fail every other comparison
        if self.op == "==":
            return (lo == hi == np.float64(self.value)
                    and f8_exact(self.value))
        if self.op == "<":
            return hi < v_lo
        if self.op == "<=":
            return hi <= v_lo
        if self.op == ">":
            return lo > v_hi
        return lo >= v_hi               # >=

    def sketch_refutes(self, sketches: dict) -> bool:
        if self.op != "==":
            return False
        sk = sketches.get(self.col)
        return sk is not None and not sk.may_contain(self.value)


@dataclass(frozen=True)
class In(Predicate):
    col: str
    values: tuple = field(default_factory=tuple)

    def __init__(self, col: str, values):
        object.__setattr__(self, "col", col)
        object.__setattr__(self, "values", tuple(np.asarray(values).ravel().tolist()))

    def __repr__(self):
        return f"({self.col} IN {list(self.values)})"

    def columns(self) -> set:
        return {self.col}

    def mask(self, table: dict) -> np.ndarray:
        x = _column(table, self.col)
        return np.isin(x, np.asarray(self.values))

    def maybe_any(self, stats: dict) -> bool:
        rec = stats.get(self.col)
        if not _usable(rec):
            return True
        lo, hi = float(rec["min"]), float(rec["max"])
        return any(not (f8_upper(v) < lo or f8_lower(v) > hi)
                   for v in self.values)

    def always(self, stats: dict) -> bool:
        return False

    def sketch_refutes(self, sketches: dict) -> bool:
        sk = sketches.get(self.col)
        if sk is None:
            return False
        # vacuously refuted when empty: ``IN {}`` matches no row
        return all(not sk.may_contain(v) for v in self.values)


class _NAry(Predicate):
    def __init__(self, *children: Predicate):
        flat: list[Predicate] = []
        for c in children:
            if type(c) is type(self):
                flat.extend(c.children)     # associative flattening
            else:
                flat.append(c)
        if not flat:
            raise ValueError(f"{type(self).__name__} needs >= 1 child")
        self.children = tuple(flat)

    def columns(self) -> set:
        out: set = set()
        for c in self.children:
            out |= c.columns()
        return out

    def __repr__(self):
        word = f" {type(self).__name__.upper()} "
        return "(" + word.join(map(repr, self.children)) + ")"


class And(_NAry):
    def mask(self, table: dict) -> np.ndarray:
        out = self.children[0].mask(table)
        for c in self.children[1:]:
            out = out & c.mask(table)
        return out

    def maybe_any(self, stats: dict) -> bool:
        return all(c.maybe_any(stats) for c in self.children)

    def always(self, stats: dict) -> bool:
        return all(c.always(stats) for c in self.children)

    def sketch_refutes(self, sketches: dict) -> bool:
        return any(c.sketch_refutes(sketches) for c in self.children)


class Or(_NAry):
    def mask(self, table: dict) -> np.ndarray:
        out = self.children[0].mask(table)
        for c in self.children[1:]:
            out = out | c.mask(table)
        return out

    def maybe_any(self, stats: dict) -> bool:
        return any(c.maybe_any(stats) for c in self.children)

    def always(self, stats: dict) -> bool:
        return any(c.always(stats) for c in self.children)

    def sketch_refutes(self, sketches: dict) -> bool:
        return all(c.sketch_refutes(sketches) for c in self.children)


class Not(Predicate):
    def __init__(self, child: Predicate):
        self.child = child

    def __repr__(self):
        return f"(NOT {self.child!r})"

    def columns(self) -> set:
        return self.child.columns()

    def mask(self, table: dict) -> np.ndarray:
        return ~self.child.mask(table)

    def maybe_any(self, stats: dict) -> bool:
        return not self.child.always(stats)

    def always(self, stats: dict) -> bool:
        return not self.child.maybe_any(stats)


class C:
    """Column handle: ``C("score") >= 0.5`` builds a ``Cmp``."""

    def __init__(self, name: str):
        self.name = name

    def __eq__(self, v):  # type: ignore[override]
        return Cmp(self.name, "==", v)

    def __ne__(self, v):  # type: ignore[override]
        return Cmp(self.name, "!=", v)

    def __lt__(self, v):
        return Cmp(self.name, "<", v)

    def __le__(self, v):
        return Cmp(self.name, "<=", v)

    def __gt__(self, v):
        return Cmp(self.name, ">", v)

    def __ge__(self, v):
        return Cmp(self.name, ">=", v)

    def isin(self, values) -> In:
        return In(self.name, values)

    def between(self, lo, hi) -> Predicate:
        return And(Cmp(self.name, ">=", lo), Cmp(self.name, "<=", hi))

    __hash__ = None  # type: ignore[assignment]


# ---------------------------------------------------------------------------
# kernel compilation: conjunction of ranges -> per-column [lo, hi]
# ---------------------------------------------------------------------------


def conjunctive_ranges(pred: Predicate) -> Optional[dict[str, tuple[float, float]]]:
    """If ``pred`` is a pure conjunction of range/equality comparisons,
    return closed float intervals per column (intersected); else None.

    This is the planable form the Pallas batch filter kernel accepts:
    ``lo[c] <= x[c] <= hi[c]`` AND-reduced over columns. Strict comparisons
    are closed by one float64 ULP, exact for every representable literal.
    """
    leaves: list[Cmp] = []

    def collect(p: Predicate) -> bool:
        if isinstance(p, And):
            return all(collect(c) for c in p.children)
        if isinstance(p, Cmp) and p.op != "!=":
            leaves.append(p)
            return True
        return False

    if not collect(pred):
        return None
    out: dict[str, tuple[float, float]] = {}
    for leaf in leaves:
        lo, hi = out.get(leaf.col, (-np.inf, np.inf))
        v = float(leaf.value)
        if leaf.op == "==":
            lo, hi = max(lo, v), min(hi, v)
        elif leaf.op == "<":
            hi = min(hi, float(np.nextafter(np.float64(v), -np.inf)))
        elif leaf.op == "<=":
            hi = min(hi, v)
        elif leaf.op == ">":
            lo = max(lo, float(np.nextafter(np.float64(v), np.inf)))
        else:                            # >=
            lo = max(lo, v)
        out[leaf.col] = (lo, hi)
    return out


def canonical_repr(pred: Optional[Predicate]) -> str:
    """Order-insensitive textual form for plan fingerprinting.

    ``And``/``Or`` are commutative and associative (the constructors already
    flatten nesting), so their children are rendered sorted: chaining
    ``.where(a).where(b)`` and ``.where(b).where(a)`` produce the same
    canonical string. Leaves reuse their deterministic ``repr``."""
    if pred is None:
        return "-"
    if isinstance(pred, (And, Or)):
        word = f" {type(pred).__name__.upper()} "
        return "(" + word.join(sorted(canonical_repr(c)
                                      for c in pred.children)) + ")"
    if isinstance(pred, Not):
        return f"(NOT {canonical_repr(pred.child)})"
    return repr(pred)


def evaluate(pred: Predicate, table: dict) -> np.ndarray:
    """Vectorized evaluation over decoded columns -> bool mask."""
    return np.asarray(pred.mask(table), bool)
