"""Per-chunk / per-page bloom value sketches for unclustered point probes.

Zone maps only prune where the write path clustered: an unclustered id
column has min==global-min, max==global-max in every chunk, so a point
probe ``C("id") == k`` degenerates to a full scan. A small write-time bloom
filter over each chunk's (and each page's) distinct values answers the one
question zone maps can't: *could this value possibly be here?* A refuted
chunk is skipped without any data pread; inside a surviving chunk, refuted
page ordinals drop one page per read column, exactly like page zone maps.

Soundness contract (false positives allowed, false negatives **never**):

- Both the write side and the probe side canonicalize values through
  ``canonical_u64`` — the float64 bit pattern of the value, with ``+ 0.0``
  applied so ``-0.0`` and ``0.0`` (which compare equal) share one key.
- NaNs are excluded at write time: ``== NaN`` matches no row under IEEE
  comparison, so their absence can never cause a false negative.
- Quantized columns sketch the *dequantized* (logical) domain, the same
  domain zone maps describe and predicates are written against.
- L2 deletes mask rows to zero in place; ``core.deletion`` inserts the key
  for 0 into every touched sketch, mirroring ``stats.widen_to_zero``.

Wire format — one self-describing blob per sketch, referenced by u64
offsets from ``Sec.CHUNK_SKETCH`` / ``Sec.PAGE_SKETCH`` into
``Sec.SKETCH_DATA`` (offset ``u64max`` = no sketch, prune nothing):

    [u32 nbits][u16 n_hash][u16 reserved][nbits/8 filter bytes]

``nbits`` is a power of two so the double-hash positions reduce with a
mask; the header makes each blob's size self-evident, so no size array is
needed alongside the offsets.
"""

from __future__ import annotations

import struct
from typing import Optional

import numpy as np

# ~8 bits/key with 4 hashes gives a ~2.4% false-positive rate — one wasted
# group read per ~40 refutable probes, against zero data reads saved by
# zone maps on unclustered columns.
BITS_PER_KEY = 8
N_HASH = 4
MIN_BITS = 64                 # floor so tiny pages still get a real filter
MAX_BITS = 1 << 20            # 128 KiB cap per sketch; skip above (no prune)
NO_SKETCH = np.uint64(0xFFFFFFFFFFFFFFFF)

_HEADER = struct.Struct("<IHH")
HEADER_SIZE = _HEADER.size

_U64 = np.uint64
# splitmix64 constants; numpy uint64 arithmetic wraps silently, which is
# exactly the mod-2^64 behaviour the mixer wants
_C1 = _U64(0xBF58476D1CE4E5B9)
_C2 = _U64(0x94D049BB133111EB)
_GOLDEN = _U64(0x9E3779B97F4A7C15)


def canonical_u64(values) -> np.ndarray:
    """Map values to their canonical u64 sketch keys (float64 bit pattern).

    Adding ``0.0`` first folds ``-0.0`` onto ``+0.0`` so equal-comparing
    values share a key. NaNs are the caller's problem: exclude them on the
    write side (``== NaN`` never matches), and never probe with them.
    Integers above 2^53 may collide after the float64 round-trip — that
    only *adds* keys a probe can hit, so it costs false positives, never
    false negatives, as long as the probe side rounds the same way."""
    f = np.asarray(values).astype(np.float64, copy=True)
    f += 0.0
    return f.view(np.uint64)


def _mix(h: np.ndarray) -> np.ndarray:
    h = (h ^ (h >> _U64(30))) * _C1
    h = (h ^ (h >> _U64(27))) * _C2
    return h ^ (h >> _U64(31))


def _positions(keys: np.ndarray, nbits: int, n_hash: int) -> np.ndarray:
    """Bit positions for each key: double hashing h1 + i*h2 (mod nbits).
    Returns shape (n_hash, len(keys)) of int64 positions."""
    h1 = _mix(keys.astype(_U64, copy=False))
    h2 = _mix(h1 + _GOLDEN) | _U64(1)
    mask = _U64(nbits - 1)
    out = np.empty((n_hash, len(h1)), np.int64)
    h = h1
    for i in range(n_hash):
        out[i] = (h & mask).astype(np.int64)
        h = h + h2
    return out


def _pow2_bits(n_keys: int) -> int:
    target = max(MIN_BITS, n_keys * BITS_PER_KEY)
    return 1 << int(target - 1).bit_length()


class BloomSketch:
    """A fixed-size bloom filter over canonical u64 keys.

    ``bits`` is a uint8 array of nbits/8 bytes (little-endian bit order
    within each byte, matching ``np.packbits(bitorder='little')``)."""

    __slots__ = ("nbits", "n_hash", "bits")

    def __init__(self, nbits: int, n_hash: int, bits: np.ndarray):
        self.nbits = int(nbits)
        self.n_hash = int(n_hash)
        self.bits = bits

    # -- construction ---------------------------------------------------------
    @classmethod
    def build(cls, keys: np.ndarray) -> Optional["BloomSketch"]:
        """Build from canonical u64 keys (pre-deduplicated or not). Returns
        None when the sized filter would blow the ``MAX_BITS`` cap — absent
        sketch means "prune nothing", which is always sound."""
        keys = np.asarray(keys, _U64)
        nbits = _pow2_bits(len(keys))
        if nbits > MAX_BITS:
            return None
        sk = cls(nbits, N_HASH, np.zeros(nbits // 8, np.uint8))
        if len(keys):
            sk.insert(keys)
        return sk

    def insert(self, keys: np.ndarray) -> None:
        keys = np.asarray(keys, _U64)
        if not len(keys):
            return
        pos = _positions(keys, self.nbits, self.n_hash).ravel()
        np.bitwise_or.at(self.bits, pos >> 3,
                         np.uint8(1) << (pos & 7).astype(np.uint8))

    # -- probing --------------------------------------------------------------
    def may_contain(self, value) -> bool:
        """True unless the filter *proves* the value absent. The probe value
        is canonicalized here, so callers pass raw predicate literals."""
        key = canonical_u64([value])
        pos = _positions(key, self.nbits, self.n_hash).ravel()
        hit = self.bits[pos >> 3] & (np.uint8(1) << (pos & 7).astype(np.uint8))
        return bool((hit != 0).all())

    # -- serialization --------------------------------------------------------
    def to_bytes(self) -> bytes:
        return _HEADER.pack(self.nbits, self.n_hash, 0) + self.bits.tobytes()

    @property
    def nbytes(self) -> int:
        return HEADER_SIZE + len(self.bits)

    @classmethod
    def from_buffer(cls, buf, offset: int = 0) -> "BloomSketch":
        """View a sketch inside a larger buffer (e.g. ``Sec.SKETCH_DATA``)
        without copying the filter bytes. The returned ``bits`` view is
        read-only when the buffer is; call sites that must mutate (deletion
        widening) copy first."""
        nbits, n_hash, _ = _HEADER.unpack_from(buf, offset)
        bits = np.frombuffer(buf, np.uint8, count=nbits // 8,
                             offset=offset + HEADER_SIZE)
        return cls(nbits, n_hash, bits)
