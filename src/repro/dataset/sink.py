"""Plan-driven materialization sink: the write half of the read/write loop.

``Dataset.write_to`` executes any optimized ``LogicalPlan`` — filters,
projections, ``head`` limits, and dequantization compose with rewrite — and
materializes the surviving rows into a fresh sharded dataset in the current
format (v2: multi-page chunks with a page index and zone maps):

* **compliance purge** — the executor resolves merge-on-read deletion
  vectors while streaming, so deleted rows are physically absent from the
  output (``deletion.verify_deleted`` reports zero raw occurrences),
* **resharding** — ``shard_rows=N`` rotates to a new ``part-NNNNN.bln``
  shard every N rows,
* **reclustering** — ``sort_by=`` re-sorts by a column (stable ascending) or
  any ``SortUDF`` such as ``quality_sort``, so zone maps on the sort column
  become selective again (zone maps are useless on unclustered columns),
* **re-encoding** — cascade encoding selection re-runs per output chunk,
  seeded by the chunk's min/max/distinct statistics through the LEA-style
  ``advise_candidates`` hook, and fresh ``Sec.PAGE_STATS`` /
  ``Sec.CHUNK_STATS`` zone maps are written.

Unsorted rewrites stream group-by-group (the writer's ``stream=True`` mode
holds at most one group per shard in memory); a ``sort_by`` rewrite must
materialize the surviving rows once to permute them globally. Input groups
decode on the shared bounded thread pool when ``parallelism > 1``, and
``io_depth > 1`` pipelines the read side through the I/O scheduler (the
next input group's preads overlap the current group's decode+encode) — with
deterministic output either way.
"""

from __future__ import annotations

import os
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional, Union

import numpy as np

from ..core.encodings.base import code_dtype
from ..core.encodings.cascade import advise_candidates
from ..core.footer import ColKind, FooterView, PageType, Sec
from ..core.quantization import QUANT_DTYPE, QuantMode, QuantSpec
from ..core.writer import BullionWriter, ColumnSpec, SortUDF
from ..obs import trace as _trace

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .core import Dataset

SortBy = Union[str, SortUDF]


@dataclass
class WriteResult:
    """What a ``Dataset.write_to`` materialization produced."""

    paths: list[str] = field(default_factory=list)
    rows: int = 0
    groups: int = 0
    pages: int = 0
    bytes_written: int = 0
    rows_per_shard: list[int] = field(default_factory=list)

    @property
    def shards(self) -> int:
        return len(self.paths)


def _uses_sparse_delta(fv: FooterView, col: int) -> bool:
    flags = fv.arr(Sec.PAGE_FLAGS, np.uint8)
    return any(int(flags[p]) & 0x7F == int(PageType.SPARSE_DELTA)
               for g in range(fv.n_groups)
               for p in range(*fv.chunk_pages(g, col)))


def output_schema(source, names, dequantize: bool) -> list[ColumnSpec]:
    """Derive the output ``ColumnSpec`` list from the input footers.

    Quantized scalar columns keep their quant spec when the plan reads the
    logical domain (the writer re-quantizes, which is idempotent for the
    float storage modes), and become plain columns of the storage dtype on
    ``dequantized(False)`` plans — raw reads materialize stored values, so
    the stored domain *is* the output's logical domain. List columns keep
    their §2.2 sparse-delta layout when any shard's pages used it (the size
    guard in ``build_list_page`` may have shipped plain pages shard by
    shard, so one shard's flags are not conclusive).
    """
    fv = source.footer(0)
    kinds = fv.arr(Sec.COL_KIND, np.uint8)
    logical = fv.arr(Sec.COL_LOGICAL, np.uint8)
    storage = fv.arr(Sec.COL_DTYPE, np.uint8)
    quant = fv.arr(Sec.QUANT_META, QUANT_DTYPE)
    specs: list[ColumnSpec] = []
    for name in names:
        c = fv.column_index(name)
        kind = ColKind(int(kinds[c]))
        if kind == ColKind.STRING:
            specs.append(ColumnSpec(name, "string"))
        elif kind == ColKind.MEDIA_REF:
            specs.append(ColumnSpec(name, "media_ref"))
        elif kind == ColKind.LIST:
            elem = code_dtype(int(logical[c])).name
            sd = any(_uses_sparse_delta(source.footer(s), c)
                     for s in range(source.n_shards))
            specs.append(ColumnSpec(name, f"list<{elem}>", sparse_delta=sd))
        else:
            q = QuantSpec.from_record(quant[c])
            if dequantize or q.mode == QuantMode.NONE:
                specs.append(ColumnSpec(
                    name, code_dtype(int(logical[c])).name, quant=q))
            else:
                specs.append(ColumnSpec(name, code_dtype(int(storage[c])).name))
    return specs


def _nrows(table: dict) -> int:
    return len(next(iter(table.values())))


def _slice(table: dict, lo: int, hi: int) -> dict:
    return {k: v[lo:hi] for k, v in table.items()}


def _permute(table: dict, perm: np.ndarray) -> dict:
    return {k: v[perm] if isinstance(v, np.ndarray) else [v[i] for i in perm]
            for k, v in table.items()}


def write_dataset(ds: "Dataset", out_dir: str, *,
                  shard_rows: Optional[int] = None,
                  rows_per_group: Optional[int] = None,
                  page_rows: Optional[int] = None,
                  sort_by: Optional[SortBy] = None,
                  compliance: Optional[int] = None,
                  parallelism: int = 1,
                  io_depth: int = 1,
                  collect_stats: bool = True,
                  use_advisor: bool = True) -> WriteResult:
    """Execute ``ds``'s plan and materialize the result under ``out_dir``.

    See ``Dataset.write_to`` for the user-facing contract. ``compliance``,
    ``rows_per_group``, and ``page_rows`` default to the input's values
    (shard 0's footer; pre-page-index inputs fall back to the writer
    default); ``collect_stats=False`` writes v0 shards (the backward-compat
    target), so ``write_to`` also upgrades v0 datasets to the current
    format by default.
    Output chunks are split into pages of ``page_rows`` rows, each encoded
    independently with per-page stats feeding the encoding advisor.
    """
    opt = ds.plan()
    if not opt.output_columns:
        raise ValueError("write_to needs at least one output column")
    if shard_rows is not None and shard_rows <= 0:
        raise ValueError(f"shard_rows must be positive, got {shard_rows}")
    if isinstance(sort_by, str) and sort_by not in opt.output_columns:
        raise KeyError(
            f"sort_by column {sort_by!r} is not in the output columns "
            f"{list(opt.output_columns)}")
    src = ds._source
    fv = src.footer(0)
    if rows_per_group is None:
        rows_per_group = int(fv.meta[4]) or 65536
    if page_rows is None:
        recorded = fv.props().get("bullion.page_rows")
        page_rows = int(recorded) if recorded else None
    if compliance is None:
        compliance = fv.compliance
    schema = output_schema(src, opt.output_columns, opt.logical.dequantize)

    from .source import _is_bullion
    os.makedirs(out_dir, exist_ok=True)
    clash = [n for n in sorted(os.listdir(out_dir))
             if _is_bullion(os.path.join(out_dir, n))]
    if clash:
        raise FileExistsError(
            f"output directory {out_dir!r} already holds Bullion shard(s) "
            f"{clash[:3]}; refusing to mix datasets")

    advisor = advise_candidates if use_advisor else None
    result = WriteResult()
    writer: Optional[BullionWriter] = None
    shard_filled = 0

    def open_shard() -> BullionWriter:
        path = os.path.join(out_dir, f"part-{len(result.paths):05d}.bln")
        result.paths.append(path)
        result.rows_per_shard.append(0)
        return BullionWriter(path, schema, rows_per_group=rows_per_group,
                             page_rows=page_rows, compliance=compliance,
                             collect_stats=collect_stats, stream=True,
                             encoding_advisor=advisor,
                             props={"bullion.sink": "write_to"})

    def close_shard(w: BullionWriter) -> None:
        with _trace.span("sink.close_shard", cat="sink",
                         shard=len(result.paths) - 1):
            info = w.close()
        result.rows += info["rows"]
        result.groups += info["groups"]
        result.pages += info["pages"]
        result.bytes_written += os.path.getsize(w.path)

    def emit(table: dict) -> None:
        nonlocal writer, shard_filled
        n = _nrows(table)
        off = 0
        while off < n:
            if writer is None:
                writer = open_shard()
                shard_filled = 0
            take = n - off if shard_rows is None \
                else min(n - off, shard_rows - shard_filled)
            # per-group flush spans (write.group) come from the writer; this
            # span is the sink-side unit: one slice into one output shard
            with _trace.span("sink.write", cat="sink", rows=take,
                             shard=len(result.paths) - 1):
                writer.write_table(_slice(table, off, off + take))
            shard_filled += take
            result.rows_per_shard[-1] += take
            off += take
            if shard_rows is not None and shard_filled >= shard_rows:
                close_shard(writer)
                writer = None

    try:
        with _trace.span("sink.write_dataset", cat="sink",
                         out_dir=out_dir, shards_in=src.n_shards):
            if sort_by is not None:
                # a global re-cluster needs the whole surviving table at once
                from .core import _concat_tables
                parts = [res.table
                         for _, res in ds._execute(parallelism=parallelism,
                                                   io_depth=io_depth)]
                full = _concat_tables(parts, opt.output_columns)
                if parts and _nrows(full):
                    perm = sort_by(full) if callable(sort_by) else \
                        np.argsort(np.asarray(full[sort_by]), kind="stable")
                    emit(_permute(full, perm))
            else:
                for _, res in ds._execute(parallelism=parallelism,
                                          io_depth=io_depth):
                    emit(res.table)

            if writer is not None:
                close_shard(writer)
            elif not result.paths:
                # zero surviving rows: still materialize one empty,
                # openable shard
                close_shard(open_shard())
    except BaseException:
        # a failed rewrite must not leave half a dataset behind: finished
        # part files would read as a complete (wrong) dataset and block the
        # retry at the clash check above
        if writer is not None:
            writer.abort()
        for p in result.paths:
            for victim in (p, p + ".tmp"):
                try:
                    os.unlink(victim)
                except OSError:
                    pass
        raise
    return result
