"""Dataset sources: shard discovery, schema checking, reader lifecycle.

A ``DataSource`` owns the ordered list of Bullion shards behind a dataset —
one file, a directory of shards, a glob, or an explicit path list — plus the
per-shard ``BullionReader`` handles. Shards are discovered and
schema-checked at open time: every shard must agree with shard 0 on column
names, kinds, and logical dtypes, so one plan executes unchanged over all
of them. Global row ids are raw per-shard row ids offset by the cumulative
row counts of the preceding shards (shard order = discovery order).

Footer metadata is served from a process-wide cache (``cached_footer``):
repeated ``dataset()`` opens, the training loader's per-rank construction,
and ``write_to``'s read side all share one parsed ``FooterView`` per
unchanged shard, validated by (mtime, size, inode) and counted in
``IOStats.footer_cache_hits``. Shards may also live in object storage:
``bullion://bucket/key`` URIs route through ``repro.core.backend`` and
their footer-cache entries validate by (ETag, length) instead of the stat
triple.
"""

from __future__ import annotations

import glob as _glob
import os
import threading
import time
from collections import OrderedDict
from typing import Optional, Sequence, Union

import numpy as np

from ..core import backend as _backend
from ..core.footer import (MAGIC, FooterView, Sec, ShardCorruptError,
                           register_footer_invalidator, read_footer)
from ..core.reader import BullionReader, IOStats, default_coalesce_gap
from ..obs import metrics as _metrics

PathSpec = Union[str, Sequence[str]]


class SchemaMismatchError(ValueError):
    """A shard disagrees with the dataset schema (names/kinds/dtypes)."""


# ---------------------------------------------------------------------------
# process-wide footer cache
# ---------------------------------------------------------------------------
#
# Every ``dataset()`` open, loader construction, and ``write_to`` read side
# used to re-pread and re-parse each shard's footer. Footers are immutable
# for an unchanged file, so one parse per (path, file version) is enough for
# the whole process: the cache maps absolute path -> parsed ``FooterView``,
# validated by (mtime_ns, size, inode) on every lookup so a rewritten shard
# invalidates itself. In-process rewriters (``BullionWriter.close``,
# ``deletion.delete_rows``) also drop their entry explicitly, which protects
# same-size rewrites on filesystems with coarse timestamp granularity.

_FOOTER_CACHE_CAP = 4096
_footer_cache: "OrderedDict[str, tuple[tuple, FooterView, int]]" = \
    OrderedDict()
_footer_cache_lock = threading.Lock()


def _footer_validator(path: str) -> tuple:
    st = os.stat(path)
    return (st.st_mtime_ns, st.st_size, st.st_ino)


def cached_footer(path: str) -> tuple[FooterView, int, bool]:
    """Parsed footer for ``path``: ``(view, footer_offset, cache_hit)``.

    A hit costs one ``stat`` and zero preads; a miss reads and parses the
    footer, then caches it keyed by the file's identity+version so every
    later open of the unchanged file is free. ``FooterView`` is read-only
    and safe to share across datasets and threads.

    ``bullion://`` shards have no ``(mtime, size, inode)`` to validate by:
    their cache entries are keyed by URI and validated by the object's
    ``(ETag, length)`` from one HEAD request — a hit costs one HEAD and
    zero range GETs."""
    if _backend.is_remote(path):
        return _cached_footer_remote(path)
    key = os.path.abspath(path)
    val = _footer_validator(path)
    with _footer_cache_lock:
        ent = _footer_cache.get(key)
        if ent is not None and ent[0] == val:
            _footer_cache.move_to_end(key)
            return ent[1], ent[2], True
    try:
        fv, off = read_footer(path)
    except ShardCorruptError:
        # a shard that fails footer/tail validation must not linger in the
        # cache under a stale validator: the repaired/replaced file re-reads
        # fresh on the next open, no process restart needed
        invalidate_cached_footer(path)
        raise
    # only cache if the file didn't change underneath the read (a torn
    # racing rewrite must not be pinned under the pre-rewrite validator)
    if _footer_validator(path) == val:
        with _footer_cache_lock:
            _footer_cache[key] = (val, fv, off)
            _footer_cache.move_to_end(key)
            while len(_footer_cache) > _FOOTER_CACHE_CAP:
                _footer_cache.popitem(last=False)
    return fv, off, False


def _cached_footer_remote(uri: str) -> tuple[FooterView, int, bool]:
    with _backend.open_shard(uri) as h:
        val = h.validator()   # one HEAD: (ETag, length)
        with _footer_cache_lock:
            ent = _footer_cache.get(uri)
            if ent is not None and ent[0] == val:
                _footer_cache.move_to_end(uri)
                return ent[1], ent[2], True
        try:
            fv, off = _backend.read_shard_footer(h)
        except ShardCorruptError:
            invalidate_cached_footer(uri)
            raise
        # same torn-rewrite guard as the local path: only cache if the
        # object identity didn't change underneath the read
        if h.validator() == val:
            with _footer_cache_lock:
                _footer_cache[uri] = (val, fv, off)
                _footer_cache.move_to_end(uri)
                while len(_footer_cache) > _FOOTER_CACHE_CAP:
                    _footer_cache.popitem(last=False)
    return fv, off, False


def invalidate_cached_footer(path: str) -> None:
    """Drop one path's cached footer (called by in-process rewriters)."""
    key = path if _backend.is_remote(path) else os.path.abspath(path)
    with _footer_cache_lock:
        _footer_cache.pop(key, None)


def clear_footer_cache() -> None:
    with _footer_cache_lock:
        _footer_cache.clear()


# core-layer rewriters (BullionWriter.close, deletion.delete_rows) notify
# through repro.core.footer so core never imports upward into this layer
register_footer_invalidator(invalidate_cached_footer)


def _is_bullion(path: str) -> bool:
    if path.endswith(".tmp"):
        # an atomic-write staging file: even a *completed* tmp (crash
        # between the final fsync and the rename) must stay invisible to
        # discovery and the sink's clash check
        return False
    try:
        with open(path, "rb") as f:
            f.seek(-8, 2)
            return f.read(8) == MAGIC
    except OSError:
        return False


def discover(spec: PathSpec) -> list[str]:
    """Resolve a path / directory / glob / explicit list into shard paths.
    ``bullion://bucket/key`` URIs pass through to the object-store backend
    (existence and magic are checked at footer-read time, where missing
    keys and unreachable endpoints raise ``FileNotFoundError``); lists may
    mix local paths and URIs."""
    if not isinstance(spec, str):
        paths = [str(p) for p in spec]
        if not paths:
            raise FileNotFoundError("empty dataset path list")
        return paths
    if _backend.is_remote(spec):
        _backend.parse_uri(spec)   # malformed URIs fail here, not mid-scan
        return [spec]
    if os.path.isdir(spec):
        paths = sorted(os.path.join(spec, n) for n in os.listdir(spec)
                       if os.path.isfile(os.path.join(spec, n)))
        paths = [p for p in paths if _is_bullion(p)]
        if not paths:
            raise FileNotFoundError(f"no Bullion shards in directory {spec!r}")
        return paths
    if any(ch in spec for ch in "*?["):
        matched = sorted(_glob.glob(spec))
        if not matched:
            raise FileNotFoundError(f"glob {spec!r} matched no files")
        paths = [p for p in matched if _is_bullion(p)]
        if not paths:
            raise FileNotFoundError(
                f"glob {spec!r} matched no Bullion files "
                f"({len(matched)} non-Bullion match(es) skipped)")
        return paths
    if not os.path.exists(spec):
        raise FileNotFoundError(
            f"dataset path {spec!r} does not exist (expected a Bullion "
            "file, a shard directory, a glob pattern, or a path list)")
    return [spec]


def _schema_sig(fv: FooterView):
    return (tuple(fv.column_names()),
            tuple(fv.arr(Sec.COL_KIND, np.uint8).tolist()),
            tuple(fv.arr(Sec.COL_LOGICAL, np.uint8).tolist()))


class DataSource:
    """Ordered shards + lazy readers + global row-offset map."""

    def __init__(self, paths: Sequence[str], *,
                 readers: Optional[Sequence[BullionReader]] = None,
                 owns_readers: bool = True,
                 coalesce_gap: Optional[int] = None):
        self.paths = list(paths)
        self.owns_readers = owns_readers
        self.coalesce_gap = coalesce_gap   # None = reader default (env var)
        self._readers: list[Optional[BullionReader]] = \
            list(readers) if readers is not None else [None] * len(self.paths)
        # retired accounting folds into the process-wide metrics registry as
        # it lands here (``bullion.io.*`` counters) — the registry is the
        # cross-dataset aggregate; ``stats`` stays the per-dataset view
        self._retired: list[IOStats] = []
        self._open_lock = threading.Lock()   # parallel tasks race reader()
        self._invalid: Optional[str] = None
        # resolve every footer now — schema mismatches surface at dataset()
        # time, not deep inside a scan — but hold no file handles: planning
        # is footer-only, and readers open lazily per shard on first data
        # access (a 10k-shard dataset must not pin 10k descriptors). Footers
        # come from the process-wide cache, so repeated opens of unchanged
        # shards re-pread and re-parse nothing; the parsed views are handed
        # to the lazy readers so metadata is read at most once per shard
        # version across the whole process.
        t0 = time.perf_counter()
        self._foots: list[tuple[FooterView, int]] = []
        self._foot_hits: list[bool] = []
        for r, p in zip(self._readers, self.paths):
            if r is not None:
                self._foots.append((r.footer, r.footer_offset))
                self._foot_hits.append(False)
            else:
                fv, off, hit = cached_footer(p)
                self._foots.append((fv, off))
                self._foot_hits.append(hit)
        hits = sum(self._foot_hits)
        if hits:
            self._retire(IOStats(
                footer_cache_hits=hits,
                metadata_seconds=time.perf_counter() - t0))
        self._footers = [f for f, _ in self._foots]
        self._sig = _schema_sig(self._footers[0])
        self.column_names: list[str] = list(self._sig[0])
        self.column_set = frozenset(self.column_names)
        offsets = [0]
        for i, fv in enumerate(self._footers):
            if i and _schema_sig(fv) != self._sig:
                raise SchemaMismatchError(
                    f"shard {self.paths[i]!r} schema {_schema_sig(fv)[0]} "
                    f"does not match shard {self.paths[0]!r} schema "
                    f"{self._sig[0]} (column names, kinds, and logical "
                    "dtypes must agree across a dataset)")
            offsets.append(offsets[-1] + fv.num_rows)
        self._row_offsets = np.asarray(offsets, np.int64)

    @classmethod
    def from_reader(cls, reader: BullionReader) -> "DataSource":
        """Wrap an already-open reader (legacy shims). Not owned: closing
        the dataset leaves the caller's reader open."""
        return cls([reader.path], readers=[reader], owns_readers=False)

    # -- shards -----------------------------------------------------------------
    @property
    def n_shards(self) -> int:
        return len(self.paths)

    def reader(self, shard: int) -> BullionReader:
        """Open (or reuse) the shard's data reader — first data access.
        Reuses the footer parsed at discovery time (no second parse), and is
        the *only* fd per shard: parallel tasks and the I/O scheduler's
        prefetch thread all share it via positional reads. A footer-cache
        hit charges no footer preads (nobody re-read the metadata)."""
        self._check_valid()
        r = self._readers[shard]
        if r is None:
            with self._open_lock:
                r = self._readers[shard]
                if r is None:
                    r = self._readers[shard] = BullionReader(
                        self.paths[shard], footer=self._foots[shard],
                        charge_footer=not self._foot_hits[shard],
                        coalesce_gap=self.coalesce_gap)
        return r

    def footer(self, shard: int) -> FooterView:
        """Footer-only access: never opens a file handle."""
        self._check_valid()
        r = self._readers[shard]
        return r.footer if r is not None else self._footers[shard]

    def shard_coalesce_gap(self, shard: int) -> int:
        """The run-coalescing gap a shard's reader will use, computed
        footer-only (no handle opens): the dataset override when given,
        else the backend default — 64 KiB local, 1 MiB for object-store
        shards, where hole bytes are cheaper than extra ranged GETs."""
        if self.coalesce_gap is not None:
            return int(self.coalesce_gap)
        return default_coalesce_gap(
            remote=_backend.is_remote(self.paths[shard]))

    def invalidate(self, reason: str) -> None:
        """Mark cached footers stale (a rewrite — e.g. ``delete_where`` —
        changed the files underneath). Every later access raises; callers
        reopen with ``dataset()``."""
        self._invalid = reason

    def _check_valid(self) -> None:
        if self._invalid is not None:
            raise ValueError(
                f"dataset is stale: {self._invalid}; reopen with dataset()")

    def row_offset(self, shard: int) -> int:
        return int(self._row_offsets[shard])

    @property
    def num_rows(self) -> int:
        return int(self._row_offsets[-1])

    @property
    def schema_path(self) -> str:
        """The shard whose footer defines the dataset schema (shard 0)."""
        return self.paths[0]

    def credit_pruned(self, nbytes: int, npages: int = 0,
                      sketch_groups: int = 0) -> None:
        """Account plan-proven avoided I/O without opening any reader.
        For a borrowed reader (legacy shims), the credit must land on the
        caller's IOStats — this source is discarded right after the call."""
        if not self.owns_readers:
            self._readers[0].stats.bytes_pruned += int(nbytes)
            self._readers[0].stats.pages_pruned += int(npages)
            self._readers[0].stats.groups_pruned_sketch += int(sketch_groups)
        else:
            self._retire(IOStats(bytes_pruned=int(nbytes),
                                 pages_pruned=int(npages),
                                 groups_pruned_sketch=int(sketch_groups)))

    # -- lifecycle --------------------------------------------------------------
    def _retire(self, st: IOStats) -> None:
        self._retired.append(st)
        _metrics.absorb_iostats(st)

    def close(self) -> None:
        """Close owned readers (idempotent). Their I/O accounting is retired
        into ``stats`` so aggregates survive the handles."""
        if not self.owns_readers:
            return
        for i, r in enumerate(self._readers):
            if r is not None:
                self._retire(r.stats)
                r.close()
                self._readers[i] = None

    @property
    def stats(self) -> IOStats:
        """Aggregate IOStats across live and retired shard readers."""
        return IOStats.sum((*self._retired,
                            *(r.stats for r in self._readers
                              if r is not None)))
