"""Lazy ``Dataset``: chainable logical plans over Bullion data.

``dataset(path_or_glob)`` opens one file, a directory of shards, a glob, or
an explicit path list. Chaining (`select`/`where`/`with_rows`/`head`/...)
only rewrites an immutable ``LogicalPlan``; no I/O happens until a terminal
(``to_table``/``to_batches``/``count_rows``/``row_ids``) optimizes, lowers,
and executes it. The same plan runs unchanged over single- and multi-file
datasets.
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

import numpy as np

from ..core.footer import ColKind, Sec
from ..core.reader import BullionReader, IOStats
from ..obs import querylog as _querylog
from ..obs import trace as _trace
from ..scan.predicate import Predicate
from . import executor
from .plan import LogicalPlan, OptimizedPlan, PhysicalPlan, ScanTask, \
    group_bounds as _group_bounds, lower, optimize
from .source import DataSource, PathSpec


def dataset(path_or_paths: PathSpec, *,
            coalesce_gap: Optional[int] = None) -> "Dataset":
    """Open a lazy Dataset over one Bullion file, a shard directory, a glob
    pattern, or an explicit list of shard paths. Shard footers come from the
    process-wide footer cache (repeated opens of unchanged files parse
    nothing). ``coalesce_gap`` overrides the readers' pread-coalescing hole
    budget in bytes (default: ``BULLION_COALESCE_GAP`` or 64 KiB)."""
    from .source import discover
    return Dataset(DataSource(discover(path_or_paths),
                              coalesce_gap=coalesce_gap))


@dataclass
class DatasetBatch:
    """One surviving row group's worth of results."""

    shard: int
    group: int
    row_ids: np.ndarray              # global ids, raw row space
    table: dict = field(default_factory=dict)


class Dataset:
    """A logical scan plan over one or more Bullion shards."""

    def __init__(self, source: DataSource,
                 plan: Optional[LogicalPlan] = None):
        self._source = source
        self._plan = plan or LogicalPlan()
        # caches: the logical plan and footers are immutable for this
        # instance, so optimize/lower run once however many terminals fire
        self._opt: Optional[OptimizedPlan] = None
        self._phys: Optional[PhysicalPlan] = None
        self._task_pages: Optional[dict] = None   # (shard, group) -> ordinals
        self._credited = False          # pruned bytes: one credit per plan

    @classmethod
    def from_reader(cls, reader: BullionReader) -> "Dataset":
        """One-file dataset over an already-open reader (legacy shims).
        The caller keeps ownership of the reader."""
        return cls(DataSource.from_reader(reader))

    def _chain(self, **kw) -> "Dataset":
        return Dataset(self._source, self._plan.replace(**kw))

    # -- chainable transforms ---------------------------------------------------
    def select(self, columns: Sequence[str]) -> "Dataset":
        """Project to ``columns`` (projection narrowing prunes all others)."""
        return self._chain(columns=tuple(columns))

    def where(self, predicate: Predicate) -> "Dataset":
        """Filter rows; repeated calls AND together. Zone maps prune row
        groups the predicate provably cannot match before any data pread."""
        combined = predicate if self._plan.predicate is None \
            else self._plan.predicate & predicate
        return self._chain(predicate=combined)

    def with_rows(self, row_ids) -> "Dataset":
        """Restrict to global row ids (raw row space, as reported by
        ``row_ids()``/``find_rows``). Groups holding none of them are pruned."""
        ids = np.unique(np.asarray(row_ids, np.int64))
        return self._chain(row_ids=ids)

    def dequantized(self, flag: bool = True) -> "Dataset":
        """Materialize quantized columns in the logical (float) domain
        (default) or as raw stored values (``False``). Predicates always
        evaluate in the logical domain either way."""
        return self._chain(dequantize=flag)

    def drop_deleted(self, flag: bool = True) -> "Dataset":
        """Hide deletion-vector rows (default) or keep the raw row space
        (``False``; what compliance tooling audits)."""
        return self._chain(drop_deleted=flag)

    def head(self, n: int) -> "Dataset":
        """Limit to the first ``n`` rows in scan order. Without a predicate
        the limit is pushed into planning: groups past the prefix holding
        ``n`` rows are never read."""
        return self._chain(limit=n)

    def _with_groups(self, groups: Optional[Sequence[int]]) -> "Dataset":
        """Legacy single-shard row-group restriction (internal)."""
        if groups is None:
            return self
        return self._chain(groups=tuple(int(g) for g in groups))

    def _with_kernel(self, use_kernel: Optional[bool]) -> "Dataset":
        return self._chain(use_kernel=use_kernel)

    # -- metadata ---------------------------------------------------------------
    @property
    def column_names(self) -> list[str]:
        return list(self._source.column_names)

    @property
    def num_rows(self) -> int:
        """Raw rows across all shards (metadata only; ignores the plan)."""
        return self._source.num_rows

    @property
    def n_shards(self) -> int:
        return self._source.n_shards

    @property
    def stats(self) -> IOStats:
        """Aggregate I/O accounting across every shard reader."""
        return self._source.stats

    # -- planning ---------------------------------------------------------------
    def plan(self) -> OptimizedPlan:
        """Optimize the logical plan (no I/O beyond footers). Cached: the
        logical plan is immutable, so hot per-group paths (the training
        loader) don't re-validate on every call."""
        if self._opt is None:
            self._opt = optimize(self._plan, self._source)
        return self._opt

    def physical_plan(self) -> PhysicalPlan:
        """Optimize + lower: per-(shard, group) tasks with pruned-bytes
        accounting. Footer-only; no file handle is opened and no data page
        touched. Cached per instance."""
        if self._phys is None:
            self._phys = lower(self.plan(), self._source)
        return self._phys

    def tasks(self) -> list[ScanTask]:
        """The physical task list, crediting pruned bytes to ``stats`` (one
        planning pass = one scan's worth of avoided I/O)."""
        phys = self.physical_plan()
        self._credit(phys)
        return phys.tasks

    def explain(self, analyze: bool = False, *,
                parallelism: int = 1, io_depth: int = 1) -> str:
        """Human-readable logical + physical plan.

        ``analyze=True`` additionally *executes* the plan under a scoped
        tracer and appends what actually happened: wall time, rows out,
        per-stage call counts / summed time / summed attributes (pages,
        bytes, rows...), and a machine-parsable ``io:`` line holding the
        ``IOStats`` delta this execution charged (every field, so the
        rendering reconciles exactly with ``Dataset.stats``). Results are
        materialized and discarded; ``parallelism``/``io_depth`` shape the
        execution like any other terminal. Run it on a fresh instance to
        also see the ``plan.optimize``/``plan.lower`` spans (plans cache
        per instance)."""
        if not analyze:
            return self._explain_static()
        before = self._source.stats
        # install the collector before plan() so optimize/lower spans land
        # in the report on a fresh instance; forwarding keeps a concurrent
        # BULLION_TRACE recording complete
        with _trace.collect() as tracer:
            static = self._explain_static()
            t0 = time.perf_counter()
            tasks = rows = 0
            for _, res in self._execute(parallelism=parallelism,
                                        io_depth=io_depth):
                tasks += 1
                rows += len(res.row_ids)
            wall = time.perf_counter() - t0
        io = self._source.stats.delta(before)
        agg = tracer.aggregate()
        lines = [static, "Execution (analyze=True):",
                 f"  wall: {wall * 1e3:.3f} ms  tasks: {tasks}  "
                 f"rows out: {rows}",
                 f"  {'stage':<20}{'calls':>7}{'time':>13}  detail"]
        for name in sorted(agg, key=lambda n: -agg[n].seconds):
            a = agg[name]
            detail = " ".join(
                f"{k}={a.args[k]:.3f}" if isinstance(a.args[k], float)
                else f"{k}={a.args[k]}" for k in sorted(a.args))
            lines.append((f"  {name:<20}{a.count:>7}"
                          f"{a.seconds * 1e3:>10.3f} ms  {detail}").rstrip())
        bits = []
        for f in dataclasses.fields(io):
            v = getattr(io, f.name)
            bits.append(f"{f.name}={v:.6f}" if isinstance(v, float)
                        else f"{f.name}={v}")
        lines.append("  io: " + " ".join(bits))
        # a capped tracer silently truncates; say so instead of looking
        # complete
        lines.append(f"  spans: {len(tracer.spans)} recorded, "
                     f"{tracer.dropped} dropped")
        return "\n".join(lines)

    def _explain_static(self) -> str:
        opt = self.plan()
        phys = self.physical_plan()
        p = self._plan
        lines = [
            "LogicalPlan:",
            f"  select: {list(opt.output_columns)}",
            f"  where: {p.predicate!r} ({len(opt.conjuncts)} conjunct(s))"
            if p.predicate is not None else "  where: -",
            f"  rows: {len(p.row_ids)} pinned row id(s)"
            if p.row_ids is not None else "  rows: -",
            f"  dequantize: {p.dequantize}  drop_deleted: {p.drop_deleted}"
            f"  limit: {p.limit}",
            f"  read columns (narrowed): {list(opt.read_columns)}",
            f"PhysicalPlan: {self.n_shards} shard(s), {len(phys.tasks)} task(s)",
            f"  groups: {phys.groups_total - phys.groups_pruned}/"
            f"{phys.groups_total} kept ({phys.groups_pruned} pruned, "
            f"{phys.groups_pruned_sketch} by value sketch)",
            f"  pages: {phys.pages_total - phys.pages_pruned}/"
            f"{phys.pages_total} kept ({phys.pages_pruned} pruned, "
            f"{sum(1 for t in phys.tasks if t.pages is not None)} "
            "page-subset task(s))",
            f"  bytes: <= {phys.bytes_total - phys.bytes_pruned} read, "
            f"{phys.bytes_pruned} pruned of {phys.bytes_total} total",
        ]
        return "\n".join(lines)

    # -- execution --------------------------------------------------------------
    def _credit(self, phys: PhysicalPlan) -> None:
        # One credit per Dataset instance (= one planned scan), however many
        # terminals observe it — tasks() + read_group() streaming and a
        # plain to_table() both count the avoided I/O exactly once.
        if (phys.bytes_pruned or phys.pages_pruned
                or phys.groups_pruned_sketch) and not self._credited:
            self._credited = True
            self._source.credit_pruned(phys.bytes_pruned, phys.pages_pruned,
                                       phys.groups_pruned_sketch)

    def _execute(self, output_columns: Optional[Sequence[str]] = None,
                 parallelism: int = 1, io_depth: int = 1
                 ) -> Iterator[tuple[ScanTask, executor.GroupResult]]:
        """Run the plan (see ``_execute_impl``). When local query-log
        recording is on (``BULLION_QUERY_LOG=path`` or
        ``querylog.enable_local()``), the run is wrapped so one structured
        record — wall time, rows, exact ``IOStats`` delta, stage timings if
        a tracer is live — lands in ``querylog.LOG`` when the iterator
        finishes (or dies); the default leaves the hot path untouched."""
        inner = self._execute_impl(output_columns, parallelism, io_depth)
        if not _querylog.local_enabled():
            return inner
        return self._execute_logged(inner, io_depth)

    def _execute_logged(self, inner, io_depth: int
                        ) -> Iterator[tuple[ScanTask, executor.GroupResult]]:
        rec = _querylog.QueryRecord(
            ts=_querylog.now(), origin="local",
            dataset=self._source.paths[0], tenant="local",
            columns=list(self._plan.columns)
            if self._plan.columns is not None else None,
            predicate=repr(self._plan.predicate)
            if self._plan.predicate is not None else None)
        try:
            rec.fingerprint = self._plan.fingerprint()
        except Exception:
            pass
        t0 = time.perf_counter()
        before = self._source.stats
        scope = tracer = None
        if _trace.enabled():
            scope = _trace.collect()
            tracer = scope.__enter__()
        try:
            for task, res in inner:
                rec.rows += len(res.row_ids)
                rec.result_bytes += executor.table_nbytes(res.table)
                yield task, res
        except BaseException as e:
            if not isinstance(e, GeneratorExit):
                rec.outcome = "error"
                rec.error = f"{type(e).__name__}: {e}"
            raise
        finally:
            if scope is not None:
                scope.__exit__(None, None, None)
            rec.wall_seconds = time.perf_counter() - t0
            rec.io = dataclasses.asdict(self._source.stats.delta(before))
            rec.degraded = bool(rec.io.get("degraded_rows"))
            if tracer is not None:
                rec.stages = _querylog.stage_dict(tracer.aggregate())
                rec.dropped_spans = tracer.dropped
                if (_querylog.LOG.slow_seconds is not None
                        and rec.wall_seconds >= _querylog.LOG.slow_seconds):
                    rec.spans = [_trace.span_to_dict(s, wall=True)
                                 for s in tracer.spans]
            _querylog.LOG.append(rec)

    def _execute_impl(self, output_columns: Optional[Sequence[str]] = None,
                      parallelism: int = 1, io_depth: int = 1
                      ) -> Iterator[tuple[ScanTask, executor.GroupResult]]:
        """Run the plan; ``output_columns`` overrides materialization for
        data-free terminals (row_ids/count) without spawning a new instance
        (caches and the pruned-bytes credit stay shared). ``parallelism > 1``
        decodes independent (shard, group) tasks on a bounded thread pool;
        ``io_depth > 1`` prefetches upcoming tasks' coalesced byte ranges on
        the I/O scheduler so preads overlap decode (``io_depth=1`` is the
        serial per-group read path). Results stream in task order either
        way, so the output is identical to a serial run."""
        opt = self.plan()
        phys = self.physical_plan()
        self._credit(phys)
        p = opt.logical
        cols = opt.output_columns if output_columns is None \
            else tuple(output_columns)
        filtered = p.predicate is not None or p.row_ids is not None

        if io_depth < 1:
            raise ValueError(f"io_depth must be >= 1, got {io_depth}")
        emitted, limit = 0, p.limit
        if limit is not None and limit <= 0:
            return
        sched = None
        prefetch_cols = opt.prefetch_columns(cols)
        if io_depth > 1 and len(phys.tasks) > 1 and prefetch_cols:
            from .io import IOScheduler
            sched = IOScheduler(self._source, phys.tasks,
                                columns=prefetch_cols, io_depth=io_depth)

        def run(item) -> Optional[executor.GroupResult]:
            i, task = item
            with _trace.span("exec.task", cat="exec",
                             shard=task.shard, group=task.group):
                reader = sched.reader_for(i) if sched is not None \
                    else self._source.reader(task.shard)
                return executor.execute_group(
                    reader, task.group,
                    columns=cols, predicate=p.predicate,
                    rows=task.rows, drop_deleted=p.drop_deleted,
                    dequant=p.dequantize, use_kernel=p.use_kernel,
                    pages=task.pages)

        for (_, task), res in executor.run_tasks(
                list(enumerate(phys.tasks)), run, parallelism, io=sched):
            if res is None or (filtered and not len(res.row_ids)):
                continue
            if limit is not None and emitted + len(res.row_ids) > limit:
                res = executor.truncate_result(res, limit - emitted)
            emitted += len(res.row_ids)
            yield task, res
            if limit is not None and emitted >= limit:
                break

    def _page_sel(self, shard: int, group: int) -> Optional[tuple]:
        """Surviving page ordinals the lowered plan picked for (shard,
        group), so per-group streaming (``read_group``) prunes pages exactly
        like batch execution. None = read every page."""
        if self._task_pages is None:
            self._task_pages = {(t.shard, t.group): t.pages
                                for t in self.physical_plan().tasks}
        return self._task_pages.get((shard, group))

    def read_group(self, group: int, shard: int = 0, *,
                   reader=None) -> Optional[dict]:
        """Execute the plan over one row group (loader-style streaming).
        Returns the table dict, or None when no row survives. Honors the
        plan's predicate, ``with_rows`` pinning, and page-granular pruning;
        ``head`` limits don't apply (per-group streaming has no cross-group
        cursor). ``reader`` overrides the shard reader — the training
        loader passes a ``PrefetchReader`` staged by its I/O scheduler."""
        from .plan import locate_rows
        opt = self.plan()
        p = opt.logical
        rows = None
        if p.row_ids is not None:
            lo, hi = self._source.row_offset(shard), \
                self._source.row_offset(shard + 1)
            ids = p.row_ids[(p.row_ids >= lo) & (p.row_ids < hi)]
            rows = locate_rows(self._source.footer(shard),
                               ids - lo).get(group) if len(ids) else None
            if rows is None:
                return None
        res = executor.execute_group(
            self._source.reader(shard) if reader is None else reader,
            group, columns=opt.output_columns,
            predicate=p.predicate, rows=rows, drop_deleted=p.drop_deleted,
            dequant=p.dequantize, use_kernel=p.use_kernel,
            pages=self._page_sel(shard, group))
        return None if res is None else res.table

    # -- terminals --------------------------------------------------------------
    def scan_batches(self, *, parallelism: int = 1,
                     io_depth: int = 1) -> Iterator[DatasetBatch]:
        """Stream per-group results *with* their global row ids — the
        single-pass terminal when a caller needs both the data and the row
        identity (one scan, one pruned-bytes credit). ``parallelism > 1``
        decodes groups on a thread pool; ``io_depth > 1`` overlaps upcoming
        groups' preads with decode; the stream order is unchanged."""
        bounds: dict[int, np.ndarray] = {}
        for task, res in self._execute(parallelism=parallelism,
                                       io_depth=io_depth):
            if task.shard not in bounds:
                bounds[task.shard] = \
                    _group_bounds(self._source.footer(task.shard))
            offset = self._source.row_offset(task.shard) + \
                bounds[task.shard][task.group]
            yield DatasetBatch(shard=task.shard, group=task.group,
                               row_ids=offset + res.row_ids, table=res.table)

    def to_batches(self, batch_size: Optional[int] = None, *,
                   parallelism: int = 1, io_depth: int = 1) -> Iterator[dict]:
        """Stream result tables. ``batch_size=None`` yields one table per
        surviving row group (natural batches); an integer re-slices the
        stream into tables of exactly ``batch_size`` rows (last may be
        short)."""
        if batch_size is None:
            for _, res in self._execute(parallelism=parallelism,
                                        io_depth=io_depth):
                yield res.table
            return
        if batch_size <= 0:
            raise ValueError(f"batch_size must be positive, got {batch_size}")
        cols = self.plan().output_columns
        buf: list[dict] = []
        buffered = 0
        for _, res in self._execute(parallelism=parallelism,
                                    io_depth=io_depth):
            buf.append(res.table)
            buffered += len(res.row_ids)
            while buffered >= batch_size:
                merged = _concat_tables(buf, cols)
                yield {k: v[:batch_size] for k, v in merged.items()}
                rest = {k: v[batch_size:] for k, v in merged.items()}
                buf, buffered = [rest], buffered - batch_size
        if buffered:
            yield _concat_tables(buf, cols)

    def to_table(self, *, parallelism: int = 1, io_depth: int = 1) -> dict:
        """Materialize the whole result as one column dict."""
        cols = self.plan().output_columns
        return _concat_tables(
            [res.table for _, res in self._execute(parallelism=parallelism,
                                                   io_depth=io_depth)],
            cols, empty=self._empty_column)

    def row_ids(self, *, parallelism: int = 1,
                io_depth: int = 1) -> np.ndarray:
        """Global row ids (raw row space) of every surviving row. Reads only
        the predicate columns (use ``scan_batches`` for ids + data in one
        pass)."""
        parts, bounds = [], {}
        for task, res in self._execute(output_columns=(),
                                       parallelism=parallelism,
                                       io_depth=io_depth):
            if task.shard not in bounds:
                bounds[task.shard] = \
                    _group_bounds(self._source.footer(task.shard))
            parts.append(self._source.row_offset(task.shard)
                         + bounds[task.shard][task.group] + res.row_ids)
        return np.concatenate(parts).astype(np.int64) if parts \
            else np.zeros(0, np.int64)

    def count_rows(self, *, parallelism: int = 1, io_depth: int = 1) -> int:
        """Number of surviving rows. Without a predicate or pinned rows this
        is answered from footers alone — zero data preads."""
        p = self._plan
        self.plan()                    # validate even on the metadata path
        if p.predicate is None and p.row_ids is None:
            total = 0
            for s in range(self._source.n_shards):
                fv = self._source.footer(s)
                groups = p.groups if p.groups is not None \
                    else range(fv.n_groups)
                for g in groups:
                    total += executor.visible_row_count(fv, g) \
                        if p.drop_deleted else executor.raw_row_count(fv, g)
            return total if p.limit is None else min(total, p.limit)
        return sum(len(res.row_ids)
                   for _, res in self._execute(output_columns=(),
                                               parallelism=parallelism,
                                               io_depth=io_depth))

    def profile(self, path: Optional[str] = None, *,
                parallelism: int = 1, io_depth: int = 1):
        """Execute the plan under a scoped tracer and return the collected
        ``obs.export.Profile`` (spans + Chrome ``trace_event`` rendering).
        ``path`` additionally writes the trace JSON — open it in Perfetto
        (ui.perfetto.dev) or chrome://tracing. Results are discarded; use
        ``BULLION_TRACE=path`` to trace a real workload instead."""
        from ..obs.export import Profile
        with _trace.collect() as tracer:
            for _ in self._execute(parallelism=parallelism,
                                   io_depth=io_depth):
                pass
        prof = Profile(tracer)
        if path is not None:
            prof.write(path)
        return prof

    # -- write path (materialization sink) ---------------------------------------
    def write_to(self, out_dir: str, *, shard_rows: Optional[int] = None,
                 rows_per_group: Optional[int] = None,
                 page_rows: Optional[int] = None, sort_by=None,
                 compliance: Optional[int] = None, parallelism: int = 1,
                 io_depth: int = 1, collect_stats: bool = True,
                 use_advisor: bool = True):
        """Materialize this plan into a fresh sharded dataset (current
        format: v2 page-indexed shards) under ``out_dir`` (the read/write
        loop's write half — see ``repro.dataset.sink``).

        The surviving rows of the plan — filters, projections, ``head``
        limits, and dequantization all compose — are re-encoded into
        ``part-NNNNN.bln`` shards: deletion-vector rows are physically
        purged (``verify_deleted`` reports zero raw occurrences), fresh
        zone maps are collected, and cascade encoding selection re-runs per
        chunk seeded by the chunk statistics. ``shard_rows`` rotates output
        shards every N rows; ``sort_by`` re-clusters by a column name (stable
        ascending) or any ``SortUDF`` (e.g. ``quality_sort``) so zone maps on
        the sort column become selective; ``page_rows`` sets the output page
        budget (default: the input's recorded budget), with each page
        re-encoded from its own statistics; ``parallelism`` decodes input
        groups on a thread pool with deterministic output, and
        ``io_depth > 1`` pipelines the read side's preads against decode
        (the write half is unaffected). Returns a ``WriteResult``."""
        from .sink import write_dataset
        return write_dataset(self, out_dir, shard_rows=shard_rows,
                             rows_per_group=rows_per_group,
                             page_rows=page_rows, sort_by=sort_by,
                             compliance=compliance, parallelism=parallelism,
                             io_depth=io_depth, collect_stats=collect_stats,
                             use_advisor=use_advisor)

    def delete_where(self, predicate: Predicate, level=None):
        """Multi-shard compliance delete: erase every row matching
        ``predicate`` across all shards (global row ids are translated to
        each shard's local raw row space, then ``core.deletion.delete_rows``
        runs per affected shard). Returns the aggregated ``DeleteStats``.

        When rows were deleted the shard files were rewritten underneath
        this dataset, so the instance is closed and marked stale — reopen
        with ``dataset()`` to observe the deletion."""
        import dataclasses

        from ..core.deletion import Compliance, DeleteStats, delete_rows

        level = Compliance.LEVEL2 if level is None else level
        ids = self.where(predicate).drop_deleted(False).row_ids()
        total = DeleteStats()
        self.close()                  # close() is recoverable; reopen on use
        if not len(ids):
            return total
        located: list[tuple[str, np.ndarray]] = []
        for s in range(self._source.n_shards):
            lo, hi = self._source.row_offset(s), self._source.row_offset(s + 1)
            local = ids[(ids >= lo) & (ids < hi)] - lo
            if len(local):
                located.append((self._source.paths[s], local))
        self._source.invalidate("delete_where rewrote shard files")
        for path, local in located:
            st = delete_rows(path, local, level)
            for f in dataclasses.fields(DeleteStats):
                setattr(total, f.name,
                        getattr(total, f.name) + getattr(st, f.name))
        return total

    def _empty_column(self, name: str):
        """Typed empty result for a column no batch produced: scalar columns
        keep their (logical or storage) dtype, list/string columns are []."""
        from ..core.encodings.base import code_dtype
        fv = self._source.footer(0)
        c = fv.column_index(name)
        kind = int(fv.arr(Sec.COL_KIND, np.uint8)[c])
        if kind not in (int(ColKind.SCALAR), int(ColKind.MEDIA_REF)):
            return []
        sec = Sec.COL_LOGICAL if (self._plan.dequantize
                                  and kind == int(ColKind.SCALAR)) \
            else Sec.COL_DTYPE
        return np.zeros(0, code_dtype(int(fv.arr(sec, np.uint8)[c])))

    # -- lifecycle --------------------------------------------------------------
    def close(self) -> None:
        """Close shard readers this dataset owns (idempotent)."""
        self._source.close()

    def __enter__(self) -> "Dataset":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __repr__(self) -> str:
        p = self._plan
        bits = [f"shards={self.n_shards}", f"rows={self.num_rows}"]
        if p.columns is not None:
            bits.append(f"select={list(p.columns)}")
        if p.predicate is not None:
            bits.append(f"where={p.predicate!r}")
        if p.limit is not None:
            bits.append(f"head={p.limit}")
        return f"Dataset({', '.join(bits)})"


def _concat_tables(tables: list[dict], columns: Sequence[str],
                   empty=None) -> dict:
    out: dict = {}
    for name in columns:
        parts = [t[name] for t in tables if name in t]
        if not parts:
            out[name] = empty(name) if empty is not None else []
        elif isinstance(parts[0], np.ndarray):
            out[name] = parts[0] if len(parts) == 1 else np.concatenate(parts)
        else:
            out[name] = [r for p in parts for r in p]
    return out
