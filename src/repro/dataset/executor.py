"""Physical-plan execution: the one read pipeline.

Every read path in the system — ``Dataset`` terminals, the legacy
``BullionReader.project``/``find_rows`` shims, ``Scanner.scan``, the
training loader, quality-filtered reads, and predicate deletes — bottoms
out in ``execute_group``, which orders the stages exactly once:

    prune (done at plan time) -> pread (coalesced) -> decode ->
    deletion-mask -> dequantize -> filter -> gather

``decode_group`` is the pread+decode+mask+dequantize core (moved here from
``BullionReader.project``); ``execute_group`` layers predicate evaluation
(NumPy or the Pallas batch filter kernel) and raw-row-id selection on top.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

from ..core import integrity as _integrity
from ..core import pages as pages_mod
from ..core.footer import ColKind, PageType, Sec, ShardCorruptError
from ..core.quantization import QuantMode, dequantize
from ..obs import metrics as _metrics
from ..obs import trace as _trace
from ..scan.predicate import Predicate, conjunctive_ranges, evaluate

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from ..core.reader import BullionReader


@dataclass
class GroupResult:
    """Matching rows of one row group (row ids are group-local, raw space)."""

    row_ids: np.ndarray
    table: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# decode core: pread -> decode -> deletion-mask -> dequantize
# ---------------------------------------------------------------------------


def table_nbytes(table: dict) -> int:
    """Payload bytes of a result table: array ``nbytes`` plus per-row bytes
    for list/string columns. The query log's byte accounting — what a
    terminal handed back, not what the wire encoding costs."""
    total = 0
    for col in table.values():
        nbytes = getattr(col, "nbytes", None)
        if nbytes is not None:
            total += int(nbytes)
        else:
            for row in col:
                total += int(getattr(row, "nbytes", None) or len(row))
    return total


def _chunk_page_ids(fv, group: int, col: int,
                    pages: Optional[Sequence[int]]) -> list[int]:
    """Physical page indices of one chunk, restricted to the page-ordinal
    selection a plan produced (None = every page)."""
    s, e = fv.chunk_pages(group, col)
    return list(range(s, e)) if pages is None else [s + int(k) for k in pages]


def _pad_raw(decoded, dv: Optional[np.ndarray], page_rows: int):
    """Re-align one page's decode to its raw row space (drop_deleted=False):
    compact-deleted pages (§2.1 RLE rule) physically removed rows, so erased
    positions are re-padded with 0 — the same value in-place masking writes —
    to keep raw row ids stable."""
    if not isinstance(decoded, np.ndarray):
        return decoded
    if len(decoded) >= page_rows:
        return decoded[:page_rows]
    out = np.zeros(page_rows, decoded.dtype)
    out[np.flatnonzero(~dv)] = decoded
    return out


# page-type flag -> histogram name, cached (per-family decode-time metric)
_FAMILY_HIST: dict[int, str] = {}


def _decode_page_timed(flag: int, blob: bytes):
    """Traced-mode decode: per-page wall time lands in the per-encoding-
    family histogram (``bullion.decode.page_seconds.<family>``)."""
    t0 = time.perf_counter()
    decoded = pages_mod.decode_page(flag, blob)
    dt = time.perf_counter() - t0
    name = _FAMILY_HIST.get(flag)
    if name is None:
        try:
            fam = PageType(flag).name.lower()
        except ValueError:
            fam = f"type{flag}"
        name = _FAMILY_HIST[flag] = f"bullion.decode.page_seconds.{fam}"
    _metrics.histogram(name).observe(dt)
    return decoded


def _mask_fill(fv, col: int, rows: int):
    """Shape-stable zero fill for a quarantined page under the ``mask``
    corruption policy: scalar/media_ref pages decode to zeros of the
    storage dtype, list pages to empty arrays, string pages to empty
    strings — same row count and types as a healthy decode."""
    from ..core.encodings.base import code_dtype
    kind = int(fv.arr(Sec.COL_KIND, np.uint8)[col])
    dt = code_dtype(int(fv.arr(Sec.COL_DTYPE, np.uint8)[col]))
    if kind == int(ColKind.LIST):
        return [np.zeros(0, dt)] * rows
    if kind == int(ColKind.STRING):
        return [b""] * rows
    return np.zeros(rows, dt)


def decode_group(reader: "BullionReader", names: Sequence[str], group: int, *,
                 drop_deleted: bool = True, dequant: bool = True,
                 pages: Optional[Sequence[int]] = None,
                 align_raw: bool = False,
                 masked_out: Optional[set] = None) -> dict:
    """Decode one row group's columns via coalesced preads.

    ``pages`` restricts the read to a plan's surviving page ordinals (the
    same ordinals for every column — pages of one ordinal cover one row
    range group-wide). ``align_raw`` pads compact-deleted pages back to the
    raw row space (only meaningful with ``drop_deleted=False``); the default
    keeps physical page content, which ``verify_deleted`` audits.

    Each stage is a distinct span (``decode.pread`` / ``decode.decode`` /
    ``decode.mask`` / ``decode.dequantize``) so traces and
    ``explain(analyze=True)`` attribute time per stage; with tracing
    disabled the spans are shared no-ops and the stage order is the only
    (behavior-identical) difference from an uninstrumented decode.
    """
    fv = reader.footer
    cols = [fv.column_index(n) for n in names]
    kinds = fv.arr(Sec.COL_KIND, np.uint8)
    flags = fv.arr(Sec.PAGE_FLAGS, np.uint8)
    page_rows = fv.arr(Sec.PAGE_ROWS, np.uint32)
    wanted: list[int] = []
    for c in cols:
        wanted.extend(_chunk_page_ids(fv, group, c, pages))
    sp = _trace.span("decode.pread", cat="io", group=group, pages=len(wanted))
    with sp:
        raw = reader._read_pages(wanted)
        if sp.enabled:
            sp.set(bytes=sum(len(b) for b in raw.values()))
    traced = _trace.enabled()
    out: dict = {}

    def _dec(c: int, p: int):
        blob = raw.get(p)
        if blob is None:
            # the verification gate removed a quarantined page (corruption
            # policy ``mask``): serve shape-stable zeros instead of failing
            # the whole group. Anything else missing is a real bug.
            if not _integrity.QUARANTINE.contains(reader.path, fv, p):
                raise KeyError(p)
            if masked_out is not None:
                masked_out.add(p)
            return _mask_fill(fv, c, int(page_rows[p]))
        if traced:
            return _decode_page_timed(int(flags[p]) & 0x7F, blob)
        return pages_mod.decode_page(int(flags[p]) & 0x7F, blob)

    for name, c in zip(names, cols):
        pids = _chunk_page_ids(fv, group, c, pages)
        with _trace.span("decode.decode", cat="decode",
                         column=name, pages=len(pids)):
            parts = [_dec(c, p) for p in pids]
        if drop_deleted or align_raw:
            with _trace.span("decode.mask", cat="decode", column=name):
                for i, p in enumerate(pids):
                    if drop_deleted:
                        parts[i] = pages_mod.apply_dv(
                            parts[i], fv.deletion_vector(p),
                            int(page_rows[p]))
                    else:
                        parts[i] = _pad_raw(parts[i], fv.deletion_vector(p),
                                            int(page_rows[p]))
        val = parts[0] if len(parts) == 1 else _concat(parts)
        if dequant and kinds[c] == int(ColKind.SCALAR):
            spec = reader.quant_spec(c)
            if spec.mode != QuantMode.NONE:
                with _trace.span("decode.dequantize", cat="decode",
                                 column=name):
                    val = dequantize(np.asarray(val), spec)
        out[name] = val
    return out


# ---------------------------------------------------------------------------
# row-space helpers (footer-only: planning never needs a file handle)
# ---------------------------------------------------------------------------


def raw_row_count(fv, group: int) -> int:
    return int(fv.arr(Sec.ROWS_PER_GROUP, np.uint32)[group])


def group_keep(fv, group: int, col: int = 0,
               pages: Optional[Sequence[int]] = None) -> Optional[np.ndarray]:
    """Raw-row keep mask from deletion vectors (None = nothing deleted),
    over the selected pages' rows when ``pages`` restricts the chunk."""
    page_rows = fv.arr(Sec.PAGE_ROWS, np.uint32)
    parts, any_dv = [], False
    for p in _chunk_page_ids(fv, group, col, pages):
        dv = fv.deletion_vector(p)
        if dv is None:
            parts.append(np.ones(int(page_rows[p]), bool))
        else:
            parts.append(~dv)
            any_dv = True
    return np.concatenate(parts) if any_dv else None


def visible_row_count(fv, group: int) -> int:
    keep = group_keep(fv, group)
    return raw_row_count(fv, group) if keep is None else int(keep.sum())


def selected_raw_rows(fv, group: int,
                      pages: Optional[Sequence[int]]) -> Optional[np.ndarray]:
    """Group-local raw row ids covered by a page-ordinal selection (None =
    the whole group). Pages partition a chunk's rows in order, so ordinal k
    covers rows [starts[k], starts[k+1]) — identical for every column."""
    if pages is None:
        return None
    rows = fv.chunk_page_rows(group, 0).astype(np.int64)
    starts = np.concatenate([[0], np.cumsum(rows)])
    if not len(pages):
        return np.zeros(0, np.int64)
    return np.concatenate([np.arange(starts[k], starts[k + 1])
                           for k in pages])


# ---------------------------------------------------------------------------
# predicate evaluation (NumPy or Pallas batch filter kernel)
# ---------------------------------------------------------------------------


def _f32_shrink(lo: float, hi: float) -> tuple[np.float32, np.float32]:
    """Tightest float32 interval inside the float64 one.

    Exact for float32 column data: a float32 x satisfies lo <= x <= hi iff
    it satisfies the shrunk float32 bounds.
    """
    lo32, hi32 = np.float32(lo), np.float32(hi)
    if np.float64(lo32) < lo:
        lo32 = np.nextafter(lo32, np.float32(np.inf), dtype=np.float32)
    if np.float64(hi32) > hi:
        hi32 = np.nextafter(hi32, np.float32(-np.inf), dtype=np.float32)
    return lo32, hi32


def eval_mask(pred: Predicate, tbl: dict,
              use_kernel: Optional[bool]) -> np.ndarray:
    """Predicate -> row mask; Pallas kernel when the predicate compiles
    to conjunctive ranges over float32 columns (exact there), NumPy
    otherwise."""
    ranges = conjunctive_ranges(pred)
    kernel_ok = ranges is not None and all(
        isinstance(tbl[c], np.ndarray) and tbl[c].dtype == np.float32
        for c in ranges)
    if use_kernel and not kernel_ok:
        raise ValueError(
            "kernel filter path requires a conjunctive range predicate "
            "over float32 columns")
    if use_kernel is None:
        use_kernel = kernel_ok
    if not use_kernel:
        return evaluate(pred, tbl)
    from ..kernels.filter import range_mask
    names = list(ranges)
    bounds = [_f32_shrink(*ranges[c]) for c in names]
    cols = np.stack([np.asarray(tbl[c], np.float32) for c in names])
    return range_mask(cols,
                      np.asarray([b[0] for b in bounds], np.float32),
                      np.asarray([b[1] for b in bounds], np.float32))


# ---------------------------------------------------------------------------
# the one per-group pipeline
# ---------------------------------------------------------------------------


def _page_ordinal(fv, group: int, page: int) -> int:
    """Page ordinal (position within its chunk) of a physical page. Every
    column of a group splits at the same row boundaries, so one ordinal
    names the same row range in every chunk."""
    for c in range(fv.n_cols):
        s, e = fv.chunk_pages(group, c)
        if s <= page < e:
            return page - s
    raise ValueError(f"page {page} not in group {group}")


def execute_group(reader: "BullionReader", group: int, *,
                  columns: Sequence[str] = (),
                  predicate: Optional[Predicate] = None,
                  rows: Optional[np.ndarray] = None,
                  drop_deleted: bool = True, dequant: bool = True,
                  use_kernel: Optional[bool] = None,
                  pages: Optional[Sequence[int]] = None
                  ) -> Optional[GroupResult]:
    """Decode + filter one row group with graceful degradation.

    The inner pipeline (``_execute_group_once``) raises
    ``ShardCorruptError`` when decode-time verification quarantines a page.
    Under the ``skip`` corruption policy that page's *ordinal* is excluded
    (dropping the same row range from every column — the result stays
    rectangular) and the group retries; dropped rows are charged exactly
    once to ``IOStats.degraded_rows``. Under ``mask`` the verification gate
    already zero-filled the page; the masked rows are charged here. Under
    ``raise`` (the default) the error propagates with (shard, group, page).
    """
    fv = reader.footer
    policy = _integrity.corruption_policy()
    masked_out: Optional[set] = set() \
        if policy == _integrity.ON_CORRUPT_MASK else None
    if policy != _integrity.ON_CORRUPT_SKIP:
        res = _execute_group_once(
            reader, group, columns=columns, predicate=predicate, rows=rows,
            drop_deleted=drop_deleted, dequant=dequant, use_kernel=use_kernel,
            pages=pages, masked_out=masked_out)
        if masked_out:
            page_rows = fv.arr(Sec.PAGE_ROWS, np.uint32)
            _charge_degraded(
                reader, sum(int(page_rows[p]) for p in masked_out))
        return res

    # skip mode: pre-exclude ordinals already quarantined for this exact
    # footer object, then retry as verification quarantines new ones
    n_ord = len(fv.chunk_page_rows(group, 0))
    excluded: set[int] = set()
    for p, (g, _reason) in _integrity.QUARANTINE.lookup(
            reader.path, fv).items():
        if g == group:
            excluded.add(_page_ordinal(fv, group, p))
    selected = set(range(n_ord)) if pages is None \
        else {int(k) for k in pages}
    for _ in range(n_ord + 1):
        if excluded:
            eff = sorted(selected - excluded)
        else:
            eff = pages
        try:
            res = _execute_group_once(
                reader, group, columns=columns, predicate=predicate,
                rows=rows, drop_deleted=drop_deleted, dequant=dequant,
                use_kernel=use_kernel, pages=eff)
        except ShardCorruptError as e:
            if e.page is None or e.path != reader.path:
                raise
            k = _page_ordinal(fv, group, e.page)
            if k in excluded:       # no progress: don't loop forever
                raise
            excluded.add(k)
            continue
        dropped = excluded & selected
        if dropped:
            rows_per = fv.chunk_page_rows(group, 0)
            _charge_degraded(
                reader, sum(int(rows_per[k]) for k in dropped))
        return res
    raise AssertionError("unreachable: every ordinal excluded")  # pragma: no cover


def _charge_degraded(reader: "BullionReader", n_rows: int) -> None:
    if not n_rows:
        return
    with reader._stats_lock:
        reader.stats.degraded_rows += n_rows
    _metrics.counter("bullion.integrity.degraded_rows").inc(n_rows)


def _execute_group_once(reader: "BullionReader", group: int, *,
                        columns: Sequence[str] = (),
                        predicate: Optional[Predicate] = None,
                        rows: Optional[np.ndarray] = None,
                        drop_deleted: bool = True, dequant: bool = True,
                        use_kernel: Optional[bool] = None,
                        pages: Optional[Sequence[int]] = None,
                        masked_out: Optional[set] = None
                        ) -> Optional[GroupResult]:
    """Decode + filter one row group. Returns None when a predicate or a
    row-id selection leaves no rows (payload pages are then never read).

    ``pages`` is a plan's surviving page-ordinal selection: only those
    pages are pread and decoded for every column, and reported row ids stay
    in the group's raw row space (each ordinal maps to its row range).

    Predicate columns are always evaluated in the dequantized (logical)
    domain — the domain the zone maps describe; ``dequant`` governs only the
    materialized payload. When the caller wants raw values of a predicate
    column, it is re-read in the payload pass instead of served from the
    evaluation copy.
    """
    fv = reader.footer
    if pages is not None and not len(pages):
        return None
    sel_raw = selected_raw_rows(fv, group, pages)
    keep = group_keep(fv, group, pages=pages) if drop_deleted else None
    if keep is not None:
        space_raw = sel_raw[keep] if sel_raw is not None \
            else np.flatnonzero(keep)
    else:
        space_raw = sel_raw
    n_space = len(space_raw) if space_raw is not None \
        else raw_row_count(fv, group)

    pred_cols = sorted(predicate.columns()) if predicate is not None else []
    reuse = set(pred_cols) if dequant else set()
    tbl: dict = {}
    mask: Optional[np.ndarray] = None
    if predicate is not None:
        # compact-deleted pages shrink their decode; align_raw re-pads each
        # page to its raw row space so mask indices line up with space_raw
        tbl = decode_group(reader, pred_cols, group,
                           drop_deleted=drop_deleted, dequant=True,
                           pages=pages, align_raw=not drop_deleted,
                           masked_out=masked_out)
        sp = _trace.span("exec.filter", cat="exec", group=group)
        with sp:
            mask = eval_mask(predicate, tbl, use_kernel)
            if sp.enabled:
                sp.set(rows_in=int(len(mask)), rows_out=int(mask.sum()))
    if rows is not None:
        rmask = np.zeros(n_space, bool)
        if space_raw is None:
            rmask[rows[rows < n_space]] = True
        else:
            rmask[np.isin(space_raw, rows)] = True
        mask = rmask if mask is None else mask & rmask

    if mask is None:
        local = np.arange(n_space)
        full = True
    else:
        if not mask.any():
            return None
        local = np.flatnonzero(mask)
        full = len(local) == n_space
    raw_local = local if space_raw is None else space_raw[local]

    out: dict = {}
    for name in columns:
        if name in reuse and name in tbl:
            out[name] = tbl[name] if full else _take(tbl[name], local)
    rest = [c for c in columns if c not in out]
    if rest:
        # drop_deleted=False means *raw row space*, always: compact-deleted
        # pages decode short, so every page is re-aligned (erased rows
        # read 0) to keep row_ids and all columns the same length.
        ptbl = decode_group(reader, rest, group,
                            drop_deleted=drop_deleted, dequant=dequant,
                            pages=pages, align_raw=not drop_deleted,
                            masked_out=masked_out)
        for name in rest:
            out[name] = ptbl[name] if full else _take(ptbl[name], local)
    return GroupResult(row_ids=raw_local, table=out)


# ---------------------------------------------------------------------------
# parallel task execution (bounded thread pool, deterministic order)
# ---------------------------------------------------------------------------


def run_tasks(tasks, fn, parallelism: int = 1, io=None):
    """Execute ``fn(task)`` for every task, yielding ``(task, result)``
    strictly in task order.

    ``parallelism <= 1`` is the plain serial loop (zero overhead, the
    default). Above that, up to ``parallelism`` tasks run concurrently on a
    thread pool with a bounded in-flight window (results are buffered at
    most ``2 * parallelism`` deep), so a consumer that stops early — a
    ``head`` limit, an aborted iteration — never waits on more than the
    window. Per-(shard, row-group) tasks are independent and readers use
    positional I/O on one shared fd per shard, so ordering the *yields* is
    all determinism needs: parallel and serial runs produce identical
    streams.

    ``io`` is an optional pipelined I/O scheduler (``dataset.io
    .IOScheduler``) whose lifecycle this loop owns: started before the first
    task runs, closed when iteration finishes *or* is abandoned early, so
    its prefetch thread never outlives the scan. ``fn`` decides whether to
    pull its reader from the scheduler.
    """
    tasks = list(tasks)
    if io is not None:
        io.start()
    try:
        if parallelism <= 1 or len(tasks) <= 1:
            for t in tasks:
                yield t, fn(t)
            return
        from collections import deque
        from concurrent.futures import ThreadPoolExecutor

        ex = ThreadPoolExecutor(max_workers=parallelism,
                                thread_name_prefix="bullion-scan")
        pending: deque = deque()
        it = iter(tasks)
        try:
            def fill() -> None:
                while len(pending) < 2 * parallelism:
                    t = next(it, None)
                    if t is None:
                        return
                    pending.append((t, ex.submit(fn, t)))

            fill()
            while pending:
                t, fut = pending.popleft()
                yield t, fut.result()
                fill()
        finally:
            for _, fut in pending:
                fut.cancel()
            ex.shutdown(wait=True)
    finally:
        if io is not None:
            io.close()


def truncate_result(res: GroupResult, n: int) -> GroupResult:
    """Keep the first n rows of a group result (head limit)."""
    return GroupResult(row_ids=res.row_ids[:n],
                       table={k: v[:n] for k, v in res.table.items()})


def _take(values, idx: np.ndarray):
    if isinstance(values, np.ndarray):
        return values[idx]
    return [values[i] for i in idx]


def _concat(parts):
    if isinstance(parts[0], np.ndarray):
        return np.concatenate(parts)
    return [r for p in parts for r in p]
