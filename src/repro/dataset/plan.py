"""Logical and physical scan plans.

A chained ``Dataset`` records *what* the caller wants in a ``LogicalPlan``
(pure data, no I/O). ``optimize`` normalizes it — conjunct splitting,
projection narrowing to predicate+output columns, validation against the
dataset schema. ``lower`` turns the optimized plan into a ``PhysicalPlan``:
one ``ScanTask`` per (shard, row group) that could contain a matching row —
carrying the group's surviving page ordinals when page-granular zone maps
pruned inside it — with every avoided group *and page* accounted as pruned
bytes (zone maps, row-id location, or a ``head`` limit each prove reads
unnecessary before any data pread).
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

from ..obs import trace as _trace
from ..scan.predicate import And, Predicate

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .source import DataSource


@dataclass(frozen=True)
class LogicalPlan:
    """Declarative description of one scan. Immutable; chaining replaces."""

    columns: Optional[tuple[str, ...]] = None   # None = all columns
    predicate: Optional[Predicate] = None
    row_ids: Optional[np.ndarray] = None        # global ids, raw row space
    groups: Optional[tuple[int, ...]] = None    # legacy single-shard restriction
    dequantize: bool = True
    drop_deleted: bool = True
    limit: Optional[int] = None                 # head(n)
    use_kernel: Optional[bool] = None           # Pallas filter: None = auto

    def replace(self, **kw) -> "LogicalPlan":
        return replace(self, **kw)

    def fingerprint(self) -> str:
        """Stable content hash for prepared-plan caching.

        Two plans that request the same scan get the same fingerprint even
        when built differently: predicate conjunct/disjunct order is
        normalized through ``canonical_repr``, so ``.where(a).where(b)``
        and ``.where(b).where(a)`` collide (on purpose). Pinned row ids
        hash by content. The hash is *not* persisted anywhere, so the
        scheme may change freely between versions."""
        import hashlib

        from ..scan.predicate import canonical_repr
        bits = [
            "cols=" + ("*" if self.columns is None
                       else ",".join(self.columns)),
            "pred=" + canonical_repr(self.predicate),
            "groups=" + ("-" if self.groups is None
                         else ",".join(map(str, self.groups))),
            f"dequant={self.dequantize}",
            f"drop_deleted={self.drop_deleted}",
            f"limit={self.limit}",
            f"kernel={self.use_kernel}",
            f"rows={self.row_ids is not None}",
        ]
        h = hashlib.sha256("\n".join(bits).encode())
        if self.row_ids is not None:
            h.update(np.ascontiguousarray(
                np.asarray(self.row_ids, np.int64)).tobytes())
        return h.hexdigest()


@dataclass(frozen=True)
class OptimizedPlan:
    """LogicalPlan after normalization, with derived read sets."""

    logical: LogicalPlan
    output_columns: tuple[str, ...]   # materialized in results, in order
    pred_columns: tuple[str, ...]     # referenced by the predicate
    read_columns: tuple[str, ...]     # projection narrowing: output ∪ predicate
    conjuncts: tuple[Predicate, ...]  # top-level AND split (empty = no pred)

    def prefetch_columns(self, output_columns: Optional[Sequence[str]] = None
                         ) -> tuple[str, ...]:
        """Columns whose pages the I/O scheduler may stage eagerly for every
        task. With a predicate, only the predicate columns are uncondi-
        tionally read — payload pages are fetched on demand so groups the
        filter empties still skip them (the serial path's second I/O win).
        Without one, every read column's pages are certain to be decoded."""
        if self.logical.predicate is not None:
            return self.pred_columns
        return self.output_columns if output_columns is None \
            else tuple(output_columns)


class ColumnNotFoundError(KeyError):
    """A plan references a column absent from the dataset schema. Raised at
    plan time (``optimize``), naming the column and the shard whose footer
    defined the schema — never as a decode-time ``KeyError``."""

    def __init__(self, missing, names, shard_path):
        self.missing = list(missing)
        self.shard_path = shard_path
        super().__init__(
            f"column(s) {self.missing} not in dataset schema "
            f"(checked shard {shard_path!r}; available: {list(names)})")

    def __str__(self) -> str:  # KeyError quotes its lone arg; keep prose
        return self.args[0]


@dataclass(frozen=True)
class ScanTask:
    """One unit of physical work: decode+filter one row group of one shard."""

    shard: int
    group: int
    rows: Optional[np.ndarray] = None  # raw-local row ids from with_rows
    # surviving page ordinals inside the group (page-granular zone-map
    # pruning); None = every page of each chunk
    pages: Optional[tuple[int, ...]] = None


@dataclass
class PhysicalPlan:
    tasks: list[ScanTask] = field(default_factory=list)
    groups_total: int = 0
    groups_pruned: int = 0            # zone-map + row-locate + limit pruning
    groups_pruned_sketch: int = 0     # of those, refuted by bloom sketches
    pages_total: int = 0
    pages_pruned: int = 0
    bytes_total: int = 0              # data bytes a naive full scan would read
    bytes_pruned: int = 0             # bytes the plan proved it never had to read

    @property
    def selectivity_bound(self) -> float:
        kept = self.groups_total - self.groups_pruned
        return kept / self.groups_total if self.groups_total else 1.0


def split_conjuncts(pred: Optional[Predicate]) -> tuple[Predicate, ...]:
    """Top-level AND split (the ``And`` constructor already flattens
    nested conjunctions, so one level of unpacking is complete)."""
    if pred is None:
        return ()
    if isinstance(pred, And):
        return tuple(pred.children)
    return (pred,)


def optimize(plan: LogicalPlan, source: "DataSource") -> OptimizedPlan:
    """Normalize and validate a logical plan against the dataset schema."""
    with _trace.span("plan.optimize", cat="plan"):
        return _optimize(plan, source)


def _optimize(plan: LogicalPlan, source: "DataSource") -> OptimizedPlan:
    names = source.column_names
    if plan.columns is None:
        output = tuple(names)
    else:
        output = tuple(dict.fromkeys(plan.columns))
        missing = [c for c in output if c not in source.column_set]
        if missing:
            raise ColumnNotFoundError(missing, names, source.schema_path)
    conjuncts = split_conjuncts(plan.predicate)
    pred_cols = tuple(sorted(plan.predicate.columns())) if plan.predicate \
        else ()
    missing = [c for c in pred_cols if c not in source.column_set]
    if missing:
        raise ColumnNotFoundError(missing, names, source.schema_path)
    if plan.limit is not None and plan.limit < 0:
        raise ValueError(f"head(n) needs n >= 0, got {plan.limit}")
    if plan.groups is not None and source.n_shards > 1:
        raise ValueError("groups= restriction is single-shard only; "
                         "use with_rows on multi-file datasets")
    # projection narrowing: the executor touches exactly these columns
    read = tuple(dict.fromkeys([*output, *pred_cols]))
    return OptimizedPlan(logical=plan, output_columns=output,
                         pred_columns=pred_cols, read_columns=read,
                         conjuncts=conjuncts)


def group_bounds(fv) -> np.ndarray:
    """Cumulative raw-row bounds per group: bounds[g] is group g's first
    global (shard-local) row id. The one copy of the row-space arithmetic
    every planner/executor shares."""
    from ..core.footer import Sec
    rpg = fv.arr(Sec.ROWS_PER_GROUP, np.uint32).astype(np.int64)
    return np.concatenate([[0], np.cumsum(rpg)])


def locate_rows(fv, local_rows: np.ndarray) -> dict[int, np.ndarray]:
    """Shard-local raw row ids -> {group: group-local rows} (footer-only)."""
    bounds = group_bounds(fv)
    local_rows = np.asarray(local_rows, np.int64)
    g = np.searchsorted(bounds, local_rows, side="right") - 1
    return {int(grp): local_rows[g == grp] - bounds[grp]
            for grp in np.unique(g)}


def lower(opt: OptimizedPlan, source: "DataSource") -> PhysicalPlan:
    """Lower to per-(shard, group) tasks.

    Per shard: restrict to located groups when ``with_rows`` pinned rows,
    intersect the predicate with the shard's zone maps (``plan_scan``),
    and — when no predicate gates the row count — cap a ``head`` limit to
    the shortest prefix of groups holding enough visible rows. Every group
    dropped at this stage is charged to ``bytes_pruned``. Lowering is
    footer-only: no shard file handle is opened until execution.
    """
    sp = _trace.span("plan.lower", cat="plan")
    with sp:
        phys = _lower(opt, source)
        if sp.enabled:
            sp.set(tasks=len(phys.tasks), shards=source.n_shards,
                   groups_pruned=phys.groups_pruned,
                   pages_pruned=phys.pages_pruned,
                   bytes_pruned=phys.bytes_pruned)
    return phys


def _lower(opt: OptimizedPlan, source: "DataSource") -> PhysicalPlan:
    from ..scan.scanner import plan_scan
    from .executor import group_keep, raw_row_count, visible_row_count

    plan = opt.logical
    phys = PhysicalPlan()
    remaining = plan.limit
    for s in range(source.n_shards):
        fv = source.footer(s)
        candidates = list(plan.groups) if plan.groups is not None \
            else list(range(fv.n_groups))
        located: Optional[dict[int, np.ndarray]] = None
        if plan.row_ids is not None:
            lo, hi = source.row_offset(s), source.row_offset(s + 1)
            ids = plan.row_ids[(plan.row_ids >= lo) & (plan.row_ids < hi)]
            located = locate_rows(fv, ids - lo) if len(ids) else {}
        scan_plan = plan_scan(fv, plan.predicate, columns=opt.read_columns,
                              groups=candidates)
        phys.groups_total += len(candidates)
        phys.pages_total += scan_plan.pages_total
        phys.bytes_total += scan_plan.bytes_total
        phys.groups_pruned += len(scan_plan.pruned_groups)
        phys.groups_pruned_sketch += scan_plan.groups_pruned_sketch
        phys.pages_pruned += scan_plan.pages_pruned
        phys.bytes_pruned += scan_plan.bytes_pruned
        groups = scan_plan.groups
        if located is not None:
            for g in groups:
                if g not in located:
                    phys.groups_pruned += 1
                    # charge only what page-granular pruning didn't already
                    pages_left, bytes_left = scan_plan.remaining_cost(g)
                    phys.pages_pruned += pages_left
                    phys.bytes_pruned += bytes_left
            groups = [g for g in groups if g in located]
        if remaining is not None and plan.predicate is None:
            # head(n) with no predicate: the row count per group is knowable
            # from metadata alone, so excess groups are provably unread.
            kept: list[int] = []
            for g in groups:
                if remaining <= 0:
                    phys.groups_pruned += 1
                    pages_left, bytes_left = scan_plan.remaining_cost(g)
                    phys.pages_pruned += pages_left
                    phys.bytes_pruned += bytes_left
                    continue
                kept.append(g)
                if located is not None:
                    if plan.drop_deleted:
                        # only pinned rows that survive deletion vectors
                        # count against the limit
                        keep = group_keep(fv, g)
                        remaining -= len(located[g]) if keep is None \
                            else int(keep[located[g]].sum())
                    else:
                        remaining -= len(located[g])
                elif plan.drop_deleted:
                    remaining -= visible_row_count(fv, g)
                else:
                    remaining -= raw_row_count(fv, g)
            groups = kept
        phys.tasks.extend(
            ScanTask(shard=s, group=g,
                     rows=located[g] if located is not None else None,
                     pages=scan_plan.group_page_sel.get(g))
            for g in groups)
    return phys
