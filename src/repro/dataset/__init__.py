"""Unified lazy ``Dataset`` API: logical/physical scan plans over Bullion data.

One plan-driven read path replaces five ad-hoc entry points. Chaining builds
a ``LogicalPlan``; the optimizer normalizes it (conjunct splitting,
projection narrowing to predicate+output columns, pushdown into the zone-map
``Scanner``) and lowers it to a ``PhysicalPlan`` of per-(shard, row-group)
tasks executed by the single pipeline in ``executor`` — the only code that
orders prune -> pread -> decode -> deletion-mask -> dequantize -> filter.
The same plan runs unchanged over a single file or a directory/glob of
schema-checked shards::

    from repro.dataset import dataset
    from repro.scan import C

    with dataset("shards/") as ds:          # file, dir, glob, or path list
        tbl = (ds.where(C("quality") >= 0.5)
                 .select(["tokens", "quality"])
                 .head(10_000)
                 .to_table())

Shards may also live in object storage: pass ``bullion://bucket/key`` URIs
(after ``repro.core.backend.configure_object_store()`` or with
``BULLION_OBJECT_STORE`` set) and the same plans execute over ranged GETs,
with ``to_table(io_depth=N)`` bounding concurrent in-flight ranges.

Legacy surface -> plan equivalent (the legacy calls survive as deprecated
shims that build exactly these one-file plans):

    =======================================================  =====================================================================
    legacy call                                              Dataset plan
    =======================================================  =====================================================================
    ``BullionReader.project(cols, predicate=p)``             ``Dataset.from_reader(r).select(cols).where(p).to_batches()``
    ``BullionReader.read_column(c)``                         ``Dataset.from_reader(r).select([c]).to_table()[c]``
    ``BullionReader.find_rows(col, vals)``                   ``Dataset.from_reader(r).where(In(col, vals)).drop_deleted(False).row_ids()``
    ``Scanner.scan(p, columns=cols)``                        ``dataset(path).where(p).select(cols).to_batches()``
    ``BullionLoader(path, predicate=p, column=c)``           ``dataset(path).where(p).select([c])`` + ``tasks()``/``read_group()``
    ``quality_filtered_read(path, cols, frac)``              ``dataset(path).select(cols).head(n).to_batches()``
    ``deletion.delete_where(path, p)``                       ``dataset(path).where(p).drop_deleted(False).row_ids()`` -> ``delete_rows``
    =======================================================  =====================================================================

Layout:

  plan.py      — ``LogicalPlan``/``OptimizedPlan``/``PhysicalPlan``/``ScanTask``,
                 the ``optimize`` and ``lower`` passes
  source.py    — shard discovery (file/dir/glob/list), open-time schema
                 checking (``SchemaMismatchError``), reader lifecycle,
                 global row offsets, aggregate ``IOStats``
  executor.py  — ``decode_group``/``execute_group``: the one read pipeline,
                 plus ``run_tasks`` (bounded thread pool, deterministic order)
                 shared by parallel reads and the sink
  io.py        — ``IOScheduler``/``PrefetchReader``: plan-wide byte-range
                 scheduling (``io_depth=`` on every terminal) — cross-task
                 pread coalescing and a prefetch thread that overlaps the
                 next tasks' reads with the current decode
  sink.py      — ``write_dataset``/``WriteResult``: the plan-driven
                 materialization sink behind ``Dataset.write_to`` (compaction
                 / compliance purge, resharding, reclustering, re-encoding)
  core.py      — the chainable ``Dataset`` and the ``dataset()`` entry point
"""

from .core import Dataset, DatasetBatch, dataset
from .executor import GroupResult, decode_group, execute_group, run_tasks
from .io import IOScheduler, PrefetchReader
from .plan import (LogicalPlan, OptimizedPlan, PhysicalPlan, ScanTask, lower,
                   optimize, split_conjuncts)
from .sink import WriteResult, write_dataset
from .source import (DataSource, SchemaMismatchError, cached_footer,
                     clear_footer_cache, discover, invalidate_cached_footer)

__all__ = [
    "Dataset", "DatasetBatch", "dataset", "DataSource",
    "SchemaMismatchError", "discover",
    "GroupResult", "decode_group", "execute_group", "run_tasks",
    "IOScheduler", "PrefetchReader",
    "LogicalPlan", "OptimizedPlan", "PhysicalPlan", "ScanTask", "lower",
    "optimize", "split_conjuncts", "WriteResult", "write_dataset",
    "cached_footer", "clear_footer_cache", "invalidate_cached_footer",
]
