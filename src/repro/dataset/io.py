"""Pipelined I/O: plan-wide byte-range scheduling with decode overlap.

Serial execution preads each task's pages from inside the decode loop — the
CPU stalls on every group boundary, and range coalescing stops at the group
the call happens to be decoding. Lowered plans already know *every* surviving
page byte range (``ScanTask.pages`` + the footer's page index), so the
``IOScheduler`` lifts I/O out of the decode path entirely:

1. **collect** — for each task, the byte extents of every page the executor
   will touch (the predicate columns when a filter gates payload reads, the
   full read set otherwise), computed footer-only before any data pread;
2. **coalesce** — extents merge across page, column, *and row-group/task*
   boundaries on the same shard whenever the hole between them is at most
   the reader's ``coalesce_gap`` (``BULLION_COALESCE_GAP`` / the
   ``dataset(coalesce_gap=)`` argument), capped at ``io_depth`` tasks and
   ``MAX_RUN_BYTES`` per submission so buffering stays bounded;
3. **prefetch** — a scheduler thread issues the coalesced runs through the
   shard's *shared* reader fd (positional reads; no second handle) at most
   ``io_depth - 1`` tasks ahead of the newest task the executor asked for,
   so task k+1's preads overlap task k's decode (``io_depth=2`` is classic
   double buffering).

The executor consumes prefetched bytes through ``reader_for(i)``: a
``PrefetchReader`` proxy that serves ``_read_pages`` from the task's buffer
and falls back to the underlying reader for anything not prefetched (payload
pages behind a filter, or after a scheduler error — correctness never
depends on the prefetch path). Output is byte-identical to serial execution
by construction: the same pages decode in the same task order; only *when*
and *how batched* the preads happen changes. ``IOStats.coalesced_preads`` /
``wasted_bytes`` account the batching win and its hole-read cost.

This scheduler is the seam storage backends plug into: the run list from
``_plan_runs`` is handed to ``BullionReader._fetch_runs`` in per-shard
batches, and the backend decides how a batch is fetched — one blocking
``pread`` per run for local files (byte-identical to serial execution), or
concurrent object-store ranged GETs with bounded in-flight requests and
completion-order staging for ``bullion://`` shards (``repro.core.backend``).
Backends replace how a coalesced run is fetched, not how plans or decoders
work; a failed run fails only the tasks it covers (they fall back to the
direct read path, which surfaces the real error).
"""

from __future__ import annotations

import threading
from typing import TYPE_CHECKING, Optional, Sequence

from ..core import backend as _backend
from ..core import integrity as _integrity
from ..core.reader import BullionReader
from ..obs import metrics as _metrics
from ..obs import trace as _trace

if TYPE_CHECKING:  # pragma: no cover - import cycle guard
    from .plan import ScanTask
    from .source import DataSource

MAX_RUN_BYTES = 8 * 1024 * 1024   # cap one coalesced submission


def task_page_ids(fv, task: "ScanTask", columns: Sequence[str]) -> list[int]:
    """Physical page ids one task will read for ``columns`` (footer-only),
    honoring the plan's surviving page-ordinal subset."""
    from .executor import _chunk_page_ids
    wanted: list[int] = []
    for name in columns:
        c = fv.column_index(name)
        wanted.extend(_chunk_page_ids(fv, task.group, c, task.pages))
    return wanted


class PrefetchReader:
    """Reader proxy serving ``_read_pages`` from a task's prefetched bytes.

    Pages the scheduler didn't (or couldn't) stage are read through the
    underlying shared reader, so a partial prefetch degrades gracefully to
    the serial path instead of failing. Everything else (footer, stats,
    quant specs) delegates to the base reader.
    """

    def __init__(self, base: BullionReader, pages: dict[int, bytes]):
        self._base = base
        self._pages = pages

    def _read_pages(self, page_ids: Sequence[int]) -> dict[int, bytes]:
        out: dict[int, bytes] = {}
        missing: list[int] = []
        for p in page_ids:
            data = self._pages.get(p)
            if data is None:
                missing.append(p)
            else:
                out[p] = data
        # staged bytes get the same decode-time verification gate the serial
        # path applies; a mismatching staged page re-reads *directly* through
        # the base reader (bypassing the prefetch buffer) before declaring
        # corruption. The fallback reads below verify inside the base call.
        out = _integrity.verify_pages(self, out)
        if missing:
            # fallback reads run through the base reader's coalesced pread
            # path, so preads / coalesced_preads / wasted_bytes are charged
            # exactly like the serial path and explain(analyze=True)
            # reconciliation holds on a partial prefetch; the counter makes
            # the fallback volume visible next to the staged-page spans
            _metrics.counter("bullion.io.prefetch_fallback_pages") \
                .inc(len(missing))
            out.update(self._base._read_pages(missing))
        return out

    def __getattr__(self, name):
        return getattr(self._base, name)


class IOScheduler:
    """Bounded plan-wide prefetcher: one background thread submits coalesced
    byte-range runs for upcoming tasks while the executor decodes.

    ``io_depth`` bounds both how far reads run ahead of decode (at most
    ``io_depth - 1`` tasks past the newest one requested) and how many
    consecutive tasks one coalesced pread may span. ``io_depth=1`` is the
    degenerate case — callers should simply not construct a scheduler.
    """

    def __init__(self, source: "DataSource", tasks: Sequence["ScanTask"], *,
                 columns: Sequence[str], io_depth: int,
                 max_run_bytes: int = MAX_RUN_BYTES):
        if io_depth < 2:
            raise ValueError(f"IOScheduler needs io_depth >= 2, "
                             f"got {io_depth}")
        self._source = source
        self._tasks = list(tasks)
        self._depth = int(io_depth)
        self._max_run_bytes = int(max_run_bytes)
        self._cond = threading.Condition()
        self._buffers: dict[int, dict[int, bytes]] = {}
        self._left: dict[int, int] = {}
        self._done: set[int] = set()
        self._max_requested = -1
        self._stop = False
        self._error: Optional[BaseException] = None
        self._thread: Optional[threading.Thread] = None
        # footer-only planning: per-task eager pages, then per-shard-segment
        # extent runs coalesced across task boundaries
        eager: list[list[int]] = []
        for t in self._tasks:
            fv = source.footer(t.shard)
            pages = task_page_ids(fv, t, columns)
            eager.append(pages)
            self._left[len(eager) - 1] = len(pages)
            if not pages:
                self._done.add(len(eager) - 1)
            else:
                self._buffers[len(eager) - 1] = {}
        self._runs = self._plan_runs(eager)

    # -- planning ---------------------------------------------------------------
    def _plan_runs(self, eager: list[list[int]]):
        """Coalesce page extents into submission runs.

        Tasks are walked in plan order; consecutive tasks on one shard form a
        segment whose extents sort by file offset (the writer lays groups out
        sequentially, so offset order tracks task order). Extents merge while
        the hole is within the shard's coalesce gap, the run stays under
        ``max_run_bytes``, and the run spans at most ``io_depth`` tasks —
        the last cap is what keeps prefetch buffering bounded. Remote shards
        halve that span cap so at least two runs fit the admission window at
        once: the async batcher can only overlap ranges that are admissible
        together.
        Returns ``[(shard, off, end, [(page_off, size, page, task_idx)],
        min_task, max_task)]``.
        """
        runs = []
        i = 0
        while i < len(self._tasks):
            shard = self._tasks[i].shard
            gap = self._source.shard_coalesce_gap(shard)
            span_cap = self._depth
            if _backend.is_remote(self._source.paths[shard]):
                span_cap = max(1, self._depth // 2)
            seg: list[tuple[int, int, int, int]] = []
            j = i
            fv = self._source.footer(shard)
            while j < len(self._tasks) and self._tasks[j].shard == shard:
                for p in eager[j]:
                    off, size = fv.page_extent(p)
                    seg.append((off, size, p, j))
                j += 1
            seg.sort()
            k = 0
            while k < len(seg):
                off, size, _, t = seg[k]
                end = off + size
                lo_t = hi_t = t
                m = k + 1
                while m < len(seg):
                    o2, s2, _, t2 = seg[m]
                    if o2 - end > gap:
                        break
                    if max(end, o2 + s2) - off > self._max_run_bytes:
                        break
                    if max(hi_t, t2) - min(lo_t, t2) + 1 > span_cap:
                        break
                    end = max(end, o2 + s2)
                    lo_t, hi_t = min(lo_t, t2), max(hi_t, t2)
                    m += 1
                runs.append((shard, off, end,
                             [(o, s, p, ti) for o, s, p, ti in seg[k:m]],
                             lo_t, hi_t))
                k = m
            i = j
        # issue order must follow *task* order, not raw file offset: a
        # relocated page (compliance deletes append rebuilt pages at the
        # file tail) can put an early task's bytes after a later task's,
        # and a window blocked on the later run would deadlock against a
        # consumer waiting for the earlier task. Sorting by (first task,
        # offset) keeps every run an awaited task needs admissible.
        runs.sort(key=lambda r: (r[4], r[1]))
        return runs

    # -- lifecycle --------------------------------------------------------------
    def start(self) -> None:
        if self._thread is None:
            self._thread = threading.Thread(
                target=self._io_loop, daemon=True,
                name="bullion-io-scheduler")
            self._thread.start()

    def close(self) -> None:
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- executor side ----------------------------------------------------------
    def reader_for(self, i: int):
        """Reader for task index ``i``: blocks until its eager pages are
        staged (the request also advances the prefetch window), then returns
        a ``PrefetchReader`` over them — or the plain shared reader when
        there is nothing staged (empty eager set, scheduler error/stop).
        Time the executor spends blocked here is the pipeline's exposed
        (un-overlapped) I/O — the ``io.stage_wait`` span."""
        base = self._source.reader(self._tasks[i].shard)
        sp = _trace.span("io.stage_wait", cat="io", task=i)
        with sp, self._cond:
            if i > self._max_requested:
                self._max_requested = i
                self._cond.notify_all()
            while i not in self._done and self._error is None \
                    and not self._stop:
                self._cond.wait()
            pages = self._buffers.pop(i, None)
            if sp.enabled:
                sp.set(staged_pages=len(pages) if pages else 0)
        if pages:
            return PrefetchReader(base, pages)
        return base

    # -- scheduler thread -------------------------------------------------------
    def _io_loop(self) -> None:
        try:
            runs = self._runs
            i = 0
            while i < len(runs):
                shard, max_task = runs[i][0], runs[i][5]
                # admit on the run's *highest* task so no staged page is
                # ever more than io_depth - 1 tasks past the newest request
                wait_sp = _trace.span("io.queue_wait", cat="io",
                                      task=max_task)
                with wait_sp, self._cond:
                    while not self._stop and \
                            max_task > self._max_requested + self._depth - 1:
                        self._cond.wait()
                    if self._stop:
                        return
                    # how far the submission runs ahead of decode (window
                    # occupancy, in tasks) — the scheduler's queue depth
                    _metrics.histogram("bullion.io.read_ahead_tasks") \
                        .observe(max(0, max_task - self._max_requested))
                    # every already-admissible same-shard run joins this
                    # submission. Local runs extend on the same strict bound
                    # they were admitted on (and are fetched serially, so
                    # batching changes nothing); remote runs extend when the
                    # run *starts* inside the window — staging may then reach
                    # ~1.5x io_depth tasks ahead, the price of having >= 2
                    # ranges in flight for the async batcher to overlap.
                    remote = _backend.is_remote(self._source.paths[shard])
                    adm = 4 if remote else 5
                    j = i + 1
                    while j < len(runs) and runs[j][0] == shard and \
                            runs[j][adm] <= self._max_requested \
                            + self._depth - 1:
                        j += 1
                reader = self._source.reader(shard)
                batch = runs[i:j]
                for k, pages, err in reader._fetch_runs(
                        [(off, end, [(o, s, p) for o, s, p, _ in ext])
                         for _, off, end, ext, _, _ in batch],
                        max_in_flight=self._depth,
                        span_meta=[{"shard": shard, "task": r[5]}
                                   for r in batch]):
                    extents = batch[k][3]
                    with self._cond:
                        if self._stop:
                            # closing the generator cancels any still-queued
                            # remote ranges in the batch
                            return
                        if err is not None:
                            # fail only the tasks this run covered: dropping
                            # their buffers makes reader_for() return the
                            # direct-read path, which retries serially and
                            # surfaces the real error to exactly those tasks
                            for _, _, _, t in extents:
                                self._buffers.pop(t, None)
                                self._done.add(t)
                        else:
                            for _, _, p, t in extents:
                                buf = self._buffers.get(t)
                                if buf is not None:
                                    buf[p] = pages[p]
                                self._left[t] -= 1
                                if self._left[t] == 0:
                                    self._done.add(t)
                        self._cond.notify_all()
                i = j
        except BaseException as e:
            # fail open: pending reader_for() calls fall back to the shared
            # reader's direct path, which surfaces any real I/O error itself
            with self._cond:
                self._error = e
                self._cond.notify_all()
