"""Model configuration schema for the architecture zoo.

A model is a sequence of *segments*; each segment is a repeating *pattern* of
blocks (scanned over the repeat count with stacked params, which keeps HLO
size and compile time independent of depth).  A block is "attn_kind:mlp_kind",
e.g. "full:swiglu", "window:moe", "rglru:swiglu", "rwkv:rwkv".
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional


@dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (frontend stubbed to precomputed frames)."""
    n_layers: int = 6
    seq: int = 1500          # mel frames after conv stub
    d_input: int = 512       # frame embedding dim (== d_model for whisper)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | encdec | vlm | audio
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    segments: tuple[tuple[tuple[str, ...], int], ...]  # ((blocks...), repeat)

    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    qk_norm: bool = False
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d) embedding scaling
    window: int = 4096               # sliding-window size for "window"/"local"
    logit_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_ff: int = 0
    n_shared: int = 0
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.01

    # MLA
    mla: Optional[MLAConfig] = None

    # RWKV / RG-LRU
    lru_width: int = 0
    conv_width: int = 4
    rwkv_chunked: bool = False   # chunk-parallel WKV (perf path; see §Perf)

    # encoder-decoder
    encoder: Optional[EncoderConfig] = None

    frontend: str = "none"           # none | audio_stub | vlm_stub
    sub_quadratic: bool = False      # supports long_500k decode
    compute_dtype: str = "bfloat16"

    # ---- performance knobs (§Perf hillclimb; defaults = paper-faithful
    # baseline behaviour) ----
    cast_params_once: bool = False   # cast params to compute dtype before the
                                     # layer scan: FSDP all-gathers + gradient
                                     # reduce-scatters move bf16, not f32
    remat_policy: str = "full"       # full | dots (save matmul outputs)
    fused_qkv: bool = False          # one fused in-projection per block: one
                                     # SP all-gather of x fwd and one partial
                                     # dx all-reduce bwd instead of 3-5 each

    @property
    def n_layers(self) -> int:
        return sum(len(blocks) * rep for blocks, rep in self.segments)

    def scaled(self, **overrides) -> "ModelConfig":
        """Derive a reduced config (smoke tests)."""
        import dataclasses
        return dataclasses.replace(self, **overrides)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}
