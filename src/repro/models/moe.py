"""Mixture-of-Experts block: token-choice top-k routing with capacity,
grouped-GEMM expert compute, and a chunked EP x TP hybrid layout:

Routed expert weights are stored as lcm(E, M) *chunks* — chunk ``e*tp + j``
holds expert e's j-th d_ff slice (tp = M / gcd(E, M)) — and the chunk axis is
sharded over 'model'. This gives pure EP when E % M == 0 (DeepSeek: 4 experts
per rank), expert-TP when E < M (Mixtral on model=16: each rank holds half of
one expert's d_ff), and every hybrid in between, with zero weight replication
across the TP axis.

Dispatch is *local* per data shard (standard at scale): inside ``shard_map``
each rank routes its own tokens, computes its chunk's partial expert outputs,
combines into per-token outputs, and one [T_local, d] psum over 'model'
finishes the job — the cheapest possible combine collective.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from .base import P

try:  # jax >= 0.7 exposes shard_map at top level
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover
    from jax.experimental.shard_map import shard_map as _shard_map

PRODUCTION_M = 16  # model-axis size of the production mesh (chunk layout)


def moe_chunking(E: int, M: int = PRODUCTION_M) -> tuple[int, int]:
    """Returns (tp, n_chunks): tp d_ff slices per expert, E*tp chunks total."""
    tp = M // math.gcd(E, M)
    return tp, E * tp


def moe_decl(cfg) -> dict:
    E, d, ff = cfg.n_experts, cfg.d_model, cfg.moe_ff or cfg.d_ff
    tp, n_chunks = moe_chunking(E)
    assert ff % tp == 0, (ff, tp)
    ff_tp = ff // tp
    decl = {
        "router": P((d, E), ("embed", None)),
        "wg": P((n_chunks, d, ff_tp), ("experts", "embed", None)),
        "wu": P((n_chunks, d, ff_tp), ("experts", "embed", None)),
        "wd": P((n_chunks, ff_tp, d), ("experts", None, "embed")),
    }
    if cfg.n_shared:
        sff = (cfg.moe_ff or cfg.d_ff) * cfg.n_shared
        decl["shared"] = {
            "w_gate": P((d, sff), ("embed", "ff")),
            "w_up": P((d, sff), ("embed", "ff")),
            "w_down": P((sff, d), ("ff", "embed")),
        }
    return decl


def unchunk(w, E: int, ff_axis: int):
    """[n_chunks, a, b] chunk layout -> dense [E, d, ff] / [E, ff, d]."""
    n_chunks = w.shape[0]
    tp = n_chunks // E
    if tp == 1:
        return w
    if ff_axis == 2:   # wg/wu: [E, tp, d, ff_tp] -> [E, d, ff]
        return w.reshape(E, tp, w.shape[1], w.shape[2]) \
                .transpose(0, 2, 1, 3).reshape(E, w.shape[1], tp * w.shape[2])
    # wd: [E, tp, ff_tp, d] -> [E, ff, d]
    return w.reshape(E, tp, w.shape[1], w.shape[2]) \
            .reshape(E, tp * w.shape[1], w.shape[2])


def _route(xt, router, top_k):
    """xt: [T, d] -> (weights [T,k], idx [T,k], aux_loss)."""
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        router.astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(gates, top_k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)
    E = router.shape[-1]
    me = gates.mean(axis=0)                                   # [E]
    ce = jnp.zeros(E, jnp.float32).at[idx.reshape(-1)].add(1.0) / idx.size
    aux = E * jnp.sum(me * ce)
    return w.astype(xt.dtype), idx, aux


def _dispatch(xt, idx, E, C):
    """Scatter tokens into an expert-major buffer [E, C, d] with capacity."""
    T, k = idx.shape
    flat_e = idx.reshape(-1)                                    # [T*k]
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot
    pos_in_e = jnp.take_along_axis(pos, flat_e[:, None], axis=1)[:, 0]
    keep = pos_in_e < C
    slot = jnp.where(keep, flat_e * C + pos_in_e, E * C)        # OOB when dropped
    token_of_slot = jnp.zeros((E * C,), jnp.int32).at[slot].set(
        jnp.repeat(jnp.arange(T, dtype=jnp.int32), k), mode="drop")
    filled = jnp.zeros((E * C,), bool).at[slot].set(True, mode="drop")
    buf = jnp.where(filled[:, None], xt[token_of_slot], 0).reshape(E, C, xt.shape[1])
    return buf, slot, keep


def moe_apply(p, x, cfg, *, model_axis: Optional[str] = None,
              all_axes: tuple = ()):
    """MoE block over x: [B, S, d]. Inside shard_map, p holds local chunks."""
    B, S, d = x.shape
    T = B * S
    xt = x.reshape(T, d)
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(math.ceil(T * k / E * cfg.capacity_factor)))

    w, idx, aux = _route(xt, p["router"], k)
    buf, slot, keep = _dispatch(xt, idx, E, C)

    wg, wu, wd = p["wg"], p["wu"], p["wd"]
    tp_total, n_chunks_total = moe_chunking(E, PRODUCTION_M)

    if model_axis is not None:
        # Local chunk slice: chunk ids r*cpr + [0, cpr) map to a contiguous,
        # statically-sized expert range (expert of chunk c == c // tp).
        cpr = wg.shape[0]                       # chunks on this rank (static)
        tp_static = n_chunks_total // E
        r = jax.lax.axis_index(model_axis)
        n_exp = max(1, cpr // tp_static)
        e_start = (r * cpr) // tp_static
        mybuf = jax.lax.dynamic_slice_in_dim(buf, e_start, n_exp, axis=0)
        mybuf_chunks = jnp.repeat(mybuf, cpr // n_exp, axis=0)  # [cpr, C, d]
        h = jnp.einsum("ecd,edf->ecf", mybuf_chunks, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", mybuf_chunks, wu.astype(x.dtype))
        out_chunks = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u,
                                wd.astype(x.dtype))             # [cpr, C, d]
        out_loc = out_chunks.reshape(n_exp, cpr // n_exp, C, d).sum(axis=1)
        out = jnp.zeros((E, C, d), x.dtype)
        out = jax.lax.dynamic_update_slice_in_dim(out, out_loc, e_start, axis=0)
    else:
        # single-device / no-mesh path: reconstruct dense expert weights
        wg_f = unchunk(wg, E, ff_axis=2).astype(x.dtype)
        wu_f = unchunk(wu, E, ff_axis=2).astype(x.dtype)
        wd_f = unchunk(wd, E, ff_axis=1).astype(x.dtype)
        h = jnp.einsum("ecd,edf->ecf", buf, wg_f)
        u = jnp.einsum("ecd,edf->ecf", buf, wu_f)
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h) * u, wd_f)

    # combine: gather each (token, k) slot's output, weight, sum over k.
    slot_g = jnp.minimum(slot, E * C - 1)
    gathered = jnp.where(keep[:, None], out.reshape(E * C, d)[slot_g], 0)
    y = (gathered.reshape(T, k, d) * w[..., None].astype(x.dtype)).sum(axis=1)

    if cfg.n_shared:
        sp = p["shared"]
        g = jnp.einsum("td,df->tf", xt, sp["w_gate"].astype(x.dtype))
        u2 = jnp.einsum("td,df->tf", xt, sp["w_up"].astype(x.dtype))
        y = y + jnp.einsum("tf,fd->td", jax.nn.silu(g) * u2,
                           sp["w_down"].astype(x.dtype))

    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    if all_axes:
        aux = jax.lax.pmean(aux, all_axes)

    return y.reshape(B, S, d), aux


def moe_specs(p, cfg, mesh, batch_axes):
    """shard_map in/out specs for the MoE params + activations."""
    xspec = PS(batch_axes, None, None)
    wspec = PS("model", None, None)
    pspec = {"router": PS(None, None), "wg": wspec, "wu": wspec, "wd": wspec}
    if "shared" in p:
        pspec["shared"] = {"w_gate": PS(None, "model"), "w_up": PS(None, "model"),
                           "w_down": PS("model", None)}
    return pspec, xspec


def moe_block(p, x, cfg, dist=None):
    """Entry point: shard_map'd when a mesh is available, local otherwise."""
    if (dist is None or dist.mesh is None
            or "model" not in dist.mesh.axis_names
            or p["wg"].shape[0] % dist.mesh.shape["model"] != 0):
        return moe_apply(p, x, cfg, model_axis=None)

    mesh = dist.mesh
    batch_axes = dist.batch_axes_for(x.shape[0])
    pspec, xspec = moe_specs(p, cfg, mesh, batch_axes)
    all_axes = tuple(mesh.axis_names)
    fn = partial(moe_apply, cfg=cfg, model_axis="model", all_axes=all_axes)
    try:
        smapped = _shard_map(fn, mesh=mesh, in_specs=(pspec, xspec),
                             out_specs=(xspec, PS()), check_vma=False)
    except TypeError:  # older jax: check_rep
        smapped = _shard_map(fn, mesh=mesh, in_specs=(pspec, xspec),
                             out_specs=(xspec, PS()), check_rep=False)
    return smapped(p, x)
