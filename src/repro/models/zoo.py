"""Model zoo entry point: build(config) -> Model with a uniform API.

Model.loss / prefill / decode_step are the three functions the launcher
lowers (train_4k -> train_step over loss; prefill_32k -> prefill;
decode_32k / long_500k -> decode_step).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import encdec as encdec_mod
from . import transformer as tf
from .base import abstract_tree, init_tree, param_count, spec_tree
from .config import ModelConfig


def _xent(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll.mean()


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    dist: Any = None

    def __post_init__(self):
        self.is_encdec = self.cfg.encoder is not None
        self.decl = (encdec_mod.encdec_decl(self.cfg) if self.is_encdec
                     else tf.model_decl(self.cfg))

    # -- params ---------------------------------------------------------------
    def init(self, rng, dtype=jnp.float32):
        return init_tree(self.decl, rng, dtype)

    def abstract_params(self, dtype=jnp.float32):
        return abstract_tree(self.decl, dtype)

    def param_specs(self):
        rules = self.dist.rules if self.dist else None
        if rules is None:
            from .base import ShardingRules
            rules = ShardingRules(embed=None, heads=None, kv_heads=None,
                                  ff=None, vocab=None, experts=None, lru=None,
                                  batch=None)
        return spec_tree(self.decl, rules)

    @property
    def n_params(self) -> int:
        return param_count(self.decl)

    def _dtype(self):
        return jnp.dtype(self.cfg.compute_dtype)

    # -- training -------------------------------------------------------------
    def loss(self, params, batch):
        """batch: {"tokens": [B, S+1]} (+ "frames" for enc-dec)."""
        cfg = self.cfg
        dt = self._dtype()
        if cfg.cast_params_once and dt != jnp.float32:
            # one sharded cast before the layer scan: every FSDP all-gather
            # (and, via AD, every gradient reduce-scatter) moves `dt` instead
            # of f32 — 2x less ICI traffic. Master weights stay f32 in the
            # optimizer; AD converts grads back through the cast.
            params = jax.tree.map(lambda p: p.astype(dt)
                                  if p.dtype == jnp.float32 else p, params)
        tokens = batch["tokens"]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        T = inputs.shape[1]
        positions = jnp.arange(T, dtype=jnp.int32)
        ctx = tf.Ctx(cfg=cfg, dist=self.dist, mode="train", positions=positions)
        if self.is_encdec:
            frames = batch["frames"].astype(dt)
            enc_out = encdec_mod.encode(params, frames, cfg, ctx)
            ek, ev = encdec_mod.cross_kv(params, enc_out)
            x = tf.embed_tokens(params, inputs, cfg, dt)
            x, _ = encdec_mod.decode_blocks(params, x, cfg, ctx, ek, ev)
            logits = tf.logits_fn(params, x, cfg)
            return _xent(logits, labels)
        x = tf.embed_tokens(params, inputs, cfg, dt)
        x, _, aux = tf.forward(params, x, cfg, ctx)
        logits = tf.logits_fn(params, x, cfg)
        return _xent(logits, labels) + cfg.aux_loss_weight * aux

    # -- serving ----------------------------------------------------------------
    def init_cache(self, batch: int, seq_len: int, dtype=jnp.bfloat16):
        if self.is_encdec:
            return encdec_mod.encdec_cache(self.cfg, batch, seq_len, dtype)
        return tf.init_cache(self.cfg, batch, seq_len, dtype)

    def prefill(self, params, batch, cache):
        """Fill the cache from a prompt; returns (last_token_logits, cache)."""
        cfg = self.cfg
        dt = self._dtype()
        tokens = batch["tokens"]
        B, T = tokens.shape
        positions = jnp.arange(T, dtype=jnp.int32)
        ctx = tf.Ctx(cfg=cfg, dist=self.dist, mode="prefill",
                     positions=positions)
        if self.is_encdec:
            frames = batch["frames"].astype(dt)
            enc_out = encdec_mod.encode(params, frames, cfg, ctx)
            ek, ev = encdec_mod.cross_kv(params, enc_out)
            x = tf.embed_tokens(params, tokens, cfg, dt)
            x, self_kv = encdec_mod.decode_blocks(params, x, cfg, ctx, ek, ev,
                                                  cache=cache["self_kv"])
            logits = tf.logits_fn(params, x[:, -1:], cfg)
            new_cache = {"pos": jnp.asarray(T, jnp.int32), "self_kv": self_kv,
                         "enc_k": ek.astype(cache["enc_k"].dtype),
                         "enc_v": ev.astype(cache["enc_v"].dtype)}
            return logits[:, 0], new_cache
        x = tf.embed_tokens(params, tokens, cfg, dt)
        x, new_cache, _ = tf.forward(params, x, cfg, ctx, cache=cache)
        new_cache["pos"] = jnp.asarray(T, jnp.int32)
        logits = tf.logits_fn(params, x[:, -1:], cfg)
        return logits[:, 0], new_cache

    def decode_step(self, params, cache, tokens):
        """tokens: [B, 1] -> (logits [B, V], cache)."""
        cfg = self.cfg
        dt = self._dtype()
        pos = cache["pos"]
        ctx = tf.Ctx(cfg=cfg, dist=self.dist, mode="decode", cache_pos=pos)
        x = tf.embed_tokens(params, tokens, cfg, dt)
        if self.is_encdec:
            x, self_kv = encdec_mod.decode_blocks(
                params, x, cfg, ctx, cache["enc_k"], cache["enc_v"],
                cache=cache["self_kv"])
            logits = tf.logits_fn(params, x, cfg)
            new_cache = dict(cache)
            new_cache["self_kv"] = self_kv
            new_cache["pos"] = pos + 1
            return logits[:, 0], new_cache
        x, new_cache, _ = tf.forward(params, x, cfg, ctx, cache=cache)
        new_cache["pos"] = pos + 1
        logits = tf.logits_fn(params, x, cfg)
        return logits[:, 0], new_cache


def build(cfg: ModelConfig, dist=None) -> Model:
    return Model(cfg=cfg, dist=dist)
