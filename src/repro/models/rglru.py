"""RG-LRU recurrent block (Griffin / RecurrentGemma): gated linear recurrence
with input-dependent retention, temporal conv, GeGLU-style gating.  Train path
uses an associative scan (parallel over sequence); decode carries O(1) state."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import P
from .layers import rmsnorm, rmsnorm_decl

RG_C = 8.0  # Griffin's constant c


def rglru_decl(cfg) -> dict:
    d = cfg.d_model
    lru = cfg.lru_width or d
    H = cfg.n_heads  # block-diagonal gate heads
    bd = lru // H
    return {
        "norm": rmsnorm_decl(d),
        "w_gate_in": P((d, lru), ("embed", "lru")),
        "w_main_in": P((d, lru), ("embed", "lru")),
        "conv_w": P((cfg.conv_width, lru), (None, "lru")),
        "conv_b": P((lru,), ("lru",), init="zeros"),
        "lam": P((lru,), ("lru",), init="ones"),          # Λ (retention logits)
        "wa": P((H, bd, bd), ("heads", None, None)),      # recurrence gate (block-diag)
        "ba": P((lru,), ("lru",), init="zeros"),
        "wx": P((H, bd, bd), ("heads", None, None)),      # input gate (block-diag)
        "bx": P((lru,), ("lru",), init="zeros"),
        "w_out": P((lru, d), ("lru", "embed")),
    }


def _block_diag(x, w, H):
    """x: [B,T,lru] -> block-diagonal linear via heads: [B,T,H,bd]@[H,bd,bd]."""
    B, T, lru = x.shape
    bd = lru // H
    xh = x.reshape(B, T, H, bd)
    return jnp.einsum("bthi,hij->bthj", xh, w.astype(x.dtype)).reshape(B, T, lru)


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, width K. x: [B,T,lru]; state: [B,K-1,lru]."""
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)                 # [B, T+K-1, lru]
    out = sum(xp[:, i:i + x.shape[1], :] * w[i].astype(x.dtype) for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else pad
    return out + b.astype(x.dtype), new_state


def rglru_block(p, x, cache=None, *, cfg):
    """cache: {"h": [B,lru] f32, "conv": [B,K-1,lru] f32} or None (train)."""
    B, T, d = x.shape
    H = cfg.n_heads
    xn = rmsnorm(p["norm"], x)

    gate = jax.nn.gelu(jnp.einsum("btd,dl->btl", xn, p["w_gate_in"].astype(x.dtype)))
    main = jnp.einsum("btd,dl->btl", xn, p["w_main_in"].astype(x.dtype))
    conv_state = cache["conv"] if cache is not None else None
    main, new_conv = _causal_conv(main, p["conv_w"], p["conv_b"], conv_state)

    r = jax.nn.sigmoid(_block_diag(main, p["wa"], H) + p["ba"].astype(x.dtype))
    i = jax.nn.sigmoid(_block_diag(main, p["wx"], H) + p["bx"].astype(x.dtype))
    log_a = (-RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32))
             * r.astype(jnp.float32))                      # [B,T,lru] <= 0
    a = jnp.exp(log_a)
    gated_x = (i * main).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated_x

    if cache is not None and T == 1:
        h0 = cache["h"]
        h = a[:, 0] * h0 + b[:, 0]
        hs = h[:, None, :]
        new_h = h
    else:
        def combine(c1, c2):
            a1, b1 = c1
            a2, b2 = c2
            return a1 * a2, b1 * a2 + b2
        a_s, b_s = jax.lax.associative_scan(combine, (a, b), axis=1)
        if cache is not None:  # prefill: fold in the initial state
            hs = b_s + a_s * cache["h"][:, None, :]
        else:
            hs = b_s
        new_h = hs[:, -1]

    y = (gate * hs.astype(x.dtype))
    out = jnp.einsum("btl,ld->btd", y, p["w_out"].astype(x.dtype))

    new_cache = None
    if cache is not None:
        new_cache = {"h": new_h.astype(jnp.float32),
                     "conv": new_conv.astype(jnp.float32)}
    return x + out, new_cache


def rglru_cache_decl(cfg, batch: int) -> dict:
    lru = cfg.lru_width or cfg.d_model
    return {"h": jnp.zeros((batch, lru), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv_width - 1, lru), jnp.float32)}
