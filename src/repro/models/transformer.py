"""Decoder-only assembly over heterogeneous block patterns.

Depth is expressed as segments of repeating patterns; parameters (and caches)
are stacked over the repeat count and the pattern is applied inside
``jax.lax.scan`` — HLO size and compile time stay O(pattern), not O(depth).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import mla as mla_mod
from . import moe as moe_mod
from . import rglru as rglru_mod
from . import rwkv6 as rwkv_mod
from .base import P, constrain, is_decl
from .config import ModelConfig
from .layers import (attention_decl, attn_out, attn_qkv, dot_attention,
                     gelu_mlp, gelu_mlp_decl, layernorm, layernorm_decl,
                     rmsnorm, rmsnorm_decl, swiglu, swiglu_decl)


# ---------------------------------------------------------------------------
# declarations
# ---------------------------------------------------------------------------


def _norm_decl(cfg):
    return rmsnorm_decl(cfg.d_model) if cfg.norm == "rmsnorm" \
        else layernorm_decl(cfg.d_model)


def _norm(cfg, p, x):
    return rmsnorm(p, x) if cfg.norm == "rmsnorm" else layernorm(p, x)


def block_decl(cfg: ModelConfig, block: str) -> dict:
    attn_kind, mlp_kind = block.split(":")
    decl: dict = {}
    if attn_kind in ("full", "window", "local", "global"):
        decl["ln_attn"] = _norm_decl(cfg)
        decl["attn"] = attention_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                      cfg.head_dim, qk_norm=cfg.qk_norm,
                                      fused=cfg.fused_qkv)
    elif attn_kind == "mla":
        decl["ln_attn"] = _norm_decl(cfg)
        decl["attn"] = mla_mod.mla_decl(cfg)
    elif attn_kind == "rwkv":
        return rwkv_mod.rwkv_decl(cfg)   # self-contained (incl. channel mix)
    elif attn_kind == "rglru":
        decl["rec"] = rglru_mod.rglru_decl(cfg)
    else:
        raise ValueError(attn_kind)

    if mlp_kind == "swiglu":
        decl["ln_mlp"] = _norm_decl(cfg)
        decl["mlp"] = swiglu_decl(cfg.d_model, cfg.d_ff)
    elif mlp_kind == "gelu":
        decl["ln_mlp"] = _norm_decl(cfg)
        decl["mlp"] = gelu_mlp_decl(cfg.d_model, cfg.d_ff)
    elif mlp_kind == "moe":
        decl["ln_mlp"] = _norm_decl(cfg)
        decl["moe"] = moe_mod.moe_decl(cfg)
    elif mlp_kind != "none":
        raise ValueError(mlp_kind)
    return decl


def stack_decl(decl, n: int):
    return jax.tree.map(
        lambda p: P((n,) + p.shape, ("layers",) + p.axes, p.init, p.scale),
        decl, is_leaf=is_decl)


def model_decl(cfg: ModelConfig) -> dict:
    decl: dict = {
        "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed",
                   scale=0.02),
        "final_norm": _norm_decl(cfg),
    }
    if not cfg.tie_embeddings:
        decl["lm_head"] = P((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    decl["segments"] = [
        {f"b{j}": stack_decl(block_decl(cfg, b), rep)
         for j, b in enumerate(blocks)}
        for blocks, rep in cfg.segments
    ]
    return decl


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _attn_cache(cfg, kind: str, batch: int, seq_len: int, dtype):
    S = seq_len if kind in ("full", "global") else min(cfg.window, seq_len)
    shape = (batch, S, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def block_cache(cfg, block: str, batch: int, seq_len: int, dtype=jnp.bfloat16):
    attn_kind, _ = block.split(":")
    if attn_kind in ("full", "window", "local", "global"):
        return _attn_cache(cfg, attn_kind, batch, seq_len, dtype)
    if attn_kind == "mla":
        return mla_mod.mla_cache_decl(cfg, batch, seq_len, dtype)
    if attn_kind == "rwkv":
        return rwkv_mod.rwkv_cache_decl(cfg, batch)
    if attn_kind == "rglru":
        return rglru_mod.rglru_cache_decl(cfg, batch)
    raise ValueError(attn_kind)


def init_cache(cfg: ModelConfig, batch: int, seq_len: int, dtype=jnp.bfloat16):
    segs = []
    for blocks, rep in cfg.segments:
        segs.append({
            f"b{j}": jax.tree.map(
                lambda a: jnp.broadcast_to(a, (rep,) + a.shape).copy()
                if rep > 0 else a,
                block_cache(cfg, b, batch, seq_len, dtype))
            for j, b in enumerate(blocks)})
    return {"pos": jnp.zeros((), jnp.int32), "segments": segs}


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Ctx:
    cfg: ModelConfig
    dist: Any = None
    mode: str = "train"                 # train | prefill | decode
    positions: Optional[jax.Array] = None
    cache_pos: Optional[jax.Array] = None


def _rolling_pos(pos, W):
    """Absolute position held by each rolling-buffer slot."""
    slots = jnp.arange(W, dtype=jnp.int32)
    return pos - ((pos - slots) % W)


def _attn_block(p, x, kind: str, ctx: Ctx, cache):
    cfg = ctx.cfg
    windowed = kind in ("window", "local")
    W = cfg.window
    xn = _norm(cfg, p["ln_attn"], x)

    if ctx.mode == "decode":
        pos = ctx.cache_pos
        positions = pos[None]
        q, k_new, v_new = attn_qkv(p["attn"], xn, positions,
                                   rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                                   n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                                   head_dim=cfg.head_dim)
        S = cache["k"].shape[1]
        slot = pos % S if windowed else pos
        k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
        v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
        if windowed:
            kv_pos = _rolling_pos(pos, S)
            kv_valid = (kv_pos >= 0)[None, :]
        else:
            kv_pos = jnp.arange(S, dtype=jnp.int32)
            kv_valid = (kv_pos <= pos)[None, :]
        o = dot_attention(q, k.astype(x.dtype), v.astype(x.dtype),
                          positions, kv_pos, causal=True,
                          window=W if windowed else 0,
                          kv_valid=jnp.broadcast_to(kv_valid, (x.shape[0], S)))
        new_cache = {"k": k, "v": v}
    else:
        positions = ctx.positions
        q, k, v = attn_qkv(p["attn"], xn, positions,
                           rope_theta=cfg.rope_theta, qk_norm=cfg.qk_norm,
                           n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
                           head_dim=cfg.head_dim)
        o = dot_attention(q, k, v, positions, positions, causal=True,
                          window=W if windowed else 0)
        new_cache = None
        if ctx.mode == "prefill" and cache is not None:
            S_cache = cache["k"].shape[1]
            T = x.shape[1]
            if windowed and T > S_cache:
                tail_k = k[:, T - S_cache:]
                tail_v = v[:, T - S_cache:]
                shift = (T - S_cache) % S_cache
                ck = jnp.roll(tail_k, shift, axis=1)
                cv = jnp.roll(tail_v, shift, axis=1)
            else:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cache["k"]), k.astype(cache["k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cache["v"]), v.astype(cache["v"].dtype), 0, axis=1)
            new_cache = {"k": ck.astype(cache["k"].dtype),
                         "v": cv.astype(cache["v"].dtype)}
    return x + attn_out(p["attn"], o), new_cache


def apply_block(p, x, block: str, ctx: Ctx, cache=None):
    """Returns (x, new_cache, aux_loss)."""
    cfg = ctx.cfg
    attn_kind, mlp_kind = block.split(":")
    aux = jnp.zeros((), jnp.float32)

    if attn_kind in ("full", "window", "local", "global"):
        x, new_cache = _attn_block(p, x, attn_kind, ctx, cache)
    elif attn_kind == "mla":
        xn = _norm(cfg, p["ln_attn"], x)
        positions = ctx.cache_pos[None] if ctx.mode == "decode" else ctx.positions
        o, new_cache = mla_mod.mla_attention(p["attn"], xn, positions, cfg,
                                             cache=cache,
                                             cache_pos=ctx.cache_pos)
        x = x + o
    elif attn_kind == "rwkv":
        x, new_cache = rwkv_mod.rwkv_block(
            p, x, cache, cfg=cfg, dist=ctx.dist,
            use_chunked=cfg.rwkv_chunked and ctx.mode != "decode")
        return x, new_cache, aux
    elif attn_kind == "rglru":
        x, new_cache = rglru_mod.rglru_block(p["rec"], x, cache, cfg=cfg)
    else:
        raise ValueError(attn_kind)

    if mlp_kind in ("swiglu", "gelu"):
        xn = _norm(cfg, p["ln_mlp"], x)
        x = x + (swiglu(p["mlp"], xn) if mlp_kind == "swiglu"
                 else gelu_mlp(p["mlp"], xn))
    elif mlp_kind == "moe":
        xn = _norm(cfg, p["ln_mlp"], x)
        y, aux = moe_mod.moe_block(p["moe"], xn, cfg, ctx.dist)
        x = x + y
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def embed_tokens(params, tokens, cfg, dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), dtype)
    return x


def logits_fn(params, x, cfg):
    x32 = x
    if cfg.tie_embeddings:
        return jnp.einsum("bsd,vd->bsv", x32, params["embed"].astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x32, params["lm_head"].astype(x.dtype))


def forward(params, x, cfg: ModelConfig, ctx: Ctx, cache=None):
    """x: [B, T, d] embedded inputs. Returns (hidden, new_cache, aux)."""
    rules = ctx.dist.rules if ctx.dist is not None else None
    if rules is not None:
        x = constrain(x, rules, ("batch", "seq", None))
    aux_total = jnp.zeros((), jnp.float32)
    new_segments = []
    for si, (blocks, rep) in enumerate(cfg.segments):
        seg_params = params["segments"][si]
        seg_cache = cache["segments"][si] if cache is not None else None

        def body(carry, xs):
            h, aux_c = carry
            if seg_cache is not None:
                ps, cs = xs
            else:
                ps, cs = xs, None
            new_cs = {}
            for j, b in enumerate(blocks):
                c_j = cs[f"b{j}"] if cs is not None else None
                h, nc, aux = apply_block(ps[f"b{j}"], h, b, ctx, c_j)
                if nc is not None:
                    new_cs[f"b{j}"] = nc
            if rules is not None:
                h = constrain(h, rules, ("batch", "seq", None))
            out_cs = new_cs if seg_cache is not None else None
            return (h, aux_c + aux), out_cs

        if ctx.mode == "train":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots" else None)
            body_fn = jax.checkpoint(body, policy=policy)
        else:
            body_fn = body
        xs = (seg_params, seg_cache) if seg_cache is not None else seg_params
        (x, aux_total), new_seg_cache = jax.lax.scan(body_fn, (x, aux_total), xs)
        new_segments.append(new_seg_cache)

    x = _norm(cfg, params["final_norm"], x)
    new_cache = None
    if cache is not None:
        new_cache = {"pos": cache["pos"], "segments": new_segments}
    return x, new_cache, aux_total
