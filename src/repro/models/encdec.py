"""Whisper-style encoder-decoder backbone.

The audio frontend (mel + 2x conv) is STUBBED per the assignment: the model
consumes precomputed frame embeddings [B, S_enc, d]. Encoder layers are
bidirectional; decoder layers are causal self-attention + cross-attention.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .base import P, is_decl
from .config import ModelConfig
from .layers import (attention_decl, attn_out, attn_qkv, dot_attention,
                     gelu_mlp, gelu_mlp_decl, layernorm, layernorm_decl,
                     sinusoidal_pos)
from .transformer import Ctx, stack_decl


def enc_block_decl(cfg: ModelConfig) -> dict:
    return {
        "ln_attn": layernorm_decl(cfg.d_model),
        "attn": attention_decl(cfg.d_model, cfg.n_heads, cfg.n_heads, cfg.head_dim),
        "ln_mlp": layernorm_decl(cfg.d_model),
        "mlp": gelu_mlp_decl(cfg.d_model, cfg.d_ff),
    }


def dec_block_decl(cfg: ModelConfig) -> dict:
    return {
        "ln_self": layernorm_decl(cfg.d_model),
        "self_attn": attention_decl(cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.head_dim),
        "ln_cross": layernorm_decl(cfg.d_model),
        "cross_attn": attention_decl(cfg.d_model, cfg.n_heads, cfg.n_heads,
                                     cfg.head_dim),
        "ln_mlp": layernorm_decl(cfg.d_model),
        "mlp": gelu_mlp_decl(cfg.d_model, cfg.d_ff),
    }


def encdec_decl(cfg: ModelConfig) -> dict:
    enc = cfg.encoder
    n_dec = cfg.n_layers
    return {
        "embed": P((cfg.vocab, cfg.d_model), ("vocab", "embed"), init="embed",
                   scale=0.02),
        "enc_blocks": stack_decl(enc_block_decl(cfg), enc.n_layers),
        "enc_norm": layernorm_decl(cfg.d_model),
        "dec_blocks": stack_decl(dec_block_decl(cfg), n_dec),
        "dec_norm": layernorm_decl(cfg.d_model),
    }


def encode(params, frames, cfg: ModelConfig, ctx: Ctx):
    """frames: [B, S_enc, d] stubbed frontend output."""
    S = frames.shape[1]
    pos_emb = jnp.asarray(sinusoidal_pos(S, cfg.d_model), frames.dtype)
    x = frames + pos_emb[None]
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(h, ps):
        xn = layernorm(ps["ln_attn"], h)
        q, k, v = attn_qkv(ps["attn"], xn, positions, use_rope=False)
        o = dot_attention(q, k, v, positions, positions, causal=False)
        h = h + attn_out(ps["attn"], o)
        h = h + gelu_mlp(ps["mlp"], layernorm(ps["ln_mlp"], h))
        return h, None

    body_fn = jax.checkpoint(body) if ctx.mode == "train" else body
    x, _ = jax.lax.scan(body_fn, x, params["enc_blocks"])
    return layernorm(params["enc_norm"], x)


def cross_kv(params, enc_out):
    """Precompute cross-attention K/V for all decoder layers: [L, B, S, H, dh]."""
    def per_layer(ps):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, ps["cross_attn"]["wk"].astype(enc_out.dtype))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, ps["cross_attn"]["wv"].astype(enc_out.dtype))
        return k, v
    return jax.vmap(per_layer)(params["dec_blocks"])


def decode_blocks(params, x, cfg: ModelConfig, ctx: Ctx, enc_k, enc_v,
                  cache=None):
    """x: [B, T, d] token embeds; enc_k/enc_v: [L, B, S_enc, H, dh]."""
    B, T, _ = x.shape
    S_enc = enc_k.shape[2]
    enc_pos = jnp.arange(S_enc, dtype=jnp.int32)

    def body(carry, xs):
        h = carry
        if cache is not None:
            ps, (ek, ev), cs = xs
        else:
            ps, (ek, ev) = xs
            cs = None
        # self attention (causal, cached in decode)
        xn = layernorm(ps["ln_self"], h)
        if ctx.mode == "decode":
            pos = ctx.cache_pos
            positions = pos[None]
            q, k_new, v_new = attn_qkv(ps["self_attn"], xn, positions,
                                       rope_theta=cfg.rope_theta)
            k = jax.lax.dynamic_update_slice_in_dim(cs["k"], k_new.astype(cs["k"].dtype), pos, axis=1)
            v = jax.lax.dynamic_update_slice_in_dim(cs["v"], v_new.astype(cs["v"].dtype), pos, axis=1)
            S = k.shape[1]
            kv_pos = jnp.arange(S, dtype=jnp.int32)
            valid = jnp.broadcast_to((kv_pos <= pos)[None], (B, S))
            o = dot_attention(q, k.astype(h.dtype), v.astype(h.dtype),
                              positions, kv_pos, causal=True, kv_valid=valid)
            new_cs = {"k": k, "v": v}
        else:
            positions = ctx.positions
            q, k, v = attn_qkv(ps["self_attn"], xn, positions,
                               rope_theta=cfg.rope_theta)
            o = dot_attention(q, k, v, positions, positions, causal=True)
            new_cs = None
            if cache is not None:  # prefill
                ck = jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cs["k"]), k.astype(cs["k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    jnp.zeros_like(cs["v"]), v.astype(cs["v"].dtype), 0, axis=1)
                new_cs = {"k": ck, "v": cv}
        h = h + attn_out(ps["self_attn"], o)
        # cross attention over encoder output
        xn = layernorm(ps["ln_cross"], h)
        qc = jnp.einsum("bsd,dhk->bshk", xn, ps["cross_attn"]["wq"].astype(h.dtype))
        q_pos = ctx.cache_pos[None] if ctx.mode == "decode" else ctx.positions
        oc = dot_attention(qc, ek.astype(h.dtype), ev.astype(h.dtype),
                           q_pos, enc_pos, causal=False)
        h = h + attn_out(ps["cross_attn"], oc)
        # mlp
        h = h + gelu_mlp(ps["mlp"], layernorm(ps["ln_mlp"], h))
        return h, new_cs

    body_fn = jax.checkpoint(body) if ctx.mode == "train" else body
    xs = (params["dec_blocks"], (enc_k, enc_v))
    if cache is not None:
        xs = xs + (cache,)
    x, new_cache = jax.lax.scan(body_fn, x, xs)
    x = layernorm(params["dec_norm"], x)
    return x, new_cache


def encdec_cache(cfg: ModelConfig, batch: int, seq_len: int,
                 dtype=jnp.bfloat16) -> dict:
    n_dec = cfg.n_layers
    shape = (n_dec, batch, seq_len, cfg.n_kv_heads, cfg.head_dim)
    return {"pos": jnp.zeros((), jnp.int32),
            "self_kv": {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)},
            "enc_k": jnp.zeros((n_dec, batch, cfg.encoder.seq, cfg.n_heads,
                                cfg.head_dim), dtype),
            "enc_v": jnp.zeros((n_dec, batch, cfg.encoder.seq, cfg.n_heads,
                                cfg.head_dim), dtype)}
