"""RWKV-6 "Finch" block: data-dependent token-shift (ddlerp), data-dependent
per-channel decay, WKV linear recurrence, and squared-ReLU channel mix.
Attention-free; decode state is O(1) in sequence length."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import P
from .layers import layernorm, layernorm_decl

LORA_R = 32
LORA_W = 64
MIX_KEYS = ("r", "k", "v", "g", "w")


def rwkv_decl(cfg) -> dict:
    d, H, dh, ff = cfg.d_model, cfg.n_heads, cfg.head_dim, cfg.d_ff
    tm = {
        "mu_x": P((d,), (None,), init="zeros"),
        "w0": P((H, dh), ("heads", None), init="zeros"),
        "u": P((H, dh), ("heads", None)),
        "lora_w1": P((d, LORA_W), ("embed", None)),
        "lora_w2": P((LORA_W, d), (None, "embed")),
        "wo": P((H, dh, d), ("heads", None, "embed")),
        "ln_x": layernorm_decl(dh),
    }
    if cfg.fused_qkv:
        # fused r/k/v/g projection: one x all-gather fwd, one dx all-reduce
        # bwd instead of four each (§Perf rwkv iteration 4)
        tm["wrkvg"] = P((d, 4, H, dh), ("embed", None, "heads", None))
    else:
        for key in ("wr", "wk", "wv", "wg"):
            tm[key] = P((d, H, dh), ("embed", "heads", None))
    for key in MIX_KEYS:
        tm[f"mu_{key}"] = P((d,), (None,), init="zeros")
        tm[f"A_{key}"] = P((d, LORA_R), ("embed", None))
        tm[f"B_{key}"] = P((LORA_R, d), (None, "embed"))
    cm = {
        "mu_k": P((d,), (None,), init="zeros"),
        "mu_r": P((d,), (None,), init="zeros"),
        "wk": P((d, ff), ("embed", "ff")),
        "wv": P((ff, d), ("ff", "embed")),
        "wr": P((d, d), ("embed", None)),
    }
    return {"ln1": layernorm_decl(d), "ln2": layernorm_decl(d), "tm": tm, "cm": cm}


def _shift(x, prev):
    """x: [B,T,d]; prev: [B,d] (last token of the previous window)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _ddlerp(p, key, x, xx, xin):
    mu = p[f"mu_{key}"].astype(x.dtype)
    lora = jnp.tanh(jnp.einsum("btd,dr->btr", xin, p[f"A_{key}"].astype(x.dtype)))
    lora = jnp.einsum("btr,rd->btd", lora, p[f"B_{key}"].astype(x.dtype))
    return x + (xx - x) * (mu + lora)


def wkv_scan(r, k, v, w, u, state):
    """Reference WKV6 recurrence via scan over time.
    r,k,v,w: [B,T,H,D]; u: [H,D]; state: [B,H,D,D] (f32). Returns y, state'."""
    B, T, H, D = r.shape
    rt = jnp.moveaxis(r, 1, 0).astype(jnp.float32)
    kt = jnp.moveaxis(k, 1, 0).astype(jnp.float32)
    vt = jnp.moveaxis(v, 1, 0).astype(jnp.float32)
    wt = jnp.moveaxis(w, 1, 0).astype(jnp.float32)

    def step(S, inp):
        r_, k_, v_, w_ = inp
        kv = jnp.einsum("bhi,bhj->bhij", k_, v_)
        y = jnp.einsum("bhi,bhij->bhj", r_, S + u[None, :, :, None] * kv)
        S = w_[..., None] * S + kv
        return S, y

    state, ys = jax.lax.scan(step, state.astype(jnp.float32), (rt, kt, vt, wt))
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), state


def wkv_chunked(r, k, v, w, u, state, chunk: int = 64):
    """Chunk-parallel WKV6 (GLA-style): O(T/c) sequential steps of MXU-friendly
    matmuls instead of T elementwise steps. Exact (fp32 accumulation)."""
    B, T, H, D = r.shape
    assert T % chunk == 0, (T, chunk)
    n = T // chunk
    f32 = jnp.float32
    rc = r.reshape(B, n, chunk, H, D).astype(f32)
    kc = k.reshape(B, n, chunk, H, D).astype(f32)
    vc = v.reshape(B, n, chunk, H, D).astype(f32)
    lw = jnp.log(jnp.maximum(w.reshape(B, n, chunk, H, D).astype(f32), 1e-38))
    # cumulative log-decay within each chunk, exclusive of self. Clamped so
    # the factorized exp() terms stay finite in f32; channels decaying below
    # e^-60 within one chunk contribute ~0 anyway (see wkv_scan oracle).
    cum = jnp.cumsum(lw, axis=2)                 # inclusive
    cum_excl = jnp.maximum(cum - lw, -60.0)
    total = jnp.maximum(cum[:, :, -1], -60.0)    # [B,n,H,D]

    def chunk_step(S, inp):
        r_, k_, v_, ce, tot, lw_ = inp           # [B,c,H,D] ...
        # inter-chunk: y += (r ⊙ prod_{<t} w) @ S
        r_dec = r_ * jnp.exp(ce)
        y_inter = jnp.einsum("bchi,bhij->bchj", r_dec, S)
        # intra-chunk: pairwise decays between positions s < t
        k_dec = k_ * jnp.exp(-ce - lw_)          # k_s / prod_{<=s} w
        att = jnp.einsum("bchi,bdhi->bhcd", r_dec, k_dec)
        mask = jnp.tril(jnp.ones((r_.shape[1], r_.shape[1]), bool), -1)
        att = jnp.where(mask[None, None], att, 0.0)
        diag = jnp.einsum("bchi,bchi,hi->bch", r_, k_, u)
        y_intra = jnp.einsum("bhcd,bdhj->bchj", att, v_) + diag[..., None] * v_
        # state update: S' = diag(prod w) S + sum_s (prod_{>s} w ⊙ k_s) v_s^T
        k_tail = k_ * jnp.exp(tot[:, None] - ce - lw_)
        S = jnp.exp(tot)[..., None] * S + jnp.einsum("bchi,bchj->bhij", k_tail, v_)
        return S, y_inter + y_intra

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0),
          jnp.moveaxis(cum_excl, 1, 0), jnp.moveaxis(total, 1, 0),
          jnp.moveaxis(lw.reshape(B, n, chunk, H, D), 1, 0))
    state, ys = jax.lax.scan(chunk_step, state.astype(f32), xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, D)
    return y.astype(r.dtype), state


def rwkv_block(p, x, cache=None, *, cfg, use_chunked=False, dist=None):
    """Full RWKV-6 layer (time mix + channel mix).
    cache: {"S": [B,H,D,D] f32, "tm_prev": [B,d], "cm_prev": [B,d]} or None."""
    from .base import constrain

    B, T, d = x.shape
    H, dh = cfg.n_heads, cfg.head_dim

    # ---- time mix ----
    xn = layernorm(p["ln1"], x)
    tm = p["tm"]
    prev = cache["tm_prev"].astype(x.dtype) if cache is not None \
        else jnp.zeros((B, d), x.dtype)
    xx = _shift(xn, prev)
    xin = xn + (xx - xn) * tm["mu_x"].astype(x.dtype)
    xr = _ddlerp(tm, "r", xn, xx, xin)
    xk = _ddlerp(tm, "k", xn, xx, xin)
    xv = _ddlerp(tm, "v", xn, xx, xin)
    xg = _ddlerp(tm, "g", xn, xx, xin)
    xw = _ddlerp(tm, "w", xn, xx, xin)

    if "wrkvg" in tm:
        # stack the four ddlerp'd inputs and project through the fused weight
        xs4 = jnp.stack([xr, xk, xv, xg], axis=2)            # [B,T,4,d]
        rkvg = jnp.einsum("btfd,dfhk->btfhk", xs4, tm["wrkvg"].astype(x.dtype))
        r, k, v, g = (rkvg[:, :, i] for i in range(4))
    else:
        r = jnp.einsum("btd,dhk->bthk", xr, tm["wr"].astype(x.dtype))
        k = jnp.einsum("btd,dhk->bthk", xk, tm["wk"].astype(x.dtype))
        v = jnp.einsum("btd,dhk->bthk", xv, tm["wv"].astype(x.dtype))
        g = jnp.einsum("btd,dhk->bthk", xg, tm["wg"].astype(x.dtype))
    wlo = jnp.einsum("btd,dr->btr", xw, tm["lora_w1"].astype(x.dtype))
    wlo = jnp.einsum("btr,rd->btd", jnp.tanh(wlo), tm["lora_w2"].astype(x.dtype))
    wln = tm["w0"].astype(jnp.float32)[None, None] + wlo.reshape(B, T, H, dh).astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wln))                                  # (0,1) decay

    if dist is not None and T > 1:
        # the WKV scan iterates the time axis: keep T *replicated* and heads
        # model-sharded here, or every scan step emits an all-gather (the
        # §Perf rwkv baseline pathology — one collective per token step)
        spec = ("batch", None, "heads", None)
        r = constrain(r, dist.rules, spec)
        k = constrain(k, dist.rules, spec)
        v = constrain(v, dist.rules, spec)
        w = constrain(w, dist.rules, spec)

    state = cache["S"] if cache is not None else jnp.zeros((B, H, dh, dh), jnp.float32)
    u = tm["u"].astype(jnp.float32)
    if use_chunked and T > 1 and T % 64 == 0:
        y, state = wkv_chunked(r, k, v, w.astype(x.dtype), u, state)
    else:
        y, state = wkv_scan(r, k, v, w.astype(x.dtype), u, state)
    y = layernorm(tm["ln_x"], y)                                 # per-head norm
    y = y * jax.nn.silu(g)
    x = x + jnp.einsum("bthk,hkd->btd", y, tm["wo"].astype(x.dtype))

    # ---- channel mix ----
    cm = p["cm"]
    xn2 = layernorm(p["ln2"], x)
    prev2 = cache["cm_prev"].astype(x.dtype) if cache is not None \
        else jnp.zeros((B, d), x.dtype)
    xx2 = _shift(xn2, prev2)
    xk2 = xn2 + (xx2 - xn2) * cm["mu_k"].astype(x.dtype)
    xr2 = xn2 + (xx2 - xn2) * cm["mu_r"].astype(x.dtype)
    kk = jnp.einsum("btd,df->btf", xk2, cm["wk"].astype(x.dtype))
    kk = jnp.square(jax.nn.relu(kk))
    out = jnp.einsum("btf,fd->btd", kk, cm["wv"].astype(x.dtype))
    rr = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr2, cm["wr"].astype(x.dtype)))
    x = x + rr * out

    new_cache = None
    if cache is not None:
        new_cache = {"S": state, "tm_prev": xn[:, -1, :].astype(jnp.float32),
                     "cm_prev": xn2[:, -1, :].astype(jnp.float32)}
    return x, new_cache


def rwkv_cache_decl(cfg, batch: int) -> dict:
    H, dh, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {"S": jnp.zeros((batch, H, dh, dh), jnp.float32),
            "tm_prev": jnp.zeros((batch, d), jnp.float32),
            "cm_prev": jnp.zeros((batch, d), jnp.float32)}
