"""Multi-head Latent Attention (DeepSeek-V2 style, as used by MiniCPM3).

The KV cache stores only the compressed latent (kv_lora_rank) plus the shared
RoPE key — decode uses the *absorbed* formulation (query projected into latent
space), so per-token decode cost is ~MQA with head_dim == kv_lora_rank and the
cache stays compressed.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .base import P
from .layers import NEG_INF, rmsnorm, rmsnorm_decl, rope


def mla_decl(cfg) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    return {
        "wq_a": P((d, m.q_lora_rank), ("embed", None)),
        "q_norm": rmsnorm_decl(m.q_lora_rank),
        "wq_b": P((m.q_lora_rank, H, dn + dr), (None, "heads", None)),
        "wkv_a": P((d, m.kv_lora_rank + dr), ("embed", None)),
        "kv_norm": rmsnorm_decl(m.kv_lora_rank),
        "wkv_b": P((m.kv_lora_rank, H, dn + dv), (None, "heads", None)),
        "wo": P((H, dv, d), ("heads", None, "embed")),
    }


def _project_q(p, x, positions, cfg):
    m = cfg.mla
    dn, dr = m.qk_nope_head_dim, m.qk_rope_head_dim
    cq = jnp.einsum("bsd,dr->bsr", x, p["wq_a"].astype(x.dtype))
    q = jnp.einsum("bsr,rhk->bshk", rmsnorm(p["q_norm"], cq),
                   p["wq_b"].astype(x.dtype))
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latent_kv(p, x, positions, cfg):
    m = cfg.mla
    dr = m.qk_rope_head_dim
    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"].astype(x.dtype))
    ckv, k_rope_raw = ckv_full[..., :m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    ckv = rmsnorm(p["kv_norm"], ckv)
    k_rope = rope(k_rope_raw[:, :, None, :], positions, cfg.rope_theta)[:, :, 0, :]
    return ckv, k_rope


def mla_attention(p, x, positions, cfg, cache=None, cache_pos=None):
    """Returns (out, new_cache). cache = {"ckv": [B,S,r], "kr": [B,S,dr]}.

    train/prefill: expand latents to full k/v (matmul-friendly).
    decode (T==1 with cache): absorbed form over the compressed cache."""
    m = cfg.mla
    B, T, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = 1.0 / math.sqrt(dn + dr)

    q_nope, q_rope = _project_q(p, x, positions, cfg)
    ckv_new, kr_new = _latent_kv(p, x, positions, cfg)

    if cache is not None and T == 1:
        # -- absorbed decode --
        pos = cache_pos
        ckv = jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(cache["kr"], kr_new.astype(cache["kr"].dtype), pos, axis=1)
        S = ckv.shape[1]
        w_k = p["wkv_b"][..., :dn].astype(x.dtype)          # [r, H, dn]
        w_v = p["wkv_b"][..., dn:].astype(x.dtype)          # [r, H, dv]
        q_lat = jnp.einsum("bqhd,rhd->bqhr", q_nope, w_k)
        scores = (jnp.einsum("bqhr,bsr->bhqs", q_lat.astype(jnp.float32),
                             ckv.astype(jnp.float32))
                  + jnp.einsum("bqhd,bsd->bhqs", q_rope.astype(jnp.float32),
                               kr.astype(jnp.float32))) * scale
        valid = jnp.arange(S) <= pos
        scores = jnp.where(valid[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx_lat = jnp.einsum("bhqs,bsr->bqhr", probs.astype(ckv.dtype), ckv)
        o = jnp.einsum("bqhr,rhd->bqhd", ctx_lat, w_v)
        out = jnp.einsum("bqhd,hdo->bqo", o, p["wo"].astype(x.dtype))
        return out, {"ckv": ckv, "kr": kr}

    # -- train / prefill: expand latents --
    kv = jnp.einsum("bsr,rhk->bshk", ckv_new, p["wkv_b"].astype(x.dtype))
    k_nope, v = kv[..., :dn], kv[..., dn:]
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(kr_new[:, :, None, :], (B, T, H, dr))],
                        axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    scores = jnp.einsum("bqhk,bshk->bhqs", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    causal = positions[:, None] >= positions[None, :]
    scores = jnp.where(causal[None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqs,bshd->bqhd", probs.astype(v.dtype), v)
    out = jnp.einsum("bqhd,hdo->bqo", o, p["wo"].astype(x.dtype))

    new_cache = None
    if cache is not None:  # prefill fills the compressed cache
        S = cache["ckv"].shape[1]
        ckv_c = jnp.zeros_like(cache["ckv"])
        kr_c = jnp.zeros_like(cache["kr"])
        ckv_c = jax.lax.dynamic_update_slice_in_dim(ckv_c, ckv_new.astype(ckv_c.dtype), 0, axis=1)
        kr_c = jax.lax.dynamic_update_slice_in_dim(kr_c, kr_new.astype(kr_c.dtype), 0, axis=1)
        new_cache = {"ckv": ckv_c, "kr": kr_c}
    return out, new_cache


def mla_cache_decl(cfg, batch: int, max_seq: int, dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    return {"ckv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "kr": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype)}
