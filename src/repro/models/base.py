"""Minimal module-lite substrate: parameter declaration trees.

A model is declared as a nested dict of ``P`` leaves (shape + logical axes +
init).  From one declaration we derive, structurally:
  * init_tree     — materialized jnp parameters
  * abstract_tree — ShapeDtypeStructs (for dry-run lowering, no allocation)
  * spec_tree     — jax.sharding.PartitionSpec per leaf via logical-axis rules

Logical axes: "embed", "heads", "kv_heads", "head_dim", "ff", "vocab",
"experts", "lru", "conv", "layers" (stack, never sharded), None.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter declaration."""

    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]
    init: str = "normal"      # normal | zeros | ones | embed
    scale: Optional[float] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _init_leaf(key, p: P, dtype) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, dtype)
    if p.init == "embed":
        scale = p.scale or 1.0
        return jax.random.normal(key, p.shape, dtype) * scale
    fan_in = p.shape[-2] if len(p.shape) >= 2 else p.shape[-1]
    scale = p.scale or (1.0 / math.sqrt(max(fan_in, 1)))
    return jax.random.normal(key, p.shape, dtype) * scale


def is_decl(x) -> bool:
    return isinstance(x, P)


def init_tree(decl, rng, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(decl, is_leaf=is_decl)
    keys = jax.random.split(rng, len(leaves))
    vals = [_init_leaf(k, p, dtype) for k, p in zip(keys, leaves)]
    return jax.tree.unflatten(treedef, vals)


def abstract_tree(decl, dtype=jnp.float32):
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, dtype), decl, is_leaf=is_decl)


def param_count(decl) -> int:
    return sum(int(np.prod(p.shape))
               for p in jax.tree.leaves(decl, is_leaf=is_decl))


# ---------------------------------------------------------------------------
# logical-axis -> mesh-axis rules
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical parameter/activation axes onto mesh axes."""

    embed: Any = "data"        # FSDP / ZeRO-3: weight d_model dim over data
    heads: Any = "model"       # Megatron TP
    kv_heads: Any = "model"
    head_dim: Any = None
    ff: Any = "model"
    vocab: Any = "model"
    experts: Any = "model"     # EP when divisible (checked per model)
    lru: Any = "model"
    conv: Any = None
    batch: Any = ("pod", "data")
    seq: Any = None            # SP for long-context decode
    kv_seq: Any = None
    layers: Any = None

    def spec_for(self, axes: tuple[Optional[str], ...]) -> PartitionSpec:
        return PartitionSpec(*(getattr(self, a) if a else None for a in axes))


def spec_tree(decl, rules: ShardingRules, mesh=None):
    """Specs per leaf; when `mesh` is given, drop shardings whose mesh-axis
    product does not divide the dimension (e.g. GQA kv_heads=8 on model=16 —
    those weights replicate across TP ranks, the standard GQA fallback)."""

    def leaf(p: P):
        spec = rules.spec_for(p.axes)
        if mesh is None:
            return spec
        fixed = []
        for dim, part in zip(p.shape, spec):
            if part is None:
                fixed.append(None)
                continue
            parts = part if isinstance(part, tuple) else (part,)
            prod = 1
            for a in parts:
                prod *= mesh.shape[a]
            fixed.append(part if dim % prod == 0 else None)
        return PartitionSpec(*fixed)

    return jax.tree.map(leaf, decl, is_leaf=is_decl)


def constrain(x, rules: ShardingRules, axes: tuple[Optional[str], ...]):
    """with_sharding_constraint by logical axes (no-op without a mesh)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec_for(axes))
    except (ValueError, RuntimeError):
        return x  # no mesh context (single-device smoke tests)
