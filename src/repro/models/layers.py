"""Core transformer layers: norms, RoPE, GQA attention (full / sliding-window
/ local / cross), SwiGLU + GELU MLPs.  Pure functions over param dicts."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from .base import P

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm_decl(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_decl(d: int) -> dict:
    return {"scale": P((d,), (None,), init="ones"),
            "bias": P((d,), (None,), init="zeros")}


def layernorm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------


def rope(x, positions, theta: float = 10000.0):
    """x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    half = d // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos(seq: int, d: int) -> np.ndarray:
    pos = np.arange(seq)[:, None]
    i = np.arange(d // 2)[None, :]
    angles = pos / np.power(10000.0, 2 * i / d)
    return np.concatenate([np.sin(angles), np.cos(angles)], axis=-1).astype(np.float32)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def attention_decl(d: int, n_heads: int, n_kv: int, head_dim: int,
                   qk_norm: bool = False, fused: bool = False) -> dict:
    if fused:
        # single in-projection [d, (H + 2*Hkv) * dh]: one x all-gather / one
        # dx partial all-reduce per block instead of three (§Perf)
        decl = {
            "wqkv": P((d, (n_heads + 2 * n_kv) * head_dim), ("embed", "heads")),
            "wo": P((n_heads, head_dim, d), ("heads", None, "embed")),
        }
    else:
        decl = {
            "wq": P((d, n_heads, head_dim), ("embed", "heads", None)),
            "wk": P((d, n_kv, head_dim), ("embed", "kv_heads", None)),
            "wv": P((d, n_kv, head_dim), ("embed", "kv_heads", None)),
            "wo": P((n_heads, head_dim, d), ("heads", None, "embed")),
        }
    if qk_norm:
        decl["q_norm"] = rmsnorm_decl(head_dim)
        decl["k_norm"] = rmsnorm_decl(head_dim)
    return decl


def _mask(q_pos, kv_pos, causal: bool, window: int):
    """[Sq, Skv] additive mask from position vectors."""
    m = jnp.ones((q_pos.shape[-1], kv_pos.shape[-1]), bool)
    if causal:
        m &= q_pos[:, None] >= kv_pos[None, :]
    if window > 0:
        m &= (q_pos[:, None] - kv_pos[None, :]) < window
    return jnp.where(m, 0.0, NEG_INF)


def dot_attention(q, k, v, q_pos, kv_pos, *, causal=True, window=0,
                  kv_valid=None):
    """GQA attention.
    q: [B,Sq,H,D]  k,v: [B,Skv,Hkv,D]  q_pos: [Sq]  kv_pos: [Skv]
    kv_valid: optional [B,Skv] bool (cache slots filled)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Sq, Hkv, G, D)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(D)
    scores = scores + _mask(q_pos, kv_pos, causal, window)[None, None, None]
    if kv_valid is not None:
        scores = scores + jnp.where(kv_valid, 0.0, NEG_INF)[:, None, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs.astype(v.dtype), v)
    return out.reshape(B, Sq, H, D)


def attn_qkv(p, x, positions, *, rope_theta=10000.0, qk_norm=False,
             use_rope=True, n_heads=None, n_kv=None, head_dim=None):
    """Project to q,k,v with optional RoPE + qk-norm."""
    if "wqkv" in p:
        B, S, _ = x.shape
        qkv = jnp.einsum("bsd,df->bsf", x, p["wqkv"].astype(x.dtype))
        H, Hkv, D = n_heads, n_kv, head_dim
        q = qkv[..., : H * D].reshape(B, S, H, D)
        k = qkv[..., H * D: (H + Hkv) * D].reshape(B, S, Hkv, D)
        v = qkv[..., (H + Hkv) * D:].reshape(B, S, Hkv, D)
    else:
        q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if qk_norm:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if use_rope:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    return q, k, v


def attn_out(p, o):
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"].astype(o.dtype))


def cross_attention_decl(d: int, n_heads: int, head_dim: int) -> dict:
    return attention_decl(d, n_heads, n_heads, head_dim)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def swiglu_decl(d: int, ff: int) -> dict:
    return {"w_gate": P((d, ff), ("embed", "ff")),
            "w_up": P((d, ff), ("embed", "ff")),
            "w_down": P((ff, d), ("ff", "embed"))}


def swiglu(p, x):
    g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("bsf,fd->bsd", jax.nn.silu(g) * u,
                      p["w_down"].astype(x.dtype))


def gelu_mlp_decl(d: int, ff: int) -> dict:
    return {"w_up": P((d, ff), ("embed", "ff")),
            "b_up": P((ff,), ("ff",), init="zeros"),
            "w_down": P((ff, d), ("ff", "embed")),
            "b_down": P((d,), (None,), init="zeros")}


def gelu_mlp(p, x):
    h = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype)) + p["b_up"].astype(x.dtype)
    h = jax.nn.gelu(h)
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype)) + p["b_down"].astype(x.dtype)
