"""Long-sequence sparse-feature delta encoding (Bullion §2.2, Figs. 3-4).

``clk_seq_cids``-style features are ``list<int64>`` vectors sorted by
(user, timestamp); consecutive vectors overlap in a *sliding window*: a few
new ids enter at the head, old ids fall off the tail.  Per row we store

    <delta bit> <delta range (start, len) into previous row>
    <len(head), head data> <len(tail), tail data>

with delta_bit=0 rows storing the full base vector.  Metadata/index arrays are
bit-packed/varint-cascaded; bulk id data is cascaded (typically chunked/zstd),
matching Fig. 4's on-disk layout (metadata + indexes first, bulk data after).
"""

from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

from .encodings import EncodeContext, decode_blob, encode_array
from .encodings.numeric import _cat, _split2

MAX_SEARCH = 32  # max head length / window start probed per row


def _best_overlap(prev: np.ndarray, cur: np.ndarray) -> tuple[int, int, int]:
    """Longest contiguous run cur[i_cur:i_cur+L] == prev[i_prev:i_prev+L].

    Returns (i_cur, i_prev, L); (0, 0, 0) when nothing useful matches.
    Search is restricted to small head offsets (the sliding-window pattern):
    new ids are prepended, the window into prev starts near its head.
    """
    best = (0, 0, 0)
    np_len, nc_len = len(prev), len(cur)
    if np_len == 0 or nc_len == 0:
        return best

    def probe(i_cur: int, i_prev: int) -> None:
        nonlocal best
        span = min(np_len - i_prev, nc_len - i_cur)
        if span <= best[2]:
            return
        neq = np.flatnonzero(cur[i_cur:i_cur + span] != prev[i_prev:i_prev + span])
        run = span if len(neq) == 0 else int(neq[0])
        if run > best[2]:
            best = (i_cur, i_prev, run)

    # the sliding-window pattern is one-sided: either new ids were prepended
    # (window starts at prev[0], head of length i_cur) or ids were dropped
    # from the head (window starts inside prev, no head).
    for i_cur in range(min(MAX_SEARCH, nc_len)):
        probe(i_cur, 0)
    for i_prev in range(1, min(MAX_SEARCH, np_len)):
        probe(0, i_prev)
    return best


def encode_page(rows: list[np.ndarray], ctx: EncodeContext | None = None) -> bytes:
    """Encode a page of list<int64> rows with sliding-window delta."""
    ctx = ctx or EncodeContext()
    n = len(rows)
    delta_bit = np.zeros(n, bool)
    win_start = np.zeros(n, np.uint32)   # i_prev
    win_len = np.zeros(n, np.uint32)     # L
    head_len = np.zeros(n, np.uint32)    # i_cur
    tail_len = np.zeros(n, np.uint32)
    row_len = np.asarray([len(r) for r in rows], np.uint32)
    bulk: list[np.ndarray] = []

    prev: np.ndarray | None = None
    for i, cur in enumerate(rows):
        cur = np.asarray(cur, np.int64)
        if prev is not None:
            i_cur, i_prev, L = _best_overlap(prev, cur)
            if L >= max(8, len(cur) // 4):  # profitable overlap
                delta_bit[i] = True
                win_start[i], win_len[i] = i_prev, L
                head_len[i] = i_cur
                tail_len[i] = len(cur) - i_cur - L
                bulk.append(cur[:i_cur])            # head data
                bulk.append(cur[i_cur + L:])        # tail data
                prev = cur
                continue
        bulk.append(cur)                            # base vector
        prev = cur

    meta_blobs = [
        encode_array(delta_bit, ctx.child()),
        encode_array(win_start, ctx.child()),
        encode_array(win_len, ctx.child()),
        encode_array(head_len, ctx.child()),
        encode_array(tail_len, ctx.child()),
        encode_array(row_len, ctx.child()),
    ]
    bulk_vals = np.concatenate(bulk) if bulk else np.zeros(0, np.int64)
    bulk_blob = encode_array(bulk_vals, ctx.child())

    payload = b"".join(struct.pack("<Q", len(b)) + b for b in meta_blobs)
    payload += struct.pack("<Q", len(bulk_blob)) + bulk_blob
    return struct.pack("<Q", n) + payload


def decode_page(blob: bytes | memoryview) -> list[np.ndarray]:
    mv = memoryview(blob)
    (n,) = struct.unpack_from("<Q", mv)
    off = 8
    parts = []
    for _ in range(7):
        (ln,) = struct.unpack_from("<Q", mv, off)
        parts.append(mv[off + 8: off + 8 + ln])
        off += 8 + ln
    delta_bit = decode_blob(parts[0]).astype(bool)
    win_start = decode_blob(parts[1]).astype(np.int64)
    win_len = decode_blob(parts[2]).astype(np.int64)
    head_len = decode_blob(parts[3]).astype(np.int64)
    tail_len = decode_blob(parts[4]).astype(np.int64)
    row_len = decode_blob(parts[5]).astype(np.int64)
    bulk = decode_blob(parts[6]).astype(np.int64)

    rows: list[np.ndarray] = []
    b = 0
    prev: np.ndarray | None = None
    for i in range(n):
        if not delta_bit[i]:
            cur = bulk[b:b + row_len[i]]
            b += row_len[i]
        else:
            head = bulk[b:b + head_len[i]]
            b += head_len[i]
            tail = bulk[b:b + tail_len[i]]
            b += tail_len[i]
            window = prev[win_start[i]:win_start[i] + win_len[i]]
            cur = np.concatenate([head, window, tail])
        rows.append(cur)
        prev = cur
    return rows


@dataclass
class SyntheticClickSeq:
    """Generator reproducing Fig. 3's sliding-window click sequences."""

    seq_len: int = 256
    id_range: int = 1 << 20
    new_per_step_max: int = 4

    def generate(self, n_rows: int, seed: int = 0) -> list[np.ndarray]:
        rng = np.random.default_rng(seed)
        cur = rng.integers(0, self.id_range, self.seq_len).astype(np.int64)
        rows = [cur.copy()]
        for _ in range(n_rows - 1):
            k = int(rng.integers(0, self.new_per_step_max + 1))
            new = rng.integers(0, self.id_range, k).astype(np.int64)
            cur = np.concatenate([new, cur])[: self.seq_len]
            rows.append(cur.copy())
        return rows
