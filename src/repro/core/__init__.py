"""Bullion core: the paper's columnar storage system (primary contribution).

Submodules: encodings (§2.6 cascading framework), footer/reader (§2.3 wide
table projection), writer/multimodal (§2.5 quality-aware organization),
deletion/merkle (§2.1 compliance), quantization (§2.4), sparse_delta (§2.2),
backend (storage backends: local pread / object-store ranged GETs behind
``bullion://`` URIs / the async batched range fetcher).
"""

from .backend import (ObjectStoreBackend, RetryPolicy, StorageBackend,
                      configure_object_store, open_shard, register_backend)

from .deletion import (Compliance, DeleteStats, delete_rows, delete_where,
                       verify_deleted)
from .encodings import (CostWeights, EncodeContext, choose_encoding,
                        decode_blob, encode_array, mask_blob)
from .footer import ColKind, FooterView, PageType, Sec, read_footer
from .merkle import MerkleTree, page_hash
from .multimodal import (MediaStore, MultimodalSample, quality_filtered_read,
                         write_multimodal_dataset)
from .quantization import (QuantMode, QuantSpec, affine_spec_for, dequantize,
                           quantize, rejoin_dual_fp16, suggest_spec)
from .reader import BullionReader
from .writer import BullionWriter, ColumnSpec, quality_sort

__all__ = [
    "BullionReader", "BullionWriter", "ColumnSpec", "ColKind", "Compliance",
    "ObjectStoreBackend", "RetryPolicy", "StorageBackend",
    "configure_object_store", "open_shard", "register_backend",
    "CostWeights", "DeleteStats", "EncodeContext", "FooterView", "MediaStore",
    "MerkleTree", "MultimodalSample", "PageType", "QuantMode", "QuantSpec",
    "Sec", "affine_spec_for", "choose_encoding", "decode_blob", "delete_rows",
    "delete_where", "dequantize", "encode_array", "mask_blob", "page_hash",
    "quality_sort",
    "quality_filtered_read", "quantize", "read_footer", "rejoin_dual_fp16",
    "suggest_spec", "verify_deleted", "write_multimodal_dataset",
]
