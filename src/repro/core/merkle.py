"""Merkle-tree checksums (Bullion §2.1, Fig. 2).

Page hashes are leaves; each row group's checksum combines its page hashes;
the file checksum combines group checksums.  A page update therefore only
re-hashes the touched page + its group + the root — never the whole file,
unlike the monolithic whole-file checksums of legacy columnar formats.
"""

from __future__ import annotations

import hashlib

import numpy as np


def page_hash(data: bytes | memoryview) -> int:
    return int.from_bytes(hashlib.blake2b(bytes(data), digest_size=8).digest(), "little")


def combine(hashes: np.ndarray) -> int:
    """Order-sensitive combine of child hashes (u64 array)."""
    return page_hash(np.ascontiguousarray(hashes, np.uint64).tobytes())


class MerkleTree:
    """page checksums -> group checksums -> file checksum, with incremental
    update on page change."""

    def __init__(self, page_checksums: np.ndarray, chunk_page_start: np.ndarray,
                 n_groups: int, n_cols: int):
        self.pages = np.asarray(page_checksums, np.uint64).copy()
        self.chunk_page_start = np.asarray(chunk_page_start, np.uint64)
        self.n_groups = n_groups
        self.n_cols = n_cols
        self.groups = np.zeros(n_groups, np.uint64)
        for g in range(n_groups):
            self.groups[g] = combine(self._group_slice(g))
        self.root = combine(self.groups)
        self.hash_ops = 0  # instrumentation for the deletion benchmark

    def _group_slice(self, g: int) -> np.ndarray:
        s = int(self.chunk_page_start[g * self.n_cols])
        e = int(self.chunk_page_start[(g + 1) * self.n_cols])
        return self.pages[s:e]

    def group_of_page(self, page: int) -> int:
        # chunk_page_start is monotone; group boundaries every n_cols entries
        idx = int(np.searchsorted(self.chunk_page_start, page, side="right")) - 1
        return min(idx // self.n_cols, self.n_groups - 1)

    def update_page(self, page: int, new_data: bytes) -> None:
        """Incremental path: leaf -> group -> root (the red arrows in Fig. 2)."""
        self.pages[page] = np.uint64(page_hash(new_data))
        g = self.group_of_page(page)
        self.groups[g] = np.uint64(combine(self._group_slice(g)))
        self.root = combine(self.groups)
        self.hash_ops += 3

    def full_recompute(self) -> int:
        """Monolithic baseline: re-derive everything (legacy formats)."""
        for g in range(self.n_groups):
            self.groups[g] = np.uint64(combine(self._group_slice(g)))
        self.root = combine(self.groups)
        self.hash_ops += self.n_groups + 1
        return self.root
