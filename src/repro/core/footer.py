"""Bullion compact binary footer (paper §2.3).

The footer is a flat sequence of fixed-dtype sections plus a fixed-size
directory; a reader creates **numpy views directly over the footer bytes with
no deserialization step** (Cap'n-Proto/FlatBuffers style).  Column lookup is a
binary search over a sorted name-hash array — O(log n_cols), independent of
table width, which is what keeps Fig. 5 flat while Parquet-style thrift
metadata grows linearly.

File layout:

    [pages ...][footer][u64 footer_len][8-byte magic]

Footer layout:

    [section payloads ...][directory: n * (u16 sid, u64 off, u64 size)]
    [u32 n_sections]
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from enum import IntEnum

import numpy as np

MAGIC = b"BULLION1"
_DIR_ENTRY = struct.Struct("<HQQ")
_TAIL = struct.Struct("<Q8s")


class ShardCorruptError(ValueError):
    """A shard failed structural validation (torn write, bad magic,
    truncated data region) or decode-time checksum verification.

    Subclasses ``ValueError`` so pre-existing ``except (OSError,
    ValueError)`` handlers keep treating corrupt shards as unreadable
    input; new code catches the typed error and reads ``path`` /
    ``reason`` / ``group`` / ``page`` directly."""

    def __init__(self, path: str, reason: str, *,
                 group: int | None = None, page: int | None = None):
        self.path = str(path)
        self.reason = reason
        self.group = group
        self.page = page
        loc = ""
        if group is not None or page is not None:
            loc = f" (group {group}, page {page})"
        super().__init__(f"{self.path}: corrupt shard{loc}: {reason}")

# Format versions (META word 7). Readers never gate on the version number —
# capabilities are detected by section presence (``has``) — so every older
# file remains fully readable: v0 files lack stats sections and never prune,
# v1 files lack the page-count index and read as one page per chunk.
FORMAT_V0 = 0             # seed format: no statistics sections
FORMAT_V1 = 1             # + PAGE_STATS / CHUNK_STATS zone maps
FORMAT_V2 = 2             # + CHUNK_PAGE_COUNT (multi-page chunks)
FORMAT_V3 = 3             # + CHUNK_SKETCH / PAGE_SKETCH bloom value sketches
FORMAT_VERSION = FORMAT_V3


class Sec(IntEnum):
    META = 0              # u64[8]: num_rows, n_cols, n_groups, n_pages, rows_per_group, compliance, file_checksum, format_version
    NAMES_DATA = 1        # raw bytes of all column names
    NAMES_OFFSETS = 2     # u32[n_cols + 1]
    NAME_HASH_SORTED = 3  # u64[n_cols]
    NAME_HASH_ORDER = 4   # u32[n_cols] column index per sorted hash
    COL_DTYPE = 5         # u8[n_cols]  (base.dtype_code of value dtype)
    COL_KIND = 6          # u8[n_cols]  0=scalar 1=list 2=string 3=media_ref
    COL_LOGICAL = 7       # u8[n_cols]  original (pre-quantization) dtype code
    ROWS_PER_GROUP = 8    # u32[n_groups]
    CHUNK_PAGE_START = 9  # u64[n_groups * n_cols] page index per logical chunk
    PAGE_OFFSET = 10      # u64[n_pages]
    PAGE_SIZE = 11        # u64[n_pages]
    PAGE_ROWS = 12        # u32[n_pages]
    PAGE_CHECKSUM = 13    # u64[n_pages]
    PAGE_FLAGS = 14       # u8[n_pages] page payload type
    DV_OFFSET = 15        # u64[n_pages] into DV_DATA (u64max = none)
    DV_SIZE = 16          # u32[n_pages]
    DV_DATA = 17          # bitmap bytes
    GROUP_CHECKSUM = 18   # u64[n_groups]
    QUANT_META = 19       # packed per-column quantization params
    PROPS = 20            # optional key\0value\0... (cold; parsed on demand)
    PAGE_STATS = 21       # STAT_DTYPE[n_pages] zone maps (v1+, see scan.stats)
    CHUNK_STATS = 22      # STAT_DTYPE[n_groups * n_cols] per-chunk zone maps (v1+)
    CHUNK_PAGE_COUNT = 23  # u32[n_groups * n_cols] pages per chunk (v2+; absent = 1)
    CHUNK_SKETCH = 24     # u64[n_groups * n_cols] offset into SKETCH_DATA (v3+; u64max = none)
    PAGE_SKETCH = 25      # u64[n_pages] offset into SKETCH_DATA (v3+; u64max = none)
    SKETCH_DATA = 26      # self-describing bloom blobs (see scan.sketch)


class PageType(IntEnum):
    SCALAR = 0
    LIST = 1
    STRING = 2
    SPARSE_DELTA = 3   # §2.2 long-sequence sliding-window delta page
    MEDIA_REF = 4


class ColKind(IntEnum):
    SCALAR = 0
    LIST = 1
    STRING = 2
    MEDIA_REF = 3


def name_hash(name: str) -> int:
    """FNV-1a 64-bit — cheap, deterministic, no deserialization needed."""
    h = 0xCBF29CE484222325
    for b in name.encode():
        h = ((h ^ b) * 0x100000001B3) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclass
class FooterBuilder:
    sections: dict[int, bytes] = field(default_factory=dict)

    def put(self, sid: Sec, data: bytes | np.ndarray) -> None:
        if isinstance(data, np.ndarray):
            data = np.ascontiguousarray(data).tobytes()
        self.sections[int(sid)] = data

    def build(self) -> bytes:
        payloads, directory = [], []
        off = 0
        for sid in sorted(self.sections):
            data = self.sections[sid]
            directory.append(_DIR_ENTRY.pack(sid, off, len(data)))
            payloads.append(data)
            off += len(data)
        return b"".join(payloads) + b"".join(directory) + struct.pack("<I", len(directory))


class FooterView:
    """Zero-deserialization footer access: every section is a numpy view or
    memoryview over the original footer buffer."""

    def __init__(self, buf: bytes | memoryview):
        self._buf = memoryview(buf)
        (n_sections,) = struct.unpack_from("<I", self._buf, len(self._buf) - 4)
        dir_start = len(self._buf) - 4 - n_sections * _DIR_ENTRY.size
        self._dir: dict[int, tuple[int, int]] = {}
        for i in range(n_sections):
            sid, off, size = _DIR_ENTRY.unpack_from(self._buf, dir_start + i * _DIR_ENTRY.size)
            self._dir[sid] = (off, size)

    # -- raw access -----------------------------------------------------------
    def raw(self, sid: Sec) -> memoryview:
        off, size = self._dir[int(sid)]
        return self._buf[off:off + size]

    def arr(self, sid: Sec, dtype) -> np.ndarray:
        return np.frombuffer(self.raw(sid), dtype=dtype)

    def has(self, sid: Sec) -> bool:
        return int(sid) in self._dir

    # -- typed views ----------------------------------------------------------
    @property
    def meta(self) -> np.ndarray:
        return self.arr(Sec.META, np.uint64)

    @property
    def num_rows(self) -> int: return int(self.meta[0])

    @property
    def n_cols(self) -> int: return int(self.meta[1])

    @property
    def n_groups(self) -> int: return int(self.meta[2])

    @property
    def n_pages(self) -> int: return int(self.meta[3])

    @property
    def compliance(self) -> int: return int(self.meta[5])

    @property
    def file_checksum(self) -> int: return int(self.meta[6])

    @property
    def format_version(self) -> int: return int(self.meta[7])

    # -- write-time statistics (v1+; absent on v0 files) ----------------------
    @property
    def has_stats(self) -> bool:
        return self.has(Sec.CHUNK_STATS)

    def page_stats(self) -> np.ndarray | None:
        """STAT_DTYPE[n_pages] view, or None on stat-less (v0) files."""
        if not self.has(Sec.PAGE_STATS):
            return None
        from ..scan.stats import STAT_DTYPE
        return self.arr(Sec.PAGE_STATS, STAT_DTYPE)

    def chunk_stats(self) -> np.ndarray | None:
        """STAT_DTYPE[n_groups * n_cols] view (row-group zone maps), or None."""
        if not self.has(Sec.CHUNK_STATS):
            return None
        from ..scan.stats import STAT_DTYPE
        return self.arr(Sec.CHUNK_STATS, STAT_DTYPE)

    # -- value sketches (v3+; absent on older files) ---------------------------
    @property
    def has_sketches(self) -> bool:
        return self.has(Sec.CHUNK_SKETCH)

    def _sketch_at(self, sid: Sec, idx: int):
        if not self.has(sid):
            return None
        off = self.arr(sid, np.uint64)[idx]
        if off == np.uint64(0xFFFFFFFFFFFFFFFF):
            return None
        from ..scan.sketch import BloomSketch
        return BloomSketch.from_buffer(self.raw(Sec.SKETCH_DATA), int(off))

    def chunk_sketch(self, group: int, col: int):
        """BloomSketch over the chunk's distinct values, or None (no sketch
        section, or this chunk skipped sketching). Absent = prune nothing."""
        return self._sketch_at(Sec.CHUNK_SKETCH, group * self.n_cols + col)

    def page_sketch(self, page: int):
        """BloomSketch over one page's distinct values, or None."""
        return self._sketch_at(Sec.PAGE_SKETCH, page)

    def column_index(self, name: str) -> int:
        """Binary map scan (paper's term): O(log n_cols), no parsing."""
        hashes = self.arr(Sec.NAME_HASH_SORTED, np.uint64)
        order = self.arr(Sec.NAME_HASH_ORDER, np.uint32)
        h = np.uint64(name_hash(name))
        i = int(np.searchsorted(hashes, h))
        offs = self.arr(Sec.NAMES_OFFSETS, np.uint32)
        names_data = self.raw(Sec.NAMES_DATA)
        while i < len(hashes) and hashes[i] == h:  # hash-collision probe
            ci = int(order[i])
            if bytes(names_data[offs[ci]:offs[ci + 1]]).decode() == name:
                return ci
            i += 1
        raise KeyError(name)

    def column_names(self) -> list[str]:
        offs = self.arr(Sec.NAMES_OFFSETS, np.uint32)
        data = self.raw(Sec.NAMES_DATA)
        return [bytes(data[offs[i]:offs[i + 1]]).decode() for i in range(self.n_cols)]

    # -- page addressing -------------------------------------------------------
    def chunk_pages(self, group: int, col: int) -> tuple[int, int]:
        """Return [start, end) page-index range for (row-group, column).
        A chunk holds ``CHUNK_PAGE_COUNT`` consecutive pages (v2+); files
        without the section (v0/v1) are one page per chunk. Layout order may
        differ from logical order (§2.5 column reordering), hence an explicit
        per-chunk index."""
        idx = group * self.n_cols + col
        p = int(self.arr(Sec.CHUNK_PAGE_START, np.uint64)[idx])
        if self.has(Sec.CHUNK_PAGE_COUNT):
            return p, p + int(self.arr(Sec.CHUNK_PAGE_COUNT, np.uint32)[idx])
        return p, p + 1

    def chunk_page_rows(self, group: int, col: int) -> np.ndarray:
        """Per-page row counts of one chunk (u32 view into PAGE_ROWS).
        Pages partition the chunk's rows in order: page k covers group-local
        rows [sum(rows[:k]), sum(rows[:k+1]))."""
        s, e = self.chunk_pages(group, col)
        return self.arr(Sec.PAGE_ROWS, np.uint32)[s:e]

    def group_page_start(self) -> np.ndarray:
        """u64[n_groups + 1] page-index boundary per row group (the Merkle
        tree's group partition). Derived: a group's pages are contiguous, so
        its first page is the min chunk start across its columns; v0/v1
        files degrade to exactly n_cols pages per group."""
        if self.n_groups == 0:
            return np.zeros(1, np.uint64)
        starts = self.arr(Sec.CHUNK_PAGE_START, np.uint64)
        mins = starts.reshape(self.n_groups, self.n_cols).min(axis=1)
        return np.concatenate([mins, np.asarray([self.n_pages], np.uint64)])

    def page_extent(self, page: int) -> tuple[int, int]:
        off = self.arr(Sec.PAGE_OFFSET, np.uint64)[page]
        size = self.arr(Sec.PAGE_SIZE, np.uint64)[page]
        return int(off), int(size)

    def deletion_vector(self, page: int) -> np.ndarray | None:
        """Decoded DV: bool array of page_rows, True = deleted."""
        dvo = self.arr(Sec.DV_OFFSET, np.uint64)[page]
        if dvo == np.uint64(0xFFFFFFFFFFFFFFFF):
            return None
        size = int(self.arr(Sec.DV_SIZE, np.uint32)[page])
        rows = int(self.arr(Sec.PAGE_ROWS, np.uint32)[page])
        raw = np.frombuffer(self.raw(Sec.DV_DATA), np.uint8, count=size, offset=int(dvo))
        return np.unpackbits(raw, count=rows, bitorder="little").astype(bool)

    def props(self) -> dict[str, str]:
        if not self.has(Sec.PROPS):
            return {}
        parts = bytes(self.raw(Sec.PROPS)).split(b"\x00")
        return {parts[i].decode(): parts[i + 1].decode()
                for i in range(0, len(parts) - 1, 2)}


# -- metadata-cache invalidation hooks ---------------------------------------
#
# Higher layers may cache parsed footers keyed by path (the dataset layer's
# process-wide footer cache). Core-layer rewriters (``BullionWriter.close``,
# ``deletion.delete_rows``) must be able to invalidate those caches without
# importing upward, so cache owners register a callback here; with no cache
# ever imported the list stays empty and notification is a no-op.

_footer_invalidators: list = []


def register_footer_invalidator(fn) -> None:
    """Register ``fn(path)`` to be called whenever a Bullion file at
    ``path`` is rewritten in-process."""
    if fn not in _footer_invalidators:
        _footer_invalidators.append(fn)


def notify_footer_rewrite(path: str) -> None:
    """Tell every registered metadata cache that ``path`` was rewritten."""
    for fn in _footer_invalidators:
        fn(path)


def parse_footer(buf: bytes | memoryview, foot_off: int,
                 path: str) -> FooterView:
    """Construct a ``FooterView`` with torn-write structural validation.

    A crash mid-write (or a truncating copy) can leave a tail whose
    ``footer_len`` points at arbitrary bytes; naive ``FooterView``
    construction then produces struct-unpack garbage or views into
    nonsense extents. Every entry point that trusts a footer — local
    ``read_footer``, the backend's speculative-tail read — funnels
    through here so a torn file of any format version (v0–v3) surfaces
    as a typed ``ShardCorruptError`` instead."""
    if len(buf) < 4:
        raise ShardCorruptError(
            path, f"footer too small ({len(buf)} byte(s))")
    (n_sections,) = struct.unpack_from("<I", buf, len(buf) - 4)
    dir_bytes = n_sections * _DIR_ENTRY.size
    if dir_bytes + 4 > len(buf):
        raise ShardCorruptError(
            path, f"footer directory ({n_sections} section(s)) exceeds "
                  f"footer size {len(buf)}")
    try:
        fv = FooterView(buf)
    except (struct.error, ValueError) as e:  # pragma: no cover - belt
        raise ShardCorruptError(path, f"footer parse failed: {e}") from None
    payload_end = len(fv._buf) - 4 - dir_bytes
    for sid, (off, size) in fv._dir.items():
        if off < 0 or size < 0 or off + size > payload_end:
            raise ShardCorruptError(
                path, f"section {sid} extent [{off}, +{size}) outside "
                      f"footer payload [0, {payload_end})")
    if not fv.has(Sec.META) or len(fv.raw(Sec.META)) < 64:
        raise ShardCorruptError(path, "META section missing or short")
    if fv.has(Sec.PAGE_OFFSET) and fv.has(Sec.PAGE_SIZE):
        offs = fv.arr(Sec.PAGE_OFFSET, np.uint64)
        sizes = fv.arr(Sec.PAGE_SIZE, np.uint64)
        if len(offs) != len(sizes):
            raise ShardCorruptError(
                path, "PAGE_OFFSET / PAGE_SIZE length mismatch")
        if len(offs):
            # guard the uint64 add against wrap before trusting max()
            if int(offs.max()) > foot_off or int(sizes.max()) > foot_off:
                raise ShardCorruptError(
                    path, "data region truncated: page extent beyond the "
                          f"footer offset {foot_off}")
            end = int((offs + sizes).max())
            if end > foot_off:
                raise ShardCorruptError(
                    path, f"data region truncated: page data ends at {end} "
                          f"but the data region is [0, {foot_off})")
    return fv


def read_footer(path: str) -> tuple[FooterView, int]:
    """Read footer with two preads (tail, then footer) — the paper's access
    pattern. Returns (view, footer_offset). ``bullion://`` URIs route
    through their storage backend (one speculative tail GET) instead of the
    local filesystem, as do local paths while a chaos/test backend is
    registered for the ``file`` scheme (so fault injection covers footer
    reads too). Torn files raise ``ShardCorruptError``."""
    from . import backend as _backend
    if _backend.is_remote(path) or _backend.has_custom_local_backend():
        with _backend.open_shard(path) as h:
            return _backend.read_shard_footer(h)
    with open(path, "rb") as f:
        size = f.seek(0, 2)
        if size < _TAIL.size:
            raise ShardCorruptError(
                path, f"file too small ({size} byte(s)) for a Bullion tail")
        f.seek(-_TAIL.size, 2)
        tail = f.read(_TAIL.size)
        flen, magic = _TAIL.unpack(tail)
        if magic != MAGIC:
            raise ShardCorruptError(
                path, "bad magic (not a Bullion file, or a torn write)")
        if flen + _TAIL.size > size:
            raise ShardCorruptError(
                path, f"footer length {flen} exceeds file size {size} "
                      "(truncated write)")
        foot_off = size - _TAIL.size - flen
        f.seek(foot_off)
        buf = f.read(flen)
    return parse_footer(buf, foot_off, path), foot_off
