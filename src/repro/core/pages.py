"""Physical page payloads.

A page holds one bounded row range of a column chunk (a chunk is a
contiguous run of pages; see ``BullionWriter(page_rows=)``) in one of four
layouts:
  SCALAR       -> one cascaded-encoding blob
  LIST         -> offsets blob + values blob (ragged list<T>)
  STRING       -> string column blob (offsets + byte data)
  SPARSE_DELTA -> §2.2 sliding-window delta page for list<int64>
"""

from __future__ import annotations

import struct

import numpy as np

from . import sparse_delta
from .encodings import (EncodeContext, decode_blob, decode_strings,
                        encode_array, encode_strings, mask_blob)
from .encodings.numeric import _cat, _split2
from .footer import PageType


def build_scalar_page(arr: np.ndarray, ctx: EncodeContext) -> bytes:
    return encode_array(arr, ctx)


def build_list_page(rows: list[np.ndarray], ctx: EncodeContext,
                    use_sparse_delta: bool = False) -> tuple[bytes, PageType]:
    lens = np.asarray([len(r) for r in rows], np.int64)
    offsets = np.concatenate([[0], np.cumsum(lens)])
    values = np.concatenate(rows) if rows else np.zeros(0, np.int64)
    blob = _cat(encode_array(offsets, ctx.child()), encode_array(values, ctx.child()))
    plain = struct.pack("<Q", len(rows)) + blob
    if use_sparse_delta:
        # §2.2 sliding-window deltas pay off only when adjacent rows share
        # window content (write-order locality); on reordered/unrelated rows
        # they degenerate, so ship whichever page is smaller — each page
        # records its own type, so the choice is per chunk.
        sd = sparse_delta.encode_page(rows, ctx)
        if len(sd) < len(plain):
            return sd, PageType.SPARSE_DELTA
    return plain, PageType.LIST


def build_string_page(strings: list[bytes], ctx: EncodeContext) -> bytes:
    return encode_strings(strings, ctx)


def decode_scalar_page(payload: bytes | memoryview) -> np.ndarray:
    return decode_blob(payload)


def decode_list_page(payload: bytes | memoryview) -> list[np.ndarray]:
    mv = memoryview(payload)
    (n,) = struct.unpack_from("<Q", mv)
    off_blob, val_blob = _split2(mv[8:])
    offsets = decode_blob(off_blob).astype(np.int64)
    values = decode_blob(val_blob)
    return [values[offsets[i]:offsets[i + 1]] for i in range(n)]


def decode_page(ptype: int, payload: bytes | memoryview):
    ptype = PageType(ptype)
    if ptype == PageType.SCALAR:
        return decode_scalar_page(payload)
    if ptype == PageType.LIST:
        return decode_list_page(payload)
    if ptype == PageType.STRING:
        return decode_strings(payload)
    if ptype == PageType.SPARSE_DELTA:
        return sparse_delta.decode_page(payload)
    if ptype == PageType.MEDIA_REF:
        return decode_scalar_page(payload)
    raise ValueError(ptype)


def apply_dv(decoded, dv: np.ndarray | None, page_rows: int):
    """Merge-on-read: drop deleted rows. Handles compact-deleted scalar pages
    (len < page_rows after an RLE in-place delete)."""
    if dv is None or not dv.any():
        if isinstance(decoded, np.ndarray) and len(decoded) > page_rows:
            return decoded[:page_rows]
        return decoded
    keep = ~dv
    if isinstance(decoded, np.ndarray):
        if len(decoded) == page_rows:
            return decoded[keep]
        # compact-delete already removed them physically
        assert len(decoded) == int(keep.sum()), (len(decoded), page_rows, int(keep.sum()))
        return decoded
    return [r for r, k in zip(decoded, keep) if k]


# ---------------------------------------------------------------------------
# in-place deletion masking (Bullion §2.1, level 2)
# ---------------------------------------------------------------------------


def mask_page(ptype: int, payload: bytes, positions: np.ndarray,
              page_rows: int) -> bytes | None:
    """Physically mask `positions` (indices into the page's *current
    physical* row space — the caller shifts logical indices for compacted
    pages) preserving page size. Returns the same-length payload, or None ->
    caller must fall back (deletion vector / relocation)."""
    ptype = PageType(ptype)
    positions = np.asarray(positions, np.int64)
    if ptype in (PageType.SCALAR, PageType.MEDIA_REF):
        return mask_blob(payload, positions, page_rows)
    if ptype == PageType.LIST:
        rows = decode_list_page(payload)
        for p in positions:
            rows[p] = np.zeros_like(rows[p])  # erase ids, keep shape
        blob, _ = build_list_page(rows, EncodeContext())
        if len(blob) <= len(payload):
            return blob + b"\x00" * (len(payload) - len(blob))
        return None
    if ptype == PageType.STRING:
        strings = decode_strings(payload)
        for p in positions:
            strings[p] = b"\x00" * len(strings[p])
        blob = build_string_page(strings, EncodeContext())
        if len(blob) <= len(payload):
            return blob + b"\x00" * (len(payload) - len(blob))
        return None
    if ptype == PageType.SPARSE_DELTA:
        rows = sparse_delta.decode_page(payload)
        for p in positions:
            rows[p] = np.zeros_like(rows[p])
        blob = sparse_delta.encode_page(rows, EncodeContext())
        if len(blob) <= len(payload):
            return blob + b"\x00" * (len(payload) - len(blob))
        return None
    raise ValueError(ptype)


def rebuild_page(ptype: int, payload: bytes, positions: np.ndarray,
                 compact: bool = False) -> bytes:
    """Unconstrained rebuild with `positions` (physical indices) erased —
    used when in-place masking cannot satisfy the size criterion and the page
    must be relocated (old extent is zeroed by the caller). ``compact=True``
    preserves the compacted-page invariant by removing the rows instead of
    zeroing them."""
    ptype = PageType(ptype)
    positions = np.asarray(positions, np.int64)
    ctx = EncodeContext()
    if ptype in (PageType.SCALAR, PageType.MEDIA_REF):
        arr = decode_scalar_page(payload).copy()
        if compact:
            keep = np.ones(len(arr), bool)
            keep[positions] = False
            arr = arr[keep]
        else:
            arr[positions] = 0
        return build_scalar_page(arr, ctx)
    if ptype == PageType.LIST:
        rows = decode_list_page(payload)
        for p in positions:
            rows[p] = np.zeros_like(rows[p])
        return build_list_page(rows, ctx)[0]
    if ptype == PageType.STRING:
        strings = decode_strings(payload)
        for p in positions:
            strings[p] = b"\x00" * len(strings[p])
        return build_string_page(strings, ctx)
    if ptype == PageType.SPARSE_DELTA:
        rows = sparse_delta.decode_page(payload)
        for p in positions:
            rows[p] = np.zeros_like(rows[p])
        return sparse_delta.encode_page(rows, ctx)
    raise ValueError(ptype)
