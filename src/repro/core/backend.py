"""Storage backends: where shard bytes come from.

The read path above this module is backend-agnostic — plans, pruning, and
decode only ever see page bytes. What varies is how a coalesced byte-range
run is fetched, so that is the whole protocol:

    ``StorageBackend.open(uri) -> ShardHandle``
    ``ShardHandle.size() / footer_tail(n) / pread(off, size)``
    ``ShardHandle.fetch_ranges(ranges, max_in_flight=) / validator() / close()``

Three implementations ship:

* **local pread** (``LocalBackend``) — a positional-read wrapper over a
  local file descriptor, byte-identical to reading the fd directly; the
  default for filesystem paths.
* **object-store ranged GETs** (``ObjectStoreBackend``) — resolves
  ``bullion://bucket/key`` URIs against an HTTP(S) endpoint
  (``configure_object_store()`` / ``BULLION_OBJECT_STORE``) with S3-style
  ``Range:`` requests, retry + capped exponential backoff + jitter on
  5xx/timeouts/truncation, and ETag/length identity for footer-cache
  validation (remote objects have no ``(mtime, size, inode)``).
* **async batched fetching** (``AsyncRangeFetcher``) — one event loop on a
  daemon thread that submits a whole batch of range GETs concurrently over
  pooled keep-alive connections with bounded in-flight requests, yielding
  results in *completion* order (asyncpg-style pipelining: the slowest
  range no longer serializes the batch). Remote handles route
  ``fetch_ranges`` through it automatically.

Accounting: remote fetches charge ``IOStats.backend_fetches`` /
``backend_retries`` / ``bytes_read`` through ``ShardHandle.bind_stats``
(local handles charge nothing — their reader keeps the exact pre-existing
``preads`` accounting), and every request feeds the always-on
``bullion.backend.*`` counters/histograms in the metrics registry.
"""

from __future__ import annotations

import asyncio
import http.client
import os
import queue
import random
import socket
import threading
import time
import urllib.parse
from dataclasses import dataclass
from typing import Iterator, Optional, Sequence

from ..obs import metrics as _metrics

SCHEME = "bullion://"
# remote holes are cheap relative to per-request latency: bridge up to 1 MiB
# (vs 64 KiB locally) so a wide projection becomes a handful of ranged GETs
REMOTE_COALESCE_GAP = 1024 * 1024
# the first footer read speculatively fetches this much object tail — enough
# for the 16-byte trailer plus effectively every real footer in one GET
SPECULATIVE_TAIL = 256 * 1024


def is_remote(path) -> bool:
    return isinstance(path, str) and path.startswith(SCHEME)


def parse_uri(uri: str) -> tuple[str, str]:
    """Split ``bullion://bucket/key...`` into ``(bucket, key)``."""
    rest = uri[len(SCHEME):]
    bucket, _, key = rest.partition("/")
    if not bucket or not key:
        raise ValueError(
            f"invalid object URI {uri!r} (expected bullion://bucket/key)")
    return bucket, key


# ---------------------------------------------------------------------------
# endpoint configuration
# ---------------------------------------------------------------------------

_endpoint_lock = threading.Lock()
_endpoint: Optional[str] = None


def configure_object_store(endpoint: Optional[str]) -> None:
    """Set (or clear, with ``None``) the process-wide object-store endpoint
    that ``bullion://`` URIs resolve against — an ``http(s)://host:port``
    base URL serving S3-style ranged GETs at ``/bucket/key``. Overrides the
    ``BULLION_OBJECT_STORE`` environment variable."""
    global _endpoint
    with _endpoint_lock:
        _endpoint = endpoint


def resolve_endpoint() -> str:
    with _endpoint_lock:
        ep = _endpoint
    ep = ep or os.environ.get("BULLION_OBJECT_STORE")
    if not ep or not ep.strip():
        raise FileNotFoundError(
            "no object-store endpoint configured for bullion:// URIs "
            "(call repro.core.backend.configure_object_store() or set "
            "BULLION_OBJECT_STORE to an http(s)://host:port base URL)")
    return ep.strip().rstrip("/")


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

class _Retryable(Exception):
    """A transient backend failure (5xx, timeout, truncated body)."""


@dataclass(frozen=True)
class RetryPolicy:
    """Capped exponential backoff with jitter for transient range-GET
    failures. 404 and connection-refused never retry — a missing key does
    not become present by waiting."""
    retries: int = 4           # attempts after the first = retries
    backoff_base: float = 0.05
    backoff_cap: float = 1.0
    jitter: float = 0.25       # ± fraction of the deterministic delay
    timeout: float = 10.0      # per-request wall clock

    @staticmethod
    def from_env() -> "RetryPolicy":
        env = os.environ.get
        return RetryPolicy(
            retries=int(env("BULLION_BACKEND_RETRIES", "4")),
            backoff_base=float(env("BULLION_BACKEND_BACKOFF", "0.05")),
            backoff_cap=float(env("BULLION_BACKEND_BACKOFF_CAP", "1.0")),
            timeout=float(env("BULLION_BACKEND_TIMEOUT", "10.0")))

    def delay(self, attempt: int) -> float:
        base = min(self.backoff_cap,
                   self.backoff_base * (2.0 ** max(0, attempt - 1)))
        return base * (1.0 + self.jitter * (2.0 * random.random() - 1.0))


# ---------------------------------------------------------------------------
# handles
# ---------------------------------------------------------------------------

class ShardHandle:
    """One open shard on some backend. ``bind_stats`` attaches the owning
    reader's ``IOStats`` so backend-level charges (fetches, retries, bytes)
    land on the same accounting every other read does."""

    uri: str
    is_remote = False

    def bind_stats(self, stats, lock) -> None:
        self._stats = stats
        self._stats_lock = lock

    def _charge(self, **fields) -> None:
        st = getattr(self, "_stats", None)
        if st is None:
            return
        with self._stats_lock:
            for k, v in fields.items():
                setattr(st, k, getattr(st, k) + v)

    # -- protocol ------------------------------------------------------------
    def size(self) -> int:
        raise NotImplementedError

    def pread(self, offset: int, size: int) -> bytes:
        raise NotImplementedError

    def footer_tail(self, n: int) -> bytes:
        """The last ``min(n, size)`` bytes of the shard."""
        size = self.size()
        n = min(n, size)
        return self.pread(size - n, n)

    def validator(self) -> tuple:
        """Identity+version tuple for footer-cache validation."""
        raise NotImplementedError

    def fetch_ranges(self, ranges: Sequence[tuple[int, int]], *,
                     max_in_flight: int = 1
                     ) -> Iterator[tuple[int, Optional[bytes],
                                         Optional[BaseException]]]:
        """Fetch ``[(off, end), ...]``, yielding ``(index, data, error)``
        per range. The base implementation is serial and in submission
        order; remote handles overlap up to ``max_in_flight`` requests and
        yield in completion order. A failed range yields its error instead
        of raising, so one bad range only fails the work that needed it."""
        for i, (off, end) in enumerate(ranges):
            try:
                data = self.pread(off, end - off)
            except Exception as e:
                yield i, None, e
            else:
                yield i, data, None

    def close(self) -> None:
        raise NotImplementedError

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class LocalShardHandle(ShardHandle):
    """Local file via positional reads — exactly the fd-based access the
    reader always did, behind the protocol."""

    def __init__(self, path: str):
        self.uri = self.path = path
        self._f = open(path, "rb")

    @property
    def closed(self) -> bool:
        return self._f is None

    def size(self) -> int:
        return os.fstat(self._f.fileno()).st_size

    def pread(self, offset: int, size: int) -> bytes:
        f = self._f
        if f is None:
            raise ValueError(f"{self.path}: handle is closed")
        return os.pread(f.fileno(), size, offset)

    def validator(self) -> tuple:
        st = os.fstat(self._f.fileno())
        return (st.st_mtime_ns, st.st_size, st.st_ino)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class RemoteShardHandle(ShardHandle):
    """``bullion://bucket/key`` over HTTP(S) ranged GETs.

    The blocking path (``pread``, ``footer_tail``, ``stat``) runs on one
    keep-alive ``http.client`` connection per handle; batch fetches go
    through the shared :class:`AsyncRangeFetcher`. Errors map to the local
    filesystem's vocabulary: missing keys and unreachable endpoints raise
    ``FileNotFoundError``; exhausted transient retries raise ``OSError``.
    """

    is_remote = True

    def __init__(self, uri: str, *, endpoint: Optional[str] = None,
                 policy: Optional[RetryPolicy] = None):
        self.uri = uri
        bucket, key = parse_uri(uri)
        ep = endpoint or resolve_endpoint()
        u = urllib.parse.urlsplit(ep)
        if u.scheme not in ("http", "https") or not u.hostname:
            raise ValueError(
                f"object-store endpoint {ep!r} must be an "
                "http(s)://host:port base URL")
        self._https = u.scheme == "https"
        self._host = u.hostname
        self._port = u.port or (443 if self._https else 80)
        self._objpath = (u.path.rstrip("/") + "/"
                         + urllib.parse.quote(bucket) + "/"
                         + urllib.parse.quote(key))
        self.policy = policy or RetryPolicy.from_env()
        self._conn = None
        self._conn_lock = threading.Lock()
        self._closed = False
        self._size: Optional[int] = None
        self._etag: Optional[str] = None

    # -- raw request ---------------------------------------------------------
    def _request(self, method: str, headers: dict) -> tuple[int, dict, bytes]:
        with self._conn_lock:
            if self._closed:
                raise ValueError(f"{self.uri}: handle is closed")
            conn = self._conn
            self._conn = None
            if conn is None:
                cls = (http.client.HTTPSConnection if self._https
                       else http.client.HTTPConnection)
                conn = cls(self._host, self._port,
                           timeout=self.policy.timeout)
            try:
                conn.request(method, self._objpath, headers=headers)
                resp = conn.getresponse()
                body = resp.read()   # http.client raises IncompleteRead on
                                     # a body shorter than Content-Length
            except BaseException:
                conn.close()
                raise
            self._conn = conn
            return (resp.status,
                    {k.lower(): v for k, v in resp.getheaders()}, body)

    def _note_identity(self, hdrs: dict, *, head: bool) -> None:
        et = hdrs.get("etag")
        if et:
            self._etag = et
        cr = hdrs.get("content-range")   # "bytes a-b/total"
        if cr and "/" in cr:
            total = cr.rsplit("/", 1)[1].strip()
            if total.isdigit():
                self._size = int(total)
        elif head and "content-length" in hdrs:
            self._size = int(hdrs["content-length"])

    def _fetch(self, *, rng=None, suffix: Optional[int] = None,
               head: bool = False, what: str = "") -> bytes:
        """One object request with the handle's retry policy. ``rng`` is a
        half-open ``(off, end)``; ``suffix`` asks for the last N bytes."""
        headers = {}
        if rng is not None:
            headers["Range"] = f"bytes={rng[0]}-{rng[1] - 1}"
        elif suffix is not None:
            headers["Range"] = f"bytes=-{suffix}"
        method = "HEAD" if head else "GET"
        attempt = 0
        while True:
            attempt += 1
            t0 = time.perf_counter()
            err: Optional[BaseException] = None
            try:
                status, hdrs, body = self._request(method, headers)
            except (OSError, http.client.HTTPException) as e:
                status, hdrs, body, err = None, {}, b"", e
            if status == 404:
                raise FileNotFoundError(
                    f"object {self.uri} not found (HTTP 404 from "
                    f"{self._host}:{self._port})")
            if status in (200, 206):
                self._note_identity(hdrs, head=head)
                if head:
                    _metrics.counter("bullion.backend.heads").inc()
                    return body
                expect = None
                if rng is not None:
                    if status == 200:   # server ignored Range: slice locally
                        body = body[rng[0]:rng[1]]
                    expect = rng[1] - rng[0]
                elif suffix is not None and status == 200:
                    body = body[-suffix:]
                if expect is not None and len(body) != expect:
                    err = _Retryable(
                        f"short range body ({len(body)} of {expect} bytes)")
                else:
                    _metrics.counter("bullion.backend.fetches").inc()
                    _metrics.histogram("bullion.backend.fetch_seconds") \
                        .observe(time.perf_counter() - t0)
                    self._charge(backend_fetches=1, bytes_read=len(body))
                    return body
            elif status is not None:
                err = _Retryable(f"HTTP {status}")
            if isinstance(err, (ConnectionRefusedError, socket.gaierror)):
                raise FileNotFoundError(
                    f"object store for {self.uri} unreachable at "
                    f"{self._host}:{self._port} ({err})") from err
            if attempt > self.policy.retries:
                raise OSError(
                    f"{what or method} {self.uri} failed after {attempt} "
                    f"attempt(s): {err}") from err
            _metrics.counter("bullion.backend.retries").inc()
            self._charge(backend_retries=1)
            time.sleep(self.policy.delay(attempt))

    # -- protocol ------------------------------------------------------------
    def stat(self) -> tuple:
        """(ETag, length) via one HEAD — the remote footer-cache validator."""
        self._fetch(head=True, what="HEAD")
        return (self._etag, self._size)

    def validator(self) -> tuple:
        return self.stat()

    def size(self) -> int:
        if self._size is None:
            self.stat()
        return self._size

    def pread(self, offset: int, size: int) -> bytes:
        return self._fetch(rng=(offset, offset + size), what="range GET")

    def footer_tail(self, n: int) -> bytes:
        return self._fetch(suffix=n, what="footer tail GET")

    def fetch_ranges(self, ranges, *, max_in_flight: int = 1):
        if len(ranges) <= 1 or max_in_flight <= 1:
            yield from super().fetch_ranges(ranges,
                                            max_in_flight=max_in_flight)
            return
        yield from _fetcher().fetch(self, ranges,
                                    max_in_flight=max_in_flight)

    def close(self) -> None:
        with self._conn_lock:
            self._closed = True
            if self._conn is not None:
                self._conn.close()
                self._conn = None


# ---------------------------------------------------------------------------
# async batched fetcher
# ---------------------------------------------------------------------------

class AsyncRangeFetcher:
    """One event loop on a daemon thread, shared process-wide: a batch of
    range GETs is submitted concurrently (bounded by ``max_in_flight``) over
    pooled keep-alive connections, and results come back in completion
    order so decode overlaps the slowest range instead of waiting on it."""

    _POOL_CAP = 8   # idle keep-alive connections retained per endpoint

    def __init__(self):
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        # (host, port, https) -> [(reader, writer)]; touched only on the
        # loop thread, so no extra locking
        self._pools: dict = {}

    def _ensure_loop(self) -> asyncio.AbstractEventLoop:
        with self._lock:
            if self._loop is None or self._thread is None \
                    or not self._thread.is_alive():
                self._loop = asyncio.new_event_loop()
                self._thread = threading.Thread(
                    target=self._loop.run_forever, daemon=True,
                    name="bullion-backend-loop")
                self._thread.start()
            return self._loop

    # -- public --------------------------------------------------------------
    def fetch(self, handle: RemoteShardHandle,
              ranges: Sequence[tuple[int, int]], *, max_in_flight: int):
        loop = self._ensure_loop()
        out: "queue.Queue" = queue.Queue()
        n = len(ranges)

        async def runner():
            sem = asyncio.Semaphore(max(1, int(max_in_flight)))
            in_flight = [0]

            async def one(i, off, end):
                async with sem:
                    in_flight[0] += 1
                    _metrics.histogram("bullion.backend.in_flight") \
                        .observe(in_flight[0])
                    try:
                        out.put((i, await self._get_range(handle, off, end),
                                 None))
                    except BaseException as e:
                        out.put((i, None, e))
                    finally:
                        in_flight[0] -= 1

            await asyncio.gather(
                *(one(i, off, end) for i, (off, end) in enumerate(ranges)),
                return_exceptions=True)

        fut = asyncio.run_coroutine_threadsafe(runner(), loop)
        try:
            for _ in range(n):
                yield out.get()
        finally:
            fut.cancel()

    # -- loop-side -----------------------------------------------------------
    async def _get_range(self, handle: RemoteShardHandle,
                         off: int, end: int) -> bytes:
        policy = handle.policy
        attempt = 0
        while True:
            attempt += 1
            t0 = time.perf_counter()
            try:
                data = await asyncio.wait_for(
                    self._request(handle, off, end), policy.timeout)
            except FileNotFoundError:
                raise
            except (OSError, EOFError, asyncio.TimeoutError,
                    asyncio.IncompleteReadError, _Retryable) as e:
                if attempt > policy.retries:
                    raise OSError(
                        f"range GET {handle.uri} [{off}, {end}) failed "
                        f"after {attempt} attempt(s): {e}") from e
                _metrics.counter("bullion.backend.retries").inc()
                handle._charge(backend_retries=1)
                await asyncio.sleep(policy.delay(attempt))
            else:
                _metrics.counter("bullion.backend.fetches").inc()
                _metrics.histogram("bullion.backend.fetch_seconds") \
                    .observe(time.perf_counter() - t0)
                handle._charge(backend_fetches=1, bytes_read=len(data))
                return data

    async def _request(self, handle: RemoteShardHandle,
                       off: int, end: int) -> bytes:
        key = (handle._host, handle._port, handle._https)
        reader, writer = await self._acquire(key)
        try:
            writer.write((
                f"GET {handle._objpath} HTTP/1.1\r\n"
                f"Host: {handle._host}:{handle._port}\r\n"
                f"Range: bytes={off}-{end - 1}\r\n"
                "Connection: keep-alive\r\n\r\n").encode("ascii"))
            await writer.drain()
            status, hdrs = await self._read_head(reader)
            clen = int(hdrs.get(b"content-length", b"0"))
            body = await reader.readexactly(clen) if clen else b""
            if status == 404:
                raise FileNotFoundError(
                    f"object {handle.uri} not found (HTTP 404)")
            if status == 200:
                body = body[off:end]
            elif status != 206:
                raise _Retryable(f"HTTP {status}")
            if len(body) != end - off:
                raise _Retryable(
                    f"short range body ({len(body)} of {end - off} bytes)")
        except BaseException:
            writer.close()
            raise
        self._release(key, reader, writer)
        return body

    @staticmethod
    async def _read_head(reader) -> tuple[int, dict]:
        line = await reader.readline()
        parts = line.split(None, 2)
        if len(parts) < 2 or not parts[1].isdigit():
            raise _Retryable(f"malformed status line {line!r}")
        status = int(parts[1])
        hdrs: dict = {}
        while True:
            line = await reader.readline()
            if line in (b"\r\n", b"\n", b""):
                break
            k, _, v = line.partition(b":")
            hdrs[k.strip().lower()] = v.strip()
        return status, hdrs

    async def _acquire(self, key):
        pool = self._pools.setdefault(key, [])
        while pool:
            reader, writer = pool.pop()
            if not writer.is_closing() and not reader.at_eof():
                return reader, writer
            writer.close()
        host, port, https = key
        return await asyncio.open_connection(
            host, port, ssl=True if https else None)

    def _release(self, key, reader, writer) -> None:
        pool = self._pools.setdefault(key, [])
        if len(pool) < self._POOL_CAP and not writer.is_closing():
            pool.append((reader, writer))
        else:
            writer.close()


_FETCHER: Optional[AsyncRangeFetcher] = None
_fetcher_lock = threading.Lock()


def _fetcher() -> AsyncRangeFetcher:
    global _FETCHER
    if _FETCHER is None:
        with _fetcher_lock:
            if _FETCHER is None:
                _FETCHER = AsyncRangeFetcher()
    return _FETCHER


# ---------------------------------------------------------------------------
# backends + dispatch
# ---------------------------------------------------------------------------

class StorageBackend:
    """Protocol: ``open(uri) -> ShardHandle``; fetch semantics live on the
    handle. ``close()`` releases backend-wide resources (none by default)."""

    scheme = ""

    def open(self, uri: str) -> ShardHandle:
        raise NotImplementedError

    def close(self) -> None:
        pass


class LocalBackend(StorageBackend):
    scheme = "file"

    def open(self, path: str) -> ShardHandle:
        return LocalShardHandle(path)


class ObjectStoreBackend(StorageBackend):
    scheme = "bullion"

    def __init__(self, endpoint: Optional[str] = None,
                 policy: Optional[RetryPolicy] = None):
        self._endpoint = endpoint
        self._policy = policy

    def open(self, uri: str) -> ShardHandle:
        return RemoteShardHandle(uri, endpoint=self._endpoint,
                                 policy=self._policy)


_LOCAL = LocalBackend()
_backends: dict[str, StorageBackend] = {}
_backends_lock = threading.Lock()


def register_backend(scheme: str, backend: StorageBackend) -> Optional[StorageBackend]:
    """Override the backend used for a scheme (tests, custom stores, the
    chaos fault-injection harness). ``"bullion"`` covers remote URIs;
    ``"file"`` covers plain local paths. Returns the previously registered
    backend (``None`` when the built-in default was active) so callers can
    restore it via ``unregister_backend(scheme, restore=prev)``."""
    with _backends_lock:
        prev = _backends.get(scheme)
        _backends[scheme] = backend
        return prev


def unregister_backend(scheme: str, *,
                       restore: Optional[StorageBackend] = None) -> None:
    """Drop a scheme override (or put back ``restore``, a previous
    ``register_backend`` return value)."""
    with _backends_lock:
        if restore is None:
            _backends.pop(scheme, None)
        else:
            _backends[scheme] = restore


def has_custom_local_backend() -> bool:
    """True while a ``file``-scheme override is registered — local footer
    reads then route through the backend protocol so fault injection sees
    them."""
    with _backends_lock:
        return "file" in _backends


def backend_for(path: str) -> StorageBackend:
    if is_remote(path):
        with _backends_lock:
            be = _backends.get("bullion")
        return be if be is not None else ObjectStoreBackend()
    with _backends_lock:
        be = _backends.get("file")
    return be if be is not None else _LOCAL


def open_shard(path: str) -> ShardHandle:
    """Open ``path`` (a filesystem path or ``bullion://`` URI) on its
    backend."""
    return backend_for(path).open(path)


def read_shard_footer(handle: ShardHandle, *,
                      speculative_tail: int = SPECULATIVE_TAIL):
    """Footer via the backend protocol: one speculative tail fetch covers
    the 16-byte trailer and (in practice) the whole footer; a second exact
    range read happens only when the footer outgrows the speculation.
    Returns ``(FooterView, footer_offset)`` like ``read_footer``."""
    from .footer import _TAIL, MAGIC, ShardCorruptError, parse_footer
    tail = handle.footer_tail(max(_TAIL.size, int(speculative_tail)))
    if len(tail) < _TAIL.size:
        raise ShardCorruptError(
            handle.uri,
            f"object too small ({len(tail)} byte(s)) for a Bullion tail")
    flen, magic = _TAIL.unpack(tail[-_TAIL.size:])
    if magic != MAGIC:
        raise ShardCorruptError(
            handle.uri, "bad magic (not a Bullion object, or a torn write)")
    size = handle.size()
    foot_off = size - _TAIL.size - flen
    if foot_off < 0:
        raise ShardCorruptError(
            handle.uri,
            f"footer length {flen} exceeds object size {size} "
            "(truncated write)")
    if flen + _TAIL.size <= len(tail):
        buf = tail[len(tail) - _TAIL.size - flen: len(tail) - _TAIL.size]
    else:
        buf = handle.pread(foot_off, flen)
    return parse_footer(bytes(buf), foot_off, handle.uri), foot_off
