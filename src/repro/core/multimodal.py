"""Multimodal storage (Bullion §2.5, Fig. 7).

Dual-table architecture:
  * **meta table** — Bullion columnar file: text, quality scores, embeddings,
    *inlined critical frames* (reduced-resolution), and media_ref keys.
  * **media table** — row-oriented binary chunk store (the paper's Avro role)
    holding full-size media blobs, looked up only when full resolution is
    actually needed.

Write path presorts rows by quality score (descending) so quality-filtered
training reads the file as one sequential prefix instead of scattered rows.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Optional, Sequence

import numpy as np

from .quantization import QuantMode, QuantSpec
from .writer import BullionWriter, ColumnSpec, quality_sort

_REC = struct.Struct("<QQ")  # key, size
_MEDIA_MAGIC = b"BULMEDIA"


class MediaStore:
    """Append-only row-oriented blob store with a trailing key index."""

    def __init__(self, path: str):
        self.path = path

    def write(self, blobs: dict[int, bytes]) -> None:
        index: list[tuple[int, int, int]] = []
        with open(self.path, "wb") as f:
            for key, blob in blobs.items():
                index.append((key, f.tell(), len(blob)))
                f.write(_REC.pack(key, len(blob)))
                f.write(blob)
            idx_off = f.tell()
            for key, off, size in index:
                f.write(struct.pack("<QQQ", key, off, size))
            f.write(struct.pack("<QI", idx_off, len(index)) + _MEDIA_MAGIC)

    def _index(self) -> dict[int, tuple[int, int]]:
        with open(self.path, "rb") as f:
            f.seek(-20, 2)
            idx_off, n = struct.unpack("<QI", f.read(12))
            assert f.read(8) == _MEDIA_MAGIC
            f.seek(idx_off)
            out = {}
            for _ in range(n):
                key, off, size = struct.unpack("<QQQ", f.read(24))
                out[key] = (off, size)
        return out

    def read(self, keys: Sequence[int]) -> dict[int, bytes]:
        """Random-access lookups (the slow path the meta table avoids)."""
        idx = self._index()
        out = {}
        with open(self.path, "rb") as f:
            for k in keys:
                off, size = idx[k]
                f.seek(off + _REC.size)
                out[k] = f.read(size)
        return out


@dataclass
class MultimodalSample:
    text: bytes
    quality: float
    embedding: np.ndarray          # float32[d]
    frames: bytes                  # reduced-res critical frames, inlined
    media_key: int                 # full-size video in the media table


def write_multimodal_dataset(meta_path: str, media_path: str,
                             samples: list[MultimodalSample],
                             rows_per_group: int = 4096,
                             embed_quant: Optional[QuantSpec] = None) -> dict:
    """Write the §2.5 layout: quality-presorted meta table + media table."""
    schema = [
        ColumnSpec("text", "string"),
        ColumnSpec("quality", "float32"),
        ColumnSpec("embedding", "list<float32>"),
        ColumnSpec("frames", "string"),
        ColumnSpec("media_key", "media_ref"),
    ]
    if embed_quant is None:
        embed_quant = QuantSpec(QuantMode.NONE)
    writer = BullionWriter(meta_path, schema, rows_per_group=rows_per_group,
                           sort_udf=quality_sort("quality"),
                           props={"layout": "multimodal-v1"})
    writer.write_table({
        "text": [s.text for s in samples],
        "quality": np.asarray([s.quality for s in samples], np.float32),
        "embedding": [s.embedding.astype(np.float32) for s in samples],
        "frames": [s.frames for s in samples],
        "media_key": np.asarray([s.media_key for s in samples], np.uint64),
    })
    stats = writer.close()
    MediaStore(media_path).write({s.media_key: s.frames * 8 for s in samples})
    return stats


def quality_filtered_read(meta_path: str, columns: Sequence[str],
                          top_fraction: float) -> tuple[list[dict], "IOStats"]:
    """Read the top-`top_fraction` quality rows. Because rows were presorted
    by quality at write time, the ``head`` plan touches only a *prefix* of
    row groups — the limit is pushed into physical planning, so trailing
    groups are accounted as pruned bytes and never pread."""
    from ..dataset import dataset

    with dataset(meta_path) as ds:
        n_take = int(ds.num_rows * top_fraction)
        out = list(ds.select(list(columns)).head(n_take).to_batches())
        if not out:
            # n_take == 0: keep the legacy shape (one table of typed empty
            # columns) so callers can concatenate unconditionally
            out = [ds.select(list(columns)).head(0).to_table()]
        stats = ds.stats
    return out, stats
