"""Byte-string column encodings: FSST-lite + raw/zstd binary.

A string/binary column is physically (offsets:int64[n+1], data:uint8[...]).
`encode_strings` cascades the offsets like any integer column and picks
between FSST-lite and chunked-zstd for the data bytes.

``zstandard`` is an optional dependency: when missing, ``RawBytes`` falls
back to stdlib ``zlib`` on the write path. The codec is recorded in the blob
header, so files written with either codec decode wherever that codec exists.
"""

from __future__ import annotations

import struct
import zlib
from collections import Counter

import numpy as np

try:
    import zstandard as zstd
except ImportError:  # pragma: no cover - environment-dependent
    zstd = None

from .base import EncodeContext, frame, register, unframe, Encoding
from .numeric import _cat, _split2


class FsstLite(Encoding):
    """Static greedy symbol table: up to 254 frequent 2-8 byte substrings are
    replaced by single codes; 0xFF escapes literal bytes >= 0xF0."""

    eid, name = 15, "fsst_lite"
    ESCAPE = 0xFF
    MAX_SYMBOLS = 254

    def applicable(self, arr, ctx):
        return isinstance(arr, (bytes, bytearray, memoryview))

    def _train(self, sample: bytes) -> list[bytes]:
        counts: Counter = Counter()
        for w in (2, 3, 4, 6, 8):
            for i in range(0, max(len(sample) - w, 0), w):
                counts[sample[i:i + w]] += 1
        scored = sorted(counts.items(), key=lambda kv: -(len(kv[0]) - 1) * kv[1])
        return [s for s, c in scored[: self.MAX_SYMBOLS] if c > 2 and len(s) > 1]

    def encode(self, data: bytes, ctx: EncodeContext):
        data = bytes(data)
        table = self._train(data[: 1 << 16])
        if not table:
            return None
        out = bytearray()
        # longest-match greedy with a first-byte index
        first: dict[int, list[tuple[bytes, int]]] = {}
        for idx, sym in enumerate(table):
            first.setdefault(sym[0], []).append((sym, idx))
        for k in first:
            first[k].sort(key=lambda t: -len(t[0]))
        i, n = 0, len(data)
        while i < n:
            b = data[i]
            hit = None
            for sym, idx in first.get(b, ()):
                if data.startswith(sym, i):
                    hit = (sym, idx)
                    break
            if hit:
                out.append(hit[1])
                i += len(hit[0])
            else:
                # literal bytes colliding with symbol codes or escape range
                if b < len(table) or b >= 0xF0:
                    out.append(self.ESCAPE)
                out.append(b)
                i += 1
        if len(out) >= n:
            return None
        tbl = b"".join(struct.pack("<B", len(s)) + s for s in table)
        header = struct.pack("<QQH", n, len(out), len(table)) + tbl
        return frame(self.eid, header, bytes(out))

    def decode(self, header, payload) -> np.ndarray:
        n, enc_len, nsym = struct.unpack_from("<QQH", header)
        off = 18
        table: list[bytes] = []
        hb = bytes(header)
        for _ in range(nsym):
            ln = hb[off]
            table.append(hb[off + 1: off + 1 + ln])
            off += 1 + ln
        data = bytes(payload)
        out = bytearray()
        i = 0
        while i < len(data):
            c = data[i]
            if c == self.ESCAPE:
                out.append(data[i + 1])
                i += 2
            elif c < len(table):
                out += table[c]
                i += 1
            else:
                out.append(c)
                i += 1
        return np.frombuffer(bytes(out), np.uint8, count=n)


class RawBytes(Encoding):
    """bytes payload, compressed when profitable.

    Codec byte in the header: 0 = stored, 1 = zstd, 2 = zlib. zstd is used
    when the optional ``zstandard`` module is importable; otherwise the write
    path degrades to zlib and zstd-coded blobs raise a clear error on read.
    """

    eid, name = 16, "raw_bytes"
    STORED, ZSTD, ZLIB = 0, 1, 2

    def applicable(self, arr, ctx):
        return isinstance(arr, (bytes, bytearray, memoryview))

    def encode(self, data: bytes, ctx: EncodeContext):
        data = bytes(data)
        if zstd is not None:
            comp, codec = zstd.ZstdCompressor(level=3).compress(data), self.ZSTD
        else:
            comp, codec = zlib.compress(data, 6), self.ZLIB
        use, codec = (comp, codec) if len(comp) < len(data) else (data, self.STORED)
        header = struct.pack("<QB", len(data), codec)
        return frame(self.eid, header, use)

    def decode(self, header, payload) -> np.ndarray:
        n, codec = struct.unpack_from("<QB", header)
        if codec == self.ZSTD:
            if zstd is None:
                raise RuntimeError(
                    "blob is zstd-compressed but the optional 'zstandard' "
                    "module is not installed")
            raw = zstd.ZstdDecompressor().decompress(bytes(payload),
                                                     max_output_size=max(n, 1))
        elif codec == self.ZLIB:
            raw = zlib.decompress(bytes(payload))
        else:
            raw = bytes(payload)
        return np.frombuffer(raw, np.uint8, count=n)


for _enc in (FsstLite(), RawBytes()):
    register(_enc)


# ---------------------------------------------------------------------------
# string column = offsets + data, encoded together
# ---------------------------------------------------------------------------

STRING_MAGIC = 0xBC


def encode_strings(strings: list[bytes], ctx: EncodeContext | None = None) -> bytes:
    from .cascade import encode_array, encode_bytes
    ctx = ctx or EncodeContext()
    lens = np.asarray([len(s) for s in strings], np.int64)
    offsets = np.concatenate([[0], np.cumsum(lens)])
    data = b"".join(strings)
    off_blob = encode_array(offsets, ctx.child())
    data_blob = encode_bytes(data, ctx.child())
    return struct.pack("<BQ", STRING_MAGIC, len(strings)) + _cat(off_blob, data_blob)


def decode_strings(blob: bytes | memoryview) -> list[bytes]:
    from .base import decode_blob
    mv = memoryview(blob)
    magic, n = struct.unpack_from("<BQ", mv)
    assert magic == STRING_MAGIC
    off_blob, data_blob = _split2(mv[9:])
    offsets = decode_blob(off_blob).astype(np.int64)
    data = decode_blob(data_blob).tobytes()
    return [data[offsets[i]:offsets[i + 1]] for i in range(n)]
