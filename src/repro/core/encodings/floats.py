"""Floating-point encodings (Bullion Table 2: Gorilla/Chimp, Pseudodecimal/ALP).

``XorFloat`` is a vectorized Chimp-flavored variant: XOR against the previous
value's bit pattern, then cascade-encode the XOR stream as integers (runs of
zeros / few set bits compress well downstream).  ``AlpDecimal`` is ALP-lite:
losslessly rescale decimals to integers when possible and cascade those.
"""

from __future__ import annotations

import struct

import numpy as np

from .base import EncodeContext, Encoding, code_dtype, dtype_code, frame, register
from .numeric import _cat, _split2


def _uint_view(arr: np.ndarray) -> tuple[np.ndarray, np.dtype]:
    if arr.dtype == np.float64:
        return arr.view(np.uint64), np.dtype(np.uint64)
    if arr.dtype == np.float32:
        return arr.view(np.uint32), np.dtype(np.uint32)
    if arr.dtype == np.float16:
        return arr.view(np.uint16), np.dtype(np.uint16)
    raise TypeError(arr.dtype)


class XorFloat(Encoding):
    eid, name = 13, "xor_float"

    def applicable(self, arr, ctx):
        return arr.dtype.kind == "f" and len(arr) > 1

    def encode(self, arr, ctx):
        from .cascade import encode_array
        u, udt = _uint_view(np.ascontiguousarray(arr))
        x = u.copy()
        x[1:] = u[1:] ^ u[:-1]
        child = encode_array(x, ctx.child())
        header = struct.pack("<BQ", dtype_code(arr.dtype), len(arr))
        return frame(self.eid, header, child)

    def decode(self, header, payload):
        from .base import decode_blob
        code, n = struct.unpack_from("<BQ", header)
        dt = code_dtype(code)
        x = decode_blob(payload)
        u = np.bitwise_xor.accumulate(x)
        return u.view(dt).copy()


class AlpDecimal(Encoding):
    """ALP-lite: x == round(x * 10^e) / 10^e exactly -> encode ints."""

    eid, name = 14, "alp_decimal"
    MAX_E = {4: 7, 8: 15}

    def applicable(self, arr, ctx):
        return arr.dtype.kind == "f" and arr.dtype.itemsize >= 4 and len(arr) > 0

    def _find_exponent(self, arr):
        finite = np.isfinite(arr)
        if not finite.all():
            return None
        for e in range(0, self.MAX_E[arr.dtype.itemsize] + 1):
            scale = 10.0 ** e
            scaled = arr.astype(np.float64) * scale
            if np.abs(scaled).max(initial=0.0) > 2**52:
                return None
            ints = np.round(scaled)
            if np.array_equal(ints / scale, arr.astype(np.float64)):
                return e, ints.astype(np.int64)
        return None

    def encode(self, arr, ctx):
        from .cascade import encode_array
        found = self._find_exponent(arr)
        if found is None:
            return None
        e, ints = found
        child = encode_array(ints, ctx.child())
        header = struct.pack("<BQB", dtype_code(arr.dtype), len(arr), e)
        return frame(self.eid, header, child)

    def decode(self, header, payload):
        from .base import decode_blob
        code, n, e = struct.unpack_from("<BQB", header)
        ints = decode_blob(payload)
        return (ints.astype(np.float64) / 10.0 ** e).astype(code_dtype(code))


for _enc in (XorFloat(), AlpDecimal()):
    register(_enc)
