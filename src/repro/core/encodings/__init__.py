"""Bullion cascading encoding framework (paper §2.6, Table 2)."""

from .base import (BY_NAME, REGISTRY, CostWeights, EncodeContext, Encoding,
                   blob_encoding_name, decode_blob, mask_blob)
from .cascade import (advise_candidates, choose_encoding, encode_array,
                      encode_bytes)
from .bytes_ import decode_strings, encode_strings

__all__ = [
    "BY_NAME", "REGISTRY", "CostWeights", "EncodeContext", "Encoding",
    "advise_candidates", "blob_encoding_name", "decode_blob", "mask_blob",
    "choose_encoding", "encode_array", "encode_bytes",
    "encode_strings", "decode_strings",
]
