"""Cascading encoding framework — base interfaces (Bullion §2.6).

Every encoded page is a self-describing binary blob:

    blob := u8 enc_id | u32 header_len | header | u64 payload_len | payload

``header`` is encoding-specific fixed metadata (widths, counts, dtypes);
``payload`` may itself contain child blobs (cascading).  Encodings register
themselves in a global registry keyed by ``eid`` so any blob decodes without
out-of-band information — the modular, composable interface the paper argues
Parquet/ORC lack.

Selection (``cascade.encode_array``) is sampling-based (BtrBlocks-style) with a
Nimble-style weighted objective over {size, encode time, decode time} and a
bounded recursion depth.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np

# ---------------------------------------------------------------------------
# dtype tagging
# ---------------------------------------------------------------------------

_DTYPE_CODES: dict[str, int] = {
    "int8": 0, "int16": 1, "int32": 2, "int64": 3,
    "uint8": 4, "uint16": 5, "uint32": 6, "uint64": 7,
    "float16": 8, "float32": 9, "float64": 10, "bool": 11,
    "bfloat16": 12,  # stored as uint16 payload; jax/ml_dtypes view on decode
}
_CODE_DTYPES = {v: k for k, v in _DTYPE_CODES.items()}


def dtype_code(dt: np.dtype) -> int:
    name = np.dtype(dt).name
    if name not in _DTYPE_CODES:
        raise TypeError(f"unsupported column dtype {name}")
    return _DTYPE_CODES[name]


def code_dtype(code: int) -> np.dtype:
    name = _CODE_DTYPES[code]
    if name == "bfloat16":
        import ml_dtypes  # pragma: no cover - optional

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(name)


# ---------------------------------------------------------------------------
# blob framing
# ---------------------------------------------------------------------------

_FRAME = struct.Struct("<BIQ")  # eid, header_len, payload_len


def frame(eid: int, header: bytes, payload: bytes) -> bytes:
    return _FRAME.pack(eid, len(header), len(payload)) + header + payload


def unframe(blob: bytes | memoryview, offset: int = 0) -> tuple[int, memoryview, memoryview, int]:
    """Return (eid, header, payload, end_offset)."""
    mv = memoryview(blob)
    eid, hlen, plen = _FRAME.unpack_from(mv, offset)
    ho = offset + _FRAME.size
    po = ho + hlen
    end = po + plen
    return eid, mv[ho:po], mv[po:end], end


# ---------------------------------------------------------------------------
# encode context / cost model
# ---------------------------------------------------------------------------


@dataclass
class CostWeights:
    """Nimble-style linear objective: minimize w_size*bytes + w_enc*t + w_dec*t."""

    size: float = 1.0
    encode_time: float = 0.0
    decode_time: float = 0.0


@dataclass
class EncodeContext:
    max_depth: int = 2
    depth: int = 0
    weights: CostWeights = field(default_factory=CostWeights)
    sample_size: int = 1024
    # restrict candidate encodings by name (None = registry order)
    candidates: Optional[tuple[str, ...]] = None
    rng: np.random.Generator = field(default_factory=lambda: np.random.default_rng(0))

    def child(self) -> "EncodeContext":
        return EncodeContext(
            max_depth=self.max_depth,
            depth=self.depth + 1,
            weights=self.weights,
            sample_size=self.sample_size,
            candidates=None,  # children pick freely
            rng=self.rng,
        )


# ---------------------------------------------------------------------------
# encoding base + registry
# ---------------------------------------------------------------------------


class Encoding:
    """One entry of the encoding catalog (Table 2)."""

    eid: int = -1
    name: str = "abstract"

    # -- selection -----------------------------------------------------------
    def applicable(self, arr: np.ndarray, ctx: EncodeContext) -> bool:
        raise NotImplementedError

    # -- codec ----------------------------------------------------------------
    def encode(self, arr: np.ndarray, ctx: EncodeContext) -> Optional[bytes]:
        """Return a full framed blob, or None if this array can't profit."""
        raise NotImplementedError

    def decode(self, header: memoryview, payload: memoryview) -> np.ndarray:
        raise NotImplementedError

    # -- deletion compliance (Bullion §2.1) ------------------------------------
    # Mask element at `positions` *in place* in the encoded representation.
    # MUST return a blob of exactly the same length (the paper's size
    # criterion) or raise Unsupported to signal the caller to fall back to a
    # deletion-vector-only strategy for this page.
    def mask(self, header: memoryview, payload: memoryview, positions: np.ndarray,
             n_values: int) -> Optional[tuple[bytes, bytes]]:
        return None  # default: no in-place masking; DV-only


REGISTRY: dict[int, Encoding] = {}
BY_NAME: dict[str, Encoding] = {}


def register(enc: Encoding) -> Encoding:
    if enc.eid in REGISTRY:
        raise ValueError(f"duplicate eid {enc.eid} ({enc.name} vs {REGISTRY[enc.eid].name})")
    REGISTRY[enc.eid] = enc
    BY_NAME[enc.name] = enc
    return enc


def decode_blob(blob: bytes | memoryview) -> np.ndarray:
    eid, header, payload, _ = unframe(blob)
    return REGISTRY[eid].decode(header, payload)


def blob_encoding_name(blob: bytes | memoryview) -> str:
    eid, _, _, _ = unframe(blob)
    return REGISTRY[eid].name


def mask_blob(blob: bytes | memoryview, positions: np.ndarray, n_values: int) -> Optional[bytes]:
    """In-place masking of deleted positions. Returns a same-length blob or
    None when only deletion-vector deletes are possible.

    Encodings with a native masking rule (§2.1: bit-packed, varint, RLE,
    dictionary, FOR) use it; for the rest we attempt the generic
    decode -> zero -> re-encode path, accepted only when the result still
    fits the original page (the paper's size criterion). zstd'd or
    mostly-constant pages usually shrink when rows zero out, so physical
    erasure succeeds for most of the catalog."""
    eid, header, payload, _ = unframe(blob)
    enc = REGISTRY[eid]
    positions = np.asarray(positions, np.int64)
    out = enc.mask(header, payload, positions, n_values)
    if out is not None:
        new_header, new_payload = out
        new_blob = frame(eid, new_header, new_payload)
    else:
        try:
            arr = enc.decode(header, payload)
        except Exception:
            return None
        if len(arr) != n_values:
            return None  # already compacted by an earlier delete
        arr = arr.copy()
        arr[positions] = 0  # physical erasure
        try:
            new_blob = enc.encode(arr, EncodeContext())
        except Exception:
            new_blob = None
        if new_blob is None or len(new_blob) > len(memoryview(blob)):
            return None
    if len(new_blob) > len(memoryview(blob)):
        raise AssertionError(
            f"{enc.name}.mask violated the size criterion: "
            f"{len(new_blob)} > {len(memoryview(blob))}")
    # pad to identical size so page offsets in the file never move
    return new_blob + b"\x00" * (len(memoryview(blob)) - len(new_blob))
