"""Sampling-based cascading encoder selection (Bullion §2.6).

BtrBlocks-style: estimate each candidate on contiguous samples, pick the one
minimizing a Nimble-style weighted objective (size + encode time + decode
time), recurse into subcolumns up to ``ctx.max_depth``.
"""

from __future__ import annotations

import time
from typing import Optional

import numpy as np

from .base import BY_NAME, EncodeContext, Encoding, decode_blob, unframe
from . import numeric, floats, bytes_  # noqa: F401  (registration side effects)

# candidate order per column kind; order breaks ties deterministically
INT_CANDIDATES = ("constant", "rle", "dictionary", "for", "fixed_bit_width",
                  "varint", "mainly_constant", "bitshuffle", "chunked", "trivial")
FLOAT_CANDIDATES = ("constant", "rle", "dictionary", "alp_decimal", "xor_float",
                    "mainly_constant", "bitshuffle", "chunked", "trivial")
BOOL_CANDIDATES = ("constant", "rle", "sparse_bool", "trivial")
# at max depth only terminal (non-recursive) encodings are allowed
TERMINAL = ("constant", "fixed_bit_width", "for", "varint", "chunked", "trivial",
            "sparse_bool")
BYTES_CANDIDATES = ("fsst_lite", "raw_bytes")


def _candidates_for(arr: np.ndarray, ctx: EncodeContext) -> tuple[str, ...]:
    if ctx.candidates is not None:
        return ctx.candidates
    if arr.dtype.kind == "b":
        names = BOOL_CANDIDATES
    elif arr.dtype.kind == "f":
        names = FLOAT_CANDIDATES
    else:
        names = INT_CANDIDATES
    if ctx.depth >= ctx.max_depth:
        names = tuple(n for n in names if n in TERMINAL)
    return names


def _sample(arr: np.ndarray, ctx: EncodeContext) -> np.ndarray:
    n = len(arr)
    if n <= ctx.sample_size * 2:
        return arr
    # BtrBlocks samples contiguous runs, not random points, so run-structure
    # (RLE/delta-friendliness) survives sampling.
    k = 4
    run = max(ctx.sample_size // k, 1)
    starts = np.linspace(0, n - run, k).astype(np.int64)
    return np.concatenate([arr[s:s + run] for s in starts])


def _objective(enc: Encoding, sample: np.ndarray, ctx: EncodeContext) -> Optional[float]:
    try:
        t0 = time.perf_counter()
        blob = enc.encode(sample, ctx)
        t_enc = time.perf_counter() - t0
    except Exception:
        return None
    if blob is None:
        return None
    t_dec = 0.0
    if ctx.weights.decode_time:
        eid, header, payload, _ = unframe(blob)
        t0 = time.perf_counter()
        enc.decode(header, payload)
        t_dec = time.perf_counter() - t0
    per_val = len(blob) / max(len(sample), 1)
    return (ctx.weights.size * per_val
            + ctx.weights.encode_time * t_enc
            + ctx.weights.decode_time * t_dec)


def advise_candidates(rec, n: int, dtype) -> Optional[tuple[str, ...]]:
    """LEA-style statistics-driven candidate restriction.

    ``rec`` is a zone-map record (``scan.stats.STAT_DTYPE``: min/max/
    null_count/distinct — exactly the features LEA trains its advisor on).
    Where the statistics already determine the encoding family, the cascade
    skips sampling trials of encodings they rule out; returns None when the
    stats don't discriminate (full sampling-based selection). Sound either
    way — selection quality, never correctness, is at stake.
    """
    if rec is None or n == 0:
        return None
    from ...scan.stats import HAS_MINMAX
    if not int(rec["flags"]) & HAS_MINMAX:
        return None
    distinct = int(rec["distinct"])
    if distinct <= 1 and not int(rec["null_count"]):
        return ("constant", "rle", "trivial")
    if distinct and distinct <= max(16, n // 256):
        # run/dictionary territory: skip bit-width and float-codec trials
        return ("constant", "rle", "dictionary", "mainly_constant", "for",
                "fixed_bit_width", "trivial")
    if np.dtype(dtype).kind in "iu":
        span = float(rec["max"]) - float(rec["min"])
        if span < float(2 ** 20):
            if distinct >= n:
                # all-unique narrow range (ids, timestamps): run and
                # dictionary structure is provably absent — bit-level codecs
                return ("bitshuffle", "for", "fixed_bit_width", "varint",
                        "chunked", "trivial")
            # narrow integer range: frame-of-reference / bit-packing family
            return ("for", "fixed_bit_width", "rle", "dictionary", "varint",
                    "trivial")
    return None


def choose_encoding(arr: np.ndarray, ctx: Optional[EncodeContext] = None) -> str:
    ctx = ctx or EncodeContext()
    sample = _sample(arr, ctx)
    best_name, best_cost = "trivial", float("inf")
    for name in _candidates_for(arr, ctx):
        enc = BY_NAME[name]
        if not enc.applicable(arr, ctx):
            continue
        cost = _objective(enc, sample, ctx)
        if cost is not None and cost < best_cost:
            best_name, best_cost = name, cost
    return best_name


def encode_array(arr: np.ndarray, ctx: Optional[EncodeContext] = None) -> bytes:
    """Cascading entry point: pick best encoding by sampling, encode fully."""
    ctx = ctx or EncodeContext()
    arr = np.asarray(arr)
    if arr.ndim != 1:
        raise ValueError("encode_array expects a 1-D column chunk")
    name = choose_encoding(arr, ctx)
    blob = BY_NAME[name].encode(arr, ctx)
    if blob is None:  # sampling said yes but full data said no -> fall back
        blob = BY_NAME["trivial"].encode(arr, ctx)
    # last-resort guard: never ship something bigger than trivial + slack
    if name != "trivial" and len(blob) > arr.nbytes + 64:
        blob = BY_NAME["trivial"].encode(arr, ctx)
    return blob


def encode_bytes(data: bytes, ctx: Optional[EncodeContext] = None) -> bytes:
    """Select between byte-level encodings for raw string data."""
    ctx = ctx or EncodeContext()
    best_blob, best_len = None, float("inf")
    for name in BYTES_CANDIDATES:
        enc = BY_NAME[name]
        try:
            blob = enc.encode(data, ctx)
        except Exception:
            blob = None
        if blob is not None and len(blob) < best_len:
            best_blob, best_len = blob, len(blob)
    assert best_blob is not None  # raw_bytes always succeeds
    return best_blob


__all__ = ["advise_candidates", "encode_array", "encode_bytes",
           "choose_encoding", "decode_blob"]
