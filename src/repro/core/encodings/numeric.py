"""Integer / boolean encodings of the Bullion catalog (Table 2).

All codecs are vectorized numpy. Each supports the framework's `mask` hook
where the paper defines an in-place deletion-masking rule (§2.1):

  FixedBitWidth  -> zero the element's bits                  (in-place)
  Varint/LEB128  -> keep continuation MSBs, zero 7-bit groups (in-place)
  RLE            -> compact-delete + deletion vector          (shrinks, padded)
  Dictionary     -> rewrite code to the reserved mask entry   (in-place)
  FOR            -> zero the offset bits (delegates to child) (in-place)
  everything else-> deletion-vector only (mask() returns None)
"""

from __future__ import annotations

import struct
from typing import Optional

import numpy as np

from .base import (EncodeContext, Encoding, code_dtype, dtype_code, frame,
                   register, unframe)

# ---------------------------------------------------------------------------
# bit packing helpers
# ---------------------------------------------------------------------------


def pack_bits(vals: np.ndarray, width: int) -> bytes:
    """Pack unsigned values into a little-endian bitstream of `width` bits each."""
    n = len(vals)
    if width == 0 or n == 0:
        return b""
    v = vals.astype(np.uint64, copy=False)
    shifts = np.arange(width, dtype=np.uint64)
    bits = ((v[:, None] >> shifts) & np.uint64(1)).astype(np.uint8)
    return np.packbits(bits.reshape(-1), bitorder="little").tobytes()


def unpack_bits(buf: memoryview | bytes, n: int, width: int) -> np.ndarray:
    if width == 0 or n == 0:
        return np.zeros(n, np.uint64)
    raw = np.frombuffer(buf, np.uint8, count=(n * width + 7) // 8)
    bits = np.unpackbits(raw, count=n * width, bitorder="little").reshape(n, width)
    shifts = np.arange(width, dtype=np.uint64)
    return (bits.astype(np.uint64) << shifts).sum(axis=1, dtype=np.uint64)


def bit_width(max_val: int) -> int:
    return int(max_val).bit_length()


# ---------------------------------------------------------------------------
# LEB128 helpers
# ---------------------------------------------------------------------------


def leb128_encode(u: np.ndarray) -> tuple[bytes, np.ndarray]:
    """Vectorized LEB128. Returns (bytes, per-value byte counts)."""
    u = u.astype(np.uint64, copy=False)
    nbytes = np.ones(len(u), np.int64)
    for k in range(1, 10):
        nbytes += (u >= (np.uint64(1) << np.uint64(7 * k))).astype(np.int64)
    total = int(nbytes.sum())
    out = np.zeros(total, np.uint8)
    starts = np.concatenate([[0], np.cumsum(nbytes)[:-1]])
    for k in range(10):
        sel = nbytes > k
        if not sel.any():
            break
        idx = starts[sel] + k
        group = ((u[sel] >> np.uint64(7 * k)) & np.uint64(0x7F)).astype(np.uint8)
        cont = (nbytes[sel] - 1 > k).astype(np.uint8) << 7
        out[idx] = group | cont
    return out.tobytes(), nbytes


def leb128_boundaries(buf: np.ndarray) -> np.ndarray:
    """Start offset of each encoded value (appends total length)."""
    ends = (buf & 0x80) == 0
    starts = np.flatnonzero(np.concatenate([[True], ends[:-1]]))
    return np.concatenate([starts, [len(buf)]])


def leb128_decode(buf: memoryview | bytes, n: int) -> np.ndarray:
    b = np.frombuffer(buf, np.uint8)
    if len(b) == 0:
        if n:
            raise ValueError(f"empty varint stream, expected {n} values")
        return np.zeros(0, np.uint64)
    ends = (b & 0x80) == 0
    group = np.concatenate([[0], np.cumsum(ends)[:-1]]).astype(np.int64)
    group_starts = np.flatnonzero(np.concatenate([[True], ends[:-1]]))
    pos = np.arange(len(b), dtype=np.int64) - group_starts[group]
    contrib = (b & 0x7F).astype(np.uint64) << (np.uint64(7) * pos.astype(np.uint64))
    out = np.zeros(int(ends.sum()), np.uint64)
    np.add.at(out, group, contrib)
    if len(out) != n:
        raise ValueError(f"varint stream holds {len(out)} values, expected {n}")
    return out


# ---------------------------------------------------------------------------
# zigzag
# ---------------------------------------------------------------------------


def zigzag_encode(x: np.ndarray) -> np.ndarray:
    x64 = x.astype(np.int64, copy=False)
    return ((x64.astype(np.uint64) << np.uint64(1)) ^ (x64 >> np.int64(63)).astype(np.uint64))


def zigzag_decode(u: np.ndarray) -> np.ndarray:
    u = u.astype(np.uint64, copy=False)
    return ((u >> np.uint64(1)).astype(np.int64) ^ -(u & np.uint64(1)).astype(np.int64))


def _is_int(arr: np.ndarray) -> bool:
    return arr.dtype.kind in "iu"


def _to_u64_lossless(arr: np.ndarray) -> np.ndarray:
    """Reinterpret any integer array as uint64 via zigzag for signed."""
    if arr.dtype.kind == "u":
        return arr.astype(np.uint64)
    return zigzag_encode(arr)


def _from_u64(u: np.ndarray, dt: np.dtype) -> np.ndarray:
    if np.dtype(dt).kind == "u":
        return u.astype(dt)
    return zigzag_decode(u).astype(dt)


# ---------------------------------------------------------------------------
# encodings
# ---------------------------------------------------------------------------


class Trivial(Encoding):
    eid, name = 1, "trivial"

    def applicable(self, arr, ctx):
        return True

    def encode(self, arr, ctx):
        header = struct.pack("<BQ", dtype_code(arr.dtype), len(arr))
        return frame(self.eid, header, np.ascontiguousarray(arr).tobytes())

    def decode(self, header, payload):
        code, n = struct.unpack_from("<BQ", header)
        return np.frombuffer(payload, code_dtype(code), count=n).copy()

    def mask(self, header, payload, positions, n_values):
        code, n = struct.unpack_from("<BQ", header)
        arr = np.frombuffer(payload, code_dtype(code), count=n).copy()
        arr[positions] = 0  # physically erase
        return bytes(header), arr.tobytes()


class FixedBitWidth(Encoding):
    """Bit-pack non-negative integers at a fixed minimal width."""

    eid, name = 2, "fixed_bit_width"

    def applicable(self, arr, ctx):
        return _is_int(arr) and len(arr) > 0 and (arr.dtype.kind == "u" or arr.min() >= 0)

    def encode(self, arr, ctx):
        u = arr.astype(np.uint64)
        width = bit_width(int(u.max())) if len(u) else 0
        header = struct.pack("<BQB", dtype_code(arr.dtype), len(arr), width)
        return frame(self.eid, header, pack_bits(u, width))

    def decode(self, header, payload):
        code, n, width = struct.unpack_from("<BQB", header)
        return unpack_bits(payload, n, width).astype(code_dtype(code))

    def mask(self, header, payload, positions, n_values):
        code, n, width = struct.unpack_from("<BQB", header)
        if width == 0:
            return bytes(header), bytes(payload)
        u = unpack_bits(payload, n, width)
        u[positions] = 0  # zero the element's bits
        return bytes(header), pack_bits(u, width)


class Varint(Encoding):
    """LEB128; signed inputs are zigzagged first (flag in header)."""

    eid, name = 3, "varint"

    def applicable(self, arr, ctx):
        return _is_int(arr)

    def encode(self, arr, ctx):
        u = _to_u64_lossless(arr)
        data, _ = leb128_encode(u)
        header = struct.pack("<BQ", dtype_code(arr.dtype), len(arr))
        return frame(self.eid, header, data)

    def decode(self, header, payload):
        code, n = struct.unpack_from("<BQ", header)
        return _from_u64(leb128_decode(payload, n), code_dtype(code))

    def mask(self, header, payload, positions, n_values):
        code, n = struct.unpack_from("<BQ", header)
        b = np.frombuffer(payload, np.uint8).copy()
        bounds = leb128_boundaries(b)
        for p in positions:  # zero 7-bit groups, preserve continuation MSBs
            s, e = bounds[p], bounds[p + 1]
            b[s:e] &= 0x80
        return bytes(header), b.tobytes()


class RLE(Encoding):
    """values + run-lengths as two child-encoded subcolumns."""

    eid, name = 4, "rle"

    def applicable(self, arr, ctx):
        return _is_int(arr) or arr.dtype.kind in "fb"

    @staticmethod
    def _runs(arr):
        n = len(arr)
        bounds = np.flatnonzero(np.concatenate([[True], arr[1:] != arr[:-1]]))
        values = arr[bounds]
        lengths = np.diff(np.concatenate([bounds, [n]]))
        return values, lengths

    def encode(self, arr, ctx):
        from .cascade import encode_array
        if len(arr) == 0:
            return None
        values, lengths = self._runs(arr)
        if len(values) > len(arr) // 2:
            return None  # not profitable
        vblob = encode_array(values, ctx.child())
        lblob = encode_array(lengths.astype(np.uint32), ctx.child())
        header = struct.pack("<BQQ", dtype_code(arr.dtype), len(arr), len(values))
        return frame(self.eid, header, _cat(vblob, lblob))

    def decode(self, header, payload):
        from .base import decode_blob
        code, n, nruns = struct.unpack_from("<BQQ", header)
        vblob, lblob = _split2(payload)
        values = decode_blob(vblob)
        lengths = decode_blob(lblob)
        return np.repeat(values, lengths.astype(np.int64)).astype(code_dtype(code))

    def mask(self, header, payload, positions, n_values):
        # compact delete: drop deleted elements, re-encode; deletion vector
        # (kept at page level) restores alignment. Never grows (runs merge).
        code, n, _ = struct.unpack_from("<BQQ", header)
        full = self.decode(header, payload)
        keep = np.ones(len(full), bool)
        keep[positions] = False
        remaining = full[keep]
        blob = self.encode(remaining, EncodeContext()) or Trivial().encode(remaining, EncodeContext())
        eid, h2, p2, _ = unframe(blob)
        if eid != self.eid:
            return None  # re-encode fell back to another encoding
        if len(h2) + len(p2) > len(header) + len(payload):
            # child-encoding choices changed; cannot honor the size criterion
            return None
        return bytes(h2), bytes(p2)


class Dictionary(Encoding):
    """Dictionary with a reserved mask entry (code == n_unique) for deletion."""

    eid, name = 5, "dictionary"

    def applicable(self, arr, ctx):
        return len(arr) > 0 and arr.dtype.kind in "iuf"

    def encode(self, arr, ctx):
        from .cascade import encode_array
        values, codes = np.unique(arr, return_inverse=True)
        if len(values) > max(16, len(arr) // 4):
            return None
        width = bit_width(len(values))  # reserve mask entry == len(values)
        vblob = encode_array(values, ctx.child())
        header = struct.pack("<BQQB", dtype_code(arr.dtype), len(arr), len(values), width)
        return frame(self.eid, header, _cat(vblob, pack_bits(codes.astype(np.uint64), width)))

    def decode(self, header, payload):
        from .base import decode_blob
        code, n, nuniq, width = struct.unpack_from("<BQQB", header)
        vblob, packed = _split2(payload)
        values = decode_blob(vblob)
        codes = unpack_bits(packed, n, width).astype(np.int64)
        # mask entries decode to a neutral 0 (NOT values[0] — decoding a real
        # value would make erasure audits see phantom occurrences); the page
        # DV drops these rows anyway
        masked = codes >= nuniq
        out = values[np.where(masked, 0, codes)]
        out[masked] = 0
        return out.astype(code_dtype(code))

    def mask(self, header, payload, positions, n_values):
        code, n, nuniq, width = struct.unpack_from("<BQQB", header)
        vblob, packed = _split2(payload)
        codes = unpack_bits(packed, n, width)
        codes[positions] = nuniq  # the reserved mask entry
        return bytes(header), _cat(bytes(vblob), pack_bits(codes, width))


class FOR(Encoding):
    """Frame-of-reference: min base + bit-packed offsets (random access)."""

    eid, name = 6, "for"

    def applicable(self, arr, ctx):
        return _is_int(arr) and len(arr) > 0

    def encode(self, arr, ctx):
        lo = int(arr.min())
        offsets = (arr.astype(np.int64) - lo).astype(np.uint64)
        width = bit_width(int(offsets.max())) if len(offsets) else 0
        header = struct.pack("<BQqB", dtype_code(arr.dtype), len(arr), lo, width)
        return frame(self.eid, header, pack_bits(offsets, width))

    def decode(self, header, payload):
        code, n, lo, width = struct.unpack_from("<BQqB", header)
        return (unpack_bits(payload, n, width).astype(np.int64) + lo).astype(code_dtype(code))

    def mask(self, header, payload, positions, n_values):
        code, n, lo, width = struct.unpack_from("<BQqB", header)
        if width == 0:
            return bytes(header), bytes(payload)
        u = unpack_bits(payload, n, width)
        u[positions] = 0  # decodes to base; page DV hides it
        return bytes(header), pack_bits(u, width)


class Constant(Encoding):
    eid, name = 7, "constant"

    def applicable(self, arr, ctx):
        return len(arr) > 0 and arr.dtype.kind in "iufb"

    def encode(self, arr, ctx):
        if len(arr) == 0 or not (arr == arr[0]).all():
            return None
        header = struct.pack("<BQ", dtype_code(arr.dtype), len(arr))
        return frame(self.eid, header, arr[:1].tobytes())

    def decode(self, header, payload):
        code, n = struct.unpack_from("<BQ", header)
        v = np.frombuffer(payload, code_dtype(code), count=1)
        return np.full(n, v[0], code_dtype(code))

    def mask(self, header, payload, positions, n_values):
        return bytes(header), bytes(payload)  # DV hides; nothing identifying stored


class MainlyConstant(Encoding):
    """Frequency encoding: constant + exception positions + exception values."""

    eid, name = 8, "mainly_constant"

    def applicable(self, arr, ctx):
        return len(arr) > 0 and arr.dtype.kind in "iuf"

    def encode(self, arr, ctx):
        from .cascade import encode_array
        values, counts = np.unique(arr, return_counts=True)
        top = values[np.argmax(counts)]
        exc = np.flatnonzero(arr != top)
        if len(exc) > len(arr) // 8:
            return None
        pos_blob = encode_array(exc.astype(np.uint32), ctx.child())
        val_blob = encode_array(arr[exc], ctx.child()) if len(exc) else b""
        header = struct.pack("<BQQ", dtype_code(arr.dtype), len(arr), len(exc)) + \
            np.asarray([top], arr.dtype).tobytes()
        return frame(self.eid, header, _cat(pos_blob, val_blob))

    def decode(self, header, payload):
        from .base import decode_blob
        code, n, nexc = struct.unpack_from("<BQQ", header)
        dt = code_dtype(code)
        top = np.frombuffer(header[17:17 + dt.itemsize], dt)[0]
        out = np.full(n, top, dt)
        if nexc:
            pos_blob, val_blob = _split2(payload)
            out[decode_blob(pos_blob).astype(np.int64)] = decode_blob(val_blob)
        return out


class SparseBool(Encoding):
    """Roaring-flavored booleans: bitmap, or position list for sparse sides."""

    eid, name = 9, "sparse_bool"

    def applicable(self, arr, ctx):
        return arr.dtype.kind == "b"

    def encode(self, arr, ctx):
        n = len(arr)
        ones = np.flatnonzero(arr)
        mode = 0  # bitmap
        if n >= 64:
            if len(ones) * 32 < n:
                mode = 1  # sparse ones as u32 positions
            elif (n - len(ones)) * 32 < n:
                mode = 2  # sparse zeros
        if mode == 0:
            payload = np.packbits(arr.astype(np.uint8), bitorder="little").tobytes()
        else:
            pos = ones if mode == 1 else np.flatnonzero(~arr)
            payload, _ = leb128_encode(pos.astype(np.uint64))
            payload = struct.pack("<Q", len(pos)) + payload
        header = struct.pack("<QB", n, mode)
        return frame(self.eid, header, payload)

    def decode(self, header, payload):
        n, mode = struct.unpack_from("<QB", header)
        if mode == 0:
            raw = np.frombuffer(payload, np.uint8)
            return np.unpackbits(raw, count=n, bitorder="little").astype(bool)
        (npos,) = struct.unpack_from("<Q", payload)
        pos = leb128_decode(payload[8:], npos).astype(np.int64)
        out = np.zeros(n, bool) if mode == 1 else np.ones(n, bool)
        out[pos] = mode == 1
        return out


class Huffman(Encoding):
    """Canonical Huffman for small-alphabet integers."""

    eid, name = 10, "huffman"
    MAX_ALPHABET = 1024

    def applicable(self, arr, ctx):
        return _is_int(arr) and 0 < len(arr)

    def encode(self, arr, ctx):
        import heapq
        values, inverse, counts = np.unique(arr, return_inverse=True, return_counts=True)
        if len(values) > self.MAX_ALPHABET or len(values) < 2:
            return None
        # build code lengths
        lens = np.zeros(len(values), np.int64)
        heap = [(int(c), i, [i]) for i, c in enumerate(counts)]
        heapq.heapify(heap)
        next_idx = len(values)
        while len(heap) > 1:
            c1, _, m1 = heapq.heappop(heap)
            c2, _, m2 = heapq.heappop(heap)
            for s in m1 + m2:
                lens[s] += 1
            heapq.heappush(heap, (c1 + c2, next_idx, m1 + m2))
            next_idx += 1
        # canonical codes (shorter first, then symbol order)
        order = np.lexsort((np.arange(len(values)), lens))
        codes = np.zeros(len(values), np.uint64)
        code, prev_len = 0, 0
        for sym in order:
            code <<= (lens[sym] - prev_len)
            codes[sym] = code
            code += 1
            prev_len = lens[sym]
        elens = lens[inverse]
        starts = np.concatenate([[0], np.cumsum(elens)[:-1]])
        total_bits = int(elens.sum())
        bits = np.zeros(total_bits, np.uint8)
        ecodes = codes[inverse]
        for k in range(int(lens.max())):
            sel = elens > k
            if not sel.any():
                break
            idx = starts[sel] + k
            bits[idx] = ((ecodes[sel] >> (elens[sel] - 1 - k).astype(np.uint64)) & np.uint64(1)).astype(np.uint8)
        payload = np.packbits(bits, bitorder="little").tobytes()
        from .cascade import encode_array
        vblob = encode_array(values, ctx.child())
        lens_blob = pack_bits(lens.astype(np.uint64), 6)
        header = struct.pack("<BQQQ", dtype_code(arr.dtype), len(arr), len(values), total_bits)
        return frame(self.eid, header, _cat(vblob, _cat(lens_blob, payload)))

    def decode(self, header, payload):
        from .base import decode_blob
        code, n, nsym, total_bits = struct.unpack_from("<BQQQ", header)
        vblob, rest = _split2(payload)
        lens_blob, bitstream = _split2(rest)
        values = decode_blob(vblob)
        lens = unpack_bits(lens_blob, nsym, 6).astype(np.int64)
        order = np.lexsort((np.arange(nsym), lens))
        codes = np.zeros(nsym, np.uint64)
        code_acc, prev_len = 0, 0
        for sym in order:
            code_acc <<= (lens[sym] - prev_len)
            codes[sym] = code_acc
            code_acc += 1
            prev_len = lens[sym]
        # decode table keyed by (len, code)
        table = {(int(lens[s]), int(codes[s])): s for s in range(nsym)}
        bits = np.unpackbits(np.frombuffer(bitstream, np.uint8), count=total_bits,
                             bitorder="little")
        out = np.empty(n, np.int64)
        acc, alen, oi = 0, 0, 0
        maxlen = int(lens.max())
        for b in bits:
            acc = (acc << 1) | int(b)
            alen += 1
            sym = table.get((alen, acc))
            if sym is not None:
                out[oi] = sym
                oi += 1
                acc, alen = 0, 0
            elif alen > maxlen:
                raise ValueError("corrupt huffman stream")
        return values[out].astype(code_dtype(code))


class BitShuffle(Encoding):
    """Transpose element-bits so same-significance bits are contiguous, then
    child-encode the shuffled bytes (typically Chunked/zstd)."""

    eid, name = 11, "bitshuffle"

    def applicable(self, arr, ctx):
        return arr.dtype.kind in "iuf" and len(arr) >= 64

    def encode(self, arr, ctx):
        from .cascade import encode_array
        a = np.ascontiguousarray(arr)
        itemsize = a.dtype.itemsize
        raw = a.view(np.uint8).reshape(len(a), itemsize)
        bits = np.unpackbits(raw, axis=1, bitorder="little")
        shuffled = np.packbits(bits.T.reshape(-1), bitorder="little")
        child = encode_array(shuffled, ctx.child())
        header = struct.pack("<BQ", dtype_code(arr.dtype), len(arr))
        return frame(self.eid, header, child)

    def decode(self, header, payload):
        from .base import decode_blob
        code, n = struct.unpack_from("<BQ", header)
        dt = code_dtype(code)
        shuffled = decode_blob(payload)
        nbits = n * dt.itemsize * 8
        bits = np.unpackbits(shuffled, count=nbits, bitorder="little")
        bits = bits.reshape(dt.itemsize * 8, n).T
        raw = np.packbits(bits.reshape(-1), bitorder="little")
        return np.frombuffer(raw.tobytes(), dt, count=n).copy()


class Chunked(Encoding):
    """zstd over fixed-size chunks (256 KiB) of raw bytes (general-purpose
    block compression — the paper argues it stays valuable for ML data)."""

    eid, name = 12, "chunked"
    CHUNK = 256 * 1024

    def applicable(self, arr, ctx):
        return arr.dtype.kind in "iufb"

    def encode(self, arr, ctx):
        import zstandard as zstd
        raw = np.ascontiguousarray(arr).tobytes()
        cctx = zstd.ZstdCompressor(level=3)
        chunks = [cctx.compress(raw[i:i + self.CHUNK]) for i in range(0, max(len(raw), 1), self.CHUNK)]
        sizes = np.asarray([len(c) for c in chunks], np.uint32)
        header = struct.pack("<BQI", dtype_code(arr.dtype), len(arr), len(chunks)) + sizes.tobytes()
        return frame(self.eid, header, b"".join(chunks))

    def decode(self, header, payload):
        import zstandard as zstd
        code, n, nchunks = struct.unpack_from("<BQI", header)
        sizes = np.frombuffer(header[13:13 + 4 * nchunks], np.uint32)
        dctx = zstd.ZstdDecompressor()
        out, off = [], 0
        for s in sizes:
            out.append(dctx.decompress(bytes(payload[off:off + s]),
                                       max_output_size=self.CHUNK * 4))
            off += int(s)
        return np.frombuffer(b"".join(out), code_dtype(code), count=n).copy()


# ---------------------------------------------------------------------------
# child-blob catenation helpers (u64 length prefixes)
# ---------------------------------------------------------------------------


def _cat(a: bytes, b: bytes) -> bytes:
    return struct.pack("<Q", len(a)) + a + b


def _split2(payload: memoryview | bytes) -> tuple[memoryview, memoryview]:
    mv = memoryview(payload)
    (la,) = struct.unpack_from("<Q", mv)
    return mv[8:8 + la], mv[8 + la:]


for _enc in (Trivial(), FixedBitWidth(), Varint(), RLE(), Dictionary(), FOR(),
             Constant(), MainlyConstant(), SparseBool(), Huffman(), BitShuffle(),
             Chunked()):
    register(_enc)
