"""Decode-time integrity: checksum verification, quarantine, policies.

Bullion's compliance story (paper §2.1) rests on verifiable storage — every
page carries a blake2b checksum in ``Sec.PAGE_CHECKSUM`` — but checksums
that are only consulted by the offline ``bullion fsck`` do nothing for a
live reader. This module closes that gap on the hot read path:

* **Verification policy** (``BULLION_VERIFY=off|sample|full``, default
  ``sample``): every batch of page bytes the reader materializes is hashed
  against the footer before decode. ``sample`` verifies each page once per
  process-wide footer-cache entry (the memo rides the shared ``FooterView``
  object, so re-opens served from the cache stay verified); ``full``
  re-verifies on every read; remote backends always verify fully — a flaky
  HTTP body is far more likely than local bit rot.
* **One re-read before declaring corruption**: a mismatch triggers a single
  direct pread (local) or a fresh ranged GET outside the coalesced run
  (remote). Transient faults — a truncated response body spliced into a
  coalesced run, a torn page cache — recover invisibly; only a *persistent*
  mismatch quarantines the page.
* **Quarantine + graceful degradation** (``BULLION_ON_CORRUPT=
  raise|skip|mask``, default ``raise``): the process-wide
  ``QuarantineRegistry`` records corrupt (shard, group, page) triples keyed
  to the exact ``FooterView`` object that was corrupt. Quarantining a page
  drops the shard from the footer cache (``notify_footer_rewrite``), so an
  out-of-band repair is picked up by stat/ETag revalidation without a
  process restart — the repaired file parses to a *new* footer object and
  the stale quarantine entry self-invalidates. ``skip`` drops the page's
  rows with exact accounting in ``IOStats.degraded_rows``; ``mask`` serves
  shape-stable zero fill for training loaders that prefer a few garbage
  rows over a dead input pipeline.

Event counts flow through ``IOStats`` (``pages_verified`` /
``checksum_failures`` / ``pages_quarantined`` / ``degraded_rows``) and the
``bullion.integrity.*`` metrics.
"""

from __future__ import annotations

import os
import threading
from typing import Optional

import numpy as np

from ..obs import metrics as _metrics
from .footer import Sec, ShardCorruptError, notify_footer_rewrite
from .merkle import page_hash

__all__ = [
    "ShardCorruptError", "QuarantineRegistry", "QUARANTINE",
    "verify_policy", "set_verify_policy", "corruption_policy",
    "set_corruption_policy", "verify_pages", "page_group",
    "VERIFY_OFF", "VERIFY_SAMPLE", "VERIFY_FULL",
    "ON_CORRUPT_RAISE", "ON_CORRUPT_SKIP", "ON_CORRUPT_MASK",
]

VERIFY_OFF = "off"
VERIFY_SAMPLE = "sample"
VERIFY_FULL = "full"
_VERIFY_POLICIES = (VERIFY_OFF, VERIFY_SAMPLE, VERIFY_FULL)

ON_CORRUPT_RAISE = "raise"
ON_CORRUPT_SKIP = "skip"
ON_CORRUPT_MASK = "mask"
_CORRUPT_POLICIES = (ON_CORRUPT_RAISE, ON_CORRUPT_SKIP, ON_CORRUPT_MASK)

_policy_lock = threading.Lock()
_verify_override: Optional[str] = None
_corrupt_override: Optional[str] = None


def _env_policy(var: str, allowed: tuple, default: str) -> str:
    val = os.environ.get(var, "").strip().lower()
    if not val:
        return default
    if val not in allowed:
        raise ValueError(
            f"{var}={val!r}: expected one of {', '.join(allowed)}")
    return val


def verify_policy() -> str:
    """Active verification policy: programmatic override, else the
    ``BULLION_VERIFY`` environment variable, else ``sample``."""
    with _policy_lock:
        if _verify_override is not None:
            return _verify_override
    return _env_policy("BULLION_VERIFY", _VERIFY_POLICIES, VERIFY_SAMPLE)


def set_verify_policy(policy: Optional[str]) -> None:
    """Override ``BULLION_VERIFY`` in-process (``None`` clears)."""
    global _verify_override
    if policy is not None and policy not in _VERIFY_POLICIES:
        raise ValueError(
            f"verify policy {policy!r}: expected one of "
            f"{', '.join(_VERIFY_POLICIES)}")
    with _policy_lock:
        _verify_override = policy


def corruption_policy() -> str:
    """Active corruption policy: programmatic override, else the
    ``BULLION_ON_CORRUPT`` environment variable, else ``raise``."""
    with _policy_lock:
        if _corrupt_override is not None:
            return _corrupt_override
    return _env_policy("BULLION_ON_CORRUPT", _CORRUPT_POLICIES,
                       ON_CORRUPT_RAISE)


def set_corruption_policy(policy: Optional[str]) -> None:
    """Override ``BULLION_ON_CORRUPT`` in-process (``None`` clears)."""
    global _corrupt_override
    if policy is not None and policy not in _CORRUPT_POLICIES:
        raise ValueError(
            f"corruption policy {policy!r}: expected one of "
            f"{', '.join(_CORRUPT_POLICIES)}")
    with _policy_lock:
        _corrupt_override = policy


def page_group(fv, page: int) -> int:
    """Row group owning a physical page (groups partition pages)."""
    gps = fv.group_page_start()
    return int(np.searchsorted(gps, page, side="right")) - 1


# ---------------------------------------------------------------------------
# quarantine registry
# ---------------------------------------------------------------------------

class QuarantineRegistry:
    """Process-wide record of corrupt (shard, group, page) triples.

    Entries are keyed to the *identity* of the ``FooterView`` that was
    corrupt: the footer cache hands the same object to every reader of an
    unchanged file, and drops it when the shard is quarantined or
    rewritten. A repaired (or still-corrupt-but-replaced) file parses to a
    fresh footer object, so stale entries self-invalidate on the next
    lookup — recovery needs no process restart and no explicit clear."""

    def __init__(self):
        self._lock = threading.Lock()
        # path -> {"footer": FooterView, "pages": {page: (group, reason)}}
        self._shards: dict[str, dict] = {}

    def add(self, path: str, fv, group: int, page: int, reason: str) -> bool:
        """Record one corrupt page; returns True if it is newly recorded."""
        with self._lock:
            ent = self._shards.get(path)
            if ent is None or ent["footer"] is not fv:
                ent = self._shards[path] = {"footer": fv, "pages": {}}
            fresh = page not in ent["pages"]
            ent["pages"][page] = (int(group), reason)
        return fresh

    def lookup(self, path: str, fv) -> dict[int, tuple[int, str]]:
        """Quarantined pages of ``path`` *as parsed into this exact
        footer object*: ``{page: (group, reason)}``. Entries recorded
        against a different (stale) footer are dropped."""
        with self._lock:
            ent = self._shards.get(path)
            if ent is None:
                return {}
            if ent["footer"] is not fv:
                del self._shards[path]
                return {}
            return dict(ent["pages"])

    def contains(self, path: str, fv, page: int) -> bool:
        return page in self.lookup(path, fv)

    def clear(self, path: Optional[str] = None) -> None:
        with self._lock:
            if path is None:
                self._shards.clear()
            else:
                self._shards.pop(path, None)

    def summary(self) -> dict:
        """Machine-readable snapshot for ``stats()`` / dashboards."""
        with self._lock:
            shards = {
                path: [{"group": g, "page": p, "reason": r}
                       for p, (g, r) in sorted(ent["pages"].items())]
                for path, ent in sorted(self._shards.items())
            }
        return {
            "quarantined_pages": sum(len(v) for v in shards.values()),
            "quarantined_shards": shards,
        }


QUARANTINE = QuarantineRegistry()


# ---------------------------------------------------------------------------
# decode-time verification
# ---------------------------------------------------------------------------

def _verified_memo(fv) -> set:
    """Sample-mode memo: pages already verified against this footer
    object. Rides the FooterView so the process-wide footer cache shares
    it across readers; a set-add race double-verifies at worst."""
    memo = getattr(fv, "_verified_pages", None)
    if memo is None:
        memo = fv._verified_pages = set()
    return memo


def _quarantine(reader, fv, page: int, reason: str) -> ShardCorruptError:
    group = page_group(fv, page)
    if QUARANTINE.add(reader.path, fv, group, page, reason):
        _metrics.counter("bullion.integrity.pages_quarantined").inc()
    # drop the cached footer: the next open re-reads and revalidates, so an
    # out-of-band repair is picked up without a restart
    notify_footer_rewrite(reader.path)
    return ShardCorruptError(reader.path, reason, group=group, page=page)


def verify_pages(reader, raw: dict) -> dict:
    """Verify a ``{page: bytes}`` batch against ``Sec.PAGE_CHECKSUM``.

    Called by the reader after materializing page bytes and before any
    decode. Returns the dict (possibly with recovered bytes swapped in);
    under policy ``mask`` corrupt pages are *removed* and the decoder
    zero-fills them. Raises ``ShardCorruptError`` for corrupt pages under
    ``raise``/``skip`` (the executor turns ``skip`` into page exclusion
    with exact degraded-row accounting)."""
    fv = reader.footer
    policy = verify_policy()
    if not raw or policy == VERIFY_OFF or not fv.has(Sec.PAGE_CHECKSUM):
        return raw
    # remote bodies are the dominant corruption source: always verify fully
    memo = None if (policy == VERIFY_FULL or reader._remote) \
        else _verified_memo(fv)
    cksums = fv.arr(Sec.PAGE_CHECKSUM, np.uint64)
    quarantined = QUARANTINE.lookup(reader.path, fv)
    on_corrupt = corruption_policy()
    verified = failures = quarantines = 0
    drop: list[int] = []
    try:
        for p in sorted(raw):
            if quarantined and p in quarantined:
                group, reason = quarantined[p]
                if on_corrupt == ON_CORRUPT_MASK:
                    drop.append(p)
                    continue
                raise ShardCorruptError(reader.path, reason,
                                        group=group, page=p)
            if memo is not None and p in memo:
                continue
            want = int(cksums[p])
            verified += 1
            if page_hash(raw[p]) == want:
                if memo is not None:
                    memo.add(p)
                continue
            # one direct re-read outside the coalesced run before declaring
            # corruption: recovers transient faults (torn cache, truncated
            # response body) without quarantining the page
            failures += 1
            _metrics.counter("bullion.integrity.checksum_failures").inc()
            off, size = fv.page_extent(p)
            try:
                fresh = reader._pread(off, size)
            except OSError:
                fresh = b""
            verified += 1
            if page_hash(fresh) == want:
                raw[p] = fresh
                if memo is not None:
                    memo.add(p)
                _metrics.counter("bullion.integrity.reread_recovered").inc()
                continue
            quarantines += 1
            err = _quarantine(
                reader, fv, p,
                "page checksum mismatch (persisted across one re-read)")
            if on_corrupt == ON_CORRUPT_MASK:
                drop.append(p)
                continue
            raise err
    finally:
        if verified or failures or quarantines:
            with reader._stats_lock:
                st = reader.stats
                st.pages_verified += verified
                st.checksum_failures += failures
                st.pages_quarantined += quarantines
    for p in drop:
        raw.pop(p, None)
    return raw
