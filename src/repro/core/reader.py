"""Bullion read path.

Feature projection (paper §2.3): footer pread -> binary map scan for column
indices -> byte ranges from the offsets arrays -> targeted preads.  Adjacent
page ranges are coalesced into single I/O operations (the Alpha-style
optimization the paper cites) because ML projections read many columns of the
same row group.

``BullionReader`` owns the file handle, the zero-copy footer view, and the
coalesced-pread primitive (``_read_pages``). Everything above that — decode,
deletion masking, dequantization, predicate filtering — lives in the unified
lazy ``Dataset`` pipeline (``repro.dataset``); the ``project``/
``read_column``/``find_rows`` methods below are deprecated shims that build
the equivalent one-file plans.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from . import backend as _backend
from . import integrity as _integrity
from .footer import ColKind, Sec, read_footer
from .quantization import QuantSpec

COALESCE_GAP = 64 * 1024  # merge preads when the hole is smaller than this


def default_coalesce_gap(remote: bool = False) -> int:
    """Coalescing gap in bytes: ``BULLION_COALESCE_GAP`` overrides the
    built-in defaults fleet-wide — 64 KiB for local files, 1 MiB for
    object-store shards, where hole bytes are cheap next to per-request
    latency. 0 still merges physically contiguous ranges (two preads for
    one contiguous span is never right) but bridges no holes, so
    ``wasted_bytes`` stays 0."""
    env = os.environ.get("BULLION_COALESCE_GAP")
    if env is None or not env.strip():
        return _backend.REMOTE_COALESCE_GAP if remote else COALESCE_GAP
    try:
        gap = int(env)
    except ValueError:
        raise ValueError(
            f"BULLION_COALESCE_GAP must be an integer byte count, "
            f"got {env!r}") from None
    if gap < 0:
        raise ValueError(f"BULLION_COALESCE_GAP must be >= 0, got {gap}")
    return gap


@dataclass
class IOStats:
    preads: int = 0
    bytes_read: int = 0
    footer_bytes: int = 0
    metadata_seconds: float = 0.0
    bytes_pruned: int = 0     # data bytes a plan proved it never had to read
                              # (zone maps, row location, head limits)
    pages_pruned: int = 0     # page reads those proofs avoided (group- and
                              # page-granular zone maps)
    coalesced_preads: int = 0  # page reads merged into a larger neighbor
                               # (= preads avoided by range coalescing)
    wasted_bytes: int = 0     # hole bytes read only because coalescing
                              # bridged a gap between two wanted ranges
    footer_cache_hits: int = 0  # shard opens served from the process-wide
                                # footer cache (no footer pread, no parse)
    groups_pruned_sketch: int = 0  # row groups the zone maps admitted but a
                                   # bloom value sketch refuted (point probes
                                   # on unclustered columns)
    backend_fetches: int = 0  # ranged GETs a storage backend served (remote
                              # shards; local reads stay in ``preads``)
    backend_retries: int = 0  # backend requests retried after a 5xx,
                              # timeout, or truncated body
    backend_wasted_bytes: int = 0  # hole bytes fetched remotely because run
                                   # coalescing bridged a gap (the remote
                                   # twin of ``wasted_bytes``)
    pages_verified: int = 0   # page payloads hashed against PAGE_CHECKSUM
                              # before decode (BULLION_VERIFY policy)
    checksum_failures: int = 0  # verification mismatches observed (includes
                                # ones the single re-read recovered)
    pages_quarantined: int = 0  # pages whose mismatch persisted across the
                                # re-read and entered the QuarantineRegistry
    degraded_rows: int = 0    # rows dropped (skip) or zero-masked (mask)
                              # because their page is quarantined

    # -- aggregation (the one field-complete merge every consumer uses) -------
    def merge(self, other: "IOStats") -> "IOStats":
        """Field-wise in-place add. Defined on the dataclass itself so a new
        counter field can never silently drop out of cross-reader
        aggregation (``DataSource.stats``, benchmark CSVs, the metrics
        registry all go through here)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    @staticmethod
    def sum(items: Iterable["IOStats"]) -> "IOStats":
        total = IOStats()
        for st in items:
            total.merge(st)
        return total

    def delta(self, before: "IOStats") -> "IOStats":
        """Field-wise ``self - before``: what one execution added to a
        cumulative snapshot (``explain(analyze=True)`` reconciliation)."""
        out = IOStats()
        for f in dataclasses.fields(self):
            setattr(out, f.name, getattr(self, f.name) - getattr(before, f.name))
        return out


class BullionReader:
    def __init__(self, path: str, *, footer=None, charge_footer: bool = True,
                 coalesce_gap: Optional[int] = None):
        self.path = path
        t0 = time.perf_counter()
        # the storage backend owns *where* bytes come from: a local fd
        # (byte-identical to the pre-backend read path) or bullion://
        # ranged GETs — everything above this handle is backend-agnostic
        self._handle = _backend.open_shard(path)
        self._remote = self._handle.is_remote
        if footer is None:
            if self._remote:
                self.footer, self.footer_offset = \
                    _backend.read_shard_footer(self._handle)
            else:
                self.footer, self.footer_offset = read_footer(path)
        else:
            # pre-parsed (FooterView, offset) from dataset discovery — the
            # metadata was read exactly once, by the DataSource
            self.footer, self.footer_offset = footer
        if coalesce_gap is None:
            self.coalesce_gap = default_coalesce_gap(remote=self._remote)
        else:
            self.coalesce_gap = int(coalesce_gap)
            if self.coalesce_gap < 0:
                raise ValueError(
                    f"coalesce_gap must be >= 0, got {coalesce_gap}")
        # ``charge_footer=False`` means the footer reads happened elsewhere
        # (or not at all: a footer-cache hit) and must not be double-counted.
        # Local metadata costs two preads (tail, then footer); remote
        # metadata is one speculative tail GET.
        flen = len(self.footer._buf)
        if not charge_footer:
            self.stats = IOStats()
        elif self._remote:
            self.stats = IOStats(backend_fetches=1, footer_bytes=flen,
                                 bytes_read=flen)
        else:
            self.stats = IOStats(preads=2, footer_bytes=flen,
                                 bytes_read=flen)
        self.stats.metadata_seconds = time.perf_counter() - t0
        self._scanner = None
        self._stats_lock = threading.Lock()
        # backend-level charges (remote fetches/retries/bytes) land on the
        # same IOStats every other read path uses
        self._handle.bind_stats(self.stats, self._stats_lock)

    def close(self) -> None:
        """Idempotent: safe to call repeatedly (context-manager exits after
        an aborted plan may race explicit close() calls)."""
        if self._handle is not None:
            self._handle.close()
            self._handle = None

    @property
    def closed(self) -> bool:
        return self._handle is None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- metadata ---------------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self.footer.num_rows

    @property
    def column_names(self) -> list[str]:
        return self.footer.column_names()

    def quant_spec(self, col: int) -> QuantSpec:
        from .quantization import QUANT_DTYPE
        recs = self.footer.arr(Sec.QUANT_META, QUANT_DTYPE)
        return QuantSpec.from_record(recs[col])

    @property
    def scanner(self):
        """Statistics-driven pruning scanner (lazy; see repro.scan)."""
        if self._scanner is None:
            from ..scan.scanner import Scanner
            self._scanner = Scanner(self)
        return self._scanner

    def _dataset(self):
        """One-file lazy Dataset over this (still caller-owned) reader."""
        from ..dataset.core import Dataset
        return Dataset.from_reader(self)

    # -- I/O ----------------------------------------------------------------------
    def _pread(self, offset: int, size: int) -> bytes:
        """Positional read: ``os.pread`` (and its remote twin, one ranged
        GET) never moves a shared cursor, so concurrent ScanTasks on the
        same shard (parallel execution) are safe on one handle. Stats
        mutate under a lock for the same reason. Per-call latency lands in
        the ``bullion.io.pread_seconds`` histogram only while tracing is
        enabled (two extra clock reads are not free on the disabled hot
        path); remote handles charge ``backend_fetches``/``bytes_read``
        themselves."""
        h = self._handle
        if h is None:
            raise ValueError(f"{self.path}: reader is closed")
        if h.is_remote:
            return h.pread(offset, size)
        if _trace.enabled():
            t0 = time.perf_counter()
            data = h.pread(offset, size)
            _metrics.histogram("bullion.io.pread_seconds").observe(
                time.perf_counter() - t0)
        else:
            data = h.pread(offset, size)
        with self._stats_lock:
            self.stats.preads += 1
            self.stats.bytes_read += size
        return data

    def _charge_run(self, off: int, end: int,
                    extents: Sequence[tuple[int, int, int]]) -> None:
        """Coalescing accounting for one run: the reads the merge avoided,
        and the hole bytes it fetched to bridge gaps — charged to
        ``wasted_bytes`` locally, ``backend_wasted_bytes`` remotely (the
        tuning knobs differ, so the counters must too)."""
        covered = sum(s for _, s, _ in extents)
        with self._stats_lock:
            self.stats.coalesced_preads += len(extents) - 1
            if self._remote:
                self.stats.backend_wasted_bytes += (end - off) - covered
            else:
                self.stats.wasted_bytes += (end - off) - covered

    def _pread_run(self, off: int, end: int,
                   extents: Sequence[tuple[int, int, int]]) -> dict[int, bytes]:
        """One positional read covering ``[off, end)``, sliced back into the
        page extents ``(page_off, size, page_id)`` it coalesced. Accounts the
        preads the merge avoided and the hole bytes it read to bridge gaps;
        every coalesced submission's size feeds ``bullion.io.run_bytes``
        (once per run — cheap enough to stay on)."""
        _metrics.histogram("bullion.io.run_bytes").observe(end - off)
        buf = self._pread(off, end - off)
        self._charge_run(off, end, extents)
        return {p: buf[o - off: o - off + s] for o, s, p in extents}

    def _fetch_runs(self, runs, *, max_in_flight: int = 1, span_meta=None):
        """Fetch a batch of coalesced runs ``[(off, end, extents)]``,
        yielding ``(index, {page: bytes} | None, error | None)``.

        Local shards fetch serially in submission order — exactly the one
        ``_pread_run`` per run the scheduler always issued, byte-identical.
        Remote shards hand the whole batch to the async range fetcher,
        which overlaps up to ``max_in_flight`` ranged GETs over keep-alive
        connections and yields in whatever order the object store answers,
        so decode overlaps the slowest range instead of waiting on it.
        Per-run errors are yielded rather than raised: one failed range
        fails only the tasks it covers."""
        meta = span_meta or [{} for _ in runs]
        if not (self._remote and len(runs) > 1 and max_in_flight > 1):
            for i, (off, end, extents) in enumerate(runs):
                sp = _trace.span("io.run", cat="io", bytes=end - off,
                                 extents=len(extents), **meta[i])
                try:
                    with sp:
                        pages = self._pread_run(off, end, extents)
                except Exception as e:
                    yield i, None, e
                else:
                    yield i, pages, None
            return
        sp = _trace.span(
            "io.run_batch", cat="io", runs=len(runs),
            bytes=sum(end - off for off, end, _ in runs),
            max_in_flight=max_in_flight, **meta[0])
        with sp:
            ranges = [(off, end) for off, end, _ in runs]
            for i, body, err in self._handle.fetch_ranges(
                    ranges, max_in_flight=max_in_flight):
                if err is not None:
                    yield i, None, err
                    continue
                off, end, extents = runs[i]
                _metrics.histogram("bullion.io.run_bytes").observe(end - off)
                self._charge_run(off, end, extents)
                yield i, {p: body[o - off: o - off + s]
                          for o, s, p in extents}, None

    def _read_pages(self, page_ids: Sequence[int]) -> dict[int, bytes]:
        """Coalesced ranged reads for a set of pages (gap-bridged merging up
        to ``self.coalesce_gap`` hole bytes between wanted ranges)."""
        fv = self.footer
        extents = sorted((fv.page_extent(p), p) for p in page_ids)
        out: dict[int, bytes] = {}
        i = 0
        while i < len(extents):
            (off, size), _ = extents[i]
            j = i + 1
            end = off + size
            while j < len(extents):
                (o2, s2), _ = extents[j]
                if o2 - end > self.coalesce_gap:
                    break
                end = max(end, o2 + s2)
                j += 1
            out.update(self._pread_run(
                off, end, [(o, s, p) for (o, s), p in extents[i:j]]))
            i = j
        # decode-time integrity gate: checksum every materialized page per
        # the BULLION_VERIFY policy before anything decodes it
        return _integrity.verify_pages(self, out)

    # -- projection (deprecated shims over the Dataset plan path) ----------------
    def project(self, names: Sequence[str], groups: Optional[Sequence[int]] = None,
                drop_deleted: bool = True, dequant: bool = True,
                predicate=None) -> Iterator[dict]:
        """Deprecated: use ``repro.dataset``. Yields one dict per row group
        with decoded columns, via the equivalent one-file plan.

        With ``predicate`` (a ``repro.scan`` Predicate), row groups the zone
        maps prove empty are skipped without any data pread and the yielded
        tables contain only the matching rows (one dict per surviving group
        with >= 1 match)."""
        ds = self._dataset().select(list(names)) \
            .drop_deleted(drop_deleted).dequantized(dequant) \
            ._with_groups(groups)
        if predicate is not None:
            ds = ds.where(predicate)
        return ds.to_batches()

    def read_column(self, name: str, **kw) -> np.ndarray | list:
        """Deprecated: use ``repro.dataset``."""
        parts = [t[name] for t in self.project([name], **kw)]
        if isinstance(parts[0], np.ndarray):
            return np.concatenate(parts)
        return [r for p in parts for r in p]

    # -- helpers for deletion / benchmarks ----------------------------------------
    def locate_rows(self, global_rows: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """Map global row ids -> [(group, local_rows)]."""
        from ..dataset.plan import locate_rows
        return list(locate_rows(self.footer, global_rows).items())

    def find_rows(self, column: str, values) -> np.ndarray:
        """Deprecated: use ``repro.dataset``. Global row ids (raw row space)
        where column ∈ values.

        On files with zone maps (format v1+) only the row groups whose
        statistics admit one of the values are read; v0 files fall back to
        the full-column scan. String columns keep the legacy full-decode
        membership probe (predicates cover scalar columns only)."""
        from ..scan.predicate import In
        kinds = self.footer.arr(Sec.COL_KIND, np.uint8)
        if kinds[self.footer.column_index(column)] not in \
                (int(ColKind.SCALAR), int(ColKind.MEDIA_REF)):
            data = self.read_column(column, drop_deleted=False, dequant=False)
            return np.flatnonzero(np.isin(np.asarray(data), np.asarray(values)))
        return self._dataset().where(In(column, values)) \
            .drop_deleted(False).row_ids()
