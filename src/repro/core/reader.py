"""Bullion read path.

Feature projection (paper §2.3): footer pread -> binary map scan for column
indices -> byte ranges from the offsets arrays -> targeted preads.  Adjacent
page ranges are coalesced into single I/O operations (the Alpha-style
optimization the paper cites) because ML projections read many columns of the
same row group.

``BullionReader`` owns the file handle, the zero-copy footer view, and the
coalesced-pread primitive (``_read_pages``). Everything above that — decode,
deletion masking, dequantization, predicate filtering — lives in the unified
lazy ``Dataset`` pipeline (``repro.dataset``); the ``project``/
``read_column``/``find_rows`` methods below are deprecated shims that build
the equivalent one-file plans.
"""

from __future__ import annotations

import dataclasses
import os
import threading
import time
from dataclasses import dataclass
from typing import Iterable, Iterator, Optional, Sequence

import numpy as np

from ..obs import metrics as _metrics
from ..obs import trace as _trace
from .footer import ColKind, Sec, read_footer
from .quantization import QuantSpec

COALESCE_GAP = 64 * 1024  # merge preads when the hole is smaller than this


def default_coalesce_gap() -> int:
    """Coalescing gap in bytes: ``BULLION_COALESCE_GAP`` overrides the
    built-in 64 KiB default fleet-wide. 0 still merges physically
    contiguous ranges (two preads for one contiguous span is never right)
    but bridges no holes, so ``wasted_bytes`` stays 0."""
    env = os.environ.get("BULLION_COALESCE_GAP")
    if env is None or not env.strip():
        return COALESCE_GAP
    try:
        gap = int(env)
    except ValueError:
        raise ValueError(
            f"BULLION_COALESCE_GAP must be an integer byte count, "
            f"got {env!r}") from None
    if gap < 0:
        raise ValueError(f"BULLION_COALESCE_GAP must be >= 0, got {gap}")
    return gap


@dataclass
class IOStats:
    preads: int = 0
    bytes_read: int = 0
    footer_bytes: int = 0
    metadata_seconds: float = 0.0
    bytes_pruned: int = 0     # data bytes a plan proved it never had to read
                              # (zone maps, row location, head limits)
    pages_pruned: int = 0     # page reads those proofs avoided (group- and
                              # page-granular zone maps)
    coalesced_preads: int = 0  # page reads merged into a larger neighbor
                               # (= preads avoided by range coalescing)
    wasted_bytes: int = 0     # hole bytes read only because coalescing
                              # bridged a gap between two wanted ranges
    footer_cache_hits: int = 0  # shard opens served from the process-wide
                                # footer cache (no footer pread, no parse)
    groups_pruned_sketch: int = 0  # row groups the zone maps admitted but a
                                   # bloom value sketch refuted (point probes
                                   # on unclustered columns)

    # -- aggregation (the one field-complete merge every consumer uses) -------
    def merge(self, other: "IOStats") -> "IOStats":
        """Field-wise in-place add. Defined on the dataclass itself so a new
        counter field can never silently drop out of cross-reader
        aggregation (``DataSource.stats``, benchmark CSVs, the metrics
        registry all go through here)."""
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(self, f.name) + getattr(other, f.name))
        return self

    @staticmethod
    def sum(items: Iterable["IOStats"]) -> "IOStats":
        total = IOStats()
        for st in items:
            total.merge(st)
        return total

    def delta(self, before: "IOStats") -> "IOStats":
        """Field-wise ``self - before``: what one execution added to a
        cumulative snapshot (``explain(analyze=True)`` reconciliation)."""
        out = IOStats()
        for f in dataclasses.fields(self):
            setattr(out, f.name, getattr(self, f.name) - getattr(before, f.name))
        return out


class BullionReader:
    def __init__(self, path: str, *, footer=None, charge_footer: bool = True,
                 coalesce_gap: Optional[int] = None):
        self.path = path
        t0 = time.perf_counter()
        if footer is None:
            self.footer, self.footer_offset = read_footer(path)
        else:
            # pre-parsed (FooterView, offset) from dataset discovery — the
            # metadata was read exactly once, by the DataSource
            self.footer, self.footer_offset = footer
        if coalesce_gap is None:
            self.coalesce_gap = default_coalesce_gap()
        else:
            self.coalesce_gap = int(coalesce_gap)
            if self.coalesce_gap < 0:
                raise ValueError(
                    f"coalesce_gap must be >= 0, got {coalesce_gap}")
        # ``charge_footer=False`` means the footer preads happened elsewhere
        # (or not at all: a footer-cache hit) and must not be double-counted
        self.stats = IOStats(preads=2, footer_bytes=len(self.footer._buf),
                             bytes_read=len(self.footer._buf)) \
            if charge_footer else IOStats()
        self.stats.metadata_seconds = time.perf_counter() - t0
        self._f = open(path, "rb")
        self._scanner = None
        self._stats_lock = threading.Lock()

    def close(self) -> None:
        """Idempotent: safe to call repeatedly (context-manager exits after
        an aborted plan may race explicit close() calls)."""
        if self._f is not None:
            self._f.close()
            self._f = None

    @property
    def closed(self) -> bool:
        return self._f is None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- metadata ---------------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self.footer.num_rows

    @property
    def column_names(self) -> list[str]:
        return self.footer.column_names()

    def quant_spec(self, col: int) -> QuantSpec:
        from .quantization import QUANT_DTYPE
        recs = self.footer.arr(Sec.QUANT_META, QUANT_DTYPE)
        return QuantSpec.from_record(recs[col])

    @property
    def scanner(self):
        """Statistics-driven pruning scanner (lazy; see repro.scan)."""
        if self._scanner is None:
            from ..scan.scanner import Scanner
            self._scanner = Scanner(self)
        return self._scanner

    def _dataset(self):
        """One-file lazy Dataset over this (still caller-owned) reader."""
        from ..dataset.core import Dataset
        return Dataset.from_reader(self)

    # -- I/O ----------------------------------------------------------------------
    def _pread(self, offset: int, size: int) -> bytes:
        """Positional read: ``os.pread`` never moves a shared file cursor,
        so concurrent ScanTasks on the same shard (parallel execution) are
        safe on one handle. Stats mutate under a lock for the same reason.
        Per-call latency lands in the ``bullion.io.pread_seconds`` histogram
        only while tracing is enabled (two extra clock reads are not free on
        the disabled hot path)."""
        f = self._f
        if f is None:
            raise ValueError(f"{self.path}: reader is closed")
        if _trace.enabled():
            t0 = time.perf_counter()
            data = os.pread(f.fileno(), size, offset)
            _metrics.histogram("bullion.io.pread_seconds").observe(
                time.perf_counter() - t0)
        else:
            data = os.pread(f.fileno(), size, offset)
        with self._stats_lock:
            self.stats.preads += 1
            self.stats.bytes_read += size
        return data

    def _pread_run(self, off: int, end: int,
                   extents: Sequence[tuple[int, int, int]]) -> dict[int, bytes]:
        """One positional read covering ``[off, end)``, sliced back into the
        page extents ``(page_off, size, page_id)`` it coalesced. Accounts the
        preads the merge avoided and the hole bytes it read to bridge gaps;
        every coalesced submission's size feeds ``bullion.io.run_bytes``
        (once per run — cheap enough to stay on)."""
        _metrics.histogram("bullion.io.run_bytes").observe(end - off)
        buf = self._pread(off, end - off)
        covered = sum(s for _, s, _ in extents)
        with self._stats_lock:
            self.stats.coalesced_preads += len(extents) - 1
            self.stats.wasted_bytes += (end - off) - covered
        return {p: buf[o - off: o - off + s] for o, s, p in extents}

    def _read_pages(self, page_ids: Sequence[int]) -> dict[int, bytes]:
        """Coalesced ranged reads for a set of pages (gap-bridged merging up
        to ``self.coalesce_gap`` hole bytes between wanted ranges)."""
        fv = self.footer
        extents = sorted((fv.page_extent(p), p) for p in page_ids)
        out: dict[int, bytes] = {}
        i = 0
        while i < len(extents):
            (off, size), _ = extents[i]
            j = i + 1
            end = off + size
            while j < len(extents):
                (o2, s2), _ = extents[j]
                if o2 - end > self.coalesce_gap:
                    break
                end = max(end, o2 + s2)
                j += 1
            out.update(self._pread_run(
                off, end, [(o, s, p) for (o, s), p in extents[i:j]]))
            i = j
        return out

    # -- projection (deprecated shims over the Dataset plan path) ----------------
    def project(self, names: Sequence[str], groups: Optional[Sequence[int]] = None,
                drop_deleted: bool = True, dequant: bool = True,
                predicate=None) -> Iterator[dict]:
        """Deprecated: use ``repro.dataset``. Yields one dict per row group
        with decoded columns, via the equivalent one-file plan.

        With ``predicate`` (a ``repro.scan`` Predicate), row groups the zone
        maps prove empty are skipped without any data pread and the yielded
        tables contain only the matching rows (one dict per surviving group
        with >= 1 match)."""
        ds = self._dataset().select(list(names)) \
            .drop_deleted(drop_deleted).dequantized(dequant) \
            ._with_groups(groups)
        if predicate is not None:
            ds = ds.where(predicate)
        return ds.to_batches()

    def read_column(self, name: str, **kw) -> np.ndarray | list:
        """Deprecated: use ``repro.dataset``."""
        parts = [t[name] for t in self.project([name], **kw)]
        if isinstance(parts[0], np.ndarray):
            return np.concatenate(parts)
        return [r for p in parts for r in p]

    # -- helpers for deletion / benchmarks ----------------------------------------
    def locate_rows(self, global_rows: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """Map global row ids -> [(group, local_rows)]."""
        from ..dataset.plan import locate_rows
        return list(locate_rows(self.footer, global_rows).items())

    def find_rows(self, column: str, values) -> np.ndarray:
        """Deprecated: use ``repro.dataset``. Global row ids (raw row space)
        where column ∈ values.

        On files with zone maps (format v1+) only the row groups whose
        statistics admit one of the values are read; v0 files fall back to
        the full-column scan. String columns keep the legacy full-decode
        membership probe (predicates cover scalar columns only)."""
        from ..scan.predicate import In
        kinds = self.footer.arr(Sec.COL_KIND, np.uint8)
        if kinds[self.footer.column_index(column)] not in \
                (int(ColKind.SCALAR), int(ColKind.MEDIA_REF)):
            data = self.read_column(column, drop_deleted=False, dequant=False)
            return np.flatnonzero(np.isin(np.asarray(data), np.asarray(values)))
        return self._dataset().where(In(column, values)) \
            .drop_deleted(False).row_ids()
