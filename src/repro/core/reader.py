"""Bullion read path.

Feature projection (paper §2.3): footer pread -> binary map scan for column
indices -> byte ranges from the offsets arrays -> targeted preads.  Adjacent
page ranges are coalesced into single I/O operations (the Alpha-style
optimization the paper cites) because ML projections read many columns of the
same row group.

Predicated reads go through the statistics-driven scan subsystem
(``repro.scan``): zone maps persisted by the writer prune whole row groups
before any data pread, and only surviving groups are decoded and filtered.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

import numpy as np

from . import pages
from .encodings.base import code_dtype
from .footer import ColKind, FooterView, PageType, Sec, read_footer
from .quantization import QuantMode, QuantSpec, dequantize

COALESCE_GAP = 64 * 1024  # merge preads when the hole is smaller than this


@dataclass
class IOStats:
    preads: int = 0
    bytes_read: int = 0
    footer_bytes: int = 0
    metadata_seconds: float = 0.0


class BullionReader:
    def __init__(self, path: str):
        self.path = path
        t0 = time.perf_counter()
        self.footer, self.footer_offset = read_footer(path)
        self.stats = IOStats(preads=2, footer_bytes=len(self.footer._buf),
                             bytes_read=len(self.footer._buf))
        self.stats.metadata_seconds = time.perf_counter() - t0
        self._f = open(path, "rb")
        self._scanner = None

    def close(self) -> None:
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- metadata ---------------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self.footer.num_rows

    @property
    def column_names(self) -> list[str]:
        return self.footer.column_names()

    def quant_spec(self, col: int) -> QuantSpec:
        from .quantization import QUANT_DTYPE
        recs = self.footer.arr(Sec.QUANT_META, QUANT_DTYPE)
        return QuantSpec.from_record(recs[col])

    @property
    def scanner(self):
        """Statistics-driven pruning scanner (lazy; see repro.scan)."""
        if self._scanner is None:
            from ..scan.scanner import Scanner
            self._scanner = Scanner(self)
        return self._scanner

    # -- I/O ----------------------------------------------------------------------
    def _pread(self, offset: int, size: int) -> bytes:
        self._f.seek(offset)
        self.stats.preads += 1
        self.stats.bytes_read += size
        return self._f.read(size)

    def _read_pages(self, page_ids: Sequence[int]) -> dict[int, bytes]:
        """Coalesced ranged reads for a set of pages."""
        fv = self.footer
        extents = sorted((fv.page_extent(p), p) for p in page_ids)
        out: dict[int, bytes] = {}
        i = 0
        while i < len(extents):
            (off, size), _ = extents[i]
            j = i + 1
            end = off + size
            while j < len(extents):
                (o2, s2), _ = extents[j]
                if o2 - end > COALESCE_GAP:
                    break
                end = max(end, o2 + s2)
                j += 1
            buf = self._pread(off, end - off)
            for k in range(i, j):
                (o, s), p = extents[k]
                out[p] = buf[o - off: o - off + s]
            i = j
        return out

    # -- projection ----------------------------------------------------------------
    def project(self, names: Sequence[str], groups: Optional[Sequence[int]] = None,
                drop_deleted: bool = True, dequant: bool = True,
                predicate=None) -> Iterator[dict]:
        """Yield one dict per row group with decoded columns.

        With ``predicate`` (a ``repro.scan`` Predicate), row groups the zone
        maps prove empty are skipped without any data pread and the yielded
        tables contain only the matching rows (one dict per surviving group
        with >= 1 match)."""
        if predicate is not None:
            for batch in self.scanner.scan(predicate, columns=list(names),
                                           groups=groups,
                                           drop_deleted=drop_deleted,
                                           dequant=dequant):
                yield batch.table
            return
        fv = self.footer
        cols = [fv.column_index(n) for n in names]
        kinds = fv.arr(Sec.COL_KIND, np.uint8)
        flags = fv.arr(Sec.PAGE_FLAGS, np.uint8)
        page_rows = fv.arr(Sec.PAGE_ROWS, np.uint32)
        for g in (groups if groups is not None else range(fv.n_groups)):
            wanted: list[int] = []
            for c in cols:
                s, e = fv.chunk_pages(g, c)
                wanted.extend(range(s, e))
            raw = self._read_pages(wanted)
            out: dict = {}
            for name, c in zip(names, cols):
                s, e = fv.chunk_pages(g, c)
                parts = []
                for p in range(s, e):
                    decoded = pages.decode_page(int(flags[p]) & 0x7F, raw[p])
                    if drop_deleted:
                        decoded = pages.apply_dv(decoded, fv.deletion_vector(p),
                                                 int(page_rows[p]))
                    parts.append(decoded)
                val = parts[0] if len(parts) == 1 else _concat(parts)
                if dequant and kinds[c] == int(ColKind.SCALAR):
                    spec = self.quant_spec(c)
                    if spec.mode != QuantMode.NONE:
                        val = dequantize(np.asarray(val), spec)
                out[name] = val
            yield out

    def read_column(self, name: str, **kw) -> np.ndarray | list:
        parts = [t[name] for t in self.project([name], **kw)]
        if isinstance(parts[0], np.ndarray):
            return np.concatenate(parts)
        return [r for p in parts for r in p]

    # -- helpers for deletion / benchmarks ----------------------------------------
    def locate_rows(self, global_rows: np.ndarray) -> list[tuple[int, np.ndarray]]:
        """Map global row ids -> [(group, local_rows)]."""
        rpg = self.footer.arr(Sec.ROWS_PER_GROUP, np.uint32).astype(np.int64)
        bounds = np.concatenate([[0], np.cumsum(rpg)])
        global_rows = np.asarray(global_rows, np.int64)
        g = np.searchsorted(bounds, global_rows, side="right") - 1
        out = []
        for grp in np.unique(g):
            out.append((int(grp), global_rows[g == grp] - bounds[grp]))
        return out

    def find_rows(self, column: str, values) -> np.ndarray:
        """Predicate helper: global row ids (raw row space) where
        column ∈ values.

        Rewritten on the pruning scanner: on files with zone maps
        (format v1+) only the row groups whose statistics admit one of the
        values are read; v0 files fall back to the full-column scan.
        String columns keep the legacy full-decode membership probe
        (predicates cover scalar columns only)."""
        from ..scan.predicate import In
        kinds = self.footer.arr(Sec.COL_KIND, np.uint8)
        if kinds[self.footer.column_index(column)] not in \
                (int(ColKind.SCALAR), int(ColKind.MEDIA_REF)):
            data = self.read_column(column, drop_deleted=False, dequant=False)
            return np.flatnonzero(np.isin(np.asarray(data), np.asarray(values)))
        return self.scanner.find_rows(In(column, values))


def _concat(parts):
    if isinstance(parts[0], np.ndarray):
        return np.concatenate(parts)
    return [r for p in parts for r in p]
