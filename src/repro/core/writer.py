"""Bullion write path.

Native write-time data organization (paper §2.5): row-wise sorting (e.g.
quality-score descending for multimodal training data) and column-wise layout
reordering (hot features adjacent for coalesced projection reads) are
first-class, UDF-driven hooks — not a query-engine afterthought.

Two buffering modes share one group-flush core:

* **batch** (default) — ``write_table`` only buffers; ``close`` materializes
  the whole table, applies the optional ``sort_udf``, and writes every group.
* **stream** (``stream=True``) — every complete ``rows_per_group`` group is
  encoded and written as soon as it fills, so a sink rewriting a dataset
  holds at most one group per shard in memory. Whole-table ``sort_udf`` is
  incompatible with streaming (sort upstream, e.g. ``Dataset.write_to``'s
  ``sort_by=``).

A column chunk is split into multiple pages of at most ``page_rows`` rows
each (default: an eighth of ``rows_per_group``, floored at 1024 rows — the
production 65536-row group gets 8 pages per column, while tiny groups stay
single-page because per-page encoding overhead would dominate; override per
writer or fleet-wide via the ``BULLION_PAGE_ROWS`` environment variable,
both of which bypass the floor). Every column of a group splits at the
*same* row boundaries, so page ordinal k covers the same row range in every
chunk — that alignment is what lets the scanner prune and the executor
decode at page granularity. ``page_rows >= rows_per_group`` degrades to the
classic one-page-per-chunk layout.

Encoding selection can be steered per page through ``encoding_advisor``: the
zone-map statistics record (min/max/distinct — the LEA feature set) is
computed *before* each page is encoded and handed to the advisor, which may
restrict the cascade's candidate list (see ``encodings.cascade
.advise_candidates``) — smaller, more homogeneous pages give the advisor
strictly better signals than whole-chunk stats. The same records are then
persisted in the footer (``Sec.PAGE_STATS``, merged into
``Sec.CHUNK_STATS``), so stats are collected once and used twice.
"""

from __future__ import annotations

import os
import struct
from dataclasses import dataclass, field, replace as _dc_replace
from typing import Callable, Optional, Sequence

import numpy as np

from ..obs import trace as _trace
from . import pages
from .encodings import EncodeContext
from .encodings.base import dtype_code
from .footer import (ColKind, FooterBuilder, FORMAT_V0, FORMAT_V2,
                     FORMAT_VERSION, MAGIC, PageType, Sec, name_hash,
                     notify_footer_rewrite)
from .merkle import MerkleTree, page_hash
from .quantization import (QUANT_DTYPE, QuantMode, QuantSpec, dequantize,
                           quantize, storage_dtype)


@dataclass
class ColumnSpec:
    name: str
    dtype: str                      # "int64", "float32", "list<int64>", "string", "media_ref"
    quant: QuantSpec = field(default_factory=QuantSpec)
    sparse_delta: bool = False      # §2.2 hint for list<int64> columns

    @property
    def kind(self) -> ColKind:
        if self.dtype.startswith("list<"):
            return ColKind.LIST
        if self.dtype == "string":
            return ColKind.STRING
        if self.dtype == "media_ref":
            return ColKind.MEDIA_REF
        return ColKind.SCALAR

    @property
    def value_dtype(self) -> np.dtype:
        if self.kind == ColKind.LIST:
            return np.dtype(self.dtype[5:-1])
        if self.kind in (ColKind.STRING,):
            return np.dtype(np.uint8)
        if self.kind == ColKind.MEDIA_REF:
            return np.dtype(np.uint64)
        return np.dtype(self.dtype)


# floor for the *derived* page_rows default (rows_per_group / 8): below
# this, per-page encoding overhead outweighs pruning granularity
MIN_DEFAULT_PAGE_ROWS = 1024

SortUDF = Callable[[dict], np.ndarray]         # table -> row permutation
ColumnOrderUDF = Callable[[list[str]], list[str]]  # names -> layout order
# (stats record, n values, storage dtype) -> restricted candidate names
EncodingAdvisor = Callable[[np.ndarray, int, np.dtype],
                           Optional[tuple[str, ...]]]


def _fsync_dir(dirpath: str) -> None:
    """Make a just-completed rename durable. Best-effort: not every
    filesystem or platform supports fsync on a directory fd."""
    try:
        fd = os.open(dirpath or ".", os.O_RDONLY)
    except OSError:  # pragma: no cover - platform-dependent
        return
    try:
        os.fsync(fd)
    except OSError:  # pragma: no cover - platform-dependent
        pass
    finally:
        os.close(fd)


def quality_sort(column: str, descending: bool = True) -> SortUDF:
    """The paper's quality-aware presorting (§2.5)."""

    def udf(table: dict) -> np.ndarray:
        key = np.asarray(table[column])
        order = np.argsort(-key if descending else key, kind="stable")
        return order

    return udf


class BullionWriter:
    def __init__(self, path: str, schema: Sequence[ColumnSpec],
                 rows_per_group: int = 65536,
                 compliance: int = 2,
                 sort_udf: Optional[SortUDF] = None,
                 column_order_udf: Optional[ColumnOrderUDF] = None,
                 encode_ctx: Optional[EncodeContext] = None,
                 props: Optional[dict[str, str]] = None,
                 collect_stats: bool = True,
                 collect_sketches: Optional[bool] = None,
                 stream: bool = False,
                 encoding_advisor: Optional[EncodingAdvisor] = None,
                 page_rows: Optional[int] = None):
        self.path = path
        self.schema = list(schema)
        self.by_name = {s.name: s for s in self.schema}
        self.rows_per_group = rows_per_group
        if page_rows is None:
            env = os.environ.get("BULLION_PAGE_ROWS")
            if not collect_stats:
                # v0 backward-compat target: seed-shaped single-page chunks
                # (multi-page without page stats prunes nothing anyway); an
                # explicit page_rows= still wins and stamps a stat-less v2
                page_rows = rows_per_group
            elif env:
                page_rows = int(env)
            else:
                # derived default only: a floor keeps tiny groups single-
                # page (each page pays a fixed cascade-selection cost at
                # write time); explicit page_rows= / env are taken verbatim
                page_rows = max(MIN_DEFAULT_PAGE_ROWS, rows_per_group // 8)
        if page_rows <= 0:
            raise ValueError(f"page_rows must be positive, got {page_rows}")
        # page budget: every chunk of a group splits at the same multiples of
        # page_rows, so page ordinals align across columns (page-granular
        # pruning depends on this)
        self.page_rows = min(int(page_rows), rows_per_group)
        self.compliance = compliance
        self.sort_udf = sort_udf
        self.column_order_udf = column_order_udf
        self.ctx = encode_ctx or EncodeContext()
        if compliance >= 2 and encode_ctx is None:
            # §2.1: at the strictest compliance level, prefer encodings with a
            # native in-place masking rule (bit-packed, varint, RLE, dict,
            # FOR) for scalar pages so deletes stay in-place. Children of
            # these encodings are unrestricted (masking happens at the top).
            self.ctx = EncodeContext(candidates=(
                "constant", "rle", "dictionary", "for", "fixed_bit_width",
                "varint", "mainly_constant", "trivial"))
        self.props = props or {}
        # write-time zone-map statistics (scan subsystem). ``collect_stats=
        # False`` writes a v0 (stat-less) file — the backward-compat target.
        self.collect_stats = collect_stats
        # bloom value sketches (v3) for unclustered equality probes; they
        # ride the stats pipeline, so stat-less files are also sketch-less
        self.collect_sketches = (collect_stats if collect_sketches is None
                                 else bool(collect_sketches) and collect_stats)
        self.stream = stream
        self.encoding_advisor = encoding_advisor
        if stream and sort_udf is not None:
            raise ValueError(
                "stream=True flushes groups incrementally and cannot apply a "
                "whole-table sort_udf; sort upstream (Dataset.write_to's "
                "sort_by=) or use stream=False")
        self._buffers: dict[str, list] = {s.name: [] for s in self.schema}
        self._n_rows = 0
        self._buffered = 0
        # incremental file state, shared by both modes: stream flushes groups
        # as they fill, batch flushes everything from close()
        self._logical_idx = {s.name: i for i, s in enumerate(self.schema)}
        self._f = None
        self._layout: Optional[list[str]] = None
        self._page_offset: list[int] = []
        self._page_size: list[int] = []
        self._page_rows: list[int] = []
        self._page_cksum: list[int] = []
        self._page_flags: list[int] = []
        self._rows_per_group_arr: list[int] = []
        self._page_stat_recs: list = []              # physical page order
        self._chunk_stat_recs: dict[tuple[int, int], list] = {}
        # canonical u64 sketch keys per physical page (None = unsketched:
        # list/string column, or sketching disabled)
        self._page_sketch_keys: list = []
        # page index per logical (group, col) chunk; with §2.5 layout
        # reordering a group's pages aren't in logical order.
        self._chunk_ranges: dict[tuple[int, int], tuple[int, int]] = {}
        self._group_page_start: list[int] = [0]   # Merkle group partition
        self._n_groups = 0
        self._result: Optional[dict] = None   # close() is idempotent

    # -- buffering -------------------------------------------------------------
    def write_table(self, table: dict) -> None:
        sizes = set()
        for spec in self.schema:
            data = table[spec.name]
            if spec.kind == ColKind.SCALAR or spec.kind == ColKind.MEDIA_REF:
                data = np.asarray(data)
                sizes.add(len(data))
                self._buffers[spec.name].append(data)
            else:
                sizes.add(len(data))
                self._buffers[spec.name].extend(data)
        if len(sizes) != 1:
            raise ValueError(f"ragged table: row counts {sizes}")
        n = sizes.pop()
        self._n_rows += n
        self._buffered += n
        if self.stream:
            while self._buffered >= self.rows_per_group:
                self._flush_group(self.rows_per_group)

    def _collect(self, name: str):
        spec = self.by_name[name]
        if spec.kind in (ColKind.SCALAR, ColKind.MEDIA_REF):
            return np.concatenate(self._buffers[name]) if self._buffers[name] \
                else np.zeros(0, spec.value_dtype)
        return self._buffers[name]

    def _pop_rows(self, take: int) -> dict:
        """Remove the first ``take`` buffered rows as one table. Consumes
        whole buffered chunks and slices only at the group boundary, so each
        flush costs O(take), not O(rows still buffered)."""
        out: dict = {}
        for s in self.schema:
            buf = self._buffers[s.name]
            if s.kind in (ColKind.SCALAR, ColKind.MEDIA_REF):
                parts, got = [], 0
                while got < take:
                    head = buf[0]
                    need = take - got
                    if len(head) <= need:
                        parts.append(buf.pop(0))
                        got += len(head)
                    else:
                        parts.append(head[:need])
                        buf[0] = head[need:]     # view, no copy
                        got = take
                out[s.name] = parts[0] if len(parts) == 1 else (
                    np.concatenate(parts) if parts
                    else np.zeros(0, s.value_dtype))
            else:
                out[s.name] = buf[:take]
                del buf[:take]
        self._buffered -= take
        return out

    # -- group flushing ----------------------------------------------------------
    def _flush_group(self, take: int) -> None:
        self._write_group(self._pop_rows(take), take)

    def _write_group(self, table: dict, n_rows: int) -> None:
        with _trace.span("write.group", cat="sink", rows=n_rows,
                         group=self._n_groups):
            self._write_group_inner(table, n_rows)

    @property
    def _tmp_path(self) -> str:
        """Crash-safe staging file: all bytes land in ``path + ".tmp"`` and
        only a completed, fsynced shard is renamed over ``path``, so a
        crash at any point leaves either the old file or an ignorable tmp —
        never a torn shard visible to readers (discovery skips ``.tmp``)."""
        return self.path + ".tmp"

    def _write_group_inner(self, table: dict, n_rows: int) -> None:
        if self._f is None:
            self._f = open(self._tmp_path, "wb")
            # §2.5 column layout reordering (hot columns adjacent)
            layout = [s.name for s in self.schema]
            if self.column_order_udf is not None:
                layout = self.column_order_udf(layout)
                assert sorted(layout) == sorted(s.name for s in self.schema)
            self._layout = layout
        g = self._n_groups
        self._rows_per_group_arr.append(n_rows)
        # every column splits at the same page_rows multiples, so ordinal k
        # covers one row range group-wide; a zero-row group still carries one
        # (empty) page per column so readers see well-formed chunks
        bounds = list(range(0, n_rows, self.page_rows)) or [0]
        for name in self._layout:
            spec = self.by_name[name]
            data = table[name]
            start_page = len(self._page_offset)
            for lo in bounds:
                hi = min(lo + self.page_rows, n_rows)
                blob, ptype, rec, skeys = self._build_page(spec, data[lo:hi])
                self._page_offset.append(self._f.tell())
                self._page_size.append(len(blob))
                self._page_rows.append(hi - lo)
                self._page_cksum.append(page_hash(blob))
                self._page_flags.append(int(ptype))
                self._f.write(blob)
                if self.collect_stats:
                    self._page_stat_recs.append(rec)
                    self._chunk_stat_recs.setdefault(
                        (g, self._logical_idx[name]), []).append(rec)
                if self.collect_sketches:
                    self._page_sketch_keys.append(skeys)
            self._chunk_ranges[(g, self._logical_idx[name])] = \
                (start_page, len(self._page_offset))
        self._group_page_start.append(len(self._page_offset))
        self._n_groups += 1

    # -- finalize ----------------------------------------------------------------
    def abort(self) -> None:
        """Drop an unfinished file: close the handle and unlink the staging
        tmp (nothing was ever renamed over ``path``, so readers never saw a
        partial shard). No-op after a successful ``close()``."""
        if self._result is None and self._f is not None:
            self._f.close()
            self._f = None
            try:
                os.unlink(self._tmp_path)
            except OSError:
                pass

    def close(self) -> dict:
        if self._result is not None:
            return self._result            # idempotent: the file is final
        if self.stream:
            while self._buffered >= self.rows_per_group:
                self._flush_group(self.rows_per_group)
            if self._buffered:
                self._flush_group(self._buffered)
        else:
            table = {s.name: self._collect(s.name) for s in self.schema}
            # §2.5 write-path row reordering (quality sort etc.)
            if self.sort_udf is not None and self._n_rows:
                perm = self.sort_udf(table)
                for s in self.schema:
                    data = table[s.name]
                    table[s.name] = data[perm] \
                        if isinstance(data, np.ndarray) \
                        else [data[i] for i in perm]
            self._buffers = {s.name: [] for s in self.schema}
            self._buffered = 0
            for lo in range(0, self._n_rows, self.rows_per_group):
                hi = min(lo + self.rows_per_group, self._n_rows)
                self._write_group({k: v[lo:hi] for k, v in table.items()},
                                  hi - lo)
        if self._n_groups == 0:
            # zero-row file still carries one (empty) group so readers see a
            # well-formed group/page structure
            self._flush_group(0)
        if self._f is None:  # pragma: no cover - _flush_group always opens
            self._f = open(self._tmp_path, "wb")

        n_rows, n_cols = self._n_rows, len(self.schema)
        n_groups, n_pages = self._n_groups, len(self._page_offset)
        f = self._f

        starts = np.zeros(n_groups * n_cols, np.uint64)
        counts = np.zeros(n_groups * n_cols, np.uint32)
        for (g, c), (s, e) in self._chunk_ranges.items():
            starts[g * n_cols + c] = s
            counts[g * n_cols + c] = e - s

        cksums = np.asarray(self._page_cksum, np.uint64)
        # merkle over physical page order, grouped by row group
        group_page_start = np.asarray(self._group_page_start, np.uint64)
        tree = MerkleTree(cksums, group_page_start, n_groups, 1)

        fb = FooterBuilder()
        meta = np.zeros(8, np.uint64)
        meta[0], meta[1], meta[2], meta[3] = n_rows, n_cols, n_groups, n_pages
        meta[4] = self.rows_per_group
        meta[5] = self.compliance
        meta[6] = tree.root
        # version word is informational (readers detect capabilities by
        # section presence), but must not claim v0 — one page per chunk —
        # for a file that actually carries multi-page chunks
        multi_page = any(e - s > 1 for s, e in self._chunk_ranges.values())
        if self.collect_stats:
            meta[7] = FORMAT_VERSION if self.collect_sketches else FORMAT_V2
        else:
            meta[7] = FORMAT_V2 if multi_page else FORMAT_V0
        fb.put(Sec.META, meta)

        if self.collect_stats:
            from ..scan.stats import STAT_DTYPE, merge_records
            page_stats = np.zeros(n_pages, STAT_DTYPE)
            for i, rec in enumerate(self._page_stat_recs):
                page_stats[i] = rec
            chunk_stats = np.zeros(n_groups * n_cols, STAT_DTYPE)
            for (g, c), recs in self._chunk_stat_recs.items():
                chunk_stats[g * n_cols + c] = \
                    recs[0] if len(recs) == 1 else merge_records(recs)
            fb.put(Sec.PAGE_STATS, page_stats)
            fb.put(Sec.CHUNK_STATS, chunk_stats)

        if self.collect_sketches:
            from ..scan.sketch import NO_SKETCH, BloomSketch
            chunk_off = np.full(n_groups * n_cols, NO_SKETCH, np.uint64)
            page_off = np.full(n_pages, NO_SKETCH, np.uint64)
            blobs: list[bytes] = []
            pos = 0
            for (g, c), (s, e) in sorted(self._chunk_ranges.items()):
                parts = [k for k in self._page_sketch_keys[s:e]
                         if k is not None]
                if len(parts) != e - s:
                    continue       # unsketched column (list/string pages)
                keys = parts[0] if len(parts) == 1 else \
                    np.unique(np.concatenate(parts))
                sk = BloomSketch.build(keys)
                if sk is None:
                    continue       # over the size cap: absent = no pruning
                b = sk.to_bytes()
                chunk_off[g * n_cols + c] = pos
                blobs.append(b)
                pos += len(b)
                if e - s > 1:
                    # per-page sketches only pay off when there is more than
                    # one ordinal to choose between (mirrors _page_prune)
                    for p in range(s, e):
                        psk = BloomSketch.build(self._page_sketch_keys[p])
                        if psk is None:
                            continue
                        pb = psk.to_bytes()
                        page_off[p] = pos
                        blobs.append(pb)
                        pos += len(pb)
            fb.put(Sec.CHUNK_SKETCH, chunk_off)
            fb.put(Sec.PAGE_SKETCH, page_off)
            fb.put(Sec.SKETCH_DATA, b"".join(blobs))

        names = [s.name for s in self.schema]
        name_bytes = b"".join(n.encode() for n in names)
        offs = np.zeros(n_cols + 1, np.uint32)
        np.cumsum([len(n.encode()) for n in names], out=offs[1:])
        fb.put(Sec.NAMES_DATA, name_bytes)
        fb.put(Sec.NAMES_OFFSETS, offs)
        hashes = np.asarray([name_hash(n) for n in names], np.uint64)
        order = np.argsort(hashes, kind="stable").astype(np.uint32)
        fb.put(Sec.NAME_HASH_SORTED, hashes[order])
        fb.put(Sec.NAME_HASH_ORDER, order)

        storage_codes, logical_codes, kinds = [], [], []
        quant = np.zeros(n_cols, QUANT_DTYPE)
        for i, s in enumerate(self.schema):
            logical_codes.append(dtype_code(s.value_dtype))
            sd = storage_dtype(s.quant.mode)
            storage_codes.append(dtype_code(sd or s.value_dtype))
            kinds.append(int(s.kind))
            quant[i] = s.quant.to_record()
        fb.put(Sec.COL_DTYPE, np.asarray(storage_codes, np.uint8))
        fb.put(Sec.COL_LOGICAL, np.asarray(logical_codes, np.uint8))
        fb.put(Sec.COL_KIND, np.asarray(kinds, np.uint8))
        fb.put(Sec.QUANT_META, quant)

        fb.put(Sec.ROWS_PER_GROUP,
               np.asarray(self._rows_per_group_arr, np.uint32))
        fb.put(Sec.CHUNK_PAGE_START, starts)
        fb.put(Sec.CHUNK_PAGE_COUNT, counts)
        fb.put(Sec.PAGE_OFFSET, np.asarray(self._page_offset, np.uint64))
        fb.put(Sec.PAGE_SIZE, np.asarray(self._page_size, np.uint64))
        fb.put(Sec.PAGE_ROWS, np.asarray(self._page_rows, np.uint32))
        fb.put(Sec.PAGE_CHECKSUM, cksums)
        fb.put(Sec.PAGE_FLAGS, np.asarray(self._page_flags, np.uint8))
        fb.put(Sec.DV_OFFSET, np.full(n_pages, 0xFFFFFFFFFFFFFFFF, np.uint64))
        fb.put(Sec.DV_SIZE, np.zeros(n_pages, np.uint32))
        fb.put(Sec.DV_DATA, b"")
        fb.put(Sec.GROUP_CHECKSUM, tree.groups)
        # page budget recorded for introspection (write_to keeps the input's
        # page layout by default); user props may override
        props = {"bullion.page_rows": str(self.page_rows), **self.props}
        fb.put(Sec.PROPS, b"\x00".join(
            k.encode() + b"\x00" + v.encode()
            for k, v in props.items()) + b"\x00")

        footer = fb.build()
        f.write(footer)
        f.write(struct.pack("<Q", len(footer)) + MAGIC)
        # crash-safe publication: fsync the staging file, rename it over the
        # final path, then fsync the directory so the rename itself is
        # durable. kill -9 anywhere before the replace leaves only the old
        # file (or nothing) plus an ignorable ``.tmp``.
        f.flush()
        os.fsync(f.fileno())
        f.close()
        self._f = None
        os.replace(self._tmp_path, self.path)
        _fsync_dir(os.path.dirname(os.path.abspath(self.path)))
        # a (re)write at this path obsoletes any cached footer even when
        # filesystem timestamps are too coarse to show it
        notify_footer_rewrite(self.path)

        self._result = {"rows": n_rows, "groups": n_groups, "pages": n_pages,
                        "file_checksum": tree.root}
        return self._result

    # -- write-time statistics ----------------------------------------------------
    def _page_stats_record(self, spec: ColumnSpec, chunk, stored):
        """Zone-map record over the values a reader will decode: quantized
        columns use the already-quantized page array, dequantized back, so
        the recorded range matches ``dequant=True`` reads exactly."""
        from ..scan.stats import stats_record
        if spec.kind == ColKind.SCALAR:
            if spec.quant.mode != QuantMode.NONE:
                return stats_record(np.asarray(dequantize(stored, spec.quant)))
            return stats_record(np.asarray(chunk))
        if spec.kind == ColKind.MEDIA_REF:
            return stats_record(np.asarray(chunk, np.uint64))
        return stats_record(list(chunk))

    def _stats_for(self, spec: ColumnSpec, chunk, stored):
        if not (self.collect_stats or self.encoding_advisor is not None):
            return None
        return self._page_stats_record(spec, chunk, stored)

    def _sketch_keys(self, spec: ColumnSpec, chunk, stored):
        """Canonical u64 keys of one scalar/media_ref page, in the same
        (dequantized) domain the zone maps describe. NaNs are dropped —
        ``== NaN`` matches no row, so omitting them is sound."""
        if not self.collect_sketches or \
                spec.kind not in (ColKind.SCALAR, ColKind.MEDIA_REF):
            return None
        from ..scan.sketch import canonical_u64
        if spec.kind == ColKind.SCALAR and spec.quant.mode != QuantMode.NONE:
            vals = np.asarray(dequantize(stored, spec.quant))
        else:
            vals = np.asarray(chunk)
        if vals.dtype.kind == "f":
            vals = vals[~np.isnan(vals)]
        return np.unique(canonical_u64(vals))

    def _ctx_for(self, rec, arr: np.ndarray) -> EncodeContext:
        """Stats-driven encoding choice hook: the advisor may restrict the
        cascade's candidate list from the chunk's min/max/distinct record.
        A compliance-restricted candidate set (maskable encodings) always
        wins — the advisor can only narrow it further."""
        if self.encoding_advisor is None or rec is None:
            return self.ctx
        advised = self.encoding_advisor(rec, len(arr), arr.dtype)
        if not advised:
            return self.ctx
        if self.ctx.candidates is not None:
            advised = tuple(c for c in advised if c in self.ctx.candidates)
            if not advised:
                return self.ctx
        return _dc_replace(self.ctx, candidates=advised)

    # -- page building -----------------------------------------------------------
    def _build_page(self, spec: ColumnSpec, chunk
                    ) -> tuple[bytes, PageType, object, object]:
        """Returns (payload, page type, stats record or None, sketch keys
        or None)."""
        if spec.kind == ColKind.SCALAR:
            arr = np.asarray(chunk)
            if spec.quant.mode != QuantMode.NONE:
                arr = quantize(arr, spec.quant)
            rec = self._stats_for(spec, chunk, arr)
            blob = pages.build_scalar_page(arr, self._ctx_for(rec, arr))
            return blob, PageType.SCALAR, rec, self._sketch_keys(
                spec, chunk, arr)
        if spec.kind == ColKind.MEDIA_REF:
            arr = np.asarray(chunk, np.uint64)
            rec = self._stats_for(spec, chunk, arr)
            blob = pages.build_scalar_page(arr, self._ctx_for(rec, arr))
            return blob, PageType.MEDIA_REF, rec, self._sketch_keys(
                spec, chunk, arr)
        if spec.kind == ColKind.LIST:
            blob, ptype = pages.build_list_page(
                list(chunk), self.ctx, use_sparse_delta=spec.sparse_delta)
            return blob, ptype, self._stats_for(spec, chunk, None), None
        if spec.kind == ColKind.STRING:
            return pages.build_string_page(list(chunk), self.ctx), \
                PageType.STRING, self._stats_for(spec, chunk, None), None
        raise ValueError(spec.kind)
