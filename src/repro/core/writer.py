"""Bullion write path.

Native write-time data organization (paper §2.5): row-wise sorting (e.g.
quality-score descending for multimodal training data) and column-wise layout
reordering (hot features adjacent for coalesced projection reads) are
first-class, UDF-driven hooks — not a query-engine afterthought.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Callable, Optional, Sequence

import numpy as np

from . import pages
from .encodings import EncodeContext
from .encodings.base import dtype_code
from .footer import (ColKind, FooterBuilder, FORMAT_V0, FORMAT_VERSION, MAGIC,
                     PageType, Sec, name_hash)
from .merkle import MerkleTree, page_hash
from .quantization import (QUANT_DTYPE, QuantMode, QuantSpec, dequantize,
                           quantize, storage_dtype)


@dataclass
class ColumnSpec:
    name: str
    dtype: str                      # "int64", "float32", "list<int64>", "string", "media_ref"
    quant: QuantSpec = field(default_factory=QuantSpec)
    sparse_delta: bool = False      # §2.2 hint for list<int64> columns

    @property
    def kind(self) -> ColKind:
        if self.dtype.startswith("list<"):
            return ColKind.LIST
        if self.dtype == "string":
            return ColKind.STRING
        if self.dtype == "media_ref":
            return ColKind.MEDIA_REF
        return ColKind.SCALAR

    @property
    def value_dtype(self) -> np.dtype:
        if self.kind == ColKind.LIST:
            return np.dtype(self.dtype[5:-1])
        if self.kind in (ColKind.STRING,):
            return np.dtype(np.uint8)
        if self.kind == ColKind.MEDIA_REF:
            return np.dtype(np.uint64)
        return np.dtype(self.dtype)


SortUDF = Callable[[dict], np.ndarray]         # table -> row permutation
ColumnOrderUDF = Callable[[list[str]], list[str]]  # names -> layout order


def quality_sort(column: str, descending: bool = True) -> SortUDF:
    """The paper's quality-aware presorting (§2.5)."""

    def udf(table: dict) -> np.ndarray:
        key = np.asarray(table[column])
        order = np.argsort(-key if descending else key, kind="stable")
        return order

    return udf


class BullionWriter:
    def __init__(self, path: str, schema: Sequence[ColumnSpec],
                 rows_per_group: int = 65536,
                 compliance: int = 2,
                 sort_udf: Optional[SortUDF] = None,
                 column_order_udf: Optional[ColumnOrderUDF] = None,
                 encode_ctx: Optional[EncodeContext] = None,
                 props: Optional[dict[str, str]] = None,
                 collect_stats: bool = True):
        self.path = path
        self.schema = list(schema)
        self.by_name = {s.name: s for s in self.schema}
        self.rows_per_group = rows_per_group
        self.compliance = compliance
        self.sort_udf = sort_udf
        self.column_order_udf = column_order_udf
        self.ctx = encode_ctx or EncodeContext()
        if compliance >= 2 and encode_ctx is None:
            # §2.1: at the strictest compliance level, prefer encodings with a
            # native in-place masking rule (bit-packed, varint, RLE, dict,
            # FOR) for scalar pages so deletes stay in-place. Children of
            # these encodings are unrestricted (masking happens at the top).
            self.ctx = EncodeContext(candidates=(
                "constant", "rle", "dictionary", "for", "fixed_bit_width",
                "varint", "mainly_constant", "trivial"))
        self.props = props or {}
        # write-time zone-map statistics (scan subsystem). ``collect_stats=
        # False`` writes a v0 (stat-less) file — the backward-compat target.
        self.collect_stats = collect_stats
        self._buffers: dict[str, list] = {s.name: [] for s in self.schema}
        self._n_rows = 0

    # -- buffering -------------------------------------------------------------
    def write_table(self, table: dict) -> None:
        sizes = set()
        for spec in self.schema:
            data = table[spec.name]
            if spec.kind == ColKind.SCALAR or spec.kind == ColKind.MEDIA_REF:
                data = np.asarray(data)
                sizes.add(len(data))
                self._buffers[spec.name].append(data)
            else:
                sizes.add(len(data))
                self._buffers[spec.name].extend(data)
        if len(sizes) != 1:
            raise ValueError(f"ragged table: row counts {sizes}")
        self._n_rows += sizes.pop()

    def _collect(self, name: str):
        spec = self.by_name[name]
        if spec.kind in (ColKind.SCALAR, ColKind.MEDIA_REF):
            return np.concatenate(self._buffers[name]) if self._buffers[name] \
                else np.zeros(0, spec.value_dtype)
        return self._buffers[name]

    # -- finalize ----------------------------------------------------------------
    def close(self) -> dict:
        table = {s.name: self._collect(s.name) for s in self.schema}

        # §2.5 write-path row reordering (quality sort etc.)
        if self.sort_udf is not None and self._n_rows:
            perm = self.sort_udf(table)
            for s in self.schema:
                data = table[s.name]
                table[s.name] = data[perm] if isinstance(data, np.ndarray) \
                    else [data[i] for i in perm]

        # §2.5 column layout reordering (hot columns adjacent)
        layout = [s.name for s in self.schema]
        if self.column_order_udf is not None:
            layout = self.column_order_udf(layout)
            assert sorted(layout) == sorted(s.name for s in self.schema)

        n_rows = self._n_rows
        n_cols = len(self.schema)
        n_groups = max(1, -(-n_rows // self.rows_per_group))

        page_offset, page_size, page_rows, page_cksum, page_flags = [], [], [], [], []
        rows_per_group_arr = []
        page_stat_recs: list = []               # physical page order
        chunk_stat_recs: dict[tuple[int, int], list] = {}

        # schema order is the *logical* order; pages are laid out in `layout`
        # order inside each group. chunk_page_start is indexed logically, so
        # we collect per-(group, logical col) page ranges after writing.
        chunk_ranges: dict[tuple[int, int], tuple[int, int]] = {}
        logical_idx = {s.name: i for i, s in enumerate(self.schema)}

        with open(self.path, "wb") as f:
            for g in range(n_groups):
                lo = g * self.rows_per_group
                hi = min(lo + self.rows_per_group, n_rows)
                rows_per_group_arr.append(hi - lo)
                for name in layout:
                    spec = self.by_name[name]
                    data = table[name]
                    chunk = data[lo:hi]
                    blob, ptype, stored = self._build_page(spec, chunk)
                    start_page = len(page_offset)
                    page_offset.append(f.tell())
                    page_size.append(len(blob))
                    page_rows.append(hi - lo)
                    page_cksum.append(page_hash(blob))
                    page_flags.append(int(ptype))
                    f.write(blob)
                    chunk_ranges[(g, logical_idx[name])] = (start_page, len(page_offset))
                    if self.collect_stats:
                        rec = self._page_stats_record(spec, chunk, stored)
                        page_stat_recs.append(rec)
                        chunk_stat_recs.setdefault(
                            (g, logical_idx[name]), []).append(rec)

            # page index per logical (group, col) chunk; with §2.5 layout
            # reordering a group's pages aren't in logical order.
            starts = np.zeros(n_groups * n_cols, np.uint64)
            for (g, c), (s, e) in chunk_ranges.items():
                starts[g * n_cols + c] = s

            n_pages = len(page_offset)
            cksums = np.asarray(page_cksum, np.uint64)
            # merkle over physical page order, grouped by row group
            group_page_start = np.arange(0, n_pages + 1, n_cols, dtype=np.uint64)
            tree = MerkleTree(cksums, group_page_start, n_groups, 1)

            fb = FooterBuilder()
            meta = np.zeros(8, np.uint64)
            meta[0], meta[1], meta[2], meta[3] = n_rows, n_cols, n_groups, n_pages
            meta[4] = self.rows_per_group
            meta[5] = self.compliance
            meta[6] = tree.root
            meta[7] = FORMAT_VERSION if self.collect_stats else FORMAT_V0
            fb.put(Sec.META, meta)

            if self.collect_stats:
                from ..scan.stats import STAT_DTYPE, merge_records
                page_stats = np.zeros(n_pages, STAT_DTYPE)
                for i, rec in enumerate(page_stat_recs):
                    page_stats[i] = rec
                chunk_stats = np.zeros(n_groups * n_cols, STAT_DTYPE)
                for (g, c), recs in chunk_stat_recs.items():
                    chunk_stats[g * n_cols + c] = \
                        recs[0] if len(recs) == 1 else merge_records(recs)
                fb.put(Sec.PAGE_STATS, page_stats)
                fb.put(Sec.CHUNK_STATS, chunk_stats)

            names = [s.name for s in self.schema]
            name_bytes = b"".join(n.encode() for n in names)
            offs = np.zeros(n_cols + 1, np.uint32)
            np.cumsum([len(n.encode()) for n in names], out=offs[1:])
            fb.put(Sec.NAMES_DATA, name_bytes)
            fb.put(Sec.NAMES_OFFSETS, offs)
            hashes = np.asarray([name_hash(n) for n in names], np.uint64)
            order = np.argsort(hashes, kind="stable").astype(np.uint32)
            fb.put(Sec.NAME_HASH_SORTED, hashes[order])
            fb.put(Sec.NAME_HASH_ORDER, order)

            storage_codes, logical_codes, kinds = [], [], []
            quant = np.zeros(n_cols, QUANT_DTYPE)
            for i, s in enumerate(self.schema):
                logical_codes.append(dtype_code(s.value_dtype))
                sd = storage_dtype(s.quant.mode)
                storage_codes.append(dtype_code(sd or s.value_dtype))
                kinds.append(int(s.kind))
                quant[i] = s.quant.to_record()
            fb.put(Sec.COL_DTYPE, np.asarray(storage_codes, np.uint8))
            fb.put(Sec.COL_LOGICAL, np.asarray(logical_codes, np.uint8))
            fb.put(Sec.COL_KIND, np.asarray(kinds, np.uint8))
            fb.put(Sec.QUANT_META, quant)

            fb.put(Sec.ROWS_PER_GROUP, np.asarray(rows_per_group_arr, np.uint32))
            fb.put(Sec.CHUNK_PAGE_START, starts)
            fb.put(Sec.PAGE_OFFSET, np.asarray(page_offset, np.uint64))
            fb.put(Sec.PAGE_SIZE, np.asarray(page_size, np.uint64))
            fb.put(Sec.PAGE_ROWS, np.asarray(page_rows, np.uint32))
            fb.put(Sec.PAGE_CHECKSUM, cksums)
            fb.put(Sec.PAGE_FLAGS, np.asarray(page_flags, np.uint8))
            fb.put(Sec.DV_OFFSET, np.full(n_pages, 0xFFFFFFFFFFFFFFFF, np.uint64))
            fb.put(Sec.DV_SIZE, np.zeros(n_pages, np.uint32))
            fb.put(Sec.DV_DATA, b"")
            fb.put(Sec.GROUP_CHECKSUM, tree.groups)
            if self.props:
                fb.put(Sec.PROPS, b"\x00".join(
                    k.encode() + b"\x00" + v.encode() for k, v in self.props.items()) + b"\x00")

            footer = fb.build()
            f.write(footer)
            f.write(struct.pack("<Q", len(footer)) + MAGIC)

        return {"rows": n_rows, "groups": n_groups, "pages": n_pages,
                "file_checksum": tree.root}

    # -- write-time statistics ----------------------------------------------------
    def _page_stats_record(self, spec: ColumnSpec, chunk, stored):
        """Zone-map record over the values a reader will decode: quantized
        columns use the already-quantized page array, dequantized back, so
        the recorded range matches ``dequant=True`` reads exactly."""
        from ..scan.stats import stats_record
        if spec.kind == ColKind.SCALAR:
            if spec.quant.mode != QuantMode.NONE:
                return stats_record(np.asarray(dequantize(stored, spec.quant)))
            return stats_record(np.asarray(chunk))
        if spec.kind == ColKind.MEDIA_REF:
            return stats_record(np.asarray(chunk, np.uint64))
        return stats_record(list(chunk))

    # -- page building -----------------------------------------------------------
    def _build_page(self, spec: ColumnSpec, chunk) -> tuple[bytes, PageType, object]:
        """Returns (payload, page type, stored scalar array or None)."""
        if spec.kind == ColKind.SCALAR:
            arr = np.asarray(chunk)
            if spec.quant.mode != QuantMode.NONE:
                arr = quantize(arr, spec.quant)
            return pages.build_scalar_page(arr, self.ctx), PageType.SCALAR, arr
        if spec.kind == ColKind.MEDIA_REF:
            arr = np.asarray(chunk, np.uint64)
            return pages.build_scalar_page(arr, self.ctx), PageType.MEDIA_REF, arr
        if spec.kind == ColKind.LIST:
            blob, ptype = pages.build_list_page(list(chunk), self.ctx,
                                                use_sparse_delta=spec.sparse_delta)
            return blob, ptype, None
        if spec.kind == ColKind.STRING:
            return pages.build_string_page(list(chunk), self.ctx), \
                PageType.STRING, None
        raise ValueError(spec.kind)
