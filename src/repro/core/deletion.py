"""Deletion compliance (Bullion §2.1).

Three configurable levels:
  L0 — legacy behaviour: compliance requires rewriting whole files.
  L1 — deletion vectors only: query-time filtering, data still on disk
       (fast, but does NOT satisfy timely-physical-erasure regulations).
  L2 — hybrid: deletion vectors *plus* in-place physical masking of the
       affected pages, never exceeding original page size, with incremental
       Merkle checksum maintenance. Only touched pages + the footer are
       rewritten — this is the paper's up-to-50x I/O reduction. When an
       encoding cannot satisfy the size criterion, the page is *relocated*:
       the old extent is zeroed on disk (physical erasure) and a rebuilt page
       is appended before the footer.

Page-state invariant maintained across repeated deletes: a page's decoded
length is either `page_rows` (deleted rows masked to zeros in place) or
`page_rows - popcount(DV)` (compact-deleted, e.g. the paper's RLE rule).
The COMPACTED flag bit in PAGE_FLAGS records which.
"""

from __future__ import annotations

import os
import struct
from dataclasses import dataclass
from enum import IntEnum

import numpy as np

from . import pages as pages_mod
from .footer import (MAGIC, FooterBuilder, FooterView, PageType, Sec,
                     notify_footer_rewrite, read_footer)
from .merkle import MerkleTree, page_hash

COMPACTED = 0x80  # PAGE_FLAGS high bit
PTYPE_MASK = 0x7F


class Compliance(IntEnum):
    LEVEL0 = 0
    LEVEL1 = 1
    LEVEL2 = 2


@dataclass
class DeleteStats:
    rows_deleted: int = 0
    pages_touched: int = 0
    pages_masked_in_place: int = 0
    pages_relocated: int = 0
    pages_dv_only: int = 0
    bytes_rewritten: int = 0           # pages + footer actually written
    bytes_rewritten_data: int = 0      # page (data) bytes only — the paper's
                                       # "data rewrite I/O" comparison
    bytes_full_rewrite: int = 0        # counterfactual: rewrite whole file (L0)
    hash_ops_incremental: int = 0
    hash_ops_monolithic: int = 0


def _shift(positions: np.ndarray, prior_dv: np.ndarray) -> np.ndarray:
    """Logical -> physical index for compacted pages."""
    return positions - np.cumsum(prior_dv)[positions]


def _erases(ptype: int, before: bytes, after: bytes, positions: np.ndarray,
            phys_rows: int, was_compacted: bool, compact_ok: bool) -> bool:
    """Is an in-place mask result both *physically erasing* and invariant-
    preserving?

    Masking writes 0 — which is no erasure when the stored value was itself
    0, and some encodings cannot even write it (a constant page's mask is a
    no-op, a FOR row at the base keeps decoding the base). An erasure audit
    (``verify_deleted``) would still find the forbidden value in all those
    cases, so the caller must fall back to compact relocation. The compact
    mask rule (rows physically removed) always erases — and is the *only*
    acceptable in-place result for an already-compacted page, whose decoded
    length must keep tracking ``page_rows - popcount(DV)``. That same
    invariant makes it *unacceptable* (``compact_ok=False``) on a
    non-compacted page still holding zero-masked DV rows from earlier
    deletes: compacting only the new rows would leave the decoded length
    tracking neither convention — relocation unions the old rows instead."""
    if ptype not in (int(PageType.SCALAR), int(PageType.MEDIA_REF)):
        return True          # list/string rows are zeroed element-wise
    dec = np.asarray(pages_mod.decode_page(ptype, after))
    if len(dec) == phys_rows - len(positions):
        return compact_ok    # compact rule physically removed the rows
    if was_compacted or len(dec) != phys_rows:
        return False         # compacted pages must stay compacted
    if np.any(dec[positions] != 0):
        return False         # the encoding could not overwrite the value
    orig = np.asarray(pages_mod.decode_page(ptype, before))
    return not np.any(orig[positions] == 0)


def delete_rows(path: str, global_rows: np.ndarray,
                level: Compliance = Compliance.LEVEL2) -> DeleteStats:
    """Delete rows from a Bullion file, per the requested compliance level."""
    from .reader import BullionReader

    stats = DeleteStats(rows_deleted=len(np.asarray(global_rows)))
    if level == Compliance.LEVEL0:
        raise ValueError("LEVEL0 has no in-file delete path: rewrite the file "
                         "(this is the legacy baseline the paper improves on)")

    reader = BullionReader(path)
    fv = reader.footer
    stats.bytes_full_rewrite = os.path.getsize(path)
    n_cols = fv.n_cols
    page_rows = fv.arr(Sec.PAGE_ROWS, np.uint32)
    page_flags = fv.arr(Sec.PAGE_FLAGS, np.uint8).copy()
    page_offset = fv.arr(Sec.PAGE_OFFSET, np.uint64).copy()
    page_size = fv.arr(Sec.PAGE_SIZE, np.uint64).copy()
    n_pages = fv.n_pages
    tree = MerkleTree(fv.arr(Sec.PAGE_CHECKSUM, np.uint64),
                      fv.group_page_start(), fv.n_groups, 1)
    baseline_ops = tree.hash_ops

    dvs: dict[int, np.ndarray] = {}
    touched_stats: set[tuple[int, int, int]] = set()  # (page, group, col)

    def dv_for(p: int) -> np.ndarray:
        if p not in dvs:
            existing = fv.deletion_vector(p)
            dvs[p] = existing if existing is not None \
                else np.zeros(int(page_rows[p]), bool)
        return dvs[p]

    located = reader.locate_rows(global_rows)
    footer_off = reader.footer_offset
    reader.close()

    with open(path, "r+b") as f:
        append_at = footer_off  # relocated pages go where the footer was

        for group, local in located:
            for col in range(n_cols):
                s, e = fv.chunk_pages(group, col)
                row_lo = 0
                for p in range(s, e):
                    # each page covers one row range of the group; only the
                    # pages actually holding victim rows are touched
                    row_hi = row_lo + int(page_rows[p])
                    plocal = local[(local >= row_lo) & (local < row_hi)] \
                        - row_lo
                    row_lo = row_hi
                    if len(plocal) == 0:
                        continue
                    dv = dv_for(p)
                    new_positions = plocal[~dv[plocal]]
                    if len(new_positions) == 0:
                        continue
                    stats.pages_touched += 1
                    if level == Compliance.LEVEL1:
                        stats.pages_dv_only += 1
                        dv[new_positions] = True
                        continue

                    ptype = int(page_flags[p]) & PTYPE_MASK
                    was_compacted = bool(page_flags[p] & COMPACTED)
                    touched_stats.add((p, group, col))
                    off, size = int(page_offset[p]), int(page_size[p])
                    f.seek(off)
                    payload = f.read(size)

                    phys = _shift(new_positions, dv) if was_compacted \
                        else new_positions
                    phys_rows = int(page_rows[p]) - int(dv.sum()) \
                        if was_compacted else int(page_rows[p])
                    masked = pages_mod.mask_page(ptype, payload, phys,
                                                 int(page_rows[p]))
                    if masked is not None and \
                            not _erases(ptype, payload, masked, phys,
                                        phys_rows, was_compacted,
                                        was_compacted or not dv.any()):
                        masked = None
                    if masked is not None:
                        f.seek(off)
                        f.write(masked)
                        stats.bytes_rewritten += size
                        stats.bytes_rewritten_data += size
                        stats.pages_masked_in_place += 1
                        tree.update_page(p, masked)
                        if _compacts(ptype, payload):
                            page_flags[p] |= COMPACTED
                    else:
                        # relocate: zero old extent (physical erasure), append
                        # a rebuilt page before the footer. Scalar pages
                        # relocate *compacted* — rows removed, not zeroed —
                        # so even a stored 0 is audit-proof; previously
                        # zero-masked rows are compacted away with them to
                        # keep the decoded-length invariant.
                        if ptype in (int(PageType.SCALAR),
                                     int(PageType.MEDIA_REF)):
                            if was_compacted:
                                drop = phys
                            else:
                                union = dv.copy()
                                union[new_positions] = True
                                drop = np.flatnonzero(union)
                            rebuilt = pages_mod.rebuild_page(
                                ptype, payload, drop, compact=True)
                            page_flags[p] |= COMPACTED
                        else:
                            rebuilt = pages_mod.rebuild_page(
                                ptype, payload, phys,
                                compact=was_compacted)
                        f.seek(off)
                        f.write(b"\x00" * size)
                        f.seek(append_at)
                        f.write(rebuilt)
                        page_offset[p] = append_at
                        page_size[p] = len(rebuilt)
                        append_at += len(rebuilt)
                        stats.bytes_rewritten += size + len(rebuilt)
                        stats.bytes_rewritten_data += size + len(rebuilt)
                        stats.pages_relocated += 1
                        tree.update_page(p, rebuilt)
                    dv[new_positions] = True

        new_footer = _rebuild_footer(fv, dvs, tree, page_flags, page_offset,
                                     page_size, touched_stats)
        f.seek(append_at)
        f.write(new_footer)
        f.write(struct.pack("<Q", len(new_footer)) + MAGIC)
        f.truncate()
        stats.bytes_rewritten += len(new_footer) + 16

    # the in-place rewrite changed the footer: drop any cached copy even if
    # filesystem timestamps are too coarse to show it
    notify_footer_rewrite(path)

    stats.hash_ops_incremental = tree.hash_ops - baseline_ops
    stats.hash_ops_monolithic = n_pages + fv.n_groups + 1
    return stats


def _compacts(ptype: int, payload: bytes) -> bool:
    """Did mask_page use the compact-delete (RLE) rule on this page?"""
    from .encodings import blob_encoding_name
    return (ptype in (int(PageType.SCALAR), int(PageType.MEDIA_REF))
            and blob_encoding_name(payload) == "rle")


def _rebuild_footer(fv: FooterView, dvs: dict[int, np.ndarray],
                    tree: MerkleTree, page_flags: np.ndarray,
                    page_offset: np.ndarray, page_size: np.ndarray,
                    touched_stats: set[tuple[int, int, int]] = frozenset()) -> bytes:
    fb = FooterBuilder()
    for sid in list(Sec):
        if fv.has(sid):
            fb.put(sid, bytes(fv.raw(sid)))
    meta = fv.meta.copy()
    meta[6] = tree.root
    fb.put(Sec.META, meta)
    fb.put(Sec.PAGE_CHECKSUM, tree.pages)
    fb.put(Sec.GROUP_CHECKSUM, tree.groups)
    fb.put(Sec.PAGE_FLAGS, page_flags)
    fb.put(Sec.PAGE_OFFSET, page_offset)
    fb.put(Sec.PAGE_SIZE, page_size)

    # L2 physical masking writes zeros into touched pages without re-reading
    # survivors, so zone maps are *widened* to include 0 rather than
    # recomputed — pruning stays sound, only slightly less selective.
    if touched_stats and fv.has_stats:
        from ..scan.stats import STAT_DTYPE, widen_to_zero
        pstats = np.frombuffer(bytes(fv.raw(Sec.PAGE_STATS)), STAT_DTYPE).copy()
        cstats = np.frombuffer(bytes(fv.raw(Sec.CHUNK_STATS)), STAT_DTYPE).copy()
        n_cols = fv.n_cols
        for p, g, c in touched_stats:
            widen_to_zero(pstats[p])
            widen_to_zero(cstats[g * n_cols + c])
        fb.put(Sec.PAGE_STATS, pstats)
        fb.put(Sec.CHUNK_STATS, cstats)

    # the same zeros must be admitted by the bloom value sketches: insert
    # 0's key into every touched page/chunk sketch (in-place bit-OR — blob
    # offsets never move), mirroring widen_to_zero above. Relocated pages
    # only *remove* rows, so their old sketch stays a sound superset.
    if touched_stats and fv.has_sketches:
        from ..scan.sketch import BloomSketch, canonical_u64
        data = bytearray(bytes(fv.raw(Sec.SKETCH_DATA)))
        chunk_off = fv.arr(Sec.CHUNK_SKETCH, np.uint64)
        pg_off = fv.arr(Sec.PAGE_SKETCH, np.uint64) \
            if fv.has(Sec.PAGE_SKETCH) else None
        zero = canonical_u64([0.0])
        no_sketch = np.uint64(0xFFFFFFFFFFFFFFFF)
        n_cols = fv.n_cols
        for p, g, c in touched_stats:
            offs = [chunk_off[g * n_cols + c]]
            if pg_off is not None:
                offs.append(pg_off[p])
            for off in offs:
                if off != no_sketch:
                    BloomSketch.from_buffer(data, int(off)).insert(zero)
        fb.put(Sec.SKETCH_DATA, bytes(data))

    n_pages = fv.n_pages
    dv_off = fv.arr(Sec.DV_OFFSET, np.uint64).copy()
    dv_size = fv.arr(Sec.DV_SIZE, np.uint32).copy()
    old_data = bytes(fv.raw(Sec.DV_DATA))
    blobs: list[bytes] = []
    cursor = 0
    new_off = dv_off.copy()
    for p in range(n_pages):
        if p in dvs and dvs[p].any():
            packed = np.packbits(dvs[p].astype(np.uint8), bitorder="little").tobytes()
        elif dv_off[p] != np.uint64(0xFFFFFFFFFFFFFFFF):
            o = int(dv_off[p])
            packed = old_data[o:o + int(dv_size[p])]
        else:
            new_off[p] = np.uint64(0xFFFFFFFFFFFFFFFF)
            dv_size[p] = 0
            continue
        new_off[p] = cursor
        dv_size[p] = len(packed)
        blobs.append(packed)
        cursor += len(packed)
    fb.put(Sec.DV_OFFSET, new_off)
    fb.put(Sec.DV_SIZE, dv_size)
    fb.put(Sec.DV_DATA, b"".join(blobs))
    return fb.build()


def delete_where(path, predicate,
                 level: Compliance = Compliance.LEVEL2) -> DeleteStats:
    """Predicate-based delete: erase every row matching a ``repro.scan``
    predicate (e.g. ``C("user_id") == victim``).

    ``path`` accepts anything ``dataset()`` opens — one file, a shard
    directory, a glob, or a path list. Victim rows are located through a
    raw-row-space Dataset plan, so on files with zone maps only the row
    groups whose statistics admit a match are read; on multi-shard datasets
    the global row ids are translated to each shard's local raw row space
    and only the affected shards are rewritten (``Dataset.delete_where``)."""
    from ..dataset import dataset

    return dataset(path).delete_where(predicate, level)


def verify_deleted(path: str, column: str, forbidden_values) -> dict:
    """Compliance audit: scan raw storage for forbidden values.

    Returns counts of (a) rows still *visible* with the value and (b) raw
    occurrences still physically present (L1 leaves them; L2 must not).

    The raw pass audits *physical page content* via the low-level decode —
    below the Dataset row-space API, whose drop_deleted=False mode pads
    compact-deleted rows with 0 to keep raw row ids stable (padding would
    count as a false occurrence when 0 is itself a forbidden value)."""
    from ..dataset.executor import decode_group
    from .reader import BullionReader

    with BullionReader(path) as r:
        visible = r.read_column(column, drop_deleted=True, dequant=False)
        parts = [decode_group(r, [column], g, drop_deleted=False,
                              dequant=False)[column]
                 for g in range(r.footer.n_groups)]
        raw = np.concatenate(parts) if isinstance(parts[0], np.ndarray) \
            else [v for p in parts for v in p]
    forbidden = np.asarray(forbidden_values)
    if isinstance(visible, np.ndarray):
        n_vis = int(np.isin(visible, forbidden).sum())
        n_raw = int(np.isin(raw, forbidden).sum())
    else:
        n_vis = sum(bool(np.isin(np.asarray(v), forbidden).any()) for v in visible)
        n_raw = sum(bool(np.isin(np.asarray(v), forbidden).any()) for v in raw)
    return {"visible_rows": n_vis, "raw_occurrences": n_raw}
