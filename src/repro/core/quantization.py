"""Storage quantization (Bullion §2.4).

Model-quantization techniques applied *in storage*: per-feature (per-column)
mixed precision, dynamically tunable.  Float features/embeddings store as
BF16/FP16/FP8 or affine INT8; integer features re-range losslessly (the
catalog's Dictionary/FOR encodings already provide the paper's "rehash to a
smaller range").  Includes the paper's dual-FP16 decomposition of FP32 across
two columns with a 1:1 rejoin.

Storage dtypes are carried as plain numpy views (bf16 -> uint16, fp8 ->
uint8) so every catalog encoding composes with quantized columns; the logical
dtype + params live in the footer's QUANT_META section.
"""

from __future__ import annotations

import struct
from dataclasses import dataclass
from enum import IntEnum

import ml_dtypes
import numpy as np


class QuantMode(IntEnum):
    NONE = 0
    BF16 = 1
    FP16 = 2
    FP8_E4M3 = 3
    INT8_AFFINE = 4
    UINT8_AFFINE = 5
    INT16_AFFINE = 6
    DUAL_FP16_HI = 7   # paper's FP32 -> two FP16 columns
    DUAL_FP16_LO = 8


# footer QUANT_META entry: mode u8, pad[7], scale f64, zero f64  (24 B/col)
QUANT_DTYPE = np.dtype([("mode", "<u1"), ("_pad", "<u1", 7),
                        ("scale", "<f8"), ("zero", "<f8")])


@dataclass(frozen=True)
class QuantSpec:
    mode: QuantMode = QuantMode.NONE
    scale: float = 1.0
    zero: float = 0.0

    def to_record(self) -> np.ndarray:
        rec = np.zeros(1, QUANT_DTYPE)
        rec["mode"] = int(self.mode)
        rec["scale"] = self.scale
        rec["zero"] = self.zero
        return rec

    @staticmethod
    def from_record(rec: np.ndarray) -> "QuantSpec":
        return QuantSpec(QuantMode(int(rec["mode"])), float(rec["scale"]),
                         float(rec["zero"]))


def storage_dtype(mode: QuantMode) -> np.dtype:
    return {
        QuantMode.NONE: None,
        QuantMode.BF16: np.dtype(np.uint16),
        QuantMode.FP16: np.dtype(np.float16),
        QuantMode.FP8_E4M3: np.dtype(np.uint8),
        QuantMode.INT8_AFFINE: np.dtype(np.int8),
        QuantMode.UINT8_AFFINE: np.dtype(np.uint8),
        QuantMode.INT16_AFFINE: np.dtype(np.int16),
        QuantMode.DUAL_FP16_HI: np.dtype(np.float16),
        QuantMode.DUAL_FP16_LO: np.dtype(np.float16),
    }[mode]


def quantize(arr: np.ndarray, spec: QuantSpec) -> np.ndarray:
    m = spec.mode
    if m == QuantMode.NONE:
        return arr
    if m == QuantMode.BF16:
        return arr.astype(ml_dtypes.bfloat16).view(np.uint16)
    if m == QuantMode.FP16:
        return arr.astype(np.float16)
    if m == QuantMode.FP8_E4M3:
        return arr.astype(ml_dtypes.float8_e4m3fn).view(np.uint8)
    if m in (QuantMode.INT8_AFFINE, QuantMode.UINT8_AFFINE, QuantMode.INT16_AFFINE):
        dt = storage_dtype(m)
        info = np.iinfo(dt)
        q = np.round((arr.astype(np.float64) - spec.zero) / spec.scale)
        return np.clip(q, info.min, info.max).astype(dt)
    if m == QuantMode.DUAL_FP16_HI:
        return arr.astype(np.float16)
    if m == QuantMode.DUAL_FP16_LO:
        hi = arr.astype(np.float16).astype(np.float32)
        return (arr.astype(np.float32) - hi).astype(np.float16)
    raise ValueError(m)


def dequantize(arr: np.ndarray, spec: QuantSpec,
               out_dtype=np.float32) -> np.ndarray:
    m = spec.mode
    if m == QuantMode.NONE:
        return arr
    if m == QuantMode.BF16:
        return arr.view(ml_dtypes.bfloat16).astype(out_dtype)
    if m in (QuantMode.FP16, QuantMode.DUAL_FP16_HI, QuantMode.DUAL_FP16_LO):
        return arr.astype(out_dtype)
    if m == QuantMode.FP8_E4M3:
        return arr.view(ml_dtypes.float8_e4m3fn).astype(out_dtype)
    if m in (QuantMode.INT8_AFFINE, QuantMode.UINT8_AFFINE, QuantMode.INT16_AFFINE):
        return (arr.astype(np.float64) * spec.scale + spec.zero).astype(out_dtype)
    raise ValueError(m)


def rejoin_dual_fp16(hi: np.ndarray, lo: np.ndarray) -> np.ndarray:
    """The paper's 1:1 join of the two FP16 halves back to ~FP32."""
    return hi.astype(np.float32) + lo.astype(np.float32)


def affine_spec_for(arr: np.ndarray, mode: QuantMode) -> QuantSpec:
    """Fit scale/zero to the column's observed range."""
    dt = storage_dtype(mode)
    info = np.iinfo(dt)
    lo, hi = float(arr.min()), float(arr.max())
    if hi == lo:
        return QuantSpec(mode, 1.0, lo)
    scale = (hi - lo) / (info.max - info.min)
    zero = lo - info.min * scale
    return QuantSpec(mode, scale, zero)


def suggest_spec(arr: np.ndarray, rel_tolerance: float = 1e-2) -> QuantSpec:
    """Mixed-precision policy: pick the cheapest storage meeting a relative
    error tolerance on this feature (the paper's per-feature sensitivity)."""
    if arr.dtype.kind != "f":
        return QuantSpec(QuantMode.NONE)
    scale = float(np.abs(arr).max()) or 1.0
    for mode in (QuantMode.FP8_E4M3, QuantMode.INT8_AFFINE, QuantMode.BF16,
                 QuantMode.FP16):
        spec = affine_spec_for(arr, mode) if "AFFINE" in mode.name else QuantSpec(mode)
        err = np.abs(dequantize(quantize(arr, spec), spec) - arr).max() / scale
        if err <= rel_tolerance:
            return spec
    return QuantSpec(QuantMode.NONE)
