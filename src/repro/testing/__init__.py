"""Hermetic test doubles shared by tests, benchmarks, and the quickstart."""

from .objstore import FakeObjectStore

__all__ = ["FakeObjectStore"]
