"""Hermetic test doubles shared by tests, benchmarks, and the quickstart."""

from .chaos import ChaosController, Fault, chaos
from .objstore import FakeObjectStore

__all__ = ["ChaosController", "FakeObjectStore", "Fault", "chaos"]
