"""Chaos fault-injection harness for the self-healing read path.

Wraps any ``ShardHandle`` (local or remote) behind the storage-backend
registry and injects the storage failures the integrity layer exists to
survive, deterministically and per-operation::

    from repro.testing.chaos import chaos

    with chaos() as ctl:                       # hooks plain local paths
        ctl.inject("bitflip", path_sub="part-00000", ordinal=0, byte=5)
        ds = dataset(path)
        ds.select("q").to_table()              # first pread comes back bad

Fault kinds:

* ``bitflip``  — XOR one byte of the returned blob (``byte`` indexes into
  it; negative indexes from the end),
* ``truncate`` — return only the first ``keep`` fraction of the blob,
* ``eio``      — raise ``OSError(EIO)`` instead of returning data,
* ``stale_footer`` — replay the *first* footer tail ever served for the
  path on every later ``footer_tail`` read, simulating a reader racing a
  shard rewrite with a stale cached footer.

Targeting: a fault fires on the ``ordinal``-th (0-based) matching
operation against a path containing ``path_sub``, counted per
``(path, section)`` where section is ``"pread"`` (data reads, including
each range of a ``fetch_ranges`` batch) or ``"footer"`` (tail reads).
``times`` widens the window to several consecutive operations (``-1`` =
every one from ``ordinal`` on). Counters and faults live on the
``ChaosController``, so one controller scripts a whole scenario and
``fired`` counts prove each fault actually hit.

The harness installs itself with ``register_backend`` — the same seam the
object-store backend uses — so every layer above (reader, prefetcher,
footer cache, fsck) is exercised unmodified. ``chaos()`` restores the
previous backends and drops the process-wide footer cache on exit, so a
footer read under chaos never leaks into the next test.
"""

from __future__ import annotations

import errno
import threading
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Iterator, Optional, Sequence

from ..core import backend as _backend
from ..core.backend import ShardHandle, StorageBackend

_KINDS = ("bitflip", "truncate", "eio", "stale_footer")
_SECTIONS = ("pread", "footer")


@dataclass
class Fault:
    """One scripted failure; ``fired`` counts the operations it hit."""

    kind: str
    path_sub: str = ""          # substring of the shard path/uri; "" = any
    section: str = "pread"      # which operation class it attaches to
    ordinal: int = 0            # fire on the Nth matching op (0-based)
    times: int = 1              # consecutive ops affected; -1 = all onward
    byte: int = 0               # bitflip: index into the returned blob
    keep: float = 0.5           # truncate: fraction of the blob kept
    fired: int = 0

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {_KINDS}")
        if self.section not in _SECTIONS:
            raise ValueError(f"unknown section {self.section!r}; "
                             f"expected one of {_SECTIONS}")

    def _matches(self, path: str, section: str, count: int) -> bool:
        if self.section != section or self.path_sub not in path:
            return False
        if count < self.ordinal:
            return False
        return self.times < 0 or count < self.ordinal + self.times


def _apply(fault: Fault, data: bytes) -> bytes:
    """Corrupt ``data`` per the fault. EIO is handled by the caller (it
    replaces the read instead of mangling its result)."""
    if fault.kind == "bitflip" and data:
        i = fault.byte if fault.byte >= 0 else len(data) + fault.byte
        i = max(0, min(len(data) - 1, i))
        out = bytearray(data)
        out[i] ^= 0xFF
        return bytes(out)
    if fault.kind == "truncate":
        return data[:max(0, int(len(data) * fault.keep))]
    return data


class ChaosController:
    """Owns the fault script and the per-(path, section) operation
    counters every wrapped handle reports into."""

    def __init__(self):
        self._lock = threading.Lock()
        self._faults: list[Fault] = []
        self._counts: dict[tuple[str, str], int] = {}
        self._tails: dict[str, bytes] = {}   # stale_footer first-served

    def inject(self, kind: str, **kw) -> Fault:
        f = Fault(kind, **kw)
        with self._lock:
            self._faults.append(f)
        return f

    def clear(self) -> None:
        with self._lock:
            self._faults.clear()
            self._counts.clear()
            self._tails.clear()

    @property
    def faults(self) -> list[Fault]:
        with self._lock:
            return list(self._faults)

    def take(self, path: str, section: str) -> list[Fault]:
        """Advance the (path, section) counter by one operation and return
        the faults that fire on it (marked fired)."""
        with self._lock:
            key = (path, section)
            count = self._counts.get(key, 0)
            self._counts[key] = count + 1
            hits = [f for f in self._faults
                    if f._matches(path, section, count)]
            for f in hits:
                f.fired += 1
            return hits

    def _stale_tail(self, path: str, tail: bytes,
                    active: bool) -> bytes:
        """First-served replay: remember the first tail per path; when a
        stale_footer fault is active, serve the remembered one."""
        with self._lock:
            first = self._tails.setdefault(path, tail)
        return first if active else tail

    def wrap(self, handle: ShardHandle) -> "ChaosShardHandle":
        return ChaosShardHandle(handle, self)


class ChaosShardHandle(ShardHandle):
    """Transparent proxy that routes every read through the controller."""

    def __init__(self, inner: ShardHandle, ctl: ChaosController):
        self._inner = inner
        self._ctl = ctl
        self.uri = inner.uri
        self.is_remote = inner.is_remote

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def bind_stats(self, stats, lock) -> None:
        self._inner.bind_stats(stats, lock)

    def _serve(self, data: bytes, hits: list[Fault]) -> bytes:
        for f in hits:
            if f.kind == "eio":
                raise OSError(errno.EIO, f"chaos: injected EIO "
                                         f"({self.uri})")
            data = _apply(f, data)
        return data

    def size(self) -> int:
        return self._inner.size()

    def pread(self, offset: int, size: int) -> bytes:
        hits = self._ctl.take(self.uri, "pread")
        return self._serve(self._inner.pread(offset, size), hits)

    def footer_tail(self, n: int) -> bytes:
        hits = self._ctl.take(self.uri, "footer")
        tail = self._inner.footer_tail(n)
        stale = any(f.kind == "stale_footer" for f in hits)
        tail = self._ctl._stale_tail(self.uri, tail, stale)
        return self._serve(tail, [f for f in hits
                                  if f.kind != "stale_footer"])

    def validator(self) -> tuple:
        return self._inner.validator()

    def fetch_ranges(self, ranges: Sequence[tuple[int, int]], *,
                     max_in_flight: int = 1
                     ) -> Iterator[tuple[int, Optional[bytes],
                                         Optional[BaseException]]]:
        # one "pread" operation per range, counted at submission order so
        # ordinals stay deterministic even when completions reorder
        plans = [self._ctl.take(self.uri, "pread") for _ in ranges]
        for i, data, err in self._inner.fetch_ranges(
                ranges, max_in_flight=max_in_flight):
            if err is None:
                try:
                    data = self._serve(data, plans[i])
                except OSError as e:
                    data, err = None, e
            yield i, data, err

    def close(self) -> None:
        self._inner.close()


@dataclass
class ChaosBackend(StorageBackend):
    """Backend decorator: opens on the inner backend, wraps the handle."""

    inner: StorageBackend
    ctl: ChaosController = field(default_factory=ChaosController)

    def open(self, uri: str) -> ShardHandle:
        return self.ctl.wrap(self.inner.open(uri))

    def close(self) -> None:
        self.inner.close()


@contextmanager
def chaos(schemes: Sequence[str] = ("file",), *,
          controller: Optional[ChaosController] = None):
    """Install fault injection for ``schemes`` (``"file"`` hooks plain
    local paths, ``"bullion"`` hooks object-store URIs) and yield the
    ``ChaosController``. Restores the previous backends and clears the
    process-wide footer cache on exit."""
    ctl = controller if controller is not None else ChaosController()
    prev: dict[str, Optional[StorageBackend]] = {}
    for scheme in schemes:
        inner = _backend._backends.get(scheme)
        if inner is None:
            inner = _backend._LOCAL if scheme == "file" \
                else _backend.ObjectStoreBackend()
        prev[scheme] = _backend.register_backend(
            scheme, ChaosBackend(inner, ctl))
    try:
        yield ctl
    finally:
        for scheme, p in prev.items():
            _backend.unregister_backend(scheme, restore=p)
        from ..dataset.source import clear_footer_cache
        clear_footer_cache()
