"""In-process fake object store: S3-style ranged GETs over a directory.

A ``ThreadingHTTPServer`` on a loopback ephemeral port serves files under a
root directory as ``/bucket/key`` objects with HTTP/1.1 keep-alive, byte
``Range:`` requests (absolute and suffix forms), ``Content-Range``, and
``ETag`` headers — everything ``repro.core.backend`` needs, nothing more.

Fault injection (``inject``) queues per-request schedules applied to the
next data range GETs: an error status, a truncated body (the server
advertises the full ``Content-Length`` then drops the connection
mid-body), a silently corrupted body (one byte flipped, length and
headers truthful — only checksum verification catches it), or an
override latency. A uniform per-request ``latency`` models
object-store RTT; ``max_in_flight`` records the high-water mark of
concurrently served requests so tests can assert the async batcher really
overlapped its ranges.
"""

from __future__ import annotations

import http.server
import os
import socket
import threading
import time
import urllib.parse
from collections import deque
from typing import Callable, Optional, Union


class FakeObjectStore:
    """Serve ``root/bucket/key`` files at ``http://127.0.0.1:<port>``."""

    def __init__(self, root: str, *,
                 latency: Union[float, Callable[[], float]] = 0.0):
        self.root = os.path.abspath(root)
        self.latency = latency
        self.requests = 0           # every request served
        self.range_requests = 0     # data GETs carrying a Range: header
        self.head_requests = 0
        self.max_in_flight = 0      # high-water concurrent requests
        self._in_flight = 0
        self._lock = threading.Lock()
        self._faults: "deque[dict]" = deque()
        self._server: Optional[http.server.ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> str:
        store = self

        class Handler(http.server.BaseHTTPRequestHandler):
            # HTTP/1.1 + exact Content-Length keeps connections alive, which
            # is what the client's pooling and the truncation fault rely on
            protocol_version = "HTTP/1.1"

            def log_message(self, *args):   # keep test output clean
                pass

            def do_HEAD(self):
                store._serve(self, head=True)

            def do_GET(self):
                store._serve(self, head=False)

        self._server = http.server.ThreadingHTTPServer(("127.0.0.1", 0),
                                                       Handler)
        self._server.daemon_threads = True
        self._thread = threading.Thread(target=self._server.serve_forever,
                                        daemon=True,
                                        name="bullion-fake-objstore")
        self._thread.start()
        return self.endpoint

    def stop(self) -> None:
        if self._server is not None:
            self._server.shutdown()
            self._server.server_close()
            self._thread.join()
            self._server = self._thread = None

    @property
    def endpoint(self) -> str:
        return f"http://127.0.0.1:{self._server.server_address[1]}"

    def __enter__(self) -> "FakeObjectStore":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    def uri(self, relpath: str) -> str:
        """``bullion://`` URI for a path relative to the store root."""
        return "bullion://" + relpath.replace(os.sep, "/")

    # -- fault schedule ------------------------------------------------------
    def inject(self, *, count: int = 1, status: Optional[int] = None,
               truncate: Optional[float] = None,
               corrupt: bool = False,
               latency: Optional[float] = None) -> None:
        """Apply a fault to each of the next ``count`` data range GETs:
        respond ``status`` (e.g. 503), send only ``truncate`` fraction of
        the advertised body then drop the connection, flip one body byte
        (``corrupt=True`` — length and headers stay truthful, so only a
        checksum can tell), and/or override the per-request ``latency``."""
        for _ in range(count):
            self._faults.append({"status": status, "truncate": truncate,
                                 "corrupt": corrupt, "latency": latency})

    def clear_faults(self) -> None:
        self._faults.clear()

    # -- serving -------------------------------------------------------------
    def _resolve(self, urlpath: str) -> Optional[str]:
        rel = os.path.normpath(
            urllib.parse.unquote(urllib.parse.urlsplit(urlpath).path)
            .lstrip("/"))
        if rel.startswith("..") or os.path.isabs(rel):
            return None
        path = os.path.join(self.root, rel)
        return path if os.path.isfile(path) else None

    def _serve(self, h, *, head: bool) -> None:
        rng = h.headers.get("Range")
        with self._lock:
            self.requests += 1
            if head:
                self.head_requests += 1
            fault = None
            if not head and rng is not None:
                self.range_requests += 1
                if self._faults:
                    fault = self._faults.popleft()
            self._in_flight += 1
            self.max_in_flight = max(self.max_in_flight, self._in_flight)
        try:
            self._serve_inner(h, head=head, rng=rng, fault=fault)
        except (BrokenPipeError, ConnectionResetError):
            pass   # client went away mid-response
        finally:
            with self._lock:
                self._in_flight -= 1

    def _serve_inner(self, h, *, head: bool, rng: Optional[str],
                     fault: Optional[dict]) -> None:
        lat = self.latency
        if fault and fault.get("latency") is not None:
            lat = fault["latency"]
        if lat:
            time.sleep(lat() if callable(lat) else lat)

        path = self._resolve(h.path)
        if path is None:
            body = b"no such object"
            h.send_response(404)
            h.send_header("Content-Length", str(len(body)))
            h.end_headers()
            if not head:
                h.wfile.write(body)
            return

        st = os.stat(path)
        etag = f'"{st.st_mtime_ns:x}-{st.st_size:x}"'
        if fault and fault.get("status"):
            body = b"injected fault"
            h.send_response(fault["status"])
            h.send_header("Content-Length", str(len(body)))
            h.end_headers()
            h.wfile.write(body)
            return

        if head:
            h.send_response(200)
            h.send_header("Content-Length", str(st.st_size))
            h.send_header("ETag", etag)
            h.send_header("Accept-Ranges", "bytes")
            h.end_headers()
            return

        start, end, status = 0, st.st_size, 200   # [start, end)
        if rng:
            spec = rng.split("=", 1)[1].strip()
            if spec.startswith("-"):               # suffix form: last N bytes
                start = max(0, st.st_size - int(spec[1:]))
            else:
                a, _, b = spec.partition("-")
                start = int(a)
                end = min(st.st_size, int(b) + 1) if b else st.st_size
            status = 206
        with open(path, "rb") as f:
            f.seek(start)
            body = f.read(end - start)
        if fault and fault.get("corrupt") and body:
            # silent in-flight corruption: flip the middle byte, keep the
            # advertised Content-Length — only checksum verification can
            # catch this one
            flipped = bytearray(body)
            flipped[len(flipped) // 2] ^= 0xFF
            body = bytes(flipped)

        h.send_response(status)
        h.send_header("Content-Length", str(len(body)))
        h.send_header("ETag", etag)
        if status == 206:
            h.send_header("Content-Range",
                          f"bytes {start}-{end - 1}/{st.st_size}")
        h.end_headers()
        if fault and fault.get("truncate") is not None:
            # advertise the full length, send a prefix, drop the connection:
            # the client must detect the short body and retry
            h.wfile.write(body[:int(len(body) * fault["truncate"])])
            h.wfile.flush()
            h.close_connection = True
            try:
                h.connection.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            return
        h.wfile.write(body)
