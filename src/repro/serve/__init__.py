from .engine import ServeEngine

__all__ = ["ServeEngine"]
