"""Bullion serve: the multi-tenant dataset service (+ the LM serving demo).

The dataset service (``DatasetServer``/``ServeClient``) fronts Bullion
datasets for feature-serving workloads: prepared-plan caching, shared
footer/fd state, admission control with per-tenant io_depth budgets, and
bloom-sketch point lookups. See ``serve.server``.

The LM serving demo engine lives in ``serve.lm``; its ``ServeEngine`` is
re-exported lazily so importing the dataset service never imports jax.
"""

from .client import ClientResult, ServeClient, ServeError
from .server import DatasetServer, PlanCache, QueryResult, TenantBudget

__all__ = [
    "DatasetServer", "PlanCache", "QueryResult", "TenantBudget",
    "ServeClient", "ClientResult", "ServeError", "ServeEngine",
]


def __getattr__(name: str):
    if name == "ServeEngine":            # lazy: pulls in jax
        from .lm import ServeEngine
        return ServeEngine
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
