"""Client for the ``DatasetServer`` AF_UNIX front-end.

One socket per client; requests on a connection are serialized (the
protocol is strict request/response). Predicates are built with the normal
``repro.scan.C`` combinators and serialized structurally::

    with ServeClient(path) as cli:
        res = cli.query("ads", where=C("id") == 12345,
                        columns=["ctr", "bid"])
        res.table["ctr"]        # numpy array, decoded

Spin up several clients (or threads each owning one) for concurrency —
the server is thread-per-session and all sessions share its bounded pool.

``ServeClient(path, trace=True)`` turns on cross-process trace
propagation: the client stamps its trace id into every request frame,
wraps each RPC in a client-side span, and the server executes the query
under a scoped tracer whose finished spans ride back on the response
(wall-clock timestamps, rebased on arrival). ``profile()`` merges both
sides into one Perfetto-loadable Chrome trace under the one trace id —
the client's ``client.rpc`` spans enclose the server's ``serve.query``
span trees, so the wire/queueing gap is visible as the difference.
"""

from __future__ import annotations

import socket
import threading
import uuid
from dataclasses import dataclass
from typing import Optional, Sequence

from ..obs import trace as _trace
from ..obs.export import Profile
from ..scan.predicate import Predicate
from . import wire

# server spans keep their own thread ids; the offset keeps their tracks
# separate from client threads in the merged trace even across processes
# that happen to reuse a tid
_SERVER_TID_OFFSET = 1 << 24


class ServeError(RuntimeError):
    """The server answered a request with ok=False."""


@dataclass
class ClientResult:
    table: dict
    rows: int
    cache_hit: bool
    fingerprint: str
    wall_seconds: float
    trace_id: Optional[str] = None
    degraded: bool = False        # server dropped/masked quarantined pages
    degraded_rows: int = 0


class ServeClient:
    def __init__(self, socket_path: str, *, timeout: Optional[float] = 30.0,
                 trace: bool = False):
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.settimeout(timeout)
        self._sock.connect(socket_path)
        self._lock = threading.Lock()   # one in-flight request per socket
        self.trace_id: Optional[str] = None
        self._tracer: Optional[_trace.Tracer] = None
        self._server_spans: list[_trace.SpanRecord] = []
        if trace:
            self.trace_id = uuid.uuid4().hex[:16]
            self._tracer = _trace.Tracer()

    def _rpc(self, req: dict) -> dict:
        if self._tracer is not None:
            sp = self._tracer.span("client.rpc", "serve",
                                   {"op": req.get("op"),
                                    "trace_id": self.trace_id})
            if "dataset" in req:
                sp.set(dataset=req["dataset"])
            with sp:
                resp = self._roundtrip(req)
        else:
            resp = self._roundtrip(req)
        if resp is None:
            raise ConnectionError("server closed the connection")
        self._absorb_trace(resp)
        if not resp.get("ok"):
            raise ServeError(resp.get("error", "unknown server error"))
        return resp

    def _roundtrip(self, req: dict) -> Optional[dict]:
        with self._lock:
            wire.send_msg(self._sock, req)
            return wire.recv_msg(self._sock)

    def _absorb_trace(self, resp: dict) -> None:
        tr = resp.get("trace")
        if not tr:
            return
        for d in tr.get("spans", []):
            rec = _trace.span_from_dict(d, wall=True)
            rec.tid += _SERVER_TID_OFFSET
            rec.tname = f"server:{rec.tname}"
            self._server_spans.append(rec)

    def ping(self) -> bool:
        return bool(self._rpc({"op": "ping"}).get("pong"))

    def datasets(self) -> list[str]:
        return self._rpc({"op": "datasets"})["datasets"]

    def stats(self) -> dict:
        return self._rpc({"op": "stats"})["stats"]

    def metrics_text(self) -> str:
        """The server's metrics registry in Prometheus text format."""
        return self._rpc({"op": "metrics"})["text"]

    def server_log(self, n: int = 50) -> list[dict]:
        """The server's most recent query-log records (plain dicts)."""
        return self._rpc({"op": "log", "n": n})["records"]

    def explain(self, dataset: str, *,
                columns: Optional[Sequence[str]] = None,
                where: Optional[Predicate] = None,
                head: Optional[int] = None) -> str:
        return self._rpc({"op": "explain", "dataset": dataset,
                          "columns": list(columns) if columns else None,
                          "where": wire.encode_predicate(where),
                          "head": head})["explain"]

    def query(self, dataset: str, *,
              columns: Optional[Sequence[str]] = None,
              where: Optional[Predicate] = None,
              head: Optional[int] = None,
              tenant: str = "default",
              io_depth: Optional[int] = None) -> ClientResult:
        req = {"op": "query", "dataset": dataset,
               "columns": list(columns) if columns else None,
               "where": wire.encode_predicate(where),
               "head": head, "tenant": tenant,
               "io_depth": io_depth}
        if self.trace_id is not None:
            req["trace"] = {"id": self.trace_id}
        resp = self._rpc(req)
        return ClientResult(table=wire.decode_table(resp["table"]),
                            rows=resp["rows"],
                            cache_hit=resp["cache_hit"],
                            fingerprint=resp["fingerprint"],
                            wall_seconds=resp["wall_seconds"],
                            trace_id=self.trace_id,
                            degraded=bool(resp.get("degraded")),
                            degraded_rows=int(resp.get("degraded_rows") or 0))

    def profile(self, path: Optional[str] = None) -> Profile:
        """Merge the client-side RPC spans with every server span this
        connection's traced queries brought back into one ``Profile``
        (single Chrome trace, one trace id). ``path`` writes the JSON —
        load it in Perfetto / chrome://tracing. Requires ``trace=True``."""
        if self._tracer is None:
            raise RuntimeError(
                "profile() needs ServeClient(..., trace=True)")
        spans = list(self._tracer.spans) + list(self._server_spans)
        spans.sort(key=lambda s: s.ts)
        prof = Profile.from_spans(spans, dropped=self._tracer.dropped,
                                  trace_id=self.trace_id)
        if path is not None:
            prof.write(path)
        return prof

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
