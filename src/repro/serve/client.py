"""Client for the ``DatasetServer`` AF_UNIX front-end.

One socket per client; requests on a connection are serialized (the
protocol is strict request/response). Predicates are built with the normal
``repro.scan.C`` combinators and serialized structurally::

    with ServeClient(path) as cli:
        res = cli.query("ads", where=C("id") == 12345,
                        columns=["ctr", "bid"])
        res.table["ctr"]        # numpy array, decoded

Spin up several clients (or threads each owning one) for concurrency —
the server is thread-per-session and all sessions share its bounded pool.
"""

from __future__ import annotations

import socket
import threading
from dataclasses import dataclass
from typing import Optional, Sequence

from ..scan.predicate import Predicate
from . import wire


class ServeError(RuntimeError):
    """The server answered a request with ok=False."""


@dataclass
class ClientResult:
    table: dict
    rows: int
    cache_hit: bool
    fingerprint: str
    wall_seconds: float


class ServeClient:
    def __init__(self, socket_path: str, *, timeout: Optional[float] = 30.0):
        self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._sock.settimeout(timeout)
        self._sock.connect(socket_path)
        self._lock = threading.Lock()   # one in-flight request per socket

    def _rpc(self, req: dict) -> dict:
        with self._lock:
            wire.send_msg(self._sock, req)
            resp = wire.recv_msg(self._sock)
        if resp is None:
            raise ConnectionError("server closed the connection")
        if not resp.get("ok"):
            raise ServeError(resp.get("error", "unknown server error"))
        return resp

    def ping(self) -> bool:
        return bool(self._rpc({"op": "ping"}).get("pong"))

    def datasets(self) -> list[str]:
        return self._rpc({"op": "datasets"})["datasets"]

    def stats(self) -> dict:
        return self._rpc({"op": "stats"})["stats"]

    def explain(self, dataset: str, *,
                columns: Optional[Sequence[str]] = None,
                where: Optional[Predicate] = None,
                head: Optional[int] = None) -> str:
        return self._rpc({"op": "explain", "dataset": dataset,
                          "columns": list(columns) if columns else None,
                          "where": wire.encode_predicate(where),
                          "head": head})["explain"]

    def query(self, dataset: str, *,
              columns: Optional[Sequence[str]] = None,
              where: Optional[Predicate] = None,
              head: Optional[int] = None,
              tenant: str = "default",
              io_depth: Optional[int] = None) -> ClientResult:
        resp = self._rpc({"op": "query", "dataset": dataset,
                          "columns": list(columns) if columns else None,
                          "where": wire.encode_predicate(where),
                          "head": head, "tenant": tenant,
                          "io_depth": io_depth})
        return ClientResult(table=wire.decode_table(resp["table"]),
                            rows=resp["rows"],
                            cache_hit=resp["cache_hit"],
                            fingerprint=resp["fingerprint"],
                            wall_seconds=resp["wall_seconds"])

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "ServeClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
