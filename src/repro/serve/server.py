"""Multi-tenant dataset service: prepared plans, admission control, probes.

``DatasetServer`` fronts one or more Bullion datasets for the paper's
feature-serving workload — many concurrent point probes and narrow
projections against wide tables. Three mechanisms make it a *service*
rather than a loop around ``dataset()``:

* **Prepared plans.** Query shapes repeat (dashboards, feature fetchers),
  so optimized plans are cached in an LRU keyed by (dataset, plan
  fingerprint) — à la prepared statements. A hit reuses a ``Dataset``
  instance whose optimize/lower caches are already populated: the repeat
  query pays zero planning, only execution. ``LogicalPlan.fingerprint``
  normalizes conjunct order, so ``.where(a).where(b)`` and
  ``.where(b).where(a)`` share one entry.
* **Shared metadata and descriptors.** All sessions read through one
  ``DataSource`` per dataset: one parsed footer and one fd per shard,
  however many clients connect (positional preads are thread-safe).
* **Admission control.** A bounded executor pool caps global concurrency;
  queue depth is observed into ``bullion.serve.queue_depth`` at every
  submit. Per-tenant ``io_depth`` budgets cap the *sum of io_depths* a
  tenant's in-flight queries may hold, bounding its concurrent preads —
  a noisy tenant queues against its own budget, not the fleet's.

Clients use the in-process API (``query``/``submit``) or the thread-per-
session AF_UNIX front-end (``serve`` + ``repro.serve.client.ServeClient``).

Point probes with *varying* literals fingerprint differently by design —
group pruning is literal-dependent, so lowering must rerun — but they still
ride the shared footer cache and the bloom sketches; the prepared cache is
for the repeated-identical-plan case, which is asserted in tests.
"""

from __future__ import annotations

import dataclasses
import os
import socket
import tempfile
import threading
import time
from collections import OrderedDict
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass
from typing import Optional, Sequence

from ..core import integrity as _integrity
from ..dataset import executor
from ..dataset.core import Dataset
from ..dataset.plan import LogicalPlan
from ..dataset.source import DataSource, PathSpec, discover
from ..obs import metrics as _metrics
from ..obs import querylog as _querylog
from ..obs import trace as _trace
from ..obs.expose import prometheus_text
from ..scan.predicate import Predicate
from . import wire

DEFAULT_TENANT = "default"


def _table_rows(table: dict) -> int:
    for col in table.values():
        return len(col)
    return 0


@dataclass
class QueryResult:
    table: dict
    rows: int
    cache_hit: bool              # served from the prepared-plan cache
    fingerprint: str
    wall_seconds: float
    tenant: str = DEFAULT_TENANT
    trace_id: Optional[str] = None
    spans: Optional[list] = None  # wall-ts span dicts (wire trace requests)
    degraded: bool = False        # quarantined pages degraded this result
    degraded_rows: int = 0        # exact rows dropped/masked (IOStats delta)


@dataclass
class _Prepared:
    ds: Dataset
    fingerprint: str
    hits: int = 0


class PlanCache:
    """LRU of prepared ``Dataset`` instances keyed by (dataset name,
    plan fingerprint). Entries hold no file handles of their own — they
    share the server's per-dataset ``DataSource`` — so eviction is free."""

    def __init__(self, capacity: int = 64):
        self.capacity = int(capacity)
        self._ent: "OrderedDict[tuple[str, str], _Prepared]" = OrderedDict()
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0

    def get_or_prepare(self, name: str, source: DataSource,
                       plan: LogicalPlan) -> tuple[Dataset, str, bool]:
        """(prepared dataset, fingerprint, was_hit). On a miss the plan is
        optimized and lowered *here*, under no lock but before publication,
        so every later hit skips both (and never races on the instance's
        plan caches)."""
        fp = plan.fingerprint()
        key = (name, fp)
        with self._lock:
            ent = self._ent.get(key)
            if ent is not None:
                self._ent.move_to_end(key)
                ent.hits += 1
                self.hits += 1
                _metrics.counter("bullion.serve.plan_cache_hits").inc()
                return ent.ds, fp, True
        ds = Dataset(source, plan)
        ds.tasks()   # populate optimize/lower caches (footer-only, no I/O)
        with self._lock:
            ent = self._ent.get(key)
            if ent is not None:          # racing prepare: first one wins
                self._ent.move_to_end(key)
                ent.hits += 1
                self.hits += 1
                _metrics.counter("bullion.serve.plan_cache_hits").inc()
                return ent.ds, fp, True
            self._ent[key] = _Prepared(ds=ds, fingerprint=fp)
            self.misses += 1
            _metrics.counter("bullion.serve.plan_cache_misses").inc()
            while len(self._ent) > self.capacity:
                self._ent.popitem(last=False)
        return ds, fp, False

    def __len__(self) -> int:
        with self._lock:
            return len(self._ent)


class TenantBudget:
    """Counting budget of io_depth permits for one tenant.

    A query acquires ``min(requested, depth)`` permits for its whole
    execution, so the sum of in-flight io_depths — and with it the tenant's
    possible concurrent preads *and* concurrent object-store ranges (the
    held depth is also the scheduler's ``max_in_flight`` for batched remote
    fetches) — never exceeds ``depth``. Requests are clamped, never
    rejected: a single query asking for more than the budget runs at the
    budget, and one permit is always obtainable, so no query can deadlock
    itself."""

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"tenant io_depth budget must be >= 1, "
                             f"got {depth}")
        self.depth = int(depth)
        self._avail = int(depth)
        self._cond = threading.Condition()
        self.peak_in_flight = 0      # max permits ever held at once
        self.waits = 0               # acquisitions that had to block

    def acquire(self, want: int) -> int:
        want = max(1, min(int(want), self.depth))
        with self._cond:
            if self._avail < want:
                self.waits += 1
            while self._avail < want:
                self._cond.wait()
            self._avail -= want
            self.peak_in_flight = max(self.peak_in_flight,
                                      self.depth - self._avail)
        return want

    def release(self, n: int) -> None:
        with self._cond:
            self._avail += n
            self._cond.notify_all()


class DatasetServer:
    """Serve select/where/head plans over attached Bullion datasets.

    In-process: ``server.query("ads", where=C("id") == 7)``. Over a local
    socket: ``server.serve(path)`` + ``ServeClient(path)``. Both funnel
    into the same bounded executor pool."""

    def __init__(self, datasets: Optional[dict[str, PathSpec]] = None, *,
                 max_workers: int = 4, plan_cache_size: int = 64,
                 tenant_io_depth: int = 8, default_io_depth: int = 2,
                 query_log: Optional[_querylog.QueryLog] = None,
                 query_log_size: int = 256):
        self._sources: dict[str, DataSource] = {}
        # the flight recorder: every query/submit appends one record (env
        # knobs BULLION_QUERY_LOG / BULLION_SLOW_MS are read here)
        self.query_log = _querylog.QueryLog(query_log_size) \
            if query_log is None else query_log
        self._cache = PlanCache(plan_cache_size)
        self._pool = ThreadPoolExecutor(
            max_workers=max_workers, thread_name_prefix="bullion-serve")
        self.max_workers = int(max_workers)
        self.default_io_depth = int(default_io_depth)
        self.tenant_io_depth = int(tenant_io_depth)
        self._tenants: dict[str, TenantBudget] = {}
        self._lock = threading.Lock()
        self._pending = 0            # submitted, not yet finished
        self._queries = 0
        self._errors = 0
        self._closed = False
        # socket front-end state
        self._listener: Optional[socket.socket] = None
        self._accept_thread: Optional[threading.Thread] = None
        self._conn_threads: list[threading.Thread] = []
        self.socket_path: Optional[str] = None
        for name, spec in (datasets or {}).items():
            self.attach(name, spec)

    # -- datasets ---------------------------------------------------------------
    def attach(self, name: str, spec: PathSpec) -> None:
        """Register a dataset — local paths or ``bullion://bucket/key``
        object-store URIs (or a mixed list). Shard footers are parsed at
        most once here (via the process-wide footer cache; remote entries
        validate by ETag/length) and shared by every session. Remote
        shards' concurrent in-flight ranges stay bounded by the same
        per-tenant io_depth budgets that bound local preads."""
        if name in self._sources:
            raise ValueError(f"dataset {name!r} already attached")
        self._sources[name] = DataSource(discover(spec))

    def datasets(self) -> list[str]:
        return sorted(self._sources)

    def _source(self, name: str) -> DataSource:
        try:
            return self._sources[name]
        except KeyError:
            raise KeyError(
                f"unknown dataset {name!r}; attached: "
                f"{sorted(self._sources)}") from None

    def tenant_budget(self, tenant: str, depth: Optional[int] = None
                      ) -> TenantBudget:
        """Get (or create) a tenant's budget; ``depth`` sets the budget on
        first use (later calls ignore it — budgets are fixed at creation)."""
        with self._lock:
            b = self._tenants.get(tenant)
            if b is None:
                b = self._tenants[tenant] = TenantBudget(
                    self.tenant_io_depth if depth is None else depth)
            return b

    # -- planning ---------------------------------------------------------------
    def _build_plan(self, columns: Optional[Sequence[str]],
                    where: Optional[Predicate],
                    head: Optional[int]) -> LogicalPlan:
        return LogicalPlan(
            columns=tuple(columns) if columns is not None else None,
            predicate=where, limit=head)

    def prepare(self, dataset: str, *,
                columns: Optional[Sequence[str]] = None,
                where: Optional[Predicate] = None,
                head: Optional[int] = None) -> tuple[Dataset, str, bool]:
        """Resolve (and cache) the prepared plan for a query shape without
        executing it. Returns (dataset instance, fingerprint, cache hit)."""
        source = self._source(dataset)
        plan = self._build_plan(columns, where, head)
        return self._cache.get_or_prepare(dataset, source, plan)

    def explain(self, dataset: str, *,
                columns: Optional[Sequence[str]] = None,
                where: Optional[Predicate] = None,
                head: Optional[int] = None) -> str:
        ds, fp, hit = self.prepare(dataset, columns=columns, where=where,
                                   head=head)
        return (f"Prepared[{dataset} {fp[:12]} "
                f"{'hit' if hit else 'miss'}]\n" + ds.explain())

    # -- querying ---------------------------------------------------------------
    def submit(self, dataset: str, *,
               columns: Optional[Sequence[str]] = None,
               where: Optional[Predicate] = None,
               head: Optional[int] = None,
               tenant: str = DEFAULT_TENANT,
               io_depth: Optional[int] = None,
               trace_id: Optional[str] = None,
               collect_spans: bool = False) -> "Future[QueryResult]":
        """Queue a query on the bounded pool and return its Future.
        Admission control happens here: the pool caps concurrent
        executions, and the submit-time queue depth is recorded.
        ``trace_id`` tags the query's spans and its query-log record;
        ``collect_spans=True`` additionally runs the query under a scoped
        tracer and returns the finished spans on the result (what the wire
        front-end uses for client-side ``profile()``)."""
        if self._closed:
            raise RuntimeError("server is closed")
        with self._lock:
            self._pending += 1
            depth = self._pending
        _metrics.histogram("bullion.serve.queue_depth").observe(depth)
        fut = self._pool.submit(self._run, dataset, columns, where, head,
                                tenant, io_depth, trace_id, collect_spans)
        fut.add_done_callback(self._done)
        return fut

    def query(self, dataset: str, *,
              columns: Optional[Sequence[str]] = None,
              where: Optional[Predicate] = None,
              head: Optional[int] = None,
              tenant: str = DEFAULT_TENANT,
              io_depth: Optional[int] = None,
              timeout: Optional[float] = None,
              trace_id: Optional[str] = None,
              collect_spans: bool = False) -> QueryResult:
        """Blocking query: submit + wait."""
        return self.submit(dataset, columns=columns, where=where, head=head,
                           tenant=tenant, io_depth=io_depth,
                           trace_id=trace_id,
                           collect_spans=collect_spans).result(timeout)

    def _done(self, fut: Future) -> None:
        with self._lock:
            self._pending -= 1
            if fut.exception() is not None:
                self._errors += 1

    def _record(self, rec: _querylog.QueryRecord) -> None:
        try:
            self.query_log.append(rec)
        except Exception:        # telemetry must never fail a query
            pass

    def _run(self, dataset: str, columns, where, head, tenant: str,
             io_depth: Optional[int], trace_id: Optional[str] = None,
             collect_spans: bool = False) -> QueryResult:
        t0 = time.perf_counter()
        rec = _querylog.QueryRecord(
            ts=time.time(), origin="serve", dataset=dataset, tenant=tenant,
            columns=list(columns) if columns is not None else None,
            predicate=repr(where) if where is not None else None,
            trace_id=trace_id)
        # the scoped tracer costs span allocations, so it runs only when a
        # caller asked for spans, a slow-query threshold is armed, or a
        # process-wide recording is already on — the default serve hot path
        # stays span-allocation-free (asserted in tests)
        want_spans = (collect_spans or _trace.enabled()
                      or self.query_log.slow_seconds is not None)
        scope = tracer = None
        held = 0
        budget = None
        try:
            ds, fp, hit = self.prepare(dataset, columns=columns, where=where,
                                       head=head)
            rec.fingerprint, rec.cache_hit = fp, hit
            source = self._sources[dataset]
            budget = self.tenant_budget(tenant)
            want = self.default_io_depth if io_depth is None else io_depth
            held = budget.acquire(want)
            if want_spans:
                scope = _trace.collect()
                tracer = scope.__enter__()
            try:
                before = source.stats
                sp = _trace.span("serve.query", cat="serve", dataset=dataset,
                                 tenant=tenant, cache_hit=hit)
                if trace_id is not None and sp.enabled:
                    sp.set(trace_id=trace_id)
                with sp:
                    table = ds.to_table(io_depth=held)
                # exact for this query while queries on the dataset don't
                # overlap (the source accounting is dataset-wide)
                rec.io = dataclasses.asdict(source.stats.delta(before))
                rec.degraded = bool(rec.io.get("degraded_rows"))
            finally:
                if scope is not None:
                    scope.__exit__(None, None, None)
            rec.rows = _table_rows(table)
            rec.result_bytes = executor.table_nbytes(table)
            rec.wall_seconds = wall = time.perf_counter() - t0
            spans_out = None
            if tracer is not None:
                rec.stages = _querylog.stage_dict(tracer.aggregate())
                rec.dropped_spans = tracer.dropped
                slow = (self.query_log.slow_seconds is not None
                        and wall >= self.query_log.slow_seconds)
                if collect_spans or slow:
                    spans_out = [_trace.span_to_dict(s, wall=True)
                                 for s in tracer.spans]
                if slow:
                    rec.spans = spans_out
            self._record(rec)
            with self._lock:
                self._queries += 1
            _metrics.counter("bullion.serve.queries").inc()
            _metrics.histogram("bullion.serve.wall_seconds").observe(wall)
            return QueryResult(table=table, rows=rec.rows, cache_hit=hit,
                               fingerprint=fp, wall_seconds=wall,
                               tenant=tenant, trace_id=trace_id,
                               spans=spans_out if collect_spans else None,
                               degraded=rec.degraded,
                               degraded_rows=int(
                                   (rec.io or {}).get("degraded_rows") or 0))
        except Exception as e:
            rec.outcome = "error"
            rec.error = f"{type(e).__name__}: {e}"
            rec.wall_seconds = time.perf_counter() - t0
            self._record(rec)
            e.__bullion_logged__ = True   # _session won't double-record
            raise
        finally:
            if held and budget is not None:
                budget.release(held)

    # -- introspection ----------------------------------------------------------
    def stats(self) -> dict:
        with self._lock:
            tenants = {name: {"io_depth": b.depth,
                              "peak_in_flight": b.peak_in_flight,
                              "waits": b.waits}
                       for name, b in self._tenants.items()}
            queries, errors, pending = \
                self._queries, self._errors, self._pending
        tr = _trace.current()
        return {
            "queries": queries,
            "errors": errors,
            "pending": pending,
            "max_workers": self.max_workers,
            "plan_cache": {"hits": self._cache.hits,
                           "misses": self._cache.misses,
                           "size": len(self._cache),
                           "capacity": self._cache.capacity},
            "tenants": tenants,
            "datasets": {
                name: {"shards": src.n_shards, "rows": src.num_rows,
                       "io": dataclasses.asdict(src.stats)}
                for name, src in self._sources.items()},
            # a truncated recording must be visible, not look complete
            "trace": {"installed": tr is not None,
                      "spans": len(tr.spans) if tr is not None else 0,
                      "dropped": tr.dropped if tr is not None else 0},
            "query_log": self.query_log.summary(),
            # decode-time verification posture + every quarantined page
            # (path -> [(group, page, reason)]), so operators see exactly
            # which shards need repair and degraded queries are explicable
            "integrity": {
                "verify_policy": _integrity.verify_policy(),
                "on_corrupt": _integrity.corruption_policy(),
                **_integrity.QUARANTINE.summary(),
            },
        }

    def metrics_text(self) -> str:
        """The process metrics registry rendered as Prometheus text
        exposition format (also served by the ``metrics`` wire command)."""
        return prometheus_text()

    # -- socket front-end -------------------------------------------------------
    def serve(self, socket_path: Optional[str] = None) -> str:
        """Start the AF_UNIX listener (thread-per-session) and return the
        socket path. Sessions submit into the same bounded pool as the
        in-process API, so admission control is shared."""
        if self._listener is not None:
            raise RuntimeError(f"already serving on {self.socket_path}")
        if socket_path is None:
            socket_path = os.path.join(
                tempfile.mkdtemp(prefix="bullion-serve-"), "serve.sock")
        self.socket_path = socket_path
        self._listener = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
        self._listener.bind(socket_path)
        self._listener.listen()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="bullion-serve-accept",
            daemon=True)
        self._accept_thread.start()
        return socket_path

    def _accept_loop(self) -> None:
        while True:
            try:
                conn, _ = self._listener.accept()
            except OSError:
                return                   # listener closed
            t = threading.Thread(target=self._session, args=(conn,),
                                 name="bullion-serve-session", daemon=True)
            t.start()
            self._conn_threads.append(t)

    def _wire_error(self, error: str, op=None, dataset=None) -> None:
        """Record a protocol-level failure (malformed/oversized frame,
        unknown command, bad request) in the query log: broken clients are
        production events too."""
        self._record(_querylog.QueryRecord(
            ts=time.time(), origin="serve.wire",
            dataset=str(dataset) if dataset is not None else "",
            outcome="error", error=error,
            predicate=f"op={op!r}" if op is not None else None))

    def _session(self, conn: socket.socket) -> None:
        with conn:
            while True:
                try:
                    req = wire.recv_msg(conn)
                except (ConnectionError, ValueError) as e:
                    # torn or oversized frame: drop this session (the frame
                    # boundary is lost), leave a record, server lives on
                    self._wire_error(f"{type(e).__name__}: {e}")
                    return
                except OSError:
                    return
                if req is None:
                    return
                try:
                    resp = self._dispatch(req)
                except Exception as e:   # per-request fault isolation
                    if not getattr(e, "__bullion_logged__", False):
                        self._wire_error(f"{type(e).__name__}: {e}",
                                         op=req.get("op"),
                                         dataset=req.get("dataset"))
                    resp = {"ok": False,
                            "error": f"{type(e).__name__}: {e}"}
                try:
                    wire.send_msg(conn, resp)
                except OSError:
                    return

    def _dispatch(self, req: dict) -> dict:
        op = req.get("op")
        if op == "ping":
            return {"ok": True, "pong": True}
        if op == "stats":
            return {"ok": True, "stats": self.stats()}
        if op == "datasets":
            return {"ok": True, "datasets": self.datasets()}
        if op == "metrics":
            return {"ok": True, "text": self.metrics_text()}
        if op == "log":
            return {"ok": True,
                    "records": [r.to_dict() for r in
                                self.query_log.tail(req.get("n", 50))]}
        if op == "explain":
            return {"ok": True, "explain": self.explain(
                req["dataset"], columns=req.get("columns"),
                where=wire.decode_predicate(req.get("where")),
                head=req.get("head"))}
        if op == "query":
            trace_req = req.get("trace") or {}
            trace_id = trace_req.get("id")
            res = self.query(
                req["dataset"], columns=req.get("columns"),
                where=wire.decode_predicate(req.get("where")),
                head=req.get("head"),
                tenant=req.get("tenant", DEFAULT_TENANT),
                io_depth=req.get("io_depth"),
                trace_id=trace_id, collect_spans=bool(trace_req))
            resp = {"ok": True, "rows": res.rows,
                    "cache_hit": res.cache_hit,
                    "fingerprint": res.fingerprint,
                    "wall_seconds": res.wall_seconds,
                    "degraded": res.degraded,
                    "degraded_rows": res.degraded_rows,
                    "table": wire.encode_table(res.table)}
            if trace_req:
                resp["trace"] = {"id": trace_id,
                                 "spans": res.spans or []}
            return resp
        self._wire_error(f"unknown op {op!r}", op=op,
                         dataset=req.get("dataset"))
        return {"ok": False, "error": f"unknown op {op!r}"}

    # -- lifecycle --------------------------------------------------------------
    def close(self) -> None:
        """Stop accepting, drain the pool, close shard readers. Idempotent."""
        if self._closed:
            return
        self._closed = True
        if self._listener is not None:
            try:
                # close() alone leaves the accept thread blocked until its
                # join timeout; shutdown() wakes accept() immediately
                self._listener.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                self._listener.close()
            finally:
                self._listener = None
            if self._accept_thread is not None:
                self._accept_thread.join(timeout=5)
            if self.socket_path and os.path.exists(self.socket_path):
                try:
                    os.unlink(self.socket_path)
                except OSError:
                    pass
        self._pool.shutdown(wait=True)
        for src in self._sources.values():
            src.close()
        self.query_log.close()

    def __enter__(self) -> "DatasetServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
