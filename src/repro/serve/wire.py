"""Wire protocol for the dataset service: framing + JSON codecs.

Messages are length-prefixed JSON over a stream socket: a little-endian u32
byte count followed by the UTF-8 payload. Binary column data rides inside
the JSON as base64 (the service targets local AF_UNIX round trips, where
simplicity beats zero-copy; the in-process ``DatasetServer`` API skips this
layer entirely).

Predicates serialize structurally (one dict node per AST node), so a client
builds them with the normal ``C`` combinators and the server rehydrates an
identical tree — including the equality leaves the bloom sketches refute.
"""

from __future__ import annotations

import base64
import json
import socket
import struct
from typing import Optional

import numpy as np

from ..scan.predicate import And, Cmp, In, Not, Or, Predicate

_LEN = struct.Struct("<I")
MAX_MESSAGE = 1 << 30


# ---------------------------------------------------------------------------
# framing
# ---------------------------------------------------------------------------

def send_msg(sock: socket.socket, obj: dict) -> None:
    data = json.dumps(obj).encode()
    if len(data) > MAX_MESSAGE:
        # refuse to emit a frame the peer is contractually bound to reject
        # (and that would wrap the u32 length prefix past 4 GiB)
        raise ValueError(f"frame of {len(data)} bytes exceeds {MAX_MESSAGE}")
    sock.sendall(_LEN.pack(len(data)) + data)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    parts = []
    got = 0
    while got < n:
        chunk = sock.recv(n - got)
        if not chunk:
            return None                 # peer closed mid-frame (or EOF at 0)
        parts.append(chunk)
        got += len(chunk)
    return b"".join(parts)


def recv_msg(sock: socket.socket) -> Optional[dict]:
    """One framed message, or None on orderly EOF.

    Error contract (what the server's session loop and its query log key
    off): ``ValueError`` for an unparseable frame — oversized length
    prefix, or a body that is not valid JSON (``json.JSONDecodeError`` is
    a ``ValueError``) — and ``ConnectionError`` for a peer that vanished
    mid-frame. Both are session-fatal: the frame boundary is gone, so the
    caller must drop the connection (never the process)."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > MAX_MESSAGE:
        raise ValueError(f"frame of {n} bytes exceeds {MAX_MESSAGE}")
    body = _recv_exact(sock, n)
    if body is None:
        raise ConnectionError("peer closed mid-frame")
    msg = json.loads(body.decode())
    if not isinstance(msg, dict):
        raise ValueError(f"frame payload must be a JSON object, "
                         f"got {type(msg).__name__}")
    return msg


# ---------------------------------------------------------------------------
# predicates
# ---------------------------------------------------------------------------

def _scalar(v):
    """JSON-able python scalar from a predicate literal."""
    if isinstance(v, (np.generic,)):
        return v.item()
    return v


def encode_predicate(pred: Optional[Predicate]) -> Optional[dict]:
    if pred is None:
        return None
    if isinstance(pred, Cmp):
        return {"t": "cmp", "col": pred.col, "op": pred.op,
                "v": _scalar(pred.value)}
    if isinstance(pred, In):
        return {"t": "in", "col": pred.col,
                "v": [_scalar(v) for v in pred.values]}
    if isinstance(pred, And):
        return {"t": "and", "c": [encode_predicate(c) for c in pred.children]}
    if isinstance(pred, Or):
        return {"t": "or", "c": [encode_predicate(c) for c in pred.children]}
    if isinstance(pred, Not):
        return {"t": "not", "c": encode_predicate(pred.child)}
    raise TypeError(f"cannot serialize predicate node {type(pred).__name__}")


def decode_predicate(obj: Optional[dict]) -> Optional[Predicate]:
    if obj is None:
        return None
    t = obj["t"]
    if t == "cmp":
        return Cmp(obj["col"], obj["op"], obj["v"])
    if t == "in":
        return In(obj["col"], obj["v"])
    if t == "and":
        return And(*[decode_predicate(c) for c in obj["c"]])
    if t == "or":
        return Or(*[decode_predicate(c) for c in obj["c"]])
    if t == "not":
        return Not(decode_predicate(obj["c"]))
    raise ValueError(f"unknown predicate node type {t!r}")


# ---------------------------------------------------------------------------
# tables
# ---------------------------------------------------------------------------

def _b64(b) -> str:
    return base64.b64encode(bytes(b)).decode("ascii")


def _unb64(s: str) -> bytes:
    return base64.b64decode(s.encode("ascii"))


def encode_table(table: dict) -> dict:
    """Dataset result table -> JSON-able dict. Scalar columns are one
    base64 buffer; list columns one buffer per row; string columns base64
    the raw bytes per row."""
    out: dict = {}
    for name, col in table.items():
        if isinstance(col, np.ndarray):
            out[name] = {"kind": "array", "dtype": col.dtype.name,
                         "b64": _b64(np.ascontiguousarray(col).tobytes())}
        elif isinstance(col, list):
            if col and isinstance(col[0], np.ndarray):
                out[name] = {"kind": "list", "dtype": col[0].dtype.name,
                             "rows": [_b64(np.ascontiguousarray(r).tobytes())
                                      for r in col]}
            else:
                # bytes rows (string/media columns) — or an empty column,
                # which decodes to an empty list either way
                out[name] = {"kind": "bytes",
                             "rows": [_b64(r) for r in col]}
        else:
            raise TypeError(f"column {name!r}: cannot serialize "
                            f"{type(col).__name__}")
    return out


def decode_table(enc: dict) -> dict:
    out: dict = {}
    for name, col in enc.items():
        kind = col["kind"]
        if kind == "array":
            out[name] = np.frombuffer(_unb64(col["b64"]),
                                      dtype=np.dtype(col["dtype"]))
        elif kind == "list":
            dt = np.dtype(col["dtype"])
            out[name] = [np.frombuffer(_unb64(r), dtype=dt)
                         for r in col["rows"]]
        elif kind == "bytes":
            out[name] = [_unb64(r) for r in col["rows"]]
        else:
            raise ValueError(f"unknown column kind {kind!r}")
    return out
