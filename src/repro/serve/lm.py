"""Batched serving engine: prefill once, decode greedily with a jitted step."""

from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ServeEngine:
    model: object
    params: object
    max_seq: int

    def __post_init__(self):
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode_step)

    def generate(self, prompts: np.ndarray, max_new_tokens: int,
                 frames: Optional[np.ndarray] = None) -> dict:
        """prompts: int32[B, P] (right-aligned, no padding support needed for
        the fixed-length demo). Returns generated tokens + timing."""
        B, P = prompts.shape
        cache = self.model.init_cache(B, self.max_seq, dtype=jnp.float32)
        batch = {"tokens": jnp.asarray(prompts)}
        if frames is not None:
            batch["frames"] = jnp.asarray(frames)
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params, batch, cache)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out = np.zeros((B, max_new_tokens), np.int32)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        t0 = time.perf_counter()
        for i in range(max_new_tokens):
            out[:, i] = np.asarray(tok)[:, 0]
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0
        return {"tokens": out,
                "prefill_s": t_prefill,
                "decode_s": t_decode,
                "decode_tok_per_s": B * max_new_tokens / max(t_decode, 1e-9)}
