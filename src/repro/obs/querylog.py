"""Structured per-query records: the service's flight recorder.

Every query the serving stack executes — ``DatasetServer.query/submit``
and, when enabled, local ``Dataset`` terminals — appends one
``QueryRecord`` to a thread-safe bounded ``QueryLog``: who asked (tenant),
what ran (dataset, plan fingerprint, cache hit/miss), what it cost
(per-stage timings from a scoped tracer, the exact ``IOStats`` delta the
execution charged, row/byte counts), and how it ended (outcome ``"ok"`` or
``"error"`` + message). The log is the substrate ``server.stats()``
summaries, the ``bullion log`` CLI, and post-hoc debugging read from.

Environment knobs (read when a ``QueryLog`` is constructed):

* ``BULLION_QUERY_LOG=path`` — mirror every record to a JSONL sink (one
  JSON object per line, append-only) *and* enable local-run recording in
  ``Dataset._execute`` (the serve path always records into the server's
  bounded log; the sink is how a benchmark or training run leaves one).
* ``BULLION_SLOW_MS=n`` — slow-query threshold. The serve path runs each
  query under a scoped tracer when set, and any query slower than ``n``
  milliseconds gets its *full span list* promoted into the record, so the
  one query that blew the latency budget arrives with its own trace
  attached.

Stdlib-only (no repro imports) like the rest of ``repro.obs``: any layer
may record without cycles. ``IOStats`` deltas arrive as plain dicts
(``dataclasses.asdict``) for the same reason.
"""

from __future__ import annotations

import json
import os
import sys
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

from .trace import StageAgg, _arg_safe

_DEFAULT_CAPACITY = 256


def _env_sink() -> Optional[str]:
    path = os.environ.get("BULLION_QUERY_LOG")
    return path.strip() if path and path.strip() else None


def _env_slow_seconds() -> Optional[float]:
    env = os.environ.get("BULLION_SLOW_MS")
    if env is None or not env.strip():
        return None
    try:
        ms = float(env)
    except ValueError:
        raise ValueError(
            f"BULLION_SLOW_MS must be a millisecond threshold, "
            f"got {env!r}") from None
    if ms < 0:
        raise ValueError(f"BULLION_SLOW_MS must be >= 0, got {ms}")
    return ms / 1e3


def stage_dict(agg: dict[str, StageAgg]) -> dict:
    """Tracer aggregate -> plain JSON-able dict (per-stage call count,
    summed seconds, summed numeric args)."""
    return {name: {"calls": a.count, "seconds": a.seconds,
                   **{k: _arg_safe(v) for k, v in a.args.items()}}
            for name, a in agg.items()}


@dataclass
class QueryRecord:
    """One executed (or failed) query, fully structured."""

    ts: float                               # wall-clock epoch seconds
    origin: str                             # "serve" | "local" | "serve.wire"
    dataset: str
    tenant: str = "default"
    fingerprint: Optional[str] = None       # LogicalPlan.fingerprint()
    cache_hit: Optional[bool] = None        # prepared-plan cache (serve only)
    columns: Optional[list] = None
    predicate: Optional[str] = None         # repr of the predicate, if any
    rows: int = 0                           # rows returned
    result_bytes: int = 0                   # payload bytes returned
    wall_seconds: float = 0.0
    outcome: str = "ok"                     # "ok" | "error"
    error: Optional[str] = None
    degraded: bool = False                  # quarantined pages dropped/masked
                                            # rows (io["degraded_rows"] > 0)
    io: Optional[dict] = None               # exact IOStats delta (asdict)
    stages: Optional[dict] = None           # scoped-tracer aggregate
    trace_id: Optional[str] = None          # wire-propagated trace id
    dropped_spans: int = 0
    slow: bool = False                      # crossed BULLION_SLOW_MS
    spans: Optional[list] = field(default=None, repr=False)  # promoted tree

    def to_dict(self) -> dict:
        d = {k: getattr(self, k) for k in self.__dataclass_fields__}
        return d

    def __repr__(self) -> str:
        tail = "" if self.outcome == "ok" else f" error={self.error!r}"
        return (f"QueryRecord({self.origin} {self.dataset!r} "
                f"rows={self.rows} wall={self.wall_seconds * 1e3:.3f}ms "
                f"outcome={self.outcome}{tail})")


class QueryLog:
    """Thread-safe bounded ring of ``QueryRecord`` + optional JSONL sink.

    Appends are one lock + one deque push; the sink (when configured)
    appends one JSON line per record under the same lock, so lines from
    concurrent sessions never interleave. Sink failures are reported once
    to stderr and disable the sink — telemetry must never fail a query.
    """

    def __init__(self, capacity: int = _DEFAULT_CAPACITY, *,
                 sink_path: Optional[str] = None,
                 slow_seconds: Optional[float] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.sink_path = _env_sink() if sink_path is None else sink_path
        self.slow_seconds = _env_slow_seconds() \
            if slow_seconds is None else slow_seconds
        self._recs: "deque[QueryRecord]" = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self._sink = None
        self._sink_failed = False
        self.total = 0               # records ever appended (ring evicts)
        self.errors = 0
        self.slow = 0
        self.degraded = 0            # records that dropped/masked rows

    def append(self, rec: QueryRecord) -> QueryRecord:
        if self.slow_seconds is not None \
                and rec.wall_seconds >= self.slow_seconds:
            rec.slow = True
        with self._lock:
            self._recs.append(rec)
            self.total += 1
            if rec.outcome != "ok":
                self.errors += 1
            if rec.slow:
                self.slow += 1
            if rec.degraded:
                self.degraded += 1
            self._sink_write(rec)
        return rec

    def _sink_write(self, rec: QueryRecord) -> None:
        if self.sink_path is None or self._sink_failed:
            return
        try:
            if self._sink is None:
                self._sink = open(self.sink_path, "a")
            json.dump(rec.to_dict(), self._sink)
            self._sink.write("\n")
            self._sink.flush()
        except OSError as e:
            self._sink_failed = True
            print(f"bullion: query-log sink {self.sink_path!r} failed: {e}",
                  file=sys.stderr)

    def records(self) -> list[QueryRecord]:
        """Snapshot, oldest first."""
        with self._lock:
            return list(self._recs)

    def tail(self, n: int = 20) -> list[QueryRecord]:
        with self._lock:
            return list(self._recs)[-max(0, int(n)):]

    def clear(self) -> None:
        with self._lock:
            self._recs.clear()

    def summary(self) -> dict:
        """Folded view for ``server.stats()``: totals plus a per-dataset
        breakdown of the records still in the ring."""
        with self._lock:
            recs = list(self._recs)
            total, errors, slow = self.total, self.errors, self.slow
            degraded = self.degraded
        by_ds: dict[str, dict] = {}
        for r in recs:
            d = by_ds.setdefault(r.dataset, {"queries": 0, "errors": 0,
                                             "degraded": 0, "rows": 0,
                                             "wall_seconds": 0.0})
            d["queries"] += 1
            d["rows"] += r.rows
            d["wall_seconds"] += r.wall_seconds
            if r.outcome != "ok":
                d["errors"] += 1
            if r.degraded:
                d["degraded"] += 1
        return {"total": total, "errors": errors, "slow": slow,
                "degraded": degraded, "retained": len(recs),
                "capacity": self.capacity, "by_dataset": by_ds}

    def close(self) -> None:
        with self._lock:
            if self._sink is not None:
                try:
                    self._sink.close()
                except OSError:
                    pass
                self._sink = None

    def __len__(self) -> int:
        with self._lock:
            return len(self._recs)


# ---------------------------------------------------------------------------
# the process-wide log local Dataset terminals record into
# ---------------------------------------------------------------------------

LOG = QueryLog()

_local = False


def enable_local(on: bool = True) -> None:
    """Turn local-run recording (``Dataset._execute``) on without the
    ``BULLION_QUERY_LOG`` env (records stay in the in-process ring)."""
    global _local
    _local = on


def local_enabled() -> bool:
    """Should local ``Dataset`` terminals record? True when a JSONL sink
    is configured or recording was enabled programmatically — the default
    (both off) keeps the local hot path record-free."""
    return _local or LOG.sink_path is not None


def now() -> float:
    return time.time()
