"""Chrome ``trace_event`` export: spans -> Perfetto-loadable JSON.

The JSON object format (``{"traceEvents": [...]}``) with complete events
(``"ph": "X"``, microsecond ``ts``/``dur``) is what Perfetto and
chrome://tracing both load directly; nesting is inferred from timestamp
containment per thread, so the tracer needs no explicit parent ids.
Thread-name metadata events give the scheduler / loader / scan-pool
threads readable track names.
"""

from __future__ import annotations

import json
import os
from typing import Optional, Sequence

from .trace import SpanRecord, Tracer


def _json_safe(v):
    """Span args may carry numpy scalars; coerce without importing numpy
    (obs stays dependency-free)."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    for cast in (int, float):
        try:
            c = cast(v)
        except (TypeError, ValueError):
            continue
        if c == v:          # int() must not truncate a fractional scalar
            return c
    return str(v)


def chrome_trace(spans: Sequence[SpanRecord], *, pid: Optional[int] = None,
                 dropped: int = 0, trace_id: Optional[str] = None) -> dict:
    """Render finished spans as a Chrome trace_event JSON object.
    ``trace_id`` stamps the wire-propagated id on the document (the
    merged client+server profile carries exactly one)."""
    pid = os.getpid() if pid is None else pid
    events: list[dict] = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": "bullion"},
    }]
    named: set[int] = set()
    for s in spans:
        if s.tid not in named:
            named.add(s.tid)
            events.append({"ph": "M", "name": "thread_name",
                           "pid": pid, "tid": s.tid,
                           "args": {"name": s.tname}})
        events.append({
            "ph": "X", "name": s.name, "cat": s.cat,
            "ts": round(s.ts * 1e6, 3), "dur": round(s.dur * 1e6, 3),
            "pid": pid, "tid": s.tid,
            "args": {k: _json_safe(v) for k, v in s.args.items()},
        })
    out = {"traceEvents": events, "displayTimeUnit": "ms"}
    if dropped:
        out["bullionDroppedSpans"] = int(dropped)
    if trace_id is not None:
        out["bullionTraceId"] = trace_id
    return out


def write_trace(path: str, spans: Sequence[SpanRecord], *,
                dropped: int = 0, trace_id: Optional[str] = None) -> str:
    """Write ``spans`` as one Chrome trace JSON file; returns ``path``."""
    doc = chrome_trace(spans, dropped=dropped, trace_id=trace_id)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f)
    os.replace(tmp, path)   # a killed export never leaves a torn JSON
    return path


class Profile:
    """What ``Dataset.profile()`` / ``ServeClient.profile()`` return: the
    collected spans plus the rendered Chrome trace, with a one-call file
    export."""

    def __init__(self, tracer: Optional[Tracer] = None, *,
                 spans: Optional[Sequence[SpanRecord]] = None,
                 dropped: int = 0, trace_id: Optional[str] = None):
        self.spans = list(tracer.spans if tracer is not None
                          else (spans or []))
        self.dropped = (tracer.dropped if tracer is not None else 0) + dropped
        self.trace_id = trace_id

    @classmethod
    def from_spans(cls, spans: Sequence[SpanRecord], *, dropped: int = 0,
                   trace_id: Optional[str] = None) -> "Profile":
        """Build a profile from a bare span list (e.g. client + server
        spans merged after wire propagation)."""
        return cls(spans=spans, dropped=dropped, trace_id=trace_id)

    @property
    def chrome(self) -> dict:
        return chrome_trace(self.spans, dropped=self.dropped,
                            trace_id=self.trace_id)

    def aggregate(self):
        from .trace import aggregate_spans
        return aggregate_spans(self.spans)

    def write(self, path: str) -> str:
        return write_trace(path, self.spans, dropped=self.dropped,
                           trace_id=self.trace_id)

    def __repr__(self) -> str:
        return f"Profile({len(self.spans)} span(s), dropped={self.dropped})"
