"""Span tracing: the observability core (stdlib-only, no repro imports).

One process-wide tracer slot drives every instrumentation point in the
read/write stack (``plan.optimize``/``lower``, ``Scanner.plan``, the
``IOScheduler``, ``decode_group``'s stages, the sink, the loader). The
contract the hot paths rely on:

* **disabled is free** — with no tracer installed, ``span()`` returns one
  shared no-op context manager and allocates no ``Span`` object at all.
  ``allocations()`` counts every real span ever created, so tests assert
  the disabled hot path stays span-allocation-free; the bench_io wide
  probe gates the wall-clock overhead (< 2%).
* **enabled is thread-safe** — finished spans append to the tracer's list
  under a lock; spans started on scheduler/loader/pool threads record on
  whatever thread finishes them (the span holds its own tracer reference,
  so uninstalling mid-span is safe).
* **scopes nest** — ``collect()`` installs a fresh tracer for its block and
  *forwards* every finished span to the tracer it shadowed, so a scoped
  ``explain(analyze=True)`` or ``Dataset.profile()`` never hides events
  from a process-wide ``BULLION_TRACE`` recording.
* **``BULLION_TRACE=path``** enables a process-wide tracer when
  ``repro.obs`` first loads and writes a Chrome ``trace_event`` JSON
  (loadable in Perfetto / chrome://tracing) at interpreter exit.
  ``BULLION_TRACE_CAP`` bounds the buffer (default 200k spans; overflow is
  counted, never an error).
"""

from __future__ import annotations

import atexit
import functools
import os
import sys
import threading
import time
from typing import Callable, Optional

# all trace timestamps are seconds relative to this module's load instant —
# a monotonic zero shared by every thread in the process. The wall-clock
# instant of the same zero lets two processes exchange spans on a shared
# (wall) timebase: rel -> wall is `ts + _EPOCH_WALL`, wall -> rel is
# `ts - _EPOCH_WALL` in the receiving process.
_EPOCH = time.perf_counter()
_EPOCH_WALL = time.time()

_DEFAULT_CAP = 200_000


def _default_cap() -> int:
    env = os.environ.get("BULLION_TRACE_CAP")
    if env is None or not env.strip():
        return _DEFAULT_CAP
    try:
        cap = int(env)
    except ValueError:
        raise ValueError(
            f"BULLION_TRACE_CAP must be an integer span count, "
            f"got {env!r}") from None
    if cap <= 0:
        raise ValueError(f"BULLION_TRACE_CAP must be positive, got {cap}")
    return cap


class SpanRecord:
    """One finished span: what the exporters and aggregators consume."""

    __slots__ = ("name", "cat", "ts", "dur", "tid", "tname", "args")

    def __init__(self, name: str, cat: str, ts: float, dur: float,
                 tid: int, tname: str, args: dict):
        self.name = name
        self.cat = cat
        self.ts = ts            # seconds since _EPOCH
        self.dur = dur          # seconds
        self.tid = tid
        self.tname = tname
        self.args = args

    def __repr__(self) -> str:
        return (f"SpanRecord({self.name!r}, dur={self.dur * 1e3:.3f}ms, "
                f"args={self.args})")


def _arg_safe(v):
    """JSON-able coercion for span args (numpy scalars included) without
    importing numpy — same contract as the exporter's coercion."""
    if isinstance(v, (bool, int, float, str)) or v is None:
        return v
    for cast in (int, float):
        try:
            c = cast(v)
        except (TypeError, ValueError):
            continue
        if c == v:
            return c
    return str(v)


def span_to_dict(rec: SpanRecord, *, wall: bool = False) -> dict:
    """JSON-able dict form of a finished span (the wire / query-log
    representation). ``wall=True`` converts the timestamp to wall-clock
    epoch seconds so a peer process can rebase it into its own timebase."""
    return {"name": rec.name, "cat": rec.cat,
            "ts": rec.ts + _EPOCH_WALL if wall else rec.ts,
            "dur": rec.dur, "tid": rec.tid, "tname": rec.tname,
            "args": {k: _arg_safe(v) for k, v in rec.args.items()}}


def span_from_dict(d: dict, *, wall: bool = False) -> SpanRecord:
    """Inverse of ``span_to_dict``; with ``wall=True`` the incoming
    timestamp is wall-clock and is rebased to this process's epoch."""
    ts = float(d["ts"])
    if wall:
        ts -= _EPOCH_WALL
    return SpanRecord(d["name"], d.get("cat", "bullion"), ts,
                      float(d["dur"]), int(d.get("tid", 0)),
                      d.get("tname", ""), dict(d.get("args") or {}))


class _NullSpan:
    """The shared disabled-mode span: enter/exit/set are no-ops. One
    instance serves every call site (re-entrant: it holds no state)."""

    __slots__ = ()
    enabled = False

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **kw) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()

# every real Span ever constructed bumps this (the disabled-mode
# zero-allocation assertion reads it before/after a scan)
_allocations = 0
_alloc_lock = threading.Lock()


def allocations() -> int:
    """Total real ``Span`` objects created since process start."""
    return _allocations


class Span:
    """A live span: context manager recording wall time on exit."""

    __slots__ = ("_tracer", "name", "cat", "args", "_t0")
    enabled = True

    def __init__(self, tracer: "Tracer", name: str, cat: str, args: dict):
        global _allocations
        with _alloc_lock:
            _allocations += 1
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self._t0 = 0.0

    def set(self, **kw) -> "Span":
        """Attach attributes mid-span (guard expensive computation with
        ``if sp.enabled:`` — the null span's class attribute is False)."""
        self.args.update(kw)
        return self

    def __enter__(self) -> "Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        th = threading.current_thread()
        self._tracer._record(SpanRecord(
            self.name, self.cat, self._t0 - _EPOCH, t1 - self._t0,
            th.ident or 0, th.name, self.args))
        return False


class StageAgg:
    """Aggregated view of one span name: call count, total seconds, and the
    numeric args summed across calls (bytes, pages, rows, ...)."""

    __slots__ = ("count", "seconds", "args")

    def __init__(self):
        self.count = 0
        self.seconds = 0.0
        self.args: dict = {}

    def __repr__(self) -> str:
        return (f"StageAgg(count={self.count}, "
                f"seconds={self.seconds:.6f}, args={self.args})")


class Tracer:
    """Thread-safe span collector with a bounded buffer.

    ``forward`` chains finished spans to an enclosing tracer (how nested
    ``collect()`` scopes coexist with a process-wide ``BULLION_TRACE``
    recording without stealing its events).
    """

    def __init__(self, *, max_spans: Optional[int] = None,
                 forward: Optional["Tracer"] = None):
        self.max_spans = _default_cap() if max_spans is None else int(max_spans)
        self.spans: list[SpanRecord] = []
        self.dropped = 0
        self._forward = forward
        self._lock = threading.Lock()

    def span(self, name: str, cat: str = "bullion",
             args: Optional[dict] = None) -> Span:
        return Span(self, name, cat, {} if args is None else args)

    def _record(self, rec: SpanRecord) -> None:
        with self._lock:
            if len(self.spans) < self.max_spans:
                self.spans.append(rec)
            else:
                self.dropped += 1
        if self._forward is not None:
            self._forward._record(rec)

    def aggregate(self) -> dict[str, StageAgg]:
        """Per-name totals (thread-safe snapshot): count, summed seconds,
        summed numeric args. Parallel stages can sum past wall clock —
        the totals are CPU-side time across threads."""
        with self._lock:
            spans = list(self.spans)
        return aggregate_spans(spans)


def aggregate_spans(spans) -> dict[str, StageAgg]:
    """Per-name totals over any span sequence (list or ``Tracer.spans``
    snapshot): count, summed seconds, summed numeric args."""
    out: dict[str, StageAgg] = {}
    for s in spans:
        agg = out.get(s.name)
        if agg is None:
            agg = out[s.name] = StageAgg()
        agg.count += 1
        agg.seconds += s.dur
        for k, v in s.args.items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            agg.args[k] = agg.args.get(k, 0) + v
    return out


# ---------------------------------------------------------------------------
# the process-wide tracer slot
# ---------------------------------------------------------------------------

_tracer: Optional[Tracer] = None


def enabled() -> bool:
    """Is any tracer installed? (One global read — safe on hot paths.)"""
    return _tracer is not None


def current() -> Optional[Tracer]:
    return _tracer


def install(tracer: Optional[Tracer]) -> None:
    """Set (or, with None, clear) the process-wide tracer."""
    global _tracer
    _tracer = tracer


def enable(*, max_spans: Optional[int] = None) -> Tracer:
    """Install and return a fresh process-wide tracer."""
    t = Tracer(max_spans=max_spans)
    install(t)
    return t


def disable() -> Optional[Tracer]:
    """Uninstall the tracer (span() reverts to the free no-op path).
    Returns the tracer that was installed, spans intact."""
    t = _tracer
    install(None)
    return t


def span(name: str, cat: str = "bullion", **args):
    """Start a span on the installed tracer — the one call every
    instrumentation point uses. Disabled: returns the shared no-op span
    (no Span allocation; the kwargs dict is the only cost)."""
    t = _tracer
    if t is None:
        return NULL_SPAN
    return t.span(name, cat, args)


class collect:
    """``with collect() as tr:`` — scoped tracing. Installs a fresh tracer
    for the block (forwarding to whatever it shadowed) and restores the
    previous tracer on exit; ``tr.spans`` holds the block's spans."""

    def __init__(self, *, max_spans: Optional[int] = None):
        self._max_spans = max_spans
        self._prev: Optional[Tracer] = None
        self.tracer: Optional[Tracer] = None

    def __enter__(self) -> Tracer:
        self._prev = _tracer
        self.tracer = Tracer(max_spans=self._max_spans, forward=self._prev)
        install(self.tracer)
        return self.tracer

    def __exit__(self, *exc) -> bool:
        install(self._prev)
        return False


def traced(name: Optional[str] = None, cat: str = "bullion") -> Callable:
    """Decorator form: ``@traced()`` wraps the function body in a span named
    after it (or ``name``). Disabled mode calls the function directly."""
    def deco(fn: Callable) -> Callable:
        label = name or fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*a, **kw):
            t = _tracer
            if t is None:
                return fn(*a, **kw)
            with t.span(label, cat):
                return fn(*a, **kw)
        return wrapper
    return deco


# ---------------------------------------------------------------------------
# BULLION_TRACE: process-wide recording -> Chrome trace JSON at exit
# ---------------------------------------------------------------------------

_env_tracer: Optional[Tracer] = None
_env_path: Optional[str] = None


def _write_env_trace() -> None:
    if _env_tracer is None or _env_path is None:
        return
    from .export import write_trace
    try:
        write_trace(_env_path, _env_tracer.spans,
                    dropped=_env_tracer.dropped)
    except Exception as e:  # never fail interpreter shutdown
        print(f"bullion: BULLION_TRACE export to {_env_path!r} failed: {e}",
              file=sys.stderr)


def init_from_env() -> Optional[Tracer]:
    """Honor ``BULLION_TRACE=path``: enable a process-wide tracer and
    register the exit-time Chrome trace export. Idempotent; called when
    ``repro.obs`` first imports."""
    global _env_tracer, _env_path
    path = os.environ.get("BULLION_TRACE")
    if not path or not path.strip() or _env_tracer is not None:
        return _env_tracer
    _env_path = path.strip()
    _env_tracer = enable()
    atexit.register(_write_env_trace)
    return _env_tracer
