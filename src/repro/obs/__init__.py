"""Observability substrate: span tracing, metrics, Perfetto trace export.

The shared instrumentation layer under the whole scan/I-O/decode pipeline
(and the substrate the serve/cloud-backend roadmap items report through).
Three pieces, all stdlib-only with no repro imports (any layer — ``core``
included — may depend on it without cycles):

* ``trace`` — a ``Span`` tracer with context-manager/decorator API and a
  process-wide slot. Disabled (the default) it is a no-op that allocates
  nothing on the hot path; ``collect()`` scopes a tracer to a block
  (forwarding to any enclosing recording), ``BULLION_TRACE=path`` records
  process-wide and exports Chrome trace JSON at exit.
* ``metrics`` — a process-wide ``MetricsRegistry`` of named counters and
  log-scale histograms (pread latency, coalesced-run sizes, queue depth,
  per-encoding-family page decode time). Counters absorb ``IOStats`` when
  reader accounting retires; timing histograms follow ``trace.enabled()``.
* ``export`` — Chrome ``trace_event`` rendering (``chrome_trace`` /
  ``write_trace``) viewable in Perfetto, plus the ``Profile`` object
  ``Dataset.profile()`` returns.

Entry points most callers want::

    from repro.obs import trace, metrics

    with trace.collect() as tr:          # scoped tracing
        ...                              # any Dataset/loader/sink work
    print(tr.aggregate())                # per-stage totals
    print(metrics.snapshot())            # process-wide counters/histograms
"""

from . import metrics, trace
from .export import Profile, chrome_trace, write_trace
from .metrics import (Counter, Histogram, MetricsRegistry, REGISTRY,
                      absorb_iostats, counter, histogram, snapshot)
from .trace import (NULL_SPAN, Span, SpanRecord, StageAgg, Tracer, collect,
                    disable, enable, enabled, install, span, traced)

# honor BULLION_TRACE=path as soon as the first instrumented module loads
trace.init_from_env()

__all__ = [
    "trace", "metrics",
    "Span", "SpanRecord", "StageAgg", "Tracer", "NULL_SPAN",
    "span", "collect", "traced", "enable", "disable", "enabled", "install",
    "Counter", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "histogram", "snapshot", "absorb_iostats",
    "Profile", "chrome_trace", "write_trace",
]
