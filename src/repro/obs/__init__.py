"""Observability substrate: span tracing, metrics, Perfetto trace export.

The shared instrumentation layer under the whole scan/I-O/decode pipeline
(and the substrate the serve/cloud-backend roadmap items report through).
Three pieces, all stdlib-only with no repro imports (any layer — ``core``
included — may depend on it without cycles):

* ``trace`` — a ``Span`` tracer with context-manager/decorator API and a
  process-wide slot. Disabled (the default) it is a no-op that allocates
  nothing on the hot path; ``collect()`` scopes a tracer to a block
  (forwarding to any enclosing recording), ``BULLION_TRACE=path`` records
  process-wide and exports Chrome trace JSON at exit.
* ``metrics`` — a process-wide ``MetricsRegistry`` of named counters and
  log-scale histograms (pread latency, coalesced-run sizes, queue depth,
  per-encoding-family page decode time). Counters absorb ``IOStats`` when
  reader accounting retires; timing histograms follow ``trace.enabled()``.
* ``export`` — Chrome ``trace_event`` rendering (``chrome_trace`` /
  ``write_trace``) viewable in Perfetto, plus the ``Profile`` object
  ``Dataset.profile()`` / ``ServeClient.profile()`` return.
* ``querylog`` — thread-safe bounded ``QueryLog`` of structured per-query
  records (tenant, fingerprint, stage timings, exact ``IOStats`` delta,
  outcome), fed by the serve path and — under ``BULLION_QUERY_LOG=path``
  (JSONL sink) — by local ``Dataset`` terminals; ``BULLION_SLOW_MS``
  promotes slow queries' full span lists into their records.
* ``expose`` — the registry snapshot rendered as Prometheus text format
  (``DatasetServer.metrics_text()`` / the ``metrics`` wire command).

Entry points most callers want::

    from repro.obs import trace, metrics

    with trace.collect() as tr:          # scoped tracing
        ...                              # any Dataset/loader/sink work
    print(tr.aggregate())                # per-stage totals
    print(metrics.snapshot())            # process-wide counters/histograms
"""

from . import expose, metrics, querylog, trace
from .export import Profile, chrome_trace, write_trace
from .expose import parse_prometheus_text, prometheus_text
from .metrics import (Counter, Histogram, MetricsRegistry, REGISTRY,
                      absorb_iostats, counter, histogram, snapshot)
from .querylog import QueryLog, QueryRecord
from .trace import (NULL_SPAN, Span, SpanRecord, StageAgg, Tracer,
                    aggregate_spans, collect, disable, enable, enabled,
                    install, span, span_from_dict, span_to_dict, traced)

# honor BULLION_TRACE=path as soon as the first instrumented module loads
trace.init_from_env()

__all__ = [
    "trace", "metrics", "querylog", "expose",
    "Span", "SpanRecord", "StageAgg", "Tracer", "NULL_SPAN",
    "span", "collect", "traced", "enable", "disable", "enabled", "install",
    "span_to_dict", "span_from_dict", "aggregate_spans",
    "Counter", "Histogram", "MetricsRegistry", "REGISTRY",
    "counter", "histogram", "snapshot", "absorb_iostats",
    "QueryLog", "QueryRecord",
    "prometheus_text", "parse_prometheus_text",
    "Profile", "chrome_trace", "write_trace",
]
