"""Process-wide metrics: named counters and log-scale histograms.

The registry is the always-on half of the observability layer (spans are
the opt-in half): counters are one lock + one add, histograms bucket on a
power-of-two scale via ``math.frexp`` so a latency or size distribution
costs O(60) ints however many observations land in it. The conventions the
instrumented stack follows:

* **counters are always cheap enough to leave on** — preads, bytes,
  cache hits absorbed in bulk from ``IOStats`` at reader-retire time
  (``absorb_iostats``), run sizes observed once per coalesced submission;
* **timing histograms record only while tracing is enabled** — wrapping
  every ``os.pread`` in two ``perf_counter`` calls is not free, so the
  per-call latency distributions (``bullion.io.pread_seconds``, per-family
  page decode time) follow ``trace.enabled()``; with tracing off the hot
  path pays one global read.

Names are dotted lowercase (``bullion.io.pread_seconds``); the per-family
decode histograms append the ``PageType`` name
(``bullion.decode.page_seconds.scalar``). ``snapshot()`` renders the whole
registry as plain dicts for printing or shipping.
"""

from __future__ import annotations

import dataclasses
import math
import threading
from typing import Optional, Union

Number = Union[int, float]


class Counter:
    """Monotonic named counter (float-tolerant: second-counters absorb
    ``IOStats.metadata_seconds`` too)."""

    __slots__ = ("name", "_v", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._v: Number = 0
        self._lock = threading.Lock()

    def inc(self, n: Number = 1) -> None:
        with self._lock:
            self._v += n

    @property
    def value(self) -> Number:
        return self._v

    def __repr__(self) -> str:
        return f"Counter({self.name!r}, {self._v})"


class Histogram:
    """Log-scale (power-of-two) histogram.

    ``observe(v)`` lands ``v`` in the bucket whose upper bound is the
    smallest power of two >= v (``frexp`` exponent), so one histogram
    covers nanoseconds to hours / bytes to gigabytes with ~60 buckets and
    no configuration. Zero and negatives fall into a dedicated underflow
    bucket (upper bound 0).
    """

    __slots__ = ("name", "count", "sum", "min", "max", "_buckets", "_lock")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.sum = 0.0
        self.min: Optional[Number] = None
        self.max: Optional[Number] = None
        self._buckets: dict[Optional[int], int] = {}   # exponent -> count
        self._lock = threading.Lock()

    @staticmethod
    def _bucket(v: Number) -> Optional[int]:
        if v <= 0:
            return None                       # underflow bucket
        m, e = math.frexp(v)                  # v = m * 2**e, 0.5 <= m < 1
        return e                              # upper bound 2**e >= v

    def observe(self, v: Number) -> None:
        b = self._bucket(v)
        with self._lock:
            self.count += 1
            self.sum += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            self._buckets[b] = self._buckets.get(b, 0) + 1

    def percentile(self, p: float) -> float:
        """Upper-bound estimate of the p-th percentile (0 < p <= 100):
        the upper edge of the bucket holding that rank."""
        with self._lock:
            if not self.count:
                return 0.0
            rank = max(1, math.ceil(self.count * p / 100.0))
            items = sorted(((e if e is not None else -10**6), n)
                           for e, n in self._buckets.items())
        seen = 0
        for e, n in items:
            seen += n
            if seen >= rank:
                return 0.0 if e == -10**6 else float(2.0 ** e)
        return float(2.0 ** items[-1][0])

    def buckets(self) -> dict[float, int]:
        """{upper_bound: count} with 0.0 for the underflow bucket."""
        with self._lock:
            return {(0.0 if e is None else float(2.0 ** e)): n
                    for e, n in sorted(self._buckets.items(),
                                       key=lambda kv: (-1 if kv[0] is None
                                                       else kv[0]))}

    def __repr__(self) -> str:
        return (f"Histogram({self.name!r}, count={self.count}, "
                f"sum={self.sum:.6g}, min={self.min}, max={self.max})")


class MetricsRegistry:
    """Named counters + histograms, get-or-create, thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._hists: dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def histogram(self, name: str) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(name, Histogram(name))
        return h

    def snapshot(self) -> dict:
        """Plain-dict view of everything: counters as numbers, histograms
        as {count, sum, min, max, p50, p99, buckets}."""
        with self._lock:
            counters = dict(self._counters)
            hists = dict(self._hists)
        out: dict = {}
        for name, c in sorted(counters.items()):
            out[name] = c.value
        for name, h in sorted(hists.items()):
            out[name] = {"count": h.count, "sum": h.sum,
                         "min": h.min, "max": h.max,
                         "p50": h.percentile(50), "p99": h.percentile(99),
                         "buckets": h.buckets()}
        return out

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._hists.clear()


# the process-wide registry every instrumentation point reports through
REGISTRY = MetricsRegistry()


def counter(name: str) -> Counter:
    return REGISTRY.counter(name)


def histogram(name: str) -> Histogram:
    return REGISTRY.histogram(name)


def snapshot() -> dict:
    return REGISTRY.snapshot()


def absorb_iostats(stats, *, prefix: str = "bullion.io.",
                   registry: Optional[MetricsRegistry] = None) -> None:
    """Fold one ``IOStats`` (any dataclass of numeric fields) into the
    registry's counters, one counter per field. Called when a reader's
    accounting retires (``DataSource``), so the registry supersedes ad-hoc
    cross-dataset aggregation without touching the per-scan hot path."""
    reg = REGISTRY if registry is None else registry
    for f in dataclasses.fields(stats):
        v = getattr(stats, f.name)
        if v:
            reg.counter(prefix + f.name).inc(v)
