"""Metrics exposition: registry snapshot -> Prometheus text format.

Renders the process-wide ``MetricsRegistry`` in the Prometheus text
exposition format (version 0.0.4): counters as ``counter`` metrics,
log-scale histograms as ``summary`` metrics carrying the p50/p99 quantile
estimates plus ``_sum``/``_count`` — exactly what the registry's
``snapshot()`` already computes, no extra locking or bucket walks on the
hot path. Dotted metric names (``bullion.io.preads``) become underscored
(``bullion_io_preads``) per Prometheus naming rules.

Served by ``DatasetServer.metrics_text()`` / the ``metrics`` wire command
and pretty-printed by ``bullion metrics``; scraping it from a sidecar is
one HTTP handler away.
"""

from __future__ import annotations

import re
from typing import Optional

from . import metrics as _metrics

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def sanitize_name(name: str) -> str:
    """Prometheus metric name: [a-zA-Z_:][a-zA-Z0-9_:]*."""
    out = _NAME_RE.sub("_", name)
    if not out or out[0].isdigit():
        out = "_" + out
    return out


def _fmt(v) -> str:
    if v is None:
        return "NaN"
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, int):
        return str(v)
    return repr(float(v))


def prometheus_text(snapshot: Optional[dict] = None) -> str:
    """Render a registry snapshot (default: the process registry) as
    Prometheus text exposition format. Deterministic order (snapshot is
    name-sorted); ends with a newline as the format requires."""
    snap = _metrics.snapshot() if snapshot is None else snapshot
    lines: list[str] = []
    for name, v in snap.items():
        pname = sanitize_name(name)
        if isinstance(v, dict):
            # histogram snapshot -> summary metric with quantile estimates
            lines.append(f"# TYPE {pname} summary")
            lines.append(f'{pname}{{quantile="0.5"}} {_fmt(v.get("p50"))}')
            lines.append(f'{pname}{{quantile="0.99"}} {_fmt(v.get("p99"))}')
            lines.append(f"{pname}_sum {_fmt(v.get('sum', 0.0))}")
            lines.append(f"{pname}_count {_fmt(v.get('count', 0))}")
        else:
            lines.append(f"# TYPE {pname} counter")
            lines.append(f"{pname} {_fmt(v)}")
    return "\n".join(lines) + "\n" if lines else ""


# one line of the text format: HELP/TYPE comment, or `name{labels} value`
_SAMPLE_RE = re.compile(
    r"^[a-zA-Z_:][a-zA-Z0-9_:]*"                       # metric name
    r"(\{[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\""            # optional label set
    r"(,[a-zA-Z_][a-zA-Z0-9_]*=\"[^\"]*\")*\})?"
    r" [^ ]+$")                                        # value


def parse_prometheus_text(text: str) -> dict[str, float]:
    """Strict parse of the exposition format back into {sample: value}
    (labels kept verbatim in the key). Raises ``ValueError`` on any line
    that is neither a comment nor a well-formed sample — the regression
    test for ``metrics_text()`` round-trips through this."""
    out: dict[str, float] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip() or line.startswith("#"):
            continue
        if not _SAMPLE_RE.match(line):
            raise ValueError(f"line {ln}: not Prometheus text format: "
                             f"{line!r}")
        key, val = line.rsplit(" ", 1)
        out[key] = float(val)
    return out
